#!/usr/bin/env python3
"""Bench-gate: validate freshly produced BENCH_*.json files against the
committed baselines' schemas.

CI runs the quick-mode benches (which overwrite the BENCH_*.json files
in place at the repo root) and then calls this script with the committed
copies saved aside::

    python3 tools/bench_check.py \
        --baseline-dir ci-baseline --fresh-dir . \
        BENCH_migration.json BENCH_cluster.json BENCH_lifecycle.json

Hard failures (exit 1 — schema drift):
  * fresh file missing, unparsable, or not produced by the same suite;
  * fresh series empty, or rows missing keys the baseline promises
    (either the placeholder's ``schema.series[]`` spec or, once a
    measured baseline is committed, the keys of its first series row);
  * NaN/Infinity anywhere, negative counts/sizes, rates, occupancies,
    or availabilities outside [0, 1], p50 > p99, or all-zero metric
    rows (a silently-dead metric must fail, not pass vacuously).

Perf deltas stay advisory: when the baseline carries measured rows, the
script prints per-row latency deltas (and writes them to
``$GITHUB_STEP_SUMMARY`` when set) without failing the job.

stdlib-only by design — the CI image has no pip.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

FAILURES: list[str] = []
SUMMARY_LINES: list[str] = []


def fail(msg: str) -> None:
    FAILURES.append(msg)
    print(f"SCHEMA-DRIFT: {msg}", file=sys.stderr)


def note(msg: str) -> None:
    SUMMARY_LINES.append(msg)
    print(msg)


def load_json(path: str, *, required: bool):
    if not os.path.exists(path):
        if required:
            fail(f"{path}: file missing")
        return None
    try:
        with open(path, encoding="utf-8") as fh:
            # reject NaN/Infinity tokens outright: the Rust writer never
            # emits them, so their presence means a broken metric
            return json.load(fh, parse_constant=lambda c: fail(f"{path}: non-finite constant {c}"))
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        fail(f"{path}: unparsable JSON ({e})")
        return None


def expected_row_keys(baseline: dict, path: str) -> set[str] | None:
    """The keys every fresh series row must carry, from the committed
    baseline: a placeholder documents them under schema."series[]"; a
    measured baseline shows them in its first series row."""
    schema = baseline.get("schema")
    if isinstance(schema, dict):
        spec = schema.get("series[]")
        if isinstance(spec, dict) and spec:
            return set(spec.keys())
    series = baseline.get("series")
    if isinstance(series, list) and series and isinstance(series[0], dict):
        return set(series[0].keys())
    note(f"  {path}: baseline declares no series schema; key check skipped")
    return None


def check_value(path: str, row_id: str, key: str, value) -> None:
    if isinstance(value, bool) or value is None:
        return
    if isinstance(value, float) and not math.isfinite(value):
        fail(f"{path}: {row_id}.{key} is non-finite ({value})")
        return
    if not isinstance(value, (int, float)):
        return  # strings (labels, tokens) are free-form
    lk = key.lower()
    if any(tag in lk for tag in ("slowdown", "delta", "pct")):
        return  # legitimately signed metrics: finiteness is enough
    if "speedup" in lk:
        # a ratio of two positive host times: zero or negative means a
        # dead timer, not a slow run
        if float(value) <= 0.0:
            fail(f"{path}: {row_id}.{key} = {value} is not a positive ratio")
        return
    if "saved" in lk:
        # provisioning savings (e.g. dram_saved_mb) must be finite and
        # non-negative: the allocator only reports capacity returned at
        # equal-or-better latency, so a negative value means it spent
        # more than uniform while claiming a win (finiteness is already
        # guaranteed by the isfinite check above)
        if float(value) < 0.0:
            fail(f"{path}: {row_id}.{key} = {value} negative saving")
        return
    if "overhead" in lk:
        # telemetry overhead_frac is (on - off) / off of two host
        # timings: slightly negative under scheduler noise is fine, but
        # it must stay bounded — checked BEFORE the generic "frac" rule,
        # whose [0,1] bounds would misfire on a signed ratio
        if not -1.0 <= float(value) <= 1.0:
            fail(f"{path}: {row_id}.{key} = {value} outside [-1,1]")
        return
    if "per_sec" in lk or "per_s" in lk:
        # throughput-style metrics (events_per_sec, throughput_per_s):
        # zero means the bench's timer or event counter is dead, so
        # require strictly positive — checked BEFORE the "rate" rule so
        # a key like offered_rate_per_s is judged as a rate-per-second,
        # not squeezed into [0,1]
        if float(value) <= 0.0:
            fail(f"{path}: {row_id}.{key} = {value} is not a positive rate")
        return
    if "availability" in lk:
        # availability = 1 - failed/completed: a fraction by
        # construction, and 1.0 (no failures) is the common case —
        # checked BEFORE the generic "rate/frac" rule so the dedicated
        # message names the metric
        if not 0.0 <= float(value) <= 1.0 + 1e-9:
            fail(f"{path}: {row_id}.{key} = {value} outside [0,1]")
        return
    if "overlap" in lk:
        # lane-scheduler overlap: *_ns keys are absolute hidden
        # nanoseconds (non-negative, unbounded), everything else
        # (overlap_frac) is hidden/(hidden + wall) — a fraction by
        # construction. Checked BEFORE the generic "frac" rule so the
        # dedicated message names the metric and overlapped_ns is not
        # squeezed into [0,1]
        if lk.endswith("_ns"):
            if float(value) < 0.0:
                fail(f"{path}: {row_id}.{key} = {value} negative overlap time")
        elif not 0.0 <= float(value) <= 1.0 + 1e-9:
            fail(f"{path}: {row_id}.{key} = {value} outside [0,1]")
        return
    if any(tag in lk for tag in ("rate", "occupancy", "frac")):
        if not 0.0 <= float(value) <= 1.0 + 1e-9:
            fail(f"{path}: {row_id}.{key} = {value} outside [0,1]")
    elif float(value) < 0.0:
        fail(f"{path}: {row_id}.{key} = {value} is negative")


def check_rows(path: str, rows: list, want_keys: set[str] | None) -> None:
    for i, row in enumerate(rows):
        row_id = f"series[{i}]"
        if not isinstance(row, dict):
            fail(f"{path}: {row_id} is not an object")
            continue
        if want_keys is not None:
            missing = want_keys - set(row.keys())
            if missing:
                fail(f"{path}: {row_id} missing keys {sorted(missing)}")
        numerics = []
        for key, value in row.items():
            check_value(path, row_id, key, value)
            if isinstance(value, (int, float)) and not isinstance(value, bool):
                numerics.append(float(value))
        if numerics and all(v == 0.0 for v in numerics):
            fail(f"{path}: {row_id} is all-zero — a dead metric row")
        p50, p99 = row.get("p50_ns"), row.get("p99_ns")
        if isinstance(p50, (int, float)) and isinstance(p99, (int, float)) and p50 > p99:
            fail(f"{path}: {row_id} has p50 {p50} > p99 {p99}")


# numeric fields that identify a sweep cell rather than measure it
IDENTITY_NUMERICS = {"nodes", "warm_pool_mb", "budget_mb", "dram_ratio"}


def row_key(row: dict) -> tuple:
    """Identity of a series row for baseline↔fresh matching: label-ish
    string fields plus the numeric sweep coordinates (node count, pool
    budget, DRAM ratio) — without these, every row of one shape would
    collapse to a single key and deltas would compare mismatched cells."""
    return tuple(
        (k, v)
        for k, v in sorted(row.items())
        if (isinstance(v, str) and k != "determinism_token") or k in IDENTITY_NUMERICS
    )


def advisory_deltas(path: str, baseline: dict, fresh: dict) -> None:
    base_rows = baseline.get("series") or []
    fresh_rows = fresh.get("series") or []
    if not base_rows or baseline.get("status") == "baseline-pending":
        note(f"  {path}: no measured baseline yet; perf deltas skipped")
        return
    by_key = {row_key(r): r for r in base_rows if isinstance(r, dict)}
    shown = 0
    for row in fresh_rows:
        if not isinstance(row, dict):
            continue
        base = by_key.get(row_key(row))
        if base is None:
            continue
        for metric in ("p50_ns", "p99_ns", "mean_ns", "wall_ns"):
            b, f = base.get(metric), row.get(metric)
            if isinstance(b, (int, float)) and isinstance(f, (int, float)) and b > 0:
                delta = (f - b) / b * 100.0
                if abs(delta) >= 1.0:
                    note(f"  {path}: {dict(row_key(row))} {metric}: {delta:+.1f}% (advisory)")
                    shown += 1
    if shown == 0:
        note(f"  {path}: no perf deltas ≥1% against the committed baseline")


def check_file(name: str, baseline_dir: str, fresh_dir: str) -> None:
    baseline_path = os.path.join(baseline_dir, name)
    fresh_path = os.path.join(fresh_dir, name)
    note(f"bench-gate: {name}")
    baseline = load_json(baseline_path, required=True)
    fresh = load_json(fresh_path, required=True)
    if baseline is None or fresh is None:
        return
    b_suite, f_suite = baseline.get("suite"), fresh.get("suite")
    if b_suite != f_suite:
        fail(f"{fresh_path}: suite {f_suite!r} != committed {b_suite!r}")
    rows = fresh.get("series")
    if not isinstance(rows, list) or not rows:
        fail(f"{fresh_path}: empty or missing series — the bench produced nothing")
        return
    check_rows(fresh_path, rows, expected_row_keys(baseline, fresh_path))
    advisory_deltas(name, baseline, fresh)
    note(f"  {name}: {len(rows)} series rows checked")


def self_test() -> int:
    """Exercise check_value's rule table with known-good and known-bad
    vectors; exits non-zero if any rule fires (or fails to fire) where
    it shouldn't. Run by CI before the real gate so a broken rule fails
    loudly instead of silently passing every bench."""
    cases = [
        # (key, value, should_fail)
        ("events_per_sec", 1.5e6, False),
        ("events_per_sec", 0.0, True),  # dead timer/counter
        ("throughput_per_s", -3.0, True),
        ("offered_rate_per_s", 4200.0, False),  # per_s wins over "rate"
        ("violation_rate", 0.25, False),
        ("violation_rate", 1.5, True),
        ("pool_peak_occupancy", 0.0, False),  # occupancy may be zero
        ("speedup", 0.0, True),
        ("dram_saved_mb", -1.0, True),
        ("overhead_frac", -0.05, False),
        ("availability", 0.97, False),
        ("availability", 1.0, False),  # fault-free runs report exactly 1.0
        ("availability", 1.5, True),
        ("availability", -0.1, True),
        ("overlap_frac", 0.42, False),
        ("overlap_frac", 0.0, False),  # serial runs hide nothing
        ("overlap_frac", 1.2, True),
        ("overlapped_ns", 3.1e9, False),  # absolute ns: unbounded above
        ("overlapped_ns", -1.0, True),
        ("p99_ns", -1, True),
        ("delta_pct", -40.0, False),
        ("p50_ns", float("inf"), True),
    ]
    ok = True
    for key, value, should_fail in cases:
        before = len(FAILURES)
        check_value("self-test", "row", key, value)
        fired = len(FAILURES) > before
        if fired != should_fail:
            verb = "missed" if should_fail else "misfired on"
            print(f"self-test: rule {verb} {key}={value}", file=sys.stderr)
            ok = False
    FAILURES.clear()
    print(f"bench-gate self-test: {'OK' if ok else 'FAILED'} ({len(cases)} vectors)")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*", help="BENCH_*.json file names to validate")
    ap.add_argument("--baseline-dir", default="ci-baseline", help="committed copies")
    ap.add_argument("--fresh-dir", default=".", help="freshly produced copies")
    ap.add_argument("--self-test", action="store_true", help="run rule-table self-test and exit")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if not args.files:
        ap.error("no BENCH files given (or use --self-test)")
    for name in args.files:
        check_file(name, args.baseline_dir, args.fresh_dir)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as fh:
            fh.write("## bench-gate\n\n")
            for line in SUMMARY_LINES:
                fh.write(f"- {line.strip()}\n")
            if FAILURES:
                fh.write("\n**schema drift:**\n\n")
                for line in FAILURES:
                    fh.write(f"- ❌ {line}\n")
            else:
                fh.write("\n✅ no schema drift\n")
    if FAILURES:
        print(f"bench-gate: FAILED with {len(FAILURES)} schema problem(s)", file=sys.stderr)
        return 1
    print("bench-gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
