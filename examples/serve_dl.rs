//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! * Layer 1/2 (build time): `make artifacts` lowered the Pallas-kernel
//!   MLP to HLO text.
//! * Runtime: rust loads the artifact manifest (native reference
//!   interpreter; the PJRT path lives in git history), **trains** the
//!   MLP on a
//!   synthetic classification task for a few hundred steps (logging the
//!   loss curve), then **serves** batched inference requests through the
//!   Porter gateway, reporting latency/throughput and SLO outcomes while
//!   the simulation half decides tier placement for the function's
//!   memory objects.
//!
//! Run with: `make artifacts && cargo run --release --example serve_dl`
//! (set SERVE_DL_STEPS / SERVE_DL_REQUESTS to scale.)

use std::sync::Arc;

use porter::config::Config;
use porter::metrics::Histogram;
use porter::porter::{FunctionSpec, Gateway};
use porter::runtime::{ArtifactManifest, MlpParams, ModelRuntime};
use porter::util::prng::Rng;
use porter::workloads::dl::DlServe;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Synthetic linearly-separable-ish task: class = argmax of 10 random
/// projections of x. Learnable by the MLP, so the loss curve must fall.
fn gen_batch(rng: &mut Rng, d_in: usize, batch: usize, proj: &[f32]) -> (Vec<f32>, Vec<i32>) {
    let mut x = vec![0f32; batch * d_in];
    let mut y = vec![0i32; batch];
    for b in 0..batch {
        for v in &mut x[b * d_in..(b + 1) * d_in] {
            *v = rng.normal() as f32;
        }
        let xs = &x[b * d_in..(b + 1) * d_in];
        let (mut best, mut best_v) = (0, f32::MIN);
        for c in 0..10 {
            let s: f32 = xs.iter().zip(&proj[c * d_in..(c + 1) * d_in]).map(|(a, b)| a * b).sum();
            if s > best_v {
                best_v = s;
                best = c;
            }
        }
        y[b] = best as i32;
    }
    (x, y)
}

fn main() -> porter::util::error::Result<()> {
    // ---------- load the AOT artifacts (request path: no Python) ----------
    let rt = ModelRuntime::load(ArtifactManifest::default_dir())?;
    println!("runtime platform: {}  artifacts: {:?}", rt.platform(), {
        let mut names: Vec<_> = rt.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        names.sort();
        names
    });
    let layers = rt.manifest.model_layers.clone();
    let d_in = layers[0];
    let train_sig = rt.manifest.get("mlp_train").expect("mlp_train artifact");
    let train_batch = train_sig.inputs[train_sig.inputs.len() - 2].shape[0];

    // ---------- phase 1: train for a few hundred steps ----------
    let steps = env_usize("SERVE_DL_STEPS", 300);
    let mut rng = Rng::new(0xD1);
    let proj: Vec<f32> = (0..10 * d_in).map(|_| rng.normal() as f32).collect();
    let mut params = MlpParams::init(&layers, 7);
    let n_params = params.param_count();
    println!("\ntraining {n_params}-param MLP for {steps} steps (batch {train_batch}) natively:");
    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for step in 0..steps {
        let (x, y) = gen_batch(&mut rng, d_in, train_batch, &proj);
        let loss = rt.mlp_train_step(&mut params, &x, &y)?;
        first_loss.get_or_insert(loss);
        last_loss = loss;
        if step % (steps / 10).max(1) == 0 || step == steps - 1 {
            println!("  step {step:4}  loss {loss:.4}");
        }
    }
    let train_secs = t0.elapsed().as_secs_f64();
    println!(
        "trained in {train_secs:.1}s ({:.1} steps/s); loss {:.4} → {:.4}",
        steps as f64 / train_secs,
        first_loss.unwrap(),
        last_loss
    );
    assert!(
        last_loss < first_loss.unwrap() * 0.8,
        "training must reduce loss: {first_loss:?} → {last_loss}"
    );

    // ---------- phase 2: serve through the Porter gateway ----------
    // The gateway decides *memory placement* for the function (simulated
    // tiers); the actual inference runs on the native runtime.
    let requests = env_usize("SERVE_DL_REQUESTS", 64);
    let mut cfg = Config::default();
    cfg.porter.servers = 2;
    cfg.porter.workers_per_server = 2;
    let mut gw = Gateway::new(&cfg);
    gw.deploy(FunctionSpec::new("dl_serve", Arc::new(DlServe::new(40))));

    // Serving prefers the XLA-fused artifact when present: on a CPU PJRT
    // backend the interpret-mode Pallas kernel lowers to un-fused loop
    // HLO (validation build); the fused build is the CPU-production one.
    // See EXPERIMENTS.md §Perf (L2).
    let infer_artifact = if rt.has("mlp_infer_fused") { "mlp_infer_fused" } else { "mlp_infer" };
    let infer_sig = rt.manifest.get(infer_artifact).expect("infer artifact");
    let xin = infer_sig.inputs.last().unwrap();
    let lat = Histogram::default();
    let t0 = std::time::Instant::now();
    let mut hint_hits = 0;
    for r in 0..requests {
        let ticket = gw.invoke("dl_serve").expect("invoke");
        // real model execution for this batch
        let x: Vec<f32> = (0..xin.elements())
            .map(|i| (((i * 7 + r * 131) % 29) as f32 - 14.0) * 0.07)
            .collect();
        let q0 = std::time::Instant::now();
        let logits = rt.mlp_infer_with(infer_artifact, &params, &x)?;
        let outcome = ticket.wait();
        lat.record(q0.elapsed().as_nanos() as u64);
        if outcome.used_hint {
            hint_hits += 1;
        }
        std::hint::black_box(logits);
        if r == 0 {
            gw.tuner.drain(); // let the profile→hint pipeline finish once
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!("\nserved {requests} batched requests in {secs:.2}s:");
    println!(
        "  throughput {:.1} req/s | inference latency mean={} p50≤{} p99≤{}",
        requests as f64 / secs,
        porter::bench::fmt_ns(lat.mean()),
        porter::bench::fmt_ns(lat.percentile(50.0) as f64),
        porter::bench::fmt_ns(lat.percentile(99.0) as f64),
    );
    println!(
        "  placement: {hint_hits}/{requests} invocations used the cached hint (first invocation profiles)"
    );
    gw.shutdown();
    println!("\nend-to-end OK: L1 Pallas kernel → L2 JAX MLP → HLO artifacts → native rust serving under Porter.");
    Ok(())
}
