//! Quickstart: the 60-second tour of the library.
//!
//! Runs one serverless workload on the simulated Table-1 machine, first
//! all-DRAM, then all-CXL, then with §3 profile-guided static placement —
//! and shows the paper's headline effect: most of the CXL penalty is
//! recovered by placing the hot objects in DRAM.
//!
//! Run with: `cargo run --release --example quickstart`

use porter::config::Config;
use porter::placement::static_place::profile_and_place;
use porter::util::table::Table;
use porter::workloads::graph::rmat;
use porter::workloads::pagerank::PageRank;

fn main() {
    let cfg = Config::default();
    println!("Simulated testbed (paper Table 1):\n{}", cfg.machine.render_table());

    // A Twitter-like (power-law) graph, sized past the 19.25MB LLC.
    let graph = rmat(17, 8, porter::workloads::registry::GRAPH_SEED);
    let workload = PageRank::new(graph, 3);
    println!("profiling + placing `pagerank` (this runs the workload three times)...");

    let r = profile_and_place(&cfg, &workload);

    let mut t = Table::new(&["policy", "virtual time", "slowdown vs all-DRAM"]).left_first();
    t.row(vec!["all-dram".into(), porter::bench::fmt_ns(r.all_dram.wall_ns), "0.0%".into()]);
    t.row(vec![
        "static-hint (hot→DRAM)".into(),
        porter::bench::fmt_ns(r.hinted.wall_ns),
        format!("{:.1}%", r.hinted_slowdown_pct()),
    ]);
    t.row(vec![
        "all-cxl".into(),
        porter::bench::fmt_ns(r.all_cxl.wall_ns),
        format!("{:.1}%", r.cxl_slowdown_pct()),
    ]);
    println!("{}", t.render());

    println!("hint classified {} objects:", r.hint.objects.len());
    for o in &r.hint.objects {
        println!(
            "  [{:4}] {:24} {:>10}  heat density {:.3}",
            o.class.name(),
            o.site,
            porter::util::bytes::fmt_bytes(o.bytes),
            o.density
        );
    }
    println!(
        "\nexecution-time reduction over pure CXL: {:.1}% (paper reports up to ~26% for PageRank)",
        r.improvement_over_cxl_pct()
    );
    assert_eq!(r.checksums[0], r.checksums[2], "placement must not change results");
}
