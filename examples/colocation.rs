//! Multi-tenant colocation (Fig. 7): DL serving colocated with {itself,
//! DL training, matmul}, on DRAM vs CXL. The paper's observation —
//! colocating in CXL always hurts more than in local DRAM — should
//! reproduce here via shared-LLC and shared-bandwidth contention.
//!
//! Run with: `cargo run --release --example colocation`

use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::sim::colocate;
use porter::trace::{RecordedTrace, TraceRecorder};
use porter::util::table::Table;
use porter::workloads::dl::{DlServe, DlTrain};
use porter::workloads::matmul::MatMul;
use porter::workloads::Workload;

fn record(w: &dyn Workload, cfg: &Config) -> RecordedTrace {
    let mut rec = TraceRecorder::new();
    let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut rec);
    w.run(&mut env);
    rec.finish()
}

/// Colocation-scale model: 80MiB of weights per tenant, so two tenants
/// genuinely fight over the 19.25MiB LLC and the tier bandwidth (the
/// paper's DL functions are ResNet-scale, not toy MLPs).
fn big_serve(requests: usize) -> DlServe {
    DlServe { layers: vec![768, 4096, 4096, 10], batch: 8, requests, flops_per_cycle: 16 }
}

fn main() {
    let cfg = Config::default();
    let serve = record(&big_serve(30), &cfg);
    let train = record(
        &DlTrain { layers: vec![768, 4096, 4096, 10], batch: 64, steps: 4, flops_per_cycle: 16 },
        &cfg,
    );
    let mm = record(&MatMul::new(1536), &cfg);
    println!(
        "traces: dl_serve {} events, dl_train {} events, matmul {} events\n",
        serve.len(),
        train.len(),
        mm.len()
    );

    let pairs: [(&str, &RecordedTrace); 3] =
        [("dl_serve", &serve), ("dl_train", &train), ("matmul", &mm)];

    let mut t =
        Table::new(&["colocated with", "DRAM slowdown %", "CXL slowdown %"]).left_first();
    for (name, other) in pairs {
        let dram = colocate(&cfg.machine, TierKind::Dram, &[&serve, other], 256);
        let cxl = colocate(&cfg.machine, TierKind::Cxl, &[&serve, other], 256);
        let d = dram.slowdown_pct(0);
        let c = cxl.slowdown_pct(0);
        t.row(vec![name.into(), format!("{d:.1}"), format!("{c:.1}")]);
        assert!(
            c > d,
            "paper's Fig. 7 shape violated: CXL ({c:.1}%) should exceed DRAM ({d:.1}%) for {name}"
        );
    }
    println!("dl_serve slowdown when colocated (vs running standalone):");
    println!("{}", t.render());
    println!("paper (Fig. 7): CXL always shows more severe colocation impact than local DRAM. ✓");
}
