//! Porter's learning loop (Fig. 6): the first invocation of each
//! function runs DRAM-first while profiled; the tuner turns the profile
//! into a placement hint; subsequent invocations place by hint and keep
//! latency near the all-DRAM level while using a fraction of the DRAM.
//!
//! Run with: `cargo run --release --example porter_learning`

use std::sync::Arc;

use porter::config::Config;
use porter::porter::slo::SloTracker;
use porter::porter::{FunctionSpec, Gateway};
use porter::util::table::Table;
use porter::workloads::graph::rmat;
use porter::workloads::kvstore::KvStore;
use porter::workloads::pagerank::PageRank;

fn main() {
    let mut cfg = Config::default();
    cfg.porter.servers = 1;
    cfg.porter.workers_per_server = 2;
    let mut gw = Gateway::new(&cfg);
    gw.deploy(FunctionSpec::new(
        "pagerank",
        Arc::new(PageRank::new(rmat(15, 8, porter::workloads::registry::GRAPH_SEED), 2)),
    ));
    gw.deploy(FunctionSpec::new("kvstore", Arc::new(KvStore::new(400_000, 400_000))));

    let mut slo = SloTracker::default();
    let mut t = Table::new(&[
        "invocation", "function", "policy", "virtual time", "DRAM peak", "SLO",
    ])
    .left_first();

    for round in 0..4 {
        for f in ["pagerank", "kvstore"] {
            let out = gw.invoke(f).unwrap().wait();
            slo.record(&out);
            t.row(vec![
                format!("#{}", round + 1),
                f.into(),
                if out.used_hint { "hint".into() } else { "profile (DRAM-first)".into() },
                porter::bench::fmt_ns(out.report.wall_ns),
                porter::util::bytes::fmt_bytes(out.report.peak_dram_bytes),
                match out.slo_met() {
                    Some(true) => "met".into(),
                    Some(false) => "VIOLATED".into(),
                    None => "-".into(),
                },
            ]);
            if round == 0 {
                gw.tuner.drain(); // let hints land before the next round
            }
        }
    }
    println!("{}", t.render());
    println!("overall SLO violation rate: {:.1}%", slo.overall_violation_rate() * 100.0);
    println!(
        "\nnote: pagerank's hot object (contrib) is page-separable, so the hint meets SLO\n\
         with a fraction of the DRAM. kvstore hash-scatters its hot keys across the whole\n\
         table, so object-granular hints under-provision it — exactly the paper's §4.2\n\
         \"not all pages of an object are hot\" limitation, flagged as Porter future work\n\
         (fine-grained awareness + runtime promotion would recover it)."
    );
    for (f, s) in slo.functions() {
        println!(
            "  {f}: {} invocations, mean virtual time {}",
            s.invocations,
            porter::bench::fmt_ns(s.mean_wall_ns())
        );
    }
    gw.shutdown();
}
