//! §3 static placement, end to end — the Fig. 3 pipeline on BFS and
//! PageRank over a Twitter-like RMAT graph (the paper's Fig. 5 setup),
//! including the DAMON heatmap the hints are generated from (Fig. 4).
//!
//! Run with: `cargo run --release --example static_placement [--full]`

use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::monitor::{Damon, Heatmap};
use porter::placement::static_place::profile_and_place;
use porter::sim::Machine;
use porter::workloads::graph::rmat;
use porter::workloads::bfs::Bfs;
use porter::workloads::pagerank::PageRank;
use porter::workloads::Workload;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { 20 } else { 16 };
    let cfg = Config::default();

    let graph = rmat(scale, 8, porter::workloads::registry::GRAPH_SEED);
    println!(
        "graph: 2^{scale} vertices, {} edges (RMAT — Twitter-like skew)\n",
        graph.m()
    );

    // --- Fig. 4: the heatmap DAMON sees during the record phase ---
    let pr = PageRank::new(graph.clone(), 2);
    println!("=== record phase: DAMON heatmap for pagerank (Fig. 4 analogue) ===");
    let mut machine = Machine::all_in(&cfg.machine, TierKind::Cxl);
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine.attach_observer(Box::new(Damon::new(&cfg.monitor, cfg.machine.page_bytes, 1)));
    let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut machine);
    pr.run(&mut env);
    let objects: Vec<_> = env.objects().to_vec();
    drop(env);
    let damon =
        machine.take_observers().pop().unwrap().into_any().downcast::<Damon>().unwrap();
    let mmap_base = porter::shim::intercept::MMAP_BASE;
    let lo = objects.iter().map(|o| o.start).filter(|&s| s >= mmap_base).min().unwrap();
    let hi = objects.iter().map(|o| o.end()).max().unwrap();
    let map = Heatmap::from_damon(&damon.snapshots, lo, hi, 72, 24);
    println!("{}", map.render_ascii());
    let score = map.locality_score();
    println!("locality score: {score:.2} (hot bands = the objects worth pinning to DRAM)\n");

    // --- Fig. 5: static placement for PageRank and BFS ---
    for (name, w) in [
        ("pagerank", Box::new(PageRank::new(graph.clone(), 2)) as Box<dyn Workload>),
        ("bfs", Box::new(Bfs::new(graph.clone(), 0)) as Box<dyn Workload>),
    ] {
        let r = profile_and_place(&cfg, w.as_ref());
        println!(
            "{name:9} pure-CXL slowdown {:6.1}%  | hinted slowdown {:5.1}%  | improvement over CXL {:5.1}%",
            r.cxl_slowdown_pct(),
            r.hinted_slowdown_pct(),
            r.improvement_over_cxl_pct()
        );
        assert_eq!(r.checksums[0], r.checksums[2]);
    }
    println!("\npaper (Fig. 5): up to ~26% execution-time reduction for PageRank on Twitter.");
}
