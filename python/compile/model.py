"""Layer 2: the DL serverless functions as JAX compute graphs.

An MLP classifier (geometry shared with rust's `workloads::dl`:
768 -> 1024 -> 1024 -> 10) built over the Layer-1 Pallas matmul kernel.
Three entry points get AOT-lowered by `aot.py`:

* ``mlp_infer(params, x)``     — the DL-serving function body
* ``mlp_train_step(params, x, y)`` — fwd + bwd + SGD, the DL-training body
* ``matmul(x, y)``             — the raw kernel, benchable standalone

Python in this package runs at build time only; the rust runtime executes
the lowered HLO via PJRT on the request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul

LAYERS = [768, 1024, 1024, 10]
TRAIN_BATCH = 64
INFER_BATCH = 8
LEARNING_RATE = 0.05


def init_params(seed=0, layers=None, scale=0.05):
    """He-ish initialized (W, b) pairs as a flat pytree."""
    layers = layers or LAYERS
    key = jax.random.PRNGKey(seed)
    params = []
    for din, dout in zip(layers[:-1], layers[1:]):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (din, dout), jnp.float32) * scale * (2.0 / din) ** 0.5 * din**0.5
        b = jnp.zeros((dout,), jnp.float32)
        params.append((w, b))
    return params


def mlp_forward(params, x):
    """Forward pass; the wide layers run through the Pallas kernel."""
    h = x
    for w, b in params[:-1]:
        h = jnp.maximum(matmul(h, w) + b, 0.0)
    w, b = params[-1]
    return matmul(h, w) + b


def mlp_infer(params, x):
    """Serving entry point: logits for a batch."""
    return (mlp_forward(params, x),)


def mlp_infer_fused(params, x):
    """Serving entry point on the pure-XLA path (no Pallas custom
    lowering): numerically equivalent, but XLA fuses the GEMM chain
    natively. On CPU the interpret-mode kernel lowers to un-fused loop
    HLO, so this variant is the production serving artifact there; on
    TPU the kernel variant is the optimized one. The §Perf log compares
    both (see EXPERIMENTS.md)."""
    h = x
    for w, b in params[:-1]:
        h = jnp.maximum(jnp.dot(h, w, preferred_element_type=jnp.float32) + b, 0.0)
    w, b = params[-1]
    return (jnp.dot(h, w, preferred_element_type=jnp.float32) + b,)


def loss_fn(params, x, y):
    """Softmax cross-entropy against integer labels."""
    logits = mlp_forward(params, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


def mlp_train_step(params, x, y):
    """One SGD step; returns (new_params..., loss) as a flat tuple so the
    HLO artifact has a stable output layout for the rust runtime."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_params = jax.tree_util.tree_map(lambda p, g: p - LEARNING_RATE * g, params, grads)
    flat, _ = jax.tree_util.tree_flatten(new_params)
    return tuple(flat) + (loss,)


def matmul_fn(x, y):
    """Standalone kernel entry point (256x256 by default in aot.py)."""
    return (matmul(x, y),)


def example_inputs(kind):
    """ShapeDtypeStructs for lowering each artifact."""
    f32 = jnp.float32
    params = [
        jax.ShapeDtypeStruct(s, f32)
        for din, dout in zip(LAYERS[:-1], LAYERS[1:])
        for s in [(din, dout), (dout,)]
    ]
    # params are passed as a pytree of (W, b) pairs
    params_tree = [(params[2 * i], params[2 * i + 1]) for i in range(len(LAYERS) - 1)]
    if kind in ("mlp_infer", "mlp_infer_fused"):
        return (params_tree, jax.ShapeDtypeStruct((INFER_BATCH, LAYERS[0]), f32))
    if kind == "mlp_train":
        return (
            params_tree,
            jax.ShapeDtypeStruct((TRAIN_BATCH, LAYERS[0]), f32),
            jax.ShapeDtypeStruct((TRAIN_BATCH,), jnp.int32),
        )
    if kind == "matmul":
        return (
            jax.ShapeDtypeStruct((256, 256), f32),
            jax.ShapeDtypeStruct((256, 256), f32),
        )
    raise ValueError(f"unknown artifact kind {kind!r}")


ENTRY_POINTS = {
    "mlp_infer": mlp_infer,
    "mlp_infer_fused": mlp_infer_fused,
    "mlp_train": mlp_train_step,
    "matmul": matmul_fn,
}
