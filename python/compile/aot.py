"""AOT lowering: JAX -> HLO text artifacts for the rust PJRT runtime.

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts``
The manifest (artifacts/manifest.json) records each artifact's entry
point, file, and flat input/output signature so the rust runtime can
marshal literals without re-deriving pytree order.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True so
    rust unwraps a single tuple regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flat_signature(tree):
    """Flatten example inputs to the positional order rust must feed."""
    flat, _ = jax.tree_util.tree_flatten(tree)
    return [
        {"shape": list(x.shape), "dtype": str(x.dtype)}
        for x in flat
    ]


def lower_entry(kind):
    fn = model.ENTRY_POINTS[kind]
    example = model.example_inputs(kind)
    lowered = jax.jit(fn).lower(*example)
    out_avals = jax.eval_shape(fn, *example)
    outputs = [
        {"shape": list(x.shape), "dtype": str(x.dtype)}
        for x in jax.tree_util.tree_leaves(out_avals)
    ]
    return to_hlo_text(lowered), flat_signature(example), outputs


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact directory")
    parser.add_argument(
        "--only", default=None, help="comma-separated subset of entry points"
    )
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    kinds = list(model.ENTRY_POINTS)
    if args.only:
        kinds = [k for k in kinds if k in set(args.only.split(","))]

    manifest = {"model_layers": model.LAYERS, "artifacts": {}}
    for kind in kinds:
        hlo, inputs, outputs = lower_entry(kind)
        path = os.path.join(args.out, f"{kind}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        manifest["artifacts"][kind] = {
            "file": f"{kind}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"wrote {path} ({len(hlo)} chars, {len(inputs)} inputs)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
