"""Pure-jnp oracle for the Pallas kernels — the correctness ground truth
pytest compares against (no pallas imports here by design)."""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Reference x @ y in f32 accumulation."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def mlp_forward_ref(params, x):
    """Reference MLP forward pass (must mirror model.mlp_forward)."""
    h = x
    for w, b in params[:-1]:
        h = jnp.maximum(jnp.dot(h, w, preferred_element_type=jnp.float32) + b, 0.0)
    w, b = params[-1]
    return jnp.dot(h, w, preferred_element_type=jnp.float32) + b
