"""Layer 1: Pallas tiled matmul kernel.

The DL serverless functions' compute hot-spot. The paper's tiered-memory
insight — keep the hot working set in the near tier — maps onto the
kernel as VMEM tiling: each grid step holds one (bm, bk) x-tile, one
(bk, bn) y-tile and the (bm, bn) output tile in VMEM (the near tier),
streaming the K dimension through HBM (the far tier). BlockSpec encodes
that HBM<->VMEM schedule; the MXU-native tile is 128x128.

CPU execution is interpret=True only: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run. Numerics are validated
against `ref.py` by pytest (hypothesis sweeps shapes/dtypes); TPU
performance is *estimated* from the VMEM footprint + MXU utilization in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tile. bm=8 also divides the serving batch.
DEFAULT_BM = 8
DEFAULT_BK = 128
DEFAULT_BN = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, nsteps_k):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis.

    The output tile is revisited across the K steps (its index_map
    ignores k), so it serves as the VMEM accumulator: zeroed at k==0,
    accumulated into afterwards.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def matmul_tiles(x, y, *, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Tiled x @ y via the Pallas kernel. Dims must divide the tiles."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"({m},{k},{n}) not divisible by tiles ({bm},{bk},{bn})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y)


def _matmul_any(x, y, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Kernel when tileable, jnp fallback otherwise (no vjp attached)."""
    m, k = x.shape
    _, n = y.shape
    if m % bm == 0 and k % bk == 0 and n % bn == 0:
        return matmul_tiles(x, y, bm=bm, bk=bk, bn=bn)
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


@jax.custom_vjp
def matmul(x, y):
    """Kernel matmul with a jnp fallback for tile-incompatible shapes.

    The MLP's last layer (1024 -> 10 logits) is far below a tile; the
    fallback keeps the model definition uniform while the big layers run
    through the kernel.

    A custom VJP makes the op differentiable (Pallas kernels have no
    automatic transpose) *and* keeps the backward GEMMs on the kernel:
    dx = g @ yᵀ and dy = xᵀ @ g route through the same tiled path when
    their shapes allow.
    """
    return _matmul_any(x, y)


def _matmul_fwd(x, y):
    return _matmul_any(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = _matmul_any(g, y.T)
    dy = _matmul_any(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN, dtype_bytes=4):
    """Estimated VMEM footprint of one grid step (DESIGN.md §Perf)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Fraction of 128x128 MXU lanes a (bm,bk)x(bk,bn) tile pair keeps
    busy, the structural proxy we optimize under interpret=True."""
    return min(bm / 128.0, 1.0) * min(bk / 128.0, 1.0) * min(bn / 128.0, 1.0)
