"""AOT pipeline: every entry point lowers to parseable HLO text with a
manifest the rust runtime can marshal from."""

import json
import subprocess
import sys

import pytest

from compile import aot, model


class TestLowering:
    @pytest.mark.parametrize("kind", list(model.ENTRY_POINTS))
    def test_lowers_to_hlo_text(self, kind):
        hlo, inputs, outputs = aot.lower_entry(kind)
        assert hlo.startswith("HloModule"), hlo[:80]
        assert "ROOT" in hlo
        assert len(inputs) >= 2
        assert len(outputs) >= 1

    def test_infer_signature(self):
        _, inputs, outputs = aot.lower_entry("mlp_infer")
        # 3 layers x (W, b) + x
        assert len(inputs) == 7
        assert inputs[0]["shape"] == [768, 1024]
        assert inputs[-1]["shape"] == [model.INFER_BATCH, 768]
        assert outputs[0]["shape"] == [model.INFER_BATCH, 10]

    def test_train_signature(self):
        _, inputs, outputs = aot.lower_entry("mlp_train")
        assert len(inputs) == 8  # params + x + y
        assert inputs[-1]["dtype"] == "int32"
        assert len(outputs) == 7  # new params + loss
        assert outputs[-1]["shape"] == []

    def test_no_serialized_protos(self):
        """Guard: the artifact must be text, not .serialize() output."""
        hlo, _, _ = aot.lower_entry("matmul")
        assert isinstance(hlo, str)
        assert hlo.isprintable() or "\n" in hlo


class TestCli:
    def test_writes_artifacts_and_manifest(self, tmp_path):
        out = tmp_path / "artifacts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(out), "--only", "matmul"],
            check=True,
            cwd=str(aot.__file__.rsplit("/compile/", 1)[0]),
        )
        manifest = json.loads((out / "manifest.json").read_text())
        assert "matmul" in manifest["artifacts"]
        entry = manifest["artifacts"]["matmul"]
        assert (out / entry["file"]).exists()
        assert entry["inputs"][0]["shape"] == [256, 256]
        assert manifest["model_layers"] == model.LAYERS
