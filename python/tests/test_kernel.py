"""L1 correctness: Pallas matmul kernel vs the pure-jnp oracle.

Hypothesis sweeps tile-compatible shapes and value distributions; the
assert_allclose against ref.py is the core correctness signal for the
kernel that every DL artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (
    DEFAULT_BK,
    DEFAULT_BM,
    DEFAULT_BN,
    matmul,
    matmul_tiles,
    mxu_utilization,
    vmem_bytes,
)
from compile.kernels.ref import matmul_ref


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


class TestKernelBasics:
    def test_matches_ref_square(self):
        x = rand(0, (128, 128))
        y = rand(1, (128, 128))
        np.testing.assert_allclose(
            matmul_tiles(x, y, bm=128, bk=128, bn=128), matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_matches_ref_rectangular(self):
        x = rand(2, (8, 768))
        y = rand(3, (768, 1024))
        np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_multi_k_step_accumulation(self):
        # K = 4 tiles: exercises the revisited-output accumulator path
        x = rand(4, (8, 512))
        y = rand(5, (512, 128))
        got = matmul_tiles(x, y, bm=8, bk=128, bn=128)
        np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_fallback_for_incompatible_shapes(self):
        # 1024 -> 10 logits layer: not tileable, must still be exact
        x = rand(6, (8, 1024))
        y = rand(7, (1024, 10))
        np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_rejects_contraction_mismatch(self):
        with pytest.raises(AssertionError):
            matmul_tiles(jnp.zeros((8, 128)), jnp.zeros((256, 128)))

    def test_identity(self):
        x = rand(8, (128, 128))
        eye = jnp.eye(128, dtype=jnp.float32)
        np.testing.assert_allclose(
            matmul_tiles(x, eye, bm=128, bk=128, bn=128), x, rtol=1e-5, atol=1e-6
        )

    def test_zeros(self):
        x = jnp.zeros((8, 128), jnp.float32)
        y = jnp.zeros((128, 128), jnp.float32)
        assert jnp.all(matmul_tiles(x, y) == 0.0)


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_kernel_matches_ref_swept(mi, ki, ni, seed, scale):
    """Property: for every tile-multiple shape and value scale, the kernel
    equals the oracle within f32 tolerance."""
    m, k, n = 8 * mi, 128 * ki, 128 * ni
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), jnp.float32) * scale
    y = jax.random.normal(ky, (k, n), jnp.float32) * scale
    got = matmul_tiles(x, y)
    want = matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale * k)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 64, 128]),
    bk=st.sampled_from([128, 256]),
    bn=st.sampled_from([128, 256]),
)
def test_tile_shape_invariance(bm, bk, bn):
    """Property: the result must not depend on the tiling."""
    x = rand(42, (128, 256))
    y = rand(43, (256, 256))
    if 128 % bm or 256 % bk or 256 % bn:
        return
    got = matmul_tiles(x, y, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_dtype_bf16_inputs():
    """bf16 inputs with f32 accumulation (the MXU-native mode)."""
    x = rand(9, (8, 128)).astype(jnp.bfloat16)
    y = rand(10, (128, 128)).astype(jnp.bfloat16)
    got = matmul_tiles(x.astype(jnp.float32), y.astype(jnp.float32))
    want = matmul_ref(x.astype(jnp.float32), y.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestPerfModel:
    def test_vmem_footprint_fits(self):
        # default tiles must fit comfortably in a 16MiB VMEM
        assert vmem_bytes() < 16 * 1024 * 1024
        # 128^3 f32 tiles: 3 * 64KiB
        assert vmem_bytes(128, 128, 128) == 3 * 128 * 128 * 4

    def test_mxu_utilization_monotone(self):
        assert mxu_utilization(128, 128, 128) == 1.0
        assert mxu_utilization(8, 128, 128) < mxu_utilization(64, 128, 128)
        assert mxu_utilization(DEFAULT_BM, DEFAULT_BK, DEFAULT_BN) > 0.0
