"""L2 correctness: MLP forward/training over the Pallas kernel."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import mlp_forward_ref


def data(batch, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (batch, model.LAYERS[0]), jnp.float32)
    y = jax.random.randint(ky, (batch,), 0, model.LAYERS[-1])
    return x, y


class TestForward:
    def test_shapes(self):
        params = model.init_params()
        x, _ = data(model.INFER_BATCH)
        (logits,) = model.mlp_infer(params, x)
        assert logits.shape == (model.INFER_BATCH, model.LAYERS[-1])

    def test_matches_pure_jnp_reference(self):
        params = model.init_params(seed=3)
        x, _ = data(8, seed=4)
        got = model.mlp_forward(params, x)
        want = mlp_forward_ref(params, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_deterministic(self):
        params = model.init_params(seed=1)
        x, _ = data(8, seed=2)
        a = model.mlp_forward(params, x)
        b = model.mlp_forward(params, x)
        np.testing.assert_array_equal(a, b)


class TestTraining:
    def test_loss_decreases_over_steps(self):
        params = model.init_params(seed=5)
        x, y = data(model.TRAIN_BATCH, seed=6)
        step = jax.jit(model.mlp_train_step)
        losses = []
        for _ in range(12):
            out = step(params, x, y)
            flat, loss = out[:-1], out[-1]
            losses.append(float(loss))
            params = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(model.LAYERS) - 1)]
        assert losses[-1] < losses[0] * 0.7, f"loss did not fall: {losses}"

    def test_grad_matches_reference_model(self):
        """Gradients through the kernel == gradients through pure jnp."""
        params = model.init_params(seed=7)
        x, y = data(16, seed=8)

        def ref_loss(params, x, y):
            logits = mlp_forward_ref(params, x)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

        g_kernel = jax.grad(model.loss_fn)(params, x, y)
        g_ref = jax.grad(ref_loss)(params, x, y)
        for (gw, gb), (rw, rb) in zip(g_kernel, g_ref):
            np.testing.assert_allclose(gw, rw, rtol=5e-3, atol=1e-5)
            np.testing.assert_allclose(gb, rb, rtol=5e-3, atol=1e-5)

    def test_train_step_output_layout(self):
        """The flat (params..., loss) layout the rust runtime relies on."""
        params = model.init_params()
        x, y = data(model.TRAIN_BATCH)
        out = model.mlp_train_step(params, x, y)
        assert len(out) == 2 * (len(model.LAYERS) - 1) + 1
        for i, (din, dout) in enumerate(zip(model.LAYERS[:-1], model.LAYERS[1:])):
            assert out[2 * i].shape == (din, dout)
            assert out[2 * i + 1].shape == (dout,)
        assert out[-1].shape == ()


class TestFusedVariant:
    def test_fused_matches_kernel_path(self):
        params = model.init_params(seed=9)
        x, _ = data(model.INFER_BATCH, seed=10)
        (a,) = model.mlp_infer(params, x)
        (b,) = model.mlp_infer_fused(params, x)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


class TestExampleInputs:
    def test_signatures_consistent_with_entry_points(self):
        for kind, fn in model.ENTRY_POINTS.items():
            example = model.example_inputs(kind)
            out = jax.eval_shape(fn, *example)
            assert len(jax.tree_util.tree_leaves(out)) >= 1, kind
