//! Native reference executor for the DL artifacts.
//!
//! Python still runs once at build time (`make artifacts`) to AOT-lower
//! the JAX/Pallas model; this module is the request-path half. The
//! original PJRT-backed executor (xla crate) lives in git history — the
//! offline image ships no crate registry, so the default build executes
//! the artifact *signatures* with a pure-Rust interpreter that computes
//! exactly the math `python/compile/model.py` lowers: an MLP with ReLU
//! hidden layers, softmax cross-entropy + SGD training (LEARNING_RATE =
//! 0.05), and the raw matmul kernel. Numerics are validated against the
//! same rust-side references as the PJRT path was
//! (`rust/tests/integration_runtime.rs`).

use crate::anyhow;
use crate::runtime::artifacts::ArtifactManifest;
use crate::util::error::Result;
use crate::util::prng::Rng;

/// SGD learning rate — must match `python/compile/model.py`.
const LEARNING_RATE: f32 = 0.05;

/// MLP parameters as flat (W, b) float vectors in layer order — the
/// positional layout `python/compile/aot.py` records in the manifest.
#[derive(Debug, Clone)]
pub struct MlpParams {
    /// [(weights, biases)] per layer; weights are row-major (din, dout).
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
    pub dims: Vec<usize>,
}

impl MlpParams {
    /// Initialize with the same scheme as `model.init_params` (different
    /// RNG — numerical equivalence is established per-execution by
    /// feeding identical inputs, not by matching Python's init).
    pub fn init(dims: &[usize], seed: u64) -> MlpParams {
        let mut rng = Rng::new(seed);
        let layers = dims
            .windows(2)
            .map(|w| {
                let (din, dout) = (w[0], w[1]);
                let scale = 0.05 * (2.0 / din as f64).sqrt() * (din as f64).sqrt();
                let weights = (0..din * dout).map(|_| (rng.normal() * scale) as f32).collect();
                (weights, vec![0f32; dout])
            })
            .collect();
        MlpParams { layers, dims: dims.to_vec() }
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|(w, b)| w.len() + b.len()).sum()
    }

    /// Forward pass for a (batch, dims[0]) row-major input: ReLU hidden
    /// layers, linear output — identical to `model.mlp_forward`.
    pub fn forward(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let mut h = x.to_vec();
        let n_layers = self.layers.len();
        for (l, (w, b)) in self.layers.iter().enumerate() {
            let relu = l + 1 < n_layers;
            h = dense_forward(&h, w, b, batch, self.dims[l], self.dims[l + 1], relu);
        }
        h
    }
}

/// out = act(x @ w + b); x is (batch, din), w is (din, dout) row-major.
fn dense_forward(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    batch: usize,
    din: usize,
    dout: usize,
    relu: bool,
) -> Vec<f32> {
    let mut out = vec![0f32; batch * dout];
    for r in 0..batch {
        let orow = &mut out[r * dout..(r + 1) * dout];
        for k in 0..din {
            let a = x[r * din + k];
            if a == 0.0 {
                continue;
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            for (o, wv) in orow.iter_mut().zip(wrow) {
                *o += a * wv;
            }
        }
        for (j, o) in orow.iter_mut().enumerate() {
            *o += b[j];
            if relu && *o < 0.0 {
                *o = 0.0;
            }
        }
    }
    out
}

/// The runtime: manifest + native interpreter state.
pub struct ModelRuntime {
    pub manifest: ArtifactManifest,
}

impl ModelRuntime {
    /// Load the artifact manifest (shapes + layer geometry). The HLO
    /// text files are not parsed by the native interpreter; the manifest
    /// alone pins the artifact signatures the interpreter honours.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<ModelRuntime> {
        let manifest = ArtifactManifest::load(dir)?;
        Ok(ModelRuntime { manifest })
    }

    pub fn platform(&self) -> String {
        "native-cpu (reference interpreter)".to_string()
    }

    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    /// Serve one inference batch: logits for `x` of shape (batch, d_in).
    pub fn mlp_infer(&self, params: &MlpParams, x: &[f32]) -> Result<Vec<f32>> {
        self.mlp_infer_with("mlp_infer", params, x)
    }

    /// Inference through a named artifact variant (`mlp_infer` embeds
    /// the Pallas kernel; `mlp_infer_fused` is the XLA-native-fusion
    /// build). Both lower the same math, so the interpreter computes one
    /// reference forward for either.
    pub fn mlp_infer_with(
        &self,
        artifact: &str,
        params: &MlpParams,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let sig = self.manifest.get(artifact).ok_or_else(|| anyhow!("no {artifact} artifact"))?;
        // positional layout: (W1, b1, ..., Wn, bn, x)
        if sig.inputs.len() != params.layers.len() * 2 + 1 {
            return Err(anyhow!(
                "{artifact}: expected {} inputs, params supply {}",
                sig.inputs.len(),
                params.layers.len() * 2 + 1
            ));
        }
        let xin = &sig.inputs[sig.inputs.len() - 1];
        if x.len() != xin.elements() {
            return Err(anyhow!("x has {} elements, artifact wants {}", x.len(), xin.elements()));
        }
        let (batch, d_in) = (xin.shape[0], xin.shape[1]);
        if d_in != params.dims[0] {
            return Err(anyhow!("artifact d_in {} vs params d_in {}", d_in, params.dims[0]));
        }
        for (l, (w, _)) in params.layers.iter().enumerate() {
            if sig.inputs[2 * l].elements() != w.len() {
                return Err(anyhow!(
                    "{artifact}: layer {l} weights have {} elements, artifact wants {}",
                    w.len(),
                    sig.inputs[2 * l].elements()
                ));
            }
        }
        Ok(params.forward(x, batch))
    }

    /// One SGD training step; updates `params` in place, returns the
    /// softmax cross-entropy loss (matches `model.mlp_train_step`).
    pub fn mlp_train_step(&self, params: &mut MlpParams, x: &[f32], y: &[i32]) -> Result<f32> {
        let sig = self.manifest.get("mlp_train").ok_or_else(|| anyhow!("no mlp_train artifact"))?;
        // positional layout: (W1, b1, ..., Wn, bn, x, y)
        if sig.inputs.len() != params.layers.len() * 2 + 2 {
            return Err(anyhow!(
                "mlp_train: expected {} inputs, params supply {}",
                sig.inputs.len(),
                params.layers.len() * 2 + 2
            ));
        }
        let xin = &sig.inputs[sig.inputs.len() - 2];
        let (batch, d_in) = (xin.shape[0], xin.shape[1]);
        if x.len() != batch * d_in {
            return Err(anyhow!("x has {} elements, artifact wants {}", x.len(), batch * d_in));
        }
        if y.len() != batch {
            return Err(anyhow!("y has {} labels, artifact wants {}", y.len(), batch));
        }
        if d_in != params.dims[0] {
            return Err(anyhow!("artifact d_in {} vs params d_in {}", d_in, params.dims[0]));
        }
        let n_layers = params.layers.len();
        let n_classes = *params.dims.last().unwrap();

        // forward, keeping every activation (acts[0] = x, acts[l+1] =
        // layer l output post-ReLU)
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
        acts.push(x.to_vec());
        for (l, (w, b)) in params.layers.iter().enumerate() {
            let relu = l + 1 < n_layers;
            let out = dense_forward(
                acts.last().unwrap(),
                w,
                b,
                batch,
                params.dims[l],
                params.dims[l + 1],
                relu,
            );
            acts.push(out);
        }

        // softmax cross-entropy: loss and d(loss)/d(logits)
        let logits = acts.last().unwrap();
        let mut grad = vec![0f32; batch * n_classes];
        let mut loss = 0f64;
        for r in 0..batch {
            let row = &logits[r * n_classes..(r + 1) * n_classes];
            let max = row.iter().cloned().fold(f32::MIN, f32::max);
            let mut denom = 0f64;
            for &v in row {
                denom += ((v - max) as f64).exp();
            }
            let label = y[r];
            if label < 0 || label as usize >= n_classes {
                return Err(anyhow!("label {} out of range 0..{}", label, n_classes));
            }
            let label = label as usize;
            let logp_label = (row[label] - max) as f64 - denom.ln();
            loss -= logp_label;
            let grow = &mut grad[r * n_classes..(r + 1) * n_classes];
            for (j, g) in grow.iter_mut().enumerate() {
                let p = (((row[j] - max) as f64).exp() / denom) as f32;
                *g = (p - if j == label { 1.0 } else { 0.0 }) / batch as f32;
            }
        }
        let loss = (loss / batch as f64) as f32;

        // backward: for layer l, dW = acts[l]^T @ g, db = Σ_rows g,
        // g_prev = (g @ W^T) ∘ relu'(acts[l])
        let mut g = grad;
        for l in (0..n_layers).rev() {
            let (din, dout) = (params.dims[l], params.dims[l + 1]);
            let a = &acts[l];
            let (w, b) = &mut params.layers[l];
            // input gradient first (needs the pre-update weights)
            let g_prev = if l > 0 {
                let mut gp = vec![0f32; batch * din];
                for r in 0..batch {
                    let grow = &g[r * dout..(r + 1) * dout];
                    let gprow = &mut gp[r * din..(r + 1) * din];
                    for (k, gp_k) in gprow.iter_mut().enumerate() {
                        if a[r * din + k] <= 0.0 {
                            continue; // ReLU gate (acts[l] is post-ReLU)
                        }
                        let wrow = &w[k * dout..(k + 1) * dout];
                        let mut s = 0f32;
                        for (gv, wv) in grow.iter().zip(wrow) {
                            s += gv * wv;
                        }
                        *gp_k = s;
                    }
                }
                Some(gp)
            } else {
                None
            };
            // parameter update
            for r in 0..batch {
                let grow = &g[r * dout..(r + 1) * dout];
                for k in 0..din {
                    let av = a[r * din + k];
                    if av == 0.0 {
                        continue;
                    }
                    let wrow = &mut w[k * dout..(k + 1) * dout];
                    for (wv, gv) in wrow.iter_mut().zip(grow) {
                        *wv -= LEARNING_RATE * av * gv;
                    }
                }
                for (bv, gv) in b.iter_mut().zip(grow) {
                    *bv -= LEARNING_RATE * gv;
                }
            }
            if let Some(gp) = g_prev {
                g = gp;
            }
        }
        Ok(loss)
    }

    /// Run the standalone Pallas-matmul artifact: plain (n,k)·(k,m).
    pub fn matmul(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let sig = self.manifest.get("matmul").ok_or_else(|| anyhow!("no matmul artifact"))?;
        let (a, b) = (&sig.inputs[0], &sig.inputs[1]);
        let (n, k) = (a.shape[0], a.shape[1]);
        let (k2, m) = (b.shape[0], b.shape[1]);
        if x.len() != n * k || y.len() != k2 * m || k != k2 {
            return Err(anyhow!("matmul shape mismatch: x {} y {}", x.len(), y.len()));
        }
        // x@y is one bias-free, activation-free dense layer
        Ok(dense_forward(x, y, &vec![0f32; m], n, k, m, false))
    }
}

#[cfg(test)]
mod tests {
    //! Manifest-dependent tests are skipped (not failed) when `make
    //! artifacts` has not run; `rust/tests/integration_runtime.rs`
    //! asserts the full numerics against the rust-side references.

    use super::*;

    fn runtime() -> Option<ModelRuntime> {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(ModelRuntime::load(dir).expect("runtime loads"))
    }

    #[test]
    fn params_layout_in_layer_order() {
        let p = MlpParams::init(&[4, 8, 2], 1);
        assert_eq!(p.param_count(), 4 * 8 + 8 + 8 * 2 + 2);
        assert_eq!(p.layers.len(), 2);
        assert_eq!(p.layers[0].0.len(), 32);
        assert_eq!(p.layers[0].1.len(), 8);
    }

    #[test]
    fn forward_identity_layer() {
        // single layer, identity weights, zero bias → logits == x
        let mut p = MlpParams::init(&[3, 3], 1);
        p.layers[0].0 = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        p.layers[0].1 = vec![0.0; 3];
        let x = vec![0.5, -1.5, 2.0];
        // output layer is linear (no ReLU), so negatives pass through
        assert_eq!(p.forward(&x, 1), x);
    }

    #[test]
    fn train_step_reduces_loss_without_artifacts() {
        // pure-math check of the interpreter: tiny net, fixed task
        let dims = [4usize, 16, 3];
        let mut params = MlpParams::init(&dims, 9);
        let mut rng = Rng::new(31);
        let batch = 16;
        let mut step = |params: &mut MlpParams| -> f32 {
            let mut x = vec![0f32; batch * 4];
            let mut y = vec![0i32; batch];
            for b in 0..batch {
                for v in &mut x[b * 4..(b + 1) * 4] {
                    *v = rng.normal() as f32;
                }
                // label = argmax of first 3 coords: linearly separable
                let xs = &x[b * 4..(b + 1) * 4];
                let mut best = 0;
                for c in 1..3 {
                    if xs[c] > xs[best] {
                        best = c;
                    }
                }
                y[b] = best as i32;
            }
            train_step_raw(params, &x, &y, batch).unwrap()
        };
        let first = step(&mut params);
        let mut last = first;
        for _ in 0..60 {
            last = step(&mut params);
        }
        assert!(last < first * 0.8, "loss did not fall: {first} → {last}");
    }

    /// Train-step body without a manifest (test helper mirroring
    /// `mlp_train_step`'s shape plumbing).
    fn train_step_raw(
        params: &mut MlpParams,
        x: &[f32],
        y: &[i32],
        batch: usize,
    ) -> crate::util::error::Result<f32> {
        // fabricate a runtime whose manifest declares the right shapes:
        // (W1, b1, ..., Wn, bn, x, y), as aot.py records them
        use crate::runtime::artifacts::{ArtifactSig, TensorSig};
        let t = |shape: Vec<usize>, dtype: &str| TensorSig { shape, dtype: dtype.into() };
        let mut inputs = Vec::new();
        for w in params.dims.windows(2) {
            inputs.push(t(vec![w[0], w[1]], "float32"));
            inputs.push(t(vec![w[1]], "float32"));
        }
        inputs.push(t(vec![batch, params.dims[0]], "float32"));
        inputs.push(t(vec![batch], "int32"));
        let rt = ModelRuntime {
            manifest: ArtifactManifest {
                dir: std::path::PathBuf::new(),
                model_layers: params.dims.clone(),
                artifacts: vec![ArtifactSig {
                    name: "mlp_train".into(),
                    file: std::path::PathBuf::new(),
                    inputs,
                    outputs: vec![],
                }],
            },
        };
        rt.mlp_train_step(params, x, y)
    }

    #[test]
    fn matmul_artifact_multiplies() {
        let Some(rt) = runtime() else { return };
        let sig = rt.manifest.get("matmul").unwrap();
        let n = sig.inputs[0].shape[0];
        // x = I, y = arbitrary → x@y = y
        let mut x = vec![0f32; n * n];
        for i in 0..n {
            x[i * n + i] = 1.0;
        }
        let y: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32 * 0.25).collect();
        let out = rt.matmul(&x, &y).unwrap();
        assert_eq!(out, y);
    }

    #[test]
    fn infer_runs_and_is_deterministic() {
        let Some(rt) = runtime() else { return };
        let params = MlpParams::init(&rt.manifest.model_layers.clone(), 7);
        let sig = rt.manifest.get("mlp_infer").unwrap();
        let xin = sig.inputs.last().unwrap();
        let x: Vec<f32> = (0..xin.elements()).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let a = rt.mlp_infer(&params, &x).unwrap();
        let b = rt.mlp_infer(&params, &x).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), sig.outputs[0].elements());
        assert!(a.iter().all(|v| v.is_finite()));
    }
}
