//! Executable cache + typed entry points for the DL artifacts.
//!
//! One `PjRtLoadedExecutable` per artifact, compiled once at startup and
//! reused for every invocation — the request path never touches Python.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::artifacts::{ArtifactManifest, ArtifactSig};
use crate::util::prng::Rng;

/// MLP parameters as flat (W, b) float vectors in layer order — the
/// positional layout `python/compile/aot.py` records in the manifest.
#[derive(Debug, Clone)]
pub struct MlpParams {
    /// [(weights, biases)] per layer; weights are row-major (din, dout).
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
    pub dims: Vec<usize>,
}

impl MlpParams {
    /// Initialize with the same scheme as `model.init_params` (different
    /// RNG — numerical equivalence is established per-execution by
    /// feeding identical literals, not by matching Python's init).
    pub fn init(dims: &[usize], seed: u64) -> MlpParams {
        let mut rng = Rng::new(seed);
        let layers = dims
            .windows(2)
            .map(|w| {
                let (din, dout) = (w[0], w[1]);
                let scale = 0.05 * (2.0 / din as f64).sqrt() * (din as f64).sqrt();
                let weights = (0..din * dout).map(|_| (rng.normal() * scale) as f32).collect();
                (weights, vec![0f32; dout])
            })
            .collect();
        MlpParams { layers, dims: dims.to_vec() }
    }

    /// Flatten into PJRT literals (W1, b1, W2, b2, ...).
    pub fn to_literals(&self) -> Result<Vec<Literal>> {
        let mut out = Vec::with_capacity(self.layers.len() * 2);
        for (i, (w, b)) in self.layers.iter().enumerate() {
            let (din, dout) = (self.dims[i] as i64, self.dims[i + 1] as i64);
            out.push(Literal::vec1(w).reshape(&[din, dout])?);
            out.push(Literal::vec1(b));
        }
        Ok(out)
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|(w, b)| w.len() + b.len()).sum()
    }
}

/// The runtime: PJRT client + compiled executables.
pub struct ModelRuntime {
    pub manifest: ArtifactManifest,
    client: PjRtClient,
    executables: HashMap<String, PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Load and compile every artifact in the manifest directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<ModelRuntime> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut executables = HashMap::new();
        for art in &manifest.artifacts {
            let exe = Self::compile_artifact(&client, art)?;
            executables.insert(art.name.clone(), exe);
        }
        Ok(ModelRuntime { manifest, client, executables })
    }

    fn compile_artifact(client: &PjRtClient, art: &ArtifactSig) -> Result<PjRtLoadedExecutable> {
        let path = art
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", art.file))?;
        let proto = HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {path}: {e:?}"))
            .with_context(|| "HLO text artifact unreadable — rerun `make artifacts`")?;
        let comp = XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", art.name))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute an artifact with positional inputs; returns the flattened
    /// tuple outputs (aot.py lowers with return_tuple=True).
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let sig = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        if inputs.len() != sig.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            ));
        }
        let exe = &self.executables[name];
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        tuple.to_tuple().map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }

    /// Serve one inference batch: logits for `x` of shape (batch, d_in).
    pub fn mlp_infer(&self, params: &MlpParams, x: &[f32]) -> Result<Vec<f32>> {
        self.mlp_infer_with("mlp_infer", params, x)
    }

    /// Inference through a named artifact variant (`mlp_infer` embeds the
    /// Pallas kernel; `mlp_infer_fused` is the XLA-native-fusion build —
    /// see EXPERIMENTS.md §Perf for the comparison).
    pub fn mlp_infer_with(&self, artifact: &str, params: &MlpParams, x: &[f32]) -> Result<Vec<f32>> {
        let sig = self.manifest.get(artifact).ok_or_else(|| anyhow!("no {artifact} artifact"))?;
        let xin = &sig.inputs[sig.inputs.len() - 1];
        if x.len() != xin.elements() {
            return Err(anyhow!("x has {} elements, artifact wants {}", x.len(), xin.elements()));
        }
        let mut inputs = params.to_literals()?;
        inputs.push(Literal::vec1(x).reshape(&[xin.shape[0] as i64, xin.shape[1] as i64])?);
        let out = self.execute(artifact, &inputs)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))
    }

    /// One SGD training step; updates `params` in place, returns loss.
    pub fn mlp_train_step(&self, params: &mut MlpParams, x: &[f32], y: &[i32]) -> Result<f32> {
        let sig = self.manifest.get("mlp_train").ok_or_else(|| anyhow!("no mlp_train artifact"))?;
        let xin = &sig.inputs[sig.inputs.len() - 2];
        let mut inputs = params.to_literals()?;
        inputs.push(Literal::vec1(x).reshape(&[xin.shape[0] as i64, xin.shape[1] as i64])?);
        inputs.push(Literal::vec1(y));
        let out = self.execute("mlp_train", &inputs)?;
        // layout: (W1, b1, W2, b2, W3, b3, loss)
        if out.len() != params.layers.len() * 2 + 1 {
            return Err(anyhow!("unexpected train output arity {}", out.len()));
        }
        for (i, lw) in params.layers.iter_mut().enumerate() {
            lw.0 = out[2 * i].to_vec::<f32>().map_err(|e| anyhow!("W{i}: {e:?}"))?;
            lw.1 = out[2 * i + 1].to_vec::<f32>().map_err(|e| anyhow!("b{i}: {e:?}"))?;
        }
        out.last().unwrap().get_first_element::<f32>().map_err(|e| anyhow!("loss: {e:?}"))
    }

    /// Run the standalone Pallas-matmul artifact.
    pub fn matmul(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let sig = self.manifest.get("matmul").ok_or_else(|| anyhow!("no matmul artifact"))?;
        let (a, b) = (&sig.inputs[0], &sig.inputs[1]);
        let xs = Literal::vec1(x).reshape(&[a.shape[0] as i64, a.shape[1] as i64])?;
        let ys = Literal::vec1(y).reshape(&[b.shape[0] as i64, b.shape[1] as i64])?;
        let out = self.execute("matmul", &[xs, ys])?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("matmul out: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    //! These tests need `make artifacts` to have run; they are skipped
    //! (not failed) when artifacts are absent so `cargo test` works in a
    //! fresh checkout. `rust/tests/integration_runtime.rs` asserts the
    //! full numerics.

    use super::*;

    fn runtime() -> Option<ModelRuntime> {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(ModelRuntime::load(dir).expect("runtime loads"))
    }

    #[test]
    fn params_flatten_in_layer_order() {
        let p = MlpParams::init(&[4, 8, 2], 1);
        assert_eq!(p.param_count(), 4 * 8 + 8 + 8 * 2 + 2);
        let lits = p.to_literals().unwrap();
        assert_eq!(lits.len(), 4);
        assert_eq!(lits[0].element_count(), 32);
        assert_eq!(lits[1].element_count(), 8);
    }

    #[test]
    fn matmul_artifact_multiplies() {
        let Some(rt) = runtime() else { return };
        let sig = rt.manifest.get("matmul").unwrap();
        let n = sig.inputs[0].shape[0];
        // x = I, y = arbitrary → x@y = y
        let mut x = vec![0f32; n * n];
        for i in 0..n {
            x[i * n + i] = 1.0;
        }
        let y: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32 * 0.25).collect();
        let out = rt.matmul(&x, &y).unwrap();
        assert_eq!(out, y);
    }

    #[test]
    fn infer_runs_and_is_deterministic() {
        let Some(rt) = runtime() else { return };
        let params = MlpParams::init(&rt.manifest.model_layers.clone(), 7);
        let sig = rt.manifest.get("mlp_infer").unwrap();
        let xin = sig.inputs.last().unwrap();
        let x: Vec<f32> = (0..xin.elements()).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let a = rt.mlp_infer(&params, &x).unwrap();
        let b = rt.mlp_infer(&params, &x).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), sig.outputs[0].elements());
        assert!(a.iter().all(|v| v.is_finite()));
    }
}
