//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one input/output tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<TensorSig> {
        let shape = v
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("tensor sig missing shape"))?
            .iter()
            .map(|d| d.as_u64().map(|x| x as usize).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow!("tensor sig missing dtype"))?
            .to_string();
        Ok(TensorSig { shape, dtype })
    }
}

/// One artifact's signature.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub model_layers: Vec<usize>,
    pub artifacts: Vec<ArtifactSig>,
}

impl ArtifactManifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let model_layers = v
            .get("model_layers")
            .and_then(|l| l.as_arr())
            .ok_or_else(|| anyhow!("manifest missing model_layers"))?
            .iter()
            .filter_map(|d| d.as_u64().map(|x| x as usize))
            .collect();
        let arts = match v.get("artifacts") {
            Some(Json::Obj(map)) => map,
            _ => return Err(anyhow!("manifest missing artifacts")),
        };
        let mut artifacts = Vec::new();
        for (name, a) in arts {
            let file = dir.join(
                a.get("file").and_then(|f| f.as_str()).ok_or_else(|| anyhow!("missing file"))?,
            );
            let parse_list = |key: &str| -> Result<Vec<TensorSig>> {
                a.get(key)
                    .and_then(|l| l.as_arr())
                    .ok_or_else(|| anyhow!("missing {key}"))?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect()
            };
            artifacts.push(ArtifactSig {
                name: name.clone(),
                file,
                inputs: parse_list("inputs")?,
                outputs: parse_list("outputs")?,
            });
        }
        Ok(ArtifactManifest { dir, model_layers, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSig> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Default artifact dir: `$PORTER_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PORTER_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        let text = r#"{
  "model_layers": [768, 1024, 1024, 10],
  "artifacts": {
    "matmul": {
      "file": "matmul.hlo.txt",
      "inputs": [
        {"shape": [256, 256], "dtype": "float32"},
        {"shape": [256, 256], "dtype": "float32"}
      ],
      "outputs": [{"shape": [256, 256], "dtype": "float32"}]
    }
  }
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!("porter-mani-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.model_layers, vec![768, 1024, 1024, 10]);
        let a = m.get("matmul").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].elements(), 256 * 256);
        assert_eq!(a.outputs[0].shape, vec![256, 256]);
        assert!(m.get("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_error_with_hint() {
        let err = ArtifactManifest::load("/nonexistent-porter-dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
