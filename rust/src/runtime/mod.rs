//! DL runtime: load the AOT artifact manifest and execute the model
//! natively.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! request-path half. The artifact manifest pins each entry point's
//! signature; [`executor::ModelRuntime`] executes the same math the HLO
//! artifacts lower (MLP forward, softmax-CE SGD step, matmul) with a
//! pure-Rust reference interpreter, so the request path needs neither
//! Python nor an XLA runtime. The original PJRT-backed executor is in
//! git history and can be reinstated by vendoring the `xla` crate.

pub mod artifacts;
pub mod executor;

pub use artifacts::{ArtifactManifest, ArtifactSig, TensorSig};
pub use executor::{MlpParams, ModelRuntime};
