//! PJRT runtime: load the AOT HLO artifacts and execute them natively.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! request-path half — `PjRtClient::cpu()` compiles each
//! `artifacts/*.hlo.txt` once, then invocations execute the cached
//! executable with concrete literals.

pub mod artifacts;
pub mod executor;

pub use artifacts::{ArtifactManifest, ArtifactSig, TensorSig};
pub use executor::{ModelRuntime, MlpParams};
