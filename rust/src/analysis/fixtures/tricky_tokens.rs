//! Lexer stress: every bad pattern appears here — but only inside
//! strings, comments, raw strings, or as harmless look-alikes. A lint
//! that matches text instead of tokens fires all over this file.
//! Expected: no findings, no directive errors.
//!
//! Prose mention of the directive syntax (not a directive): the
//! detlint allow(D1, reason = "...") form is documented in DESIGN.md.

use std::collections::HashMap;

/* block comment: HashMap.iter() Instant::now() SystemTime RandomState
   /* nested: for k in &map { } DefaultHasher .sum::<f64>() */
   still inside the outer comment */

pub struct Doc<'a> {
    pub title: &'a str,
    store: HashMap<String, u64>,
}

pub fn render(doc: &Doc<'_>) -> String {
    // line comment: self.token = mix(self.token, 1) — never fires
    let help = "usage: .keys() .values() .drain() Instant::now() thread_rng()";
    let raw = r#"raw string with "quotes" and HashMap.iter() inside"#;
    let bytes = b"SystemTime::now() in a byte string";
    let sep = '\'';
    let nl = '\n';
    let plain = 'x';
    format!("{help}{raw}{:?}{sep}{nl}{plain}{}", bytes, doc.title)
}

pub fn look_alikes(doc: &Doc<'_>, pipe: &mut Vec<u64>) -> u64 {
    // `values` as a plain variable, not a map method
    let values = [1u64, 2, 3];
    // `.drain()` on a Vec — receiver is not a hash collection
    let drained: u64 = pipe.drain(..).sum();
    // `.elapsed_micros()` is the audited hosttime accessor, not `.elapsed()`
    // lookups on the real map stay legal
    let hit = doc.store.get("k").copied().unwrap_or(0);
    // ranges and float method chains keep their tokens separate
    let mut acc = 0u64;
    for i in 0..values.len() {
        acc = acc.wrapping_add(values[i]);
    }
    let clamped = 1.5f64.max(0.5).min(2.0);
    acc + drained + hit + clamped as u64
}

pub fn nested_generics(m: &Vec<HashMap<u64, Vec<u8>>>) -> usize {
    // a HashMap in a parameter's generic position registers no binding
    m.len()
}
