//! D3 known-bad: float accumulation in a thread-spawning file, outside
//! settle-ordered code. Expected: D3 fires on the `+=` and the
//! `.sum::<f64>()`.

pub struct ShardStat {
    wait_sum_ns: f64,
    pub events: u64,
}

pub fn fan_out(shards: &mut [ShardStat]) {
    std::thread::scope(|scope| {
        for shard in shards.iter_mut() {
            scope.spawn(move || {
                shard.events += 1;
            });
        }
    });
}

impl ShardStat {
    pub fn absorb(&mut self, other: &ShardStat) {
        // BAD: merge order is shard-completion order → bits differ per run
        self.wait_sum_ns += other.wait_sum_ns;
        self.events += other.events;
    }
}

pub fn grand_total(stats: &[ShardStat]) -> f64 {
    // BAD: f64 addition is not associative; slice order is fine but this
    // file's stats arrive in completion order upstream
    stats.iter().map(|s| s.wait_sum_ns).sum::<f64>()
}
