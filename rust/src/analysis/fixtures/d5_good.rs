//! D5 known-good twin: the token is mixed only in `settle` and
//! `apply_fault` — phase-A code that runs in deterministic index order
//! after the epoch barrier. Expected: no findings.

fn mix(h: u64, v: u64) -> u64 {
    let z = h.rotate_left(13) ^ v;
    z.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

pub struct Cluster {
    token: u64,
    events: u64,
}

impl Cluster {
    pub fn settle(&mut self, epoch: u64) {
        // GOOD: settle() walks shards 0..K in index order
        self.token = mix(self.token, epoch);
    }

    pub fn apply_fault(&mut self, fault_id: u64) {
        // GOOD: fault application is epoch-barrier-ordered too
        self.token = mix(self.token, fault_id);
    }

    pub fn checksum(&self) -> u64 {
        // GOOD: mixing plain values (not the token) is unconstrained
        let h = mix(self.events, 17);
        mix(h, 23)
    }
}
