//! D3 known-good twin: the same accumulation, but inside `settle()` —
//! the epoch-barrier merge that visits shards in index order — plus
//! integer accumulation, which is associative and always legal.
//! Expected: no findings.

pub struct ShardStat {
    wait_sum_ns: f64,
    pub events: u64,
}

pub fn fan_out(shards: &mut [ShardStat]) {
    std::thread::scope(|scope| {
        for shard in shards.iter_mut() {
            scope.spawn(move || {
                shard.events += 1;
            });
        }
    });
}

pub fn settle(stats: &mut [ShardStat]) -> f64 {
    // GOOD: settle() runs after the barrier, walking shards 0..K in
    // index order, so the float sum is bit-stable for any K
    let mut total = 0.0f64;
    for s in stats.iter() {
        total += s.wait_sum_ns;
    }
    stats.iter().map(|s| s.wait_sum_ns).sum::<f64>()
}

pub fn event_count(stats: &[ShardStat]) -> u64 {
    // GOOD: integer accumulation is order-insensitive
    stats.iter().map(|s| s.events).sum::<u64>()
}
