//! D2 known-good twin: virtual time only; `Duration` the value type is
//! fine anywhere. Expected: no findings.

use std::time::Duration;

pub struct Clock {
    now_ns: u64,
}

impl Clock {
    pub fn advance(&mut self, by: Duration) {
        // GOOD: simulation time is a counter, not a wall-clock read
        self.now_ns += by.as_nanos() as u64;
    }

    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    pub fn budget() -> Duration {
        Duration::from_micros(250)
    }
}
