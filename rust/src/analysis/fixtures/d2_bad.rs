//! D2 known-bad: host-clock reads on a simulation path.
//! Expected: D2 fires on the `Instant::now()`, `SystemTime`, and
//! `.elapsed()` sites.

use std::time::Instant;

pub fn run_epoch(work: impl Fn()) -> u64 {
    // BAD: wall-clock read feeding a value a report could observe
    let started = Instant::now();
    work();
    // BAD: and reading it back
    started.elapsed().as_micros() as u64
}

pub fn stamp() -> u64 {
    // BAD: wall-clock epoch on a decision path
    let t = std::time::SystemTime::now();
    t.duration_since(std::time::UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}
