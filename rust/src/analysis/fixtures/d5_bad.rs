//! D5 known-bad: mixing the determinism token from dispatch code.
//! Expected: D5 fires on the `self.token = mix(...)` in `dispatch`.

fn mix(h: u64, v: u64) -> u64 {
    let z = h.rotate_left(13) ^ v;
    z.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

pub struct Cluster {
    token: u64,
    inflight: u64,
}

impl Cluster {
    pub fn dispatch(&mut self, invocation_id: u64) {
        self.inflight += 1;
        // BAD: phase-B dispatch order is worker-completion order under
        // --shards, so mixing here makes the token shard-dependent
        self.token = mix(self.token, invocation_id);
    }
}
