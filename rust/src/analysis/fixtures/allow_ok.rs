//! Allow-directive round trip: real violations, each suppressed with a
//! written reason — one own-line directive, one trailing. Expected: no
//! findings, no errors, `allows_used == 2`, nothing stale.

use std::collections::HashMap;

pub struct Shapes {
    by_key: HashMap<u64, u64>,
}

impl Shapes {
    pub fn total(&self) -> u64 {
        let mut acc = 0u64;
        // detlint: allow(D1, reason = "wrapping u64 fold is order-insensitive")
        for v in self.by_key.values() {
            acc = acc.wrapping_add(*v);
        }
        acc
    }

    pub fn host_probe(&self) -> u64 {
        let t0 = std::time::Instant::now(); // detlint: allow(D2, reason = "host metric only; excluded from report equality")
        drop(t0);
        0
    }
}
