//! `#[cfg(test)]` code is exempt by default (`skip_test_code = true`):
//! tests may time themselves and iterate maps freely — the lints defend
//! simulation decision paths, not test scaffolding.
//! Expected: no findings with the default config.

use std::collections::HashMap;

pub struct Registry {
    by_name: HashMap<String, u64>,
}

impl Registry {
    pub fn lookup(&self, name: &str) -> Option<u64> {
        self.by_name.get(name).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_and_iteration_are_fine_in_tests() {
        let t0 = std::time::Instant::now();
        let r = Registry { by_name: HashMap::new() };
        let total: u64 = r.by_name.values().sum();
        assert_eq!(total, 0);
        assert!(t0.elapsed().as_secs() < 60);
    }
}
