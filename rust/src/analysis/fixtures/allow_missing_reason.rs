//! A suppression without a reason is a hard error, not a warning — the
//! whole point of the directive grammar is that every allow documents
//! *why* the site is safe. Expected: one directive error.

use std::collections::HashMap;

pub struct Shapes {
    by_key: HashMap<u64, u64>,
}

impl Shapes {
    pub fn total(&self) -> u64 {
        // detlint: allow(D1)
        self.by_key.values().fold(0, |a, v| a.wrapping_add(*v))
    }
}
