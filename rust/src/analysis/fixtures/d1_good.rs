//! D1 known-good twin: lookup-only maps and sorted iteration.
//! Expected: no findings — point lookups and inserts are always legal,
//! and order-sensitive walks go through a sorted `Vec`.

use std::collections::{HashMap, HashSet};

pub struct Registry {
    by_name: HashMap<String, u64>,
    resident: HashSet<u64>,
    /// Insertion-ordered mirror for deterministic walks.
    order: Vec<String>,
}

impl Registry {
    pub fn insert(&mut self, name: String, v: u64) {
        if self.by_name.insert(name.clone(), v).is_none() {
            self.order.push(name);
        }
    }

    pub fn lookup(&self, name: &str) -> Option<u64> {
        self.by_name.get(name).copied()
    }

    pub fn is_resident(&self, page: u64) -> bool {
        self.resident.contains(&page)
    }

    pub fn total(&self) -> u64 {
        // GOOD: the walk follows the deterministic insertion order
        self.order.iter().filter_map(|n| self.by_name.get(n)).fold(0, |a, v| a.wrapping_add(*v))
    }
}
