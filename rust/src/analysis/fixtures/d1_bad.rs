//! D1 known-bad: iterating hash collections on a decision path.
//! Expected: D1 fires on the `.iter()`, `.keys()`, and `for … in` sites.

use std::collections::{HashMap, HashSet};

pub struct Registry {
    by_name: HashMap<String, u64>,
    resident: HashSet<u64>,
}

impl Registry {
    pub fn total(&self) -> u64 {
        // BAD: visit order is per-process random; any order-sensitive
        // consumer (first-wins, tie-break, float sum) diverges per run
        self.by_name.iter().map(|(_, v)| *v).fold(0, u64::wrapping_add)
    }

    pub fn first_name(&self) -> Option<String> {
        // BAD: "first" key is nondeterministic
        self.by_name.keys().next().cloned()
    }

    pub fn evict_all(&mut self, out: &mut Vec<u64>) {
        // BAD: eviction order drives downstream placement decisions
        for page in &self.resident {
            out.push(*page);
        }
    }
}
