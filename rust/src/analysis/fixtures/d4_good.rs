//! D4 known-good twin: explicitly seeded generators only (the repo's
//! `util::prng` SplitMix64 idiom). Expected: no findings.

pub struct SplitMix {
    state: u64,
}

impl SplitMix {
    /// Every stream derives from an explicit caller-provided seed.
    pub fn seeded(seed: u64) -> SplitMix {
        SplitMix { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

pub fn bucket_of(addr: u64, buckets: u64, seed: u64) -> u64 {
    // GOOD: same seed → same bucket, run after run
    let mut g = SplitMix::seeded(seed ^ addr);
    g.next_u64() % buckets
}
