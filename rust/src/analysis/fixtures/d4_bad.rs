//! D4 known-bad: unseeded randomness / hashing feeding decisions.
//! Expected: D4 fires on the `DefaultHasher` and `RandomState` sites.

use std::collections::hash_map::DefaultHasher;
use std::hash::{BuildHasher, Hasher};

pub fn bucket_of(addr: u64, buckets: u64) -> u64 {
    // BAD: DefaultHasher is SipHash with a per-process random key —
    // the same address lands in different buckets every run
    let mut h = DefaultHasher::new();
    h.write_u64(addr);
    h.finish() % buckets
}

pub fn probe(addr: u64) -> u64 {
    // BAD: RandomState reseeds per process
    let state = std::collections::hash_map::RandomState::new();
    let mut h = state.build_hasher();
    h.write_u64(addr);
    h.finish()
}
