//! Hand-rolled Rust token scanner for the determinism lints.
//!
//! Same spirit as the in-crate TOML/JSON parsers: a small, dependency-free
//! scanner that understands exactly as much Rust as the lints need — line
//! and nested block comments, string / raw-string / byte-string / char
//! literals, lifetimes, identifiers, numbers, and single-character
//! punctuation (multi-character operators like `::`, `+=`, or `>>` arrive
//! as consecutive punct tokens; the lint patterns match the sequences).
//!
//! The scanner also extracts suppression directives from line comments:
//!
//! ```text
//!     // detlint: allow(D1, reason = "keys are sorted before use")
//! ```
//!
//! A directive suppresses matching findings on its own line (trailing
//! comment) or the line directly below (own-line comment). The `reason`
//! is mandatory — a directive without one is a hard error, not a warning,
//! so suppressions always document *why* the site is safe.

/// Token classes the lints distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Str,
    Char,
    Num,
    Lifetime,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

/// A parsed `// detlint: allow(...)` directive.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    pub rules: Vec<String>,
    pub reason: String,
}

/// Scanner output: tokens, suppression directives, and directive syntax
/// errors (line, message).
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub allows: Vec<Allow>,
    pub errors: Vec<(u32, String)>,
}

/// Rule names a directive may reference.
pub const RULE_NAMES: [&str; 5] = ["D1", "D2", "D3", "D4", "D5"];

pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[start..j].iter().collect();
            scan_directive(&text, line, &mut out);
            i = j;
        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            // nested block comment; directives are line-comment-only
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
        } else if c == '"' {
            i = scan_string(&chars, i, &mut line, &mut out);
        } else if c == '\'' {
            i = scan_quote(&chars, i, line, &mut out);
        } else if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n {
                if chars[j].is_ascii_alphanumeric() || chars[j] == '_' {
                    j += 1;
                } else if chars[j] == '.' && j + 1 < n && chars[j + 1].is_ascii_digit() {
                    // `1.5` continues the number; `0..n` and `1.0.max(x)`
                    // stop so ranges and method calls keep their tokens
                    j += 1;
                } else {
                    break;
                }
            }
            out.tokens.push(Tok {
                kind: TokKind::Num,
                text: chars[start..j].iter().collect(),
                line,
            });
            i = j;
        } else if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < n && (chars[j].is_ascii_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            let text: String = chars[start..j].iter().collect();
            // raw / byte string prefixes: r"..", r#".."#, b"..", br#".."#
            let raw_start = matches!(text.as_str(), "r" | "b" | "br" | "rb")
                && j < n
                && (chars[j] == '"' || (chars[j] == '#' && text != "b"));
            if raw_start && text == "b" {
                // plain byte string b".." — ordinary escape rules
                i = scan_string(&chars, j, &mut line, &mut out);
            } else if raw_start {
                i = scan_raw_string(&chars, j, &mut line, &mut out);
            } else if text == "b" && j < n && chars[j] == '\'' {
                // byte char b'x'
                i = scan_quote(&chars, j, line, &mut out);
            } else {
                out.tokens.push(Tok { kind: TokKind::Ident, text, line });
                i = j;
            }
        } else {
            out.tokens.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    out
}

/// Scan a `"..."` literal starting at the opening quote; returns the
/// index past the closing quote. Tracks embedded newlines.
fn scan_string(chars: &[char], open: usize, line: &mut u32, out: &mut Lexed) -> usize {
    let start_line = *line;
    let n = chars.len();
    let mut j = open + 1;
    while j < n {
        match chars[j] {
            '\\' => j += 2,
            '\n' => {
                *line += 1;
                j += 1;
            }
            '"' => {
                j += 1;
                break;
            }
            _ => j += 1,
        }
    }
    out.tokens.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
    j
}

/// Scan `#*"` ... `"#*` after a raw-string prefix ident; `open` points at
/// the first `#` or the quote.
fn scan_raw_string(chars: &[char], open: usize, line: &mut u32, out: &mut Lexed) -> usize {
    let start_line = *line;
    let n = chars.len();
    let mut hashes = 0usize;
    let mut j = open;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && chars[j] == '"' {
        j += 1;
    }
    while j < n {
        if chars[j] == '\n' {
            *line += 1;
            j += 1;
        } else if chars[j] == '"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < n && chars[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                j += 1 + hashes;
                break;
            }
            j += 1;
        } else {
            j += 1;
        }
    }
    out.tokens.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
    j
}

/// Disambiguate a `'` into a char literal or a lifetime.
fn scan_quote(chars: &[char], open: usize, line: u32, out: &mut Lexed) -> usize {
    let n = chars.len();
    if open + 1 < n && chars[open + 1] == '\\' {
        // escaped char literal: the escaped character itself may be a
        // quote (`'\''`), so the closing-quote search starts after it
        let mut j = open + 3;
        while j < n && chars[j] != '\'' {
            j += 1;
        }
        out.tokens.push(Tok { kind: TokKind::Char, text: String::new(), line });
        return (j + 1).min(n);
    }
    if open + 2 < n && chars[open + 2] == '\'' {
        out.tokens.push(Tok { kind: TokKind::Char, text: String::new(), line });
        return open + 3;
    }
    // lifetime: 'ident
    let mut j = open + 1;
    while j < n && (chars[j].is_ascii_alphanumeric() || chars[j] == '_') {
        j += 1;
    }
    let text: String = chars[open + 1..j].iter().collect();
    out.tokens.push(Tok { kind: TokKind::Lifetime, text, line });
    j
}

/// Parse a line comment's text for a `detlint: allow(...)` directive.
/// Only comments that *start* with `detlint:` (after trimming) count, so
/// prose that mentions the syntax never parses as a directive.
fn scan_directive(text: &str, line: u32, out: &mut Lexed) {
    let t = text.trim();
    let Some(rest) = t.strip_prefix("detlint:") else {
        return;
    };
    let rest = rest.trim();
    let Some(inner) = rest.strip_prefix("allow(").and_then(|r| r.rfind(')').map(|p| &r[..p]))
    else {
        out.errors.push((line, format!("malformed detlint directive: {t:?} (expected `detlint: allow(D*, reason = \"...\")`)")));
        return;
    };
    let (rules_part, reason) = match inner.find("reason") {
        None => {
            out.errors.push((line, "detlint allow without a reason — every suppression must say why the site is safe".to_string()));
            return;
        }
        Some(pos) => {
            let after = inner[pos + "reason".len()..].trim_start();
            let Some(val) = after.strip_prefix('=') else {
                out.errors.push((line, "detlint allow: expected `reason = \"...\"`".to_string()));
                return;
            };
            let val = val.trim();
            let stripped = val
                .strip_prefix('"')
                .and_then(|v| v.rfind('"').map(|p| &v[..p]))
                .map(str::to_string);
            let Some(reason) = stripped else {
                out.errors.push((line, "detlint allow: reason must be a quoted string".to_string()));
                return;
            };
            (inner[..pos].trim_end().trim_end_matches(','), reason)
        }
    };
    if reason.trim().is_empty() {
        out.errors.push((line, "detlint allow: empty reason".to_string()));
        return;
    }
    let mut rules = Vec::new();
    for r in rules_part.split(',') {
        let r = r.trim();
        if r.is_empty() {
            continue;
        }
        if !RULE_NAMES.contains(&r) {
            out.errors.push((line, format!("detlint allow: unknown rule {r:?} (known: D1..D5)")));
            return;
        }
        rules.push(r.to_string());
    }
    if rules.is_empty() {
        out.errors.push((line, "detlint allow: no rules named".to_string()));
        return;
    }
    out.allows.push(Allow { line, rules, reason });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_tokens() {
        let src = r##"
let a = "HashMap .iter() Instant::now()"; // HashSet in a comment
/* block DefaultHasher /* nested SystemTime */ still comment */
let b = r#"raw "quoted" Instant"#;
"##;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "a", "let", "b"]);
    }

    #[test]
    fn raw_and_byte_strings_track_lines() {
        let src = "let s = r#\"one\ntwo\nthree\"#;\nlet t = b\"bytes\";\nlet u = 1;";
        let lexed = lex(src);
        let u = lexed.tokens.iter().find(|t| t.is_ident("u")).unwrap();
        assert_eq!(u.line, 4, "line counting must survive embedded newlines");
        assert_eq!(lexed.tokens.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a u32) { let c = 'x'; let d = '\\n'; let e = '\\''; }";
        let lexed = lex(src);
        let lifetimes: Vec<_> =
            lexed.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(lifetimes.iter().all(|t| t.text == "a"));
        assert_eq!(lexed.tokens.iter().filter(|t| t.kind == TokKind::Char).count(), 3);
    }

    #[test]
    fn nested_generics_tokenize_without_confusion() {
        let src = "let m: Vec<HashMap<u64, Vec<u8>>> = make();";
        let ids = idents(src);
        assert!(ids.contains(&"HashMap".to_string()));
        let lexed = lex(src);
        // `>>>` arrives as three single-char puncts
        let gt = lexed.tokens.iter().filter(|t| t.is_punct('>')).count();
        assert_eq!(gt, 3);
    }

    #[test]
    fn directive_round_trip() {
        let src = "let x = 1; // detlint: allow(D1, reason = \"sorted, then consumed\")\n\
                   // detlint: allow(D2, D4, reason = \"a, reason with, commas\")\n";
        let lexed = lex(src);
        assert!(lexed.errors.is_empty(), "{:?}", lexed.errors);
        assert_eq!(lexed.allows.len(), 2);
        assert_eq!(lexed.allows[0].line, 1);
        assert_eq!(lexed.allows[0].rules, vec!["D1"]);
        assert_eq!(lexed.allows[1].rules, vec!["D2", "D4"]);
        assert_eq!(lexed.allows[1].reason, "a, reason with, commas");
    }

    #[test]
    fn directive_without_reason_is_an_error() {
        let lexed = lex("// detlint: allow(D1)\n");
        assert!(lexed.allows.is_empty());
        assert_eq!(lexed.errors.len(), 1);
        assert!(lexed.errors[0].1.contains("reason"), "{}", lexed.errors[0].1);
    }

    #[test]
    fn directive_with_unknown_rule_is_an_error() {
        let lexed = lex("// detlint: allow(D9, reason = \"nope\")\n");
        assert!(lexed.allows.is_empty());
        assert_eq!(lexed.errors.len(), 1);
        assert!(lexed.errors[0].1.contains("unknown rule"), "{}", lexed.errors[0].1);
    }

    #[test]
    fn prose_mentioning_detlint_is_not_a_directive() {
        let lexed = lex("// the detlint: allow(...) syntax is documented in DESIGN.md\n");
        assert!(lexed.allows.is_empty());
        assert!(lexed.errors.is_empty());
    }
}
