//! `detlint.toml` — configuration for the determinism lints.
//!
//! Parsed with the in-crate TOML subset ([`crate::config::toml`]), which
//! has no arrays, so every list is a comma-separated string (the same
//! idiom as `[provision] ladder`). Path entries are prefixes of
//! forward-slash paths relative to the directory holding the config file
//! (the repo root for the checked-in `detlint.toml`).

use crate::config::toml::TomlDoc;

/// Everything the lint pass needs to know beyond the source text.
#[derive(Debug, Clone, PartialEq)]
pub struct DetlintConfig {
    /// Skip `#[cfg(test)]` items: tests may time themselves and iterate
    /// freely — the lints defend *simulation decision paths*.
    pub skip_test_code: bool,
    /// Directory roots to walk for `.rs` files.
    pub scan: Vec<String>,
    /// Path prefixes excluded from the walk (the lint's own fixture
    /// corpus is deliberately full of violations).
    pub exclude: Vec<String>,
    /// D1: module prefixes whose hash-map iteration order is declared
    /// harmless (none today — per-site allows carry the reasons).
    pub d1_order_insensitive: Vec<String>,
    /// D2: the only paths allowed to read host time.
    pub d2_host_time_ok: Vec<String>,
    /// D3: functions that run in deterministic merge order, where f64
    /// accumulation across shard results is sound.
    pub d3_settle_fns: Vec<String>,
    /// D4: modules that own the seeded generators.
    pub d4_seeded_modules: Vec<String>,
    /// D5: functions allowed to mix the determinism token.
    pub d5_mix_fns: Vec<String>,
}

impl Default for DetlintConfig {
    fn default() -> DetlintConfig {
        DetlintConfig {
            skip_test_code: true,
            scan: list("rust/src,rust/benches"),
            exclude: list("rust/src/analysis/fixtures"),
            d1_order_insensitive: Vec::new(),
            d2_host_time_ok: list(
                "rust/src/bench,rust/src/cli,rust/src/main.rs,\
                 rust/src/util/hosttime.rs,rust/benches",
            ),
            d3_settle_fns: list("settle,finish"),
            d4_seeded_modules: list("rust/src/util/prng.rs,rust/src/testing"),
            d5_mix_fns: list("settle,apply_fault"),
        }
    }
}

fn list(csv: &str) -> Vec<String> {
    csv.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
}

impl DetlintConfig {
    pub fn from_toml(text: &str) -> Result<DetlintConfig, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = DetlintConfig::default();
        for (section, key, value) in doc.entries() {
            let slot = match (section, key) {
                ("detlint", "skip_test_code") => {
                    cfg.skip_test_code = value.as_bool()?;
                    continue;
                }
                ("paths", "scan") => &mut cfg.scan,
                ("paths", "exclude") => &mut cfg.exclude,
                ("d1", "order_insensitive") => &mut cfg.d1_order_insensitive,
                ("d2", "host_time_ok") => &mut cfg.d2_host_time_ok,
                ("d3", "settle_fns") => &mut cfg.d3_settle_fns,
                ("d4", "seeded_modules") => &mut cfg.d4_seeded_modules,
                ("d5", "mix_fns") => &mut cfg.d5_mix_fns,
                _ => return Err(format!("detlint.toml: unknown key [{section}] {key}")),
            };
            *slot = list(value.as_str()?);
        }
        if cfg.scan.is_empty() {
            return Err("detlint.toml: [paths] scan must name at least one root".into());
        }
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<DetlintConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        DetlintConfig::from_toml(&text)
    }
}

/// Does a normalized relative path fall under one of the prefixes?
pub fn path_matches(path: &str, prefixes: &[String]) -> bool {
    prefixes.iter().any(|p| path == p || path.starts_with(&format!("{p}/")))
}

/// Normalize a path for matching: forward slashes, no leading `./`.
pub fn normalize(path: &str) -> String {
    let p = path.replace('\\', "/");
    p.strip_prefix("./").unwrap_or(&p).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn committed_config_parses_and_covers_the_defaults() {
        let text = include_str!("../../../detlint.toml");
        let cfg = DetlintConfig::from_toml(text).expect("checked-in detlint.toml must parse");
        assert!(cfg.skip_test_code);
        assert!(cfg.scan.contains(&"rust/src".to_string()));
        assert!(cfg.exclude.iter().any(|e| e.contains("fixtures")));
        assert!(cfg.d2_host_time_ok.iter().any(|p| p.contains("hosttime")));
        assert!(cfg.d3_settle_fns.contains(&"settle".to_string()));
        assert!(cfg.d5_mix_fns.contains(&"apply_fault".to_string()));
    }

    #[test]
    fn unknown_keys_fail_loudly() {
        let e = DetlintConfig::from_toml("[detlint]\ntypo_key = true\n").unwrap_err();
        assert!(e.contains("unknown key"), "{e}");
    }

    #[test]
    fn prefix_matching_is_component_wise() {
        let pre = vec!["rust/src/bench".to_string(), "rust/src/main.rs".to_string()];
        assert!(path_matches("rust/src/bench/mod.rs", &pre));
        assert!(path_matches("rust/src/main.rs", &pre));
        assert!(!path_matches("rust/src/benchmarks.rs", &pre));
        assert!(!path_matches("rust/src/bench.rs", &pre));
    }

    #[test]
    fn normalize_strips_dot_prefix() {
        assert_eq!(normalize("./rust/src/lib.rs"), "rust/src/lib.rs");
    }
}
