//! detlint — static analysis for the simulator's determinism invariants.
//!
//! Every headline claim of this reproduction is a determinism claim:
//! live == replay (Trace-IR replay identity), `--shards K` bit-identity,
//! and disabled-path bit-identity for each optional feature. Those were
//! defended by hand-audits and property tests; this subsystem makes them
//! machine-checked on every push, before any bench number is believed.
//!
//! Layout:
//! * [`lexer`] — hand-rolled Rust token scanner (same spirit as the
//!   in-crate TOML/JSON parsers) + `detlint: allow(...)` directives
//! * [`lints`] — the D1–D5 rules over the token stream
//! * [`config`] — `detlint.toml`, parsed by the in-crate TOML subset
//! * `fixtures/` — known-bad / known-good corpus pinning each rule's
//!   behavior (excluded from the tree walk; exercised by tests here)
//!
//! Entry points: the `detlint` binary (`src/bin/detlint.rs`) and the
//! `porter-cli detlint` subcommand both land in [`cli_main`]. Output is
//! a rustc-style `file:line: D2: ...` report plus one greppable line:
//!
//! ```text
//! DETLINT files=93 violations=0 allows=4
//! ```
//!
//! Exit status: 0 clean, 1 violations or directive errors, 2 usage /
//! configuration errors. Unused allows are warnings, not failures —
//! they surface stale suppressions without blocking CI on refactors.

pub mod config;
pub mod lexer;
pub mod lints;

use std::path::{Path, PathBuf};

use self::config::{normalize, path_matches, DetlintConfig};
use self::lints::Violation;

/// Aggregate result of linting a tree.
#[derive(Debug, Default)]
pub struct RunSummary {
    /// Files scanned (after exclusions).
    pub files: usize,
    /// Surviving findings, sorted by (file, line, rule).
    pub violations: Vec<Violation>,
    /// Directive syntax errors — never suppressible.
    pub errors: Vec<Violation>,
    /// Suppressions that matched a finding.
    pub allows_used: usize,
    /// Stale suppressions: (file, line, rules-csv).
    pub allows_unused: Vec<(String, u32, String)>,
}

impl RunSummary {
    pub fn clean(&self) -> bool {
        self.violations.is_empty() && self.errors.is_empty()
    }

    /// The greppable counter line (CI greps `violations=0`).
    pub fn counter_line(&self) -> String {
        format!(
            "DETLINT files={} violations={} allows={}",
            self.files,
            self.violations.len() + self.errors.len(),
            self.allows_used
        )
    }
}

/// Walk the configured scan roots under `base` (the directory holding
/// `detlint.toml`) and lint every `.rs` file. Deterministic: directory
/// entries are sorted, so reports never depend on readdir order.
pub fn run(base: &Path, cfg: &DetlintConfig) -> Result<RunSummary, String> {
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    for root in &cfg.scan {
        let abs = base.join(root);
        if abs.is_file() {
            files.push((normalize(root), abs));
        } else if abs.is_dir() {
            walk(&abs, root, &cfg.exclude, &mut files)?;
        } else {
            return Err(format!(
                "scan root `{root}` not found under {} — fix [paths] scan in detlint.toml",
                base.display()
            ));
        }
    }
    files.sort();
    files.dedup();

    let mut sum = RunSummary::default();
    for (rel, abs) in &files {
        let src = std::fs::read_to_string(abs)
            .map_err(|e| format!("read {}: {e}", abs.display()))?;
        let rep = lints::lint_source(rel, &src, cfg);
        sum.files += 1;
        sum.violations.extend(rep.violations);
        sum.errors.extend(rep.errors);
        sum.allows_used += rep.allows_used;
        for (line, rules) in rep.allows_unused {
            sum.allows_unused.push((rel.clone(), line, rules));
        }
    }
    Ok(sum)
}

fn walk(
    dir: &Path,
    rel: &str,
    exclude: &[String],
    out: &mut Vec<(String, PathBuf)>,
) -> Result<(), String> {
    let mut entries: Vec<(String, PathBuf)> = Vec::new();
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in rd {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let name = entry.file_name().to_string_lossy().to_string();
        entries.push((name, entry.path()));
    }
    entries.sort();
    for (name, path) in entries {
        let child_rel = normalize(&format!("{rel}/{name}"));
        if path_matches(&child_rel, exclude) {
            continue;
        }
        if path.is_dir() {
            walk(&path, &child_rel, exclude, out)?;
        } else if name.ends_with(".rs") {
            out.push((child_rel, path));
        }
    }
    Ok(())
}

/// Render the full report: rustc-style findings, stale-allow warnings,
/// and the counter line last (so `tail -1` is always the summary).
pub fn render(sum: &RunSummary) -> String {
    let mut out = String::new();
    let mut findings: Vec<&Violation> = sum.errors.iter().chain(sum.violations.iter()).collect();
    findings.sort_by_key(|v| (v.file.clone(), v.line, v.rule));
    for v in &findings {
        out.push_str(&format!("{}:{}: {}: {}\n", v.file, v.line, v.rule, v.msg));
    }
    for (file, line, rules) in &sum.allows_unused {
        out.push_str(&format!(
            "{file}:{line}: warning: unused detlint allow({rules}) — remove the stale suppression\n"
        ));
    }
    out.push_str(&sum.counter_line());
    out.push('\n');
    out
}

/// Shared entry point for the `detlint` binary and `porter-cli detlint`.
/// `config_opt` is an explicit `--config` path; otherwise the tool looks
/// for `detlint.toml` in `.` then `..` (so it works both from the repo
/// root and from `rust/`, CI's working directory). Prints the report and
/// returns the process exit code.
pub fn cli_main(config_opt: Option<&str>) -> i32 {
    let found = match config_opt {
        Some(p) => Some(PathBuf::from(p)),
        None => ["detlint.toml", "../detlint.toml"]
            .iter()
            .map(PathBuf::from)
            .find(|p| p.is_file()),
    };
    let (base, cfg) = match found {
        Some(path) => {
            let cfg = match DetlintConfig::from_file(&path) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("detlint: {e}");
                    return 2;
                }
            };
            let base = path.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
            let base = if base.as_os_str().is_empty() { PathBuf::from(".") } else { base };
            (base, cfg)
        }
        None => {
            eprintln!(
                "detlint: no detlint.toml in . or .. — run from the repo root (or rust/), \
                 or pass --config <path>"
            );
            return 2;
        }
    };
    match run(&base, &cfg) {
        Ok(sum) => {
            print!("{}", render(&sum));
            if sum.clean() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("detlint: {e}");
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lints::lint_source;

    fn cfg() -> DetlintConfig {
        DetlintConfig::default()
    }

    /// Lint a fixture as if it lived on a simulation path (no zone).
    fn fixture(src: &str) -> lints::FileReport {
        lint_source("rust/src/cluster/fixture.rs", src, &cfg())
    }

    fn rules(r: &lints::FileReport) -> Vec<&'static str> {
        r.violations.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn corpus_d1_fires_on_bad_and_not_on_good() {
        let bad = fixture(include_str!("fixtures/d1_bad.rs"));
        assert!(rules(&bad).iter().all(|r| *r == "D1"), "{:?}", bad.violations);
        assert!(rules(&bad).len() >= 3, "iter/keys/for-loop must all fire: {:?}", bad.violations);
        let good = fixture(include_str!("fixtures/d1_good.rs"));
        assert!(good.violations.is_empty(), "{:?}", good.violations);
    }

    #[test]
    fn corpus_d2_fires_on_bad_and_not_on_good() {
        let bad = fixture(include_str!("fixtures/d2_bad.rs"));
        assert!(rules(&bad).iter().all(|r| *r == "D2"), "{:?}", bad.violations);
        assert!(rules(&bad).len() >= 2, "{:?}", bad.violations);
        let good = fixture(include_str!("fixtures/d2_good.rs"));
        assert!(good.violations.is_empty(), "{:?}", good.violations);
        // the same bad file is legal inside a host-time zone
        let zoned =
            lint_source("rust/src/bench/fixture.rs", include_str!("fixtures/d2_bad.rs"), &cfg());
        assert!(zoned.violations.is_empty(), "{:?}", zoned.violations);
    }

    #[test]
    fn corpus_d3_fires_on_bad_and_not_on_good() {
        let bad = fixture(include_str!("fixtures/d3_bad.rs"));
        assert_eq!(rules(&bad), vec!["D3", "D3"], "{:?}", bad.violations);
        let good = fixture(include_str!("fixtures/d3_good.rs"));
        assert!(good.violations.is_empty(), "{:?}", good.violations);
    }

    #[test]
    fn corpus_d4_fires_on_bad_and_not_on_good() {
        let bad = fixture(include_str!("fixtures/d4_bad.rs"));
        assert!(rules(&bad).iter().all(|r| *r == "D4"), "{:?}", bad.violations);
        assert!(rules(&bad).len() >= 2, "{:?}", bad.violations);
        let good = fixture(include_str!("fixtures/d4_good.rs"));
        assert!(good.violations.is_empty(), "{:?}", good.violations);
    }

    #[test]
    fn corpus_d5_fires_on_bad_and_not_on_good() {
        let bad = fixture(include_str!("fixtures/d5_bad.rs"));
        assert_eq!(rules(&bad), vec!["D5"], "{:?}", bad.violations);
        let good = fixture(include_str!("fixtures/d5_good.rs"));
        assert!(good.violations.is_empty(), "{:?}", good.violations);
    }

    #[test]
    fn corpus_allow_directives_suppress_with_reasons() {
        let r = fixture(include_str!("fixtures/allow_ok.rs"));
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert!(r.errors.is_empty(), "{:?}", r.errors);
        assert_eq!(r.allows_used, 2);
        assert!(r.allows_unused.is_empty(), "{:?}", r.allows_unused);
    }

    #[test]
    fn corpus_allow_without_reason_is_fatal() {
        let r = fixture(include_str!("fixtures/allow_missing_reason.rs"));
        assert!(!r.errors.is_empty());
        assert!(r.errors[0].msg.contains("reason"), "{}", r.errors[0].msg);
    }

    #[test]
    fn corpus_tricky_tokens_stay_silent() {
        let r = fixture(include_str!("fixtures/tricky_tokens.rs"));
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert!(r.errors.is_empty(), "{:?}", r.errors);
    }

    #[test]
    fn corpus_cfg_test_code_is_skipped() {
        let r = fixture(include_str!("fixtures/tests_skipped.rs"));
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn counter_line_is_greppable() {
        let sum = RunSummary { files: 93, allows_used: 4, ..RunSummary::default() };
        assert_eq!(sum.counter_line(), "DETLINT files=93 violations=0 allows=4");
        assert!(render(&sum).ends_with("allows=4\n"));
    }

    #[test]
    fn walk_excludes_the_fixture_corpus() {
        // lint the real tree in-place: src/analysis is three levels below
        // the repo root where detlint.toml and the scan roots live
        let base = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf();
        let cfg = DetlintConfig::from_file(&base.join("detlint.toml")).unwrap();
        let mut files: Vec<(String, PathBuf)> = Vec::new();
        walk(&base.join("rust/src"), "rust/src", &cfg.exclude, &mut files).unwrap();
        assert!(files.iter().any(|(rel, _)| rel == "rust/src/analysis/mod.rs"));
        assert!(
            !files.iter().any(|(rel, _)| rel.contains("fixtures")),
            "fixture corpus must be excluded from the walk"
        );
    }

    #[test]
    fn full_tree_is_clean() {
        // The enforced CI gate in miniature: the committed tree must lint
        // clean under the committed config, with no stale allows.
        let base = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf();
        let cfg = DetlintConfig::from_file(&base.join("detlint.toml")).unwrap();
        let sum = run(&base, &cfg).unwrap();
        assert!(sum.files > 50, "walk found only {} files", sum.files);
        assert!(sum.clean(), "{}", render(&sum));
        assert!(sum.allows_unused.is_empty(), "{}", render(&sum));
    }
}
