//! The determinism lints D1–D5 over the token stream.
//!
//! Every headline invariant of this reproduction — replay identity
//! (PR 4), `--shards K` bit-identity (PR 7), disabled-path bit-identity
//! (every feature since) — dies silently if a decision path iterates a
//! hash map, reads host time, or accumulates floats in a
//! shard-dependent order. These lints make those rules machine-checked:
//!
//! * **D1** — no iteration over `HashMap`/`HashSet` (`.iter()`,
//!   `.keys()`, `.values()`, `.drain()`, `for … in &map`) outside
//!   modules declared order-insensitive. Lookup-only maps stay legal.
//! * **D2** — no `Instant::now` / `SystemTime` / `std::time` reads
//!   outside the bench harness, the CLI front-ends, and the audited
//!   `util::hosttime` chokepoint (`Duration` the value type is fine).
//! * **D3** — no `f64`/`f32` accumulation (`.sum::<f64>()`, `+=` on a
//!   float field) in files that also spawn threads, except inside
//!   `settle()`-ordered functions.
//! * **D4** — no unseeded RNG or hashing (`DefaultHasher`,
//!   `RandomState`, `thread_rng`, …) outside the seeded-generator
//!   modules.
//! * **D5** — the determinism token is mixed only from phase-A/settle
//!   code (`settle`, `apply_fault`).
//!
//! The pass is a token-level heuristic, not a type checker: it tracks
//! identifiers *declared* as hash collections or float fields in the
//! same file and flags operations on those names. That trades a few
//! false negatives (an alias through `let m = &self.map;` escapes) for
//! zero build-graph cost and total independence from rustc internals —
//! the fixture corpus in `fixtures/` pins exactly what fires.

use crate::analysis::config::{path_matches, DetlintConfig};
use crate::analysis::lexer::{lex, Tok, TokKind};

/// One finding, keyed for rustc-style rendering.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// Lint result for one file.
#[derive(Debug, Default)]
pub struct FileReport {
    pub violations: Vec<Violation>,
    /// Directive syntax errors (missing reason, unknown rule) — always
    /// fatal, never suppressible.
    pub errors: Vec<Violation>,
    pub allows_used: usize,
    /// Directives that matched nothing (stale suppressions) — surfaced
    /// as warnings, not failures.
    pub allows_unused: Vec<(u32, String)>,
}

const ITER_METHODS: [&str; 9] = [
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
    "extract_if",
];

const UNSEEDED: [&str; 8] = [
    "DefaultHasher",
    "RandomState",
    "thread_rng",
    "from_entropy",
    "StdRng",
    "SmallRng",
    "OsRng",
    "getrandom",
];

/// Lint one file's source; `path` is the normalized repo-relative path
/// used for zone matching and reporting.
pub fn lint_source(path: &str, source: &str, cfg: &DetlintConfig) -> FileReport {
    let lexed = lex(source);
    let mut report = FileReport::default();
    for (line, msg) in &lexed.errors {
        report.errors.push(Violation { file: path.to_string(), line: *line, rule: "allow", msg: msg.clone() });
    }
    let toks = &lexed.tokens;
    let ctx = Context::build(toks);
    let maps = collect_hash_bindings(toks);
    let floats = collect_float_fields(toks);
    let has_threads = (0..toks.len())
        .any(|i| toks[i].is_ident("thread") && i + 1 < toks.len() && toks[i + 1].is_punct(':'));

    let d1_zone = !path_matches(path, &cfg.d1_order_insensitive);
    let d2_zone = !path_matches(path, &cfg.d2_host_time_ok);
    let d4_zone = !path_matches(path, &cfg.d4_seeded_modules);

    let mut raw: Vec<Violation> = Vec::new();
    let mut push = |line: u32, rule: &'static str, msg: String| {
        raw.push(Violation { file: path.to_string(), line, rule, msg });
    };

    for i in 0..toks.len() {
        if cfg.skip_test_code && ctx.in_test[i] {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }

        // ---- D1: hash-collection iteration --------------------------------
        if d1_zone {
            if ITER_METHODS.contains(&t.text.as_str())
                && i >= 2
                && toks[i - 1].is_punct('.')
                && toks[i - 2].kind == TokKind::Ident
                && maps.contains(&toks[i - 2].text)
                && i + 1 < toks.len()
                && toks[i + 1].is_punct('(')
            {
                push(
                    t.line,
                    "D1",
                    format!(
                        "iteration over hash collection `{}` via `.{}()` — order is \
                         nondeterministic; iterate a sorted Vec or allowlist with a reason",
                        toks[i - 2].text, t.text
                    ),
                );
            }
            if t.is_ident("for") {
                if let Some((name, line)) = for_loop_over(toks, i, &maps) {
                    push(
                        line,
                        "D1",
                        format!(
                            "`for … in` over hash collection `{name}` — order is \
                             nondeterministic; iterate a sorted Vec or allowlist with a reason"
                        ),
                    );
                }
            }
        }

        // ---- D2: host-time reads ------------------------------------------
        if d2_zone {
            if t.is_ident("Instant") && path_seq(toks, i + 1, &["now"]) {
                push(t.line, "D2", "`Instant::now()` on a simulation path — host time \
                     must flow through util::hosttime and land only in host-metrics \
                     fields excluded from report equality".into());
            }
            if t.is_ident("SystemTime") {
                push(t.line, "D2", "`SystemTime` on a simulation path — wall-clock \
                     reads poison replay identity".into());
            }
            if t.is_ident("std") && path_seq(toks, i + 1, &["time"]) {
                // std :: time :: <what>
                for what in time_path_idents(toks, i) {
                    if what.text != "Duration" {
                        push(
                            what.line,
                            "D2",
                            format!(
                                "`std::time::{}` on a simulation path — only `Duration` \
                                 (a value type) is allowed outside host-time zones",
                                what.text
                            ),
                        );
                    }
                }
            }
            if t.is_ident("elapsed")
                && i >= 1
                && toks[i - 1].is_punct('.')
                && i + 1 < toks.len()
                && toks[i + 1].is_punct('(')
            {
                push(t.line, "D2", "`.elapsed()` on a simulation path — host time must \
                     flow through util::hosttime".into());
            }
        }

        // ---- D3: float accumulation in threaded files ---------------------
        if has_threads {
            let settle_ok = ctx
                .enclosing_fn(i)
                .map(|f| cfg.d3_settle_fns.iter().any(|s| s == f))
                .unwrap_or(false);
            if !settle_ok {
                if t.is_ident("sum")
                    && i >= 1
                    && toks[i - 1].is_punct('.')
                    && ident_at(toks, i + 4, &["f64", "f32"])
                    && toks[i + 1].is_punct(':')
                    && toks[i + 2].is_punct(':')
                {
                    // `.sum::<f64>` — the `<` sits between `::` and the type
                    push(t.line, "D3", "float `.sum::<f64>()` in a thread-spawning file \
                         outside settle-ordered code — summation order is \
                         shard-dependent".into());
                }
                if maps_contains(&floats, &t.text)
                    && i >= 1
                    && toks[i - 1].is_punct('.')
                    && i + 2 < toks.len()
                    && toks[i + 1].is_punct('+')
                    && toks[i + 2].is_punct('=')
                {
                    push(
                        t.line,
                        "D3",
                        format!(
                            "`+=` on float field `{}` in a thread-spawning file outside \
                             settle-ordered code — accumulation order is shard-dependent",
                            t.text
                        ),
                    );
                }
            }
        }

        // ---- D4: unseeded RNG / hashing -----------------------------------
        if d4_zone {
            if UNSEEDED.contains(&t.text.as_str()) {
                push(
                    t.line,
                    "D4",
                    format!(
                        "`{}` — unseeded randomness/hashing feeds address-dependent \
                         decisions; use the seeded util::prng generators",
                        t.text
                    ),
                );
            }
            if t.is_ident("rand") && i + 1 < toks.len() && toks[i + 1].is_punct(':') {
                push(t.line, "D4", "`rand::` path — the crate is zero-dependency and \
                     all randomness is seeded via util::prng".into());
            }
        }

        // ---- D5: determinism-token mixing ---------------------------------
        if t.text.starts_with("mix")
            && i >= 2
            && toks[i - 1].is_punct('=')
            && toks[i - 2].is_ident("token")
            && i + 1 < toks.len()
            && toks[i + 1].is_punct('(')
        {
            let fn_ok = ctx
                .enclosing_fn(i)
                .map(|f| cfg.d5_mix_fns.iter().any(|s| s == f))
                .unwrap_or(false);
            if !fn_ok {
                let fn_name = ctx.enclosing_fn(i).unwrap_or("<top level>");
                push(
                    t.line,
                    "D5",
                    format!(
                        "determinism token mixed in `{fn_name}` — token mixes are only \
                         legal in phase-A/settle code ({})",
                        cfg.d5_mix_fns.join(", ")
                    ),
                );
            }
        }
    }

    // de-duplicate overlapping patterns (e.g. `std::time::Instant::now()`
    // fires both the path rule and the now rule on the same line)
    raw.sort_by(|a, b| (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg)));
    raw.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);

    // apply suppression directives: an allow on line L covers findings
    // on L (trailing comment) and L+1 (own-line comment above)
    let mut used = vec![false; lexed.allows.len()];
    for v in raw {
        let mut suppressed = false;
        for (ai, a) in lexed.allows.iter().enumerate() {
            if (a.line == v.line || a.line + 1 == v.line) && a.rules.iter().any(|r| r == v.rule)
            {
                used[ai] = true;
                suppressed = true;
                break;
            }
        }
        if !suppressed {
            report.violations.push(v);
        }
    }
    report.allows_used = used.iter().filter(|u| **u).count();
    for (ai, a) in lexed.allows.iter().enumerate() {
        if !used[ai] {
            report.allows_unused.push((a.line, a.rules.join(",")));
        }
    }
    report
}

fn maps_contains(set: &[String], name: &str) -> bool {
    set.iter().any(|s| s == name)
}

fn ident_at(toks: &[Tok], i: usize, any_of: &[&str]) -> bool {
    // used for `.sum::<f64>`: toks[i] is the type ident after `::<`
    i < toks.len()
        && toks[i].kind == TokKind::Ident
        && any_of.contains(&toks[i].text.as_str())
        && i >= 1
        && toks[i - 1].is_punct('<')
}

/// Does `toks[start..]` spell `:: seg1 [:: seg2 …]`?
fn path_seq(toks: &[Tok], start: usize, segs: &[&str]) -> bool {
    let mut i = start;
    for seg in segs {
        if i + 2 >= toks.len()
            || !toks[i].is_punct(':')
            || !toks[i + 1].is_punct(':')
            || !toks[i + 2].is_ident(seg)
        {
            return false;
        }
        i += 3;
    }
    true
}

/// For a `std :: time ::` path at `i` (pointing at `std`), return the
/// idents it resolves to — the single next segment, or every ident in a
/// `{...}` use-group.
fn time_path_idents(toks: &[Tok], i: usize) -> Vec<Tok> {
    // i: std, i+1,2: '::', i+3: time, i+4,5: '::', i+6: ident or '{'
    let j = i + 6;
    if j >= toks.len() || !toks[i + 4].is_punct(':') || !toks[i + 5].is_punct(':') {
        return Vec::new();
    }
    if toks[j].kind == TokKind::Ident {
        return vec![toks[j].clone()];
    }
    let mut out = Vec::new();
    if toks[j].is_punct('{') {
        let mut k = j + 1;
        while k < toks.len() && !toks[k].is_punct('}') {
            if toks[k].kind == TokKind::Ident && !toks[k].is_ident("self") {
                out.push(toks[k].clone());
            }
            k += 1;
        }
    }
    out
}

/// Match `for … in [&|mut]* ident[.ident]* {` and return the last ident
/// of the chain if it names a hash collection.
fn for_loop_over(toks: &[Tok], for_idx: usize, maps: &[String]) -> Option<(String, u32)> {
    let limit = (for_idx + 40).min(toks.len());
    let mut i = for_idx + 1;
    while i < limit && !toks[i].is_ident("in") {
        // a `{` before `in` means this wasn't a loop header after all
        if toks[i].is_punct('{') {
            return None;
        }
        i += 1;
    }
    if i >= limit {
        return None;
    }
    i += 1;
    while i < toks.len() && (toks[i].is_punct('&') || toks[i].is_ident("mut")) {
        i += 1;
    }
    if i >= toks.len() || toks[i].kind != TokKind::Ident {
        return None;
    }
    let mut last = i;
    while last + 2 < toks.len()
        && toks[last + 1].is_punct('.')
        && toks[last + 2].kind == TokKind::Ident
    {
        last += 2;
    }
    let name = &toks[last];
    if maps_contains(maps, &name.text)
        && last + 1 < toks.len()
        && toks[last + 1].is_punct('{')
    {
        return Some((name.text.clone(), name.line));
    }
    None
}

/// Identifiers declared with a `HashMap`/`HashSet` type in this file:
/// struct fields (`name: HashMap<…>`), typed params, and `let` bindings
/// (`let mut name = HashMap::new()`; `let name: Mutex<HashMap<…>> = …`).
fn collect_hash_bindings(toks: &[Tok]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident("HashMap") || toks[i].is_ident("HashSet")) {
            continue;
        }
        if let Some(name) = binding_name_before(toks, i) {
            if !maps_contains(&out, &name) {
                out.push(name);
            }
        }
    }
    out
}

/// Identifiers declared `: f64` / `: f32` (struct fields, params, let
/// ascriptions) — the candidates for D3's `+=` check.
fn collect_float_fields(toks: &[Tok]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for i in 2..toks.len() {
        if (toks[i].is_ident("f64") || toks[i].is_ident("f32"))
            && toks[i - 1].is_punct(':')
            && !toks[i - 2].is_punct(':')
            && toks[i - 2].kind == TokKind::Ident
        {
            let name = toks[i - 2].text.clone();
            if !maps_contains(&out, &name) {
                out.push(name);
            }
        }
    }
    out
}

/// Walk backwards from a `HashMap`/`HashSet` token to the identifier it
/// is bound to. Stops at statement / grouping boundaries, so a map in a
/// return type or a call argument registers nothing.
fn binding_name_before(toks: &[Tok], map_idx: usize) -> Option<String> {
    let floor = map_idx.saturating_sub(40);
    let mut j = map_idx;
    while j > floor {
        j -= 1;
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                ";" | "{" | "}" | "," | "(" | ")" | "[" | "]" => return None,
                ":" => {
                    // skip `::` path separators; a single `:` is a binding
                    if j > floor && toks[j - 1].is_punct(':') {
                        j -= 1;
                        continue;
                    }
                    if j + 1 < toks.len() && toks[j + 1].is_punct(':') {
                        continue;
                    }
                    if j > 0 && toks[j - 1].kind == TokKind::Ident {
                        return Some(toks[j - 1].text.clone());
                    }
                    return None;
                }
                "=" => {
                    // `=>` match arm: boundary. `==` comparison: boundary.
                    if j + 1 < toks.len() && toks[j + 1].is_punct('>') {
                        return None;
                    }
                    if j > 0 && toks[j - 1].is_punct('=') {
                        return None;
                    }
                    // `let [mut] name = …` / `lvalue = …`
                    if j > 0 && toks[j - 1].kind == TokKind::Ident {
                        let name = &toks[j - 1];
                        if name.is_ident("let") || name.is_ident("mut") {
                            return None;
                        }
                        return Some(name.text.clone());
                    }
                    return None;
                }
                _ => {}
            }
        }
    }
    None
}

/// Per-token context: enclosing function name and `#[cfg(test)]` state.
struct Context {
    in_test: Vec<bool>,
    fn_idx: Vec<Option<usize>>,
    names: Vec<String>,
}

impl Context {
    fn enclosing_fn(&self, i: usize) -> Option<&str> {
        self.fn_idx[i].map(|n| self.names[n].as_str())
    }

    fn build(toks: &[Tok]) -> Context {
        let mut in_test = vec![false; toks.len()];
        let mut fn_idx = vec![None; toks.len()];
        let mut names: Vec<String> = Vec::new();
        let mut depth: i64 = 0;
        let mut fn_stack: Vec<(usize, i64)> = Vec::new();
        let mut test_stack: Vec<i64> = Vec::new();
        let mut pending_fn: Option<usize> = None;
        let mut pending_test = false;
        for i in 0..toks.len() {
            in_test[i] = !test_stack.is_empty();
            fn_idx[i] = fn_stack.last().map(|&(n, _)| n);
            let t = &toks[i];
            if t.is_punct('#')
                && i + 5 < toks.len()
                && toks[i + 1].is_punct('[')
                && toks[i + 2].is_ident("cfg")
                && toks[i + 3].is_punct('(')
                && toks[i + 4].is_ident("test")
                && toks[i + 5].is_punct(')')
            {
                pending_test = true;
            } else if t.is_ident("fn") && i + 1 < toks.len() && toks[i + 1].kind == TokKind::Ident
            {
                let name = toks[i + 1].text.clone();
                let idx = names.iter().position(|n| *n == name).unwrap_or_else(|| {
                    names.push(name);
                    names.len() - 1
                });
                pending_fn = Some(idx);
            } else if t.is_punct('{') {
                depth += 1;
                if pending_test {
                    test_stack.push(depth);
                    pending_test = false;
                }
                if let Some(idx) = pending_fn.take() {
                    fn_stack.push((idx, depth));
                }
            } else if t.is_punct('}') {
                if test_stack.last() == Some(&depth) {
                    test_stack.pop();
                }
                if fn_stack.last().map(|&(_, d)| d) == Some(depth) {
                    fn_stack.pop();
                }
                depth -= 1;
            } else if t.is_punct(';') {
                // `fn f(…);` trait decl or `#[cfg(test)] use …;`
                pending_fn = None;
                pending_test = false;
            }
        }
        Context { in_test, fn_idx, names }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(src: &str) -> FileReport {
        lint_source("some/module.rs", src, &DetlintConfig::default())
    }

    fn rules(r: &FileReport) -> Vec<&'static str> {
        r.violations.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn d1_flags_map_iteration_but_not_lookups() {
        let src = "struct S { m: HashMap<String, u32> }\n\
                   impl S {\n\
                   fn bad(&self) -> u32 { self.m.values().sum() }\n\
                   fn good(&self) -> Option<&u32> { self.m.get(\"k\") }\n\
                   fn also_good(&mut self) { self.m.insert(String::new(), 1); }\n\
                   }\n";
        let r = lint(src);
        assert_eq!(rules(&r), vec!["D1"]);
        assert_eq!(r.violations[0].line, 3);
    }

    #[test]
    fn d1_flags_for_loops_over_maps() {
        let src = "struct S { m: HashSet<u64> }\n\
                   impl S { fn f(&self) { for x in &self.m { drop(x); } } }\n";
        let r = lint(src);
        assert_eq!(rules(&r), vec!["D1"]);
    }

    #[test]
    fn d1_ignores_vec_iteration() {
        let src = "fn f(v: &Vec<u64>, w: &[u64]) -> u64 {\n\
                   let m: HashMap<u64, u64> = HashMap::new();\n\
                   let _ = m.get(&1);\n\
                   v.iter().chain(w.iter()).sum()\n\
                   }\n";
        assert!(lint(src).violations.is_empty());
    }

    #[test]
    fn d2_flags_time_and_honors_zones() {
        let src = "use std::time::Instant;\n\
                   fn f() -> u64 { let t = Instant::now(); t.elapsed().as_micros() as u64 }\n";
        let r = lint(src);
        // line 1: `use std::time::Instant`; line 2: `Instant::now()` and
        // `.elapsed()` dedupe to a single finding (same line, same rule)
        assert_eq!(rules(&r), vec!["D2", "D2"]);
        let mut cfg = DetlintConfig::default();
        cfg.d2_host_time_ok.push("some/module.rs".to_string());
        assert!(lint_source("some/module.rs", src, &cfg).violations.is_empty());
    }

    #[test]
    fn d2_allows_duration_the_value_type() {
        let src = "use std::time::Duration;\nfn f() -> Duration { Duration::from_secs(1) }\n";
        assert!(lint(src).violations.is_empty());
    }

    #[test]
    fn d3_only_fires_in_threaded_files_outside_settle() {
        let threaded = "struct R { wall_ns: f64 }\n\
                        fn run() { std::thread::scope(|s| { let _ = s; }); }\n\
                        fn merge(rs: &[R]) -> f64 { rs.iter().map(|r| r.wall_ns).sum::<f64>() }\n";
        let r = lint(threaded);
        assert_eq!(rules(&r), vec!["D3"]);
        // the same accumulation inside settle() is legal
        let settled = threaded.replace("fn merge", "fn settle");
        assert!(lint(&settled).violations.is_empty());
        // and a single-threaded file is out of scope entirely
        let unthreaded = threaded.replace("std::thread::scope(|s| { let _ = s; });", "");
        assert!(lint(&unthreaded).violations.is_empty());
    }

    #[test]
    fn d3_flags_float_field_accumulation() {
        let src = "struct R { wait_sum_ns: f64, count: u64 }\n\
                   fn spawn_all() { std::thread::spawn(|| {}); }\n\
                   impl R { fn absorb(&mut self, d: &R) {\n\
                   self.wait_sum_ns += d.wait_sum_ns;\n\
                   self.count += d.count;\n\
                   } }\n";
        let r = lint(src);
        assert_eq!(rules(&r), vec!["D3"], "u64 += must not fire");
        assert_eq!(r.violations[0].line, 4);
    }

    #[test]
    fn d4_flags_unseeded_sources() {
        let src = "use std::collections::hash_map::DefaultHasher;\n\
                   fn f() { let s = RandomState::new(); drop(s); }\n";
        let r = lint(src);
        assert_eq!(rules(&r), vec!["D4", "D4"]);
        let mut cfg = DetlintConfig::default();
        cfg.d4_seeded_modules.push("some/module.rs".to_string());
        assert!(lint_source("some/module.rs", src, &cfg).violations.is_empty());
    }

    #[test]
    fn d5_constrains_token_mixes_to_settle_code() {
        let bad = "impl C { fn dispatch(&mut self) { self.token = mix(self.token, 1); } }\n";
        let r = lint(bad);
        assert_eq!(rules(&r), vec!["D5"]);
        assert!(r.violations[0].msg.contains("dispatch"));
        let good = bad.replace("fn dispatch", "fn settle");
        assert!(lint(&good).violations.is_empty());
        // checksum mixes on ordinary variables never fire
        let checksum = "fn hash(h: u64) -> u64 { let h = mix(h, 7); h }\n";
        assert!(lint(checksum).violations.is_empty());
    }

    #[test]
    fn allows_suppress_same_and_next_line() {
        let src = "struct S { m: HashMap<u64, u64> }\n\
                   impl S {\n\
                   fn a(&self) -> u64 {\n\
                   // detlint: allow(D1, reason = \"sum of u64 is order-insensitive\")\n\
                   self.m.values().sum()\n\
                   }\n\
                   fn b(&self) -> usize { self.m.keys().count() // detlint: allow(D1, reason = \"count only\")\n\
                   }\n\
                   }\n";
        let r = lint(src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.allows_used, 2);
        assert!(r.allows_unused.is_empty());
    }

    #[test]
    fn wrong_rule_allow_does_not_suppress() {
        let src = "struct S { m: HashMap<u64, u64> }\n\
                   impl S { fn a(&self) -> u64 {\n\
                   // detlint: allow(D2, reason = \"wrong rule\")\n\
                   self.m.values().sum()\n\
                   } }\n";
        let r = lint(src);
        assert_eq!(rules(&r), vec!["D1"]);
        assert_eq!(r.allows_used, 0);
        assert_eq!(r.allows_unused.len(), 1);
    }

    #[test]
    fn cfg_test_modules_are_skipped_by_default() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn t() { let t0 = std::time::Instant::now(); drop(t0); }\n\
                   }\n";
        assert!(lint(src).violations.is_empty());
        let cfg = DetlintConfig { skip_test_code: false, ..DetlintConfig::default() };
        let r = lint_source("some/module.rs", src, &cfg);
        assert!(!r.violations.is_empty());
    }

    #[test]
    fn directive_errors_surface_as_errors() {
        let r = lint("// detlint: allow(D1)\nfn f() {}\n");
        assert_eq!(r.errors.len(), 1);
        assert_eq!(r.errors[0].rule, "allow");
    }
}
