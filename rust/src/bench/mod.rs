//! Bench harness (criterion substitute for the offline image).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary that builds a
//! [`BenchSuite`], registers measurements, and calls [`BenchSuite::run`].
//! The harness does warmup, fixed-iteration timing, reports mean ± σ and
//! throughput, and emits both an ASCII table and a JSON line per bench so
//! EXPERIMENTS.md rows can be regenerated mechanically.
//!
//! Figure-reproduction benches additionally print their *figure series*
//! (the rows the paper plots) via [`FigureReport`]; the timing part
//! covers the harness cost itself.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::Table;

/// Timing configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub sample_iters: u32,
    /// Hard cap on total time per bench; sampling stops early once hit.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Benches run in CI alongside the full suite; keep defaults modest
        // and override per-bench where more samples matter.
        Self { warmup_iters: 2, sample_iters: 10, max_time: Duration::from_secs(30) }
    }
}

/// Result of one measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// Optional work units per iteration for throughput reporting
    /// (e.g. accesses replayed, requests served).
    pub units_per_iter: Option<f64>,
    pub unit_name: String,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    pub fn throughput_per_sec(&self) -> Option<f64> {
        let u = self.units_per_iter?;
        let mean_ns = self.summary().mean;
        if mean_ns <= 0.0 {
            return None;
        }
        Some(u / (mean_ns / 1e9))
    }
}

/// A collection of benches that prints a unified report.
pub struct BenchSuite {
    pub title: String,
    pub config: BenchConfig,
    results: Vec<BenchResult>,
    extra_sections: Vec<String>,
}

/// Quick-mode switch shared by every bench: set `PORTER_BENCH_QUICK`
/// (any value) to shrink scales/iterations so CI smoke runs stay fast.
/// All `rust/benches/*.rs` consult this one helper instead of sniffing
/// the environment themselves.
pub fn quick_mode() -> bool {
    std::env::var("PORTER_BENCH_QUICK").is_ok()
}

impl BenchSuite {
    pub fn new(title: &str) -> BenchSuite {
        let mut config = BenchConfig::default();
        // Honour the quick mode so `cargo bench` smoke runs stay fast.
        if quick_mode() {
            config.warmup_iters = 1;
            config.sample_iters = 3;
            config.max_time = Duration::from_secs(10);
        }
        BenchSuite {
            title: title.to_string(),
            config,
            results: Vec::new(),
            extra_sections: Vec::new(),
        }
    }

    pub fn with_config(mut self, config: BenchConfig) -> BenchSuite {
        self.config = config;
        self
    }

    /// Time `f` (called once per iteration, result discarded via
    /// `black_box`).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        self.bench_units(name, None, "iter", &mut f);
    }

    /// Time `f`, reporting `units` work items per iteration as
    /// throughput.
    pub fn bench_with_throughput<T>(
        &mut self,
        name: &str,
        units: f64,
        unit_name: &str,
        mut f: impl FnMut() -> T,
    ) {
        self.bench_units(name, Some(units), unit_name, &mut f);
    }

    fn bench_units<T>(
        &mut self,
        name: &str,
        units: Option<f64>,
        unit_name: &str,
        f: &mut impl FnMut() -> T,
    ) {
        let cfg = &self.config;
        for _ in 0..cfg.warmup_iters {
            std::hint::black_box(f());
        }
        let started = Instant::now();
        let mut samples = Vec::with_capacity(cfg.sample_iters as usize);
        for _ in 0..cfg.sample_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
            if started.elapsed() > cfg.max_time && samples.len() >= 3 {
                break;
            }
        }
        let result = BenchResult {
            name: name.to_string(),
            samples_ns: samples,
            units_per_iter: units,
            unit_name: unit_name.to_string(),
        };
        eprintln!("  bench {name}: {}", one_line(&result));
        self.results.push(result);
    }

    /// Attach a pre-rendered section (figure series etc.) to the report.
    pub fn section(&mut self, text: String) {
        self.extra_sections.push(text);
    }

    /// Print the full report and the JSON lines. Call this last.
    pub fn run(&self) {
        println!("\n=== {} ===", self.title);
        for s in &self.extra_sections {
            println!("{s}");
        }
        if !self.results.is_empty() {
            let headers = ["bench", "mean", "p50", "σ", "min", "max", "throughput"];
            let mut t = Table::new(&headers).left_first();
            for r in &self.results {
                let s = r.summary();
                t.row(vec![
                    r.name.clone(),
                    fmt_ns(s.mean),
                    fmt_ns(s.p50),
                    fmt_ns(s.std),
                    fmt_ns(s.min),
                    fmt_ns(s.max),
                    match r.throughput_per_sec() {
                        Some(tp) => format!("{} {}/s", human_count(tp), r.unit_name),
                        None => "-".to_string(),
                    },
                ]);
            }
            println!("{}", t.render());
        }
        for r in &self.results {
            let s = r.summary();
            let j = Json::obj(vec![
                ("suite", Json::str(self.title.clone())),
                ("bench", Json::str(r.name.clone())),
                ("mean_ns", Json::num(s.mean)),
                ("std_ns", Json::num(s.std)),
                ("n", Json::num(s.n as f64)),
                (
                    "throughput_per_s",
                    r.throughput_per_sec().map(Json::num).unwrap_or(Json::Null),
                ),
            ]);
            println!("BENCH-JSON {j}");
        }
    }
}

fn one_line(r: &BenchResult) -> String {
    let s = r.summary();
    match r.throughput_per_sec() {
        Some(tp) => {
            let rate = human_count(tp);
            format!("{} ± {} ({} {}/s)", fmt_ns(s.mean), fmt_ns(s.std), rate, r.unit_name)
        }
        None => format!("{} ± {}", fmt_ns(s.mean), fmt_ns(s.std)),
    }
}

/// Render nanoseconds at a readable scale.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Render counts at a readable scale (for throughput).
pub fn human_count(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

/// A figure series: named x/y rows matching what the paper plots.
/// `render()` gives an ASCII bar chart plus the raw rows so the shape is
/// visible directly in bench output.
pub struct FigureReport {
    pub figure: String,
    pub caption: String,
    columns: Vec<String>,
    rows: Vec<(String, Vec<f64>)>,
}

impl FigureReport {
    pub fn new(figure: &str, caption: &str, columns: &[&str]) -> FigureReport {
        FigureReport {
            figure: figure.to_string(),
            caption: caption.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "figure row arity");
        self.rows.push((label.to_string(), values));
    }

    pub fn rows(&self) -> &[(String, Vec<f64>)] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let mut out = format!("--- {}: {} ---\n", self.figure, self.caption);
        let mut t = Table::new(
            &std::iter::once("series")
                .chain(self.columns.iter().map(|s| s.as_str()))
                .collect::<Vec<_>>(),
        )
        .left_first();
        for (label, vals) in &self.rows {
            t.row(
                std::iter::once(label.clone())
                    .chain(vals.iter().map(|v| crate::util::fmt_f64(*v, 2)))
                    .collect(),
            );
        }
        out.push_str(&t.render());
        // ASCII bars over the first column for a quick shape check.
        if !self.rows.is_empty() {
            let max = self.rows.iter().map(|(_, v)| v[0]).fold(f64::MIN, f64::max).max(1e-12);
            let width = self.rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
            out.push_str(&format!("bars: {}\n", self.columns[0]));
            for (label, vals) in &self.rows {
                let bar_len = ((vals[0] / max) * 50.0).round().max(0.0) as usize;
                let bar = "#".repeat(bar_len);
                let v0 = crate::util::fmt_f64(vals[0], 2);
                out.push_str(&format!("  {label:width$} |{bar} {v0}\n"));
            }
        }
        // machine-readable line
        let j = Json::obj(vec![
            ("figure", Json::str(self.figure.clone())),
            ("columns", Json::arr(self.columns.iter().map(|c| Json::str(c.clone())))),
            (
                "rows",
                Json::arr(self.rows.iter().map(|(l, v)| {
                    Json::obj(vec![
                        ("label", Json::str(l.clone())),
                        ("values", Json::arr(v.iter().map(|x| Json::num(*x)))),
                    ])
                })),
            ),
        ]);
        out.push_str(&format!("FIGURE-JSON {j}\n"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut suite = BenchSuite::new("t").with_config(BenchConfig {
            warmup_iters: 1,
            sample_iters: 4,
            max_time: Duration::from_secs(5),
        });
        let mut acc = 0u64;
        suite.bench("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(suite.results.len(), 1);
        assert_eq!(suite.results[0].samples_ns.len(), 4);
    }

    #[test]
    fn throughput_computed() {
        let r = BenchResult {
            name: "x".into(),
            samples_ns: vec![1e9, 1e9],
            units_per_iter: Some(1000.0),
            unit_name: "req".into(),
        };
        let tp = r.throughput_per_sec().unwrap();
        assert!((tp - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn figure_report_renders() {
        let mut f = FigureReport::new("fig2", "slowdown", &["slowdown_pct", "boundness_pct"]);
        f.row("pagerank", vec![38.0, 55.0]);
        f.row("chameleon", vec![2.0, 6.0]);
        let s = f.render();
        assert!(s.contains("pagerank"));
        assert!(s.contains("FIGURE-JSON"));
    }

    #[test]
    #[should_panic]
    fn figure_row_arity_checked() {
        let mut f = FigureReport::new("f", "c", &["a", "b"]);
        f.row("x", vec![1.0]);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.500µs");
        assert_eq!(fmt_ns(2.5e6), "2.500ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
