//! Minimal JSON value model, writer, and recursive-descent parser.
//!
//! Used for: the artifact manifest written by `python/compile/aot.py`,
//! machine-readable bench/experiment outputs, and the Porter hint cache.
//! `serde`/`serde_json` are unavailable in the offline image, so this is
//! a small, strict (RFC 8259 subset: no NaN/Inf, UTF-8 input) codec.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — experiment artifacts diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|v| v as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Strict: trailing garbage is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only; surrogate pairs are out of scope for
                            // our own artifacts (we never emit them).
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x80 => {
                    s.push(b as char);
                    self.pos += 1;
                }
                Some(b) => {
                    // decode one multi-byte UTF-8 scalar (validate only
                    // its own bytes, not the whole remaining input)
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    let end = (self.pos + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[self.pos..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = chunk.chars().next().ok_or_else(|| self.err("invalid utf-8"))?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::str("mlp_train")),
            ("dims", Json::arr([Json::num(128), Json::num(256)])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true)), ("x", Json::Null)])),
        ]);
        let parsed = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn escapes() {
        let v = Json::str("a\"b\\c\nd\te");
        let parsed = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::str("A"));
    }

    #[test]
    fn literal_multibyte_utf8() {
        let v = Json::str("héllo → 世界 🦀");
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        // truncated multibyte sequence is rejected, not panicking
        assert!(Json::parse("\"\u{fffd}".trim_end_matches('\u{fffd}')).is_err());
    }

    #[test]
    fn large_document_parses_fast() {
        // regression guard for the O(n²) string-scan bug
        let big = Json::arr((0..20_000).map(|i| Json::str(format!("item-{i}-with-text"))));
        let text = big.to_string_compact();
        let t0 = std::time::Instant::now();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, big);
        assert!(t0.elapsed().as_secs_f64() < 2.0, "parse took {:?}", t0.elapsed());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": 3, "b": "x", "c": [1,2], "d": true}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert!(v.get("zz").is_none());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(3.0).to_string_compact(), "3");
        assert_eq!(Json::num(3.25).to_string_compact(), "3.25");
    }
}
