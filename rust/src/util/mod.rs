//! Small self-contained substrates the rest of the crate builds on.
//!
//! The offline build image ships no crates.io registry, so the usual
//! ecosystem crates (`rand`, `serde`, `criterion`, `clap`, `proptest`,
//! `anyhow`) are unavailable. Everything here is a deliberate, tested
//! stand-in: a deterministic PRNG, summary statistics, a JSON
//! reader/writer, ASCII tables, byte-size formatting, and a chained
//! error type.

pub mod bytes;
pub mod error;
pub mod hosttime;
pub mod json;
pub mod prng;
pub mod stats;
pub mod table;

/// Format a float with a fixed number of significant-looking decimals,
/// trimming trailing zeros (used by tables and reports).
pub fn fmt_f64(v: f64, decimals: usize) -> String {
    let s = format!("{v:.decimals$}");
    if s.contains('.') {
        let t = s.trim_end_matches('0').trim_end_matches('.');
        if t.is_empty() || t == "-" {
            "0".to_string()
        } else {
            t.to_string()
        }
    } else {
        s
    }
}

/// Clamp helper for f64 (std's `clamp` panics on NaN bounds; this never
/// panics and propagates NaN inputs unchanged).
pub fn clamp_f64(v: f64, lo: f64, hi: f64) -> f64 {
    if v < lo {
        lo
    } else if v > hi {
        hi
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_trims_zeros() {
        assert_eq!(fmt_f64(1.5000, 4), "1.5");
        assert_eq!(fmt_f64(2.0, 2), "2");
        assert_eq!(fmt_f64(0.0, 3), "0");
        assert_eq!(fmt_f64(-0.25, 2), "-0.25");
    }

    #[test]
    fn clamp_works() {
        assert_eq!(clamp_f64(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp_f64(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp_f64(0.5, 0.0, 1.0), 0.5);
    }
}
