//! The audited host-clock chokepoint.
//!
//! The simulator's results are functions of virtual time only — every
//! latency, every report field the determinism token or a report-equality
//! assert can see derives from the deterministic event clock. But two
//! *host-side* throughput metrics are worth reporting (how long did the
//! host take to churn through the simulation): `InvocationOutcome::
//! host_micros` (excluded from `RunReport`'s `PartialEq`) and
//! `ShardStats::events_per_sec` (behind an always-true `PartialEq`).
//!
//! Those are the only legitimate consumers of the host clock outside the
//! bench harness and the CLI, and this module is the only simulation-path
//! code allowed to read it — `detlint.toml` lists exactly this file under
//! `[d2] host_time_ok`, so any new `Instant::now()` elsewhere fails the
//! D2 gate. The accessor names (`elapsed_micros`, `elapsed_secs`)
//! deliberately avoid the bare `.elapsed()` spelling D2 flags.
//!
//! Adding a caller? The value must land in a field excluded from report
//! equality (document which), or the D2 gate is defending nothing.

use std::time::Instant;

/// A started host stopwatch for host-metrics fields.
#[derive(Debug, Clone, Copy)]
pub struct HostTimer {
    started: Instant,
}

impl HostTimer {
    pub fn start() -> HostTimer {
        HostTimer { started: Instant::now() }
    }

    /// Whole microseconds since `start()` (for `host_micros` fields).
    pub fn elapsed_micros(&self) -> u64 {
        self.started.elapsed().as_micros() as u64
    }

    /// Seconds since `start()` (for `events_per_sec`-style rates).
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_is_monotonic_and_nonnegative() {
        let t = HostTimer::start();
        let a = t.elapsed_micros();
        let b = t.elapsed_micros();
        assert!(b >= a);
        assert!(t.elapsed_secs() >= 0.0);
    }
}
