//! Summary statistics used by benches, metrics, and the Porter tuner.

/// Descriptive statistics for a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Welford online mean/variance — used in hot loops where we cannot
/// afford to buffer samples (e.g. per-access latency accounting).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Merge another accumulator into this one (parallel reduction).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Geometric mean of strictly-positive values (Fig. 2 aggregate row).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-9);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
    }

    #[test]
    fn online_merge_matches_single() {
        let xs: Vec<f64> = (0..500).map(|i| i as f64 * 0.1).collect();
        let ys: Vec<f64> = (0..300).map(|i| 100.0 - i as f64).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs {
            a.push(x);
        }
        for &y in &ys {
            b.push(y);
        }
        let mut whole = OnlineStats::new();
        for v in xs.iter().chain(ys.iter()) {
            whole.push(*v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std() - whole.std()).abs() < 1e-9);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }
}
