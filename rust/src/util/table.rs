//! ASCII table rendering for bench reports and CLI output.
//!
//! Every figure/table bench prints its rows through this so the output
//! visually matches the paper's tables and can be diffed between runs.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple ASCII table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Override alignments (defaults to all right-aligned; first column is
    /// usually a label, so `left_first()` is the common tweak).
    pub fn aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn left_first(mut self) -> Table {
        if !self.aligns.is_empty() {
            self.aligns[0] = Align::Left;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Table {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Table {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            out.push('+');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('+');
            }
            out.push('\n');
        };
        let line = |out: &mut String, cells: &[String], aligns: &[Align]| {
            out.push('|');
            for i in 0..cols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                match aligns[i] {
                    Align::Left => {
                        out.push(' ');
                        out.push_str(cell);
                        out.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        out.push_str(&" ".repeat(pad + 1));
                        out.push_str(cell);
                        out.push(' ');
                    }
                }
                out.push('|');
            }
            out.push('\n');
        };
        sep(&mut out);
        line(&mut out, &self.headers, &vec![Align::Left; cols]);
        sep(&mut out);
        for row in &self.rows {
            line(&mut out, row, &self.aligns);
        }
        sep(&mut out);
        out
    }

    /// Render as GitHub-flavoured markdown (for EXPERIMENTS.md snippets).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| ");
        out.push_str(&self.headers.join(" | "));
        out.push_str(" |\n|");
        for a in &self.aligns {
            out.push_str(match a {
                Align::Left => " :--- |",
                Align::Right => " ---: |",
            });
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str("| ");
            out.push_str(&row.join(" | "));
            out.push_str(" |\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["workload", "slowdown %"]).left_first();
        t.row_strs(&["pagerank", "38.2"]);
        t.row_strs(&["bfs", "31.0"]);
        let s = t.render();
        assert!(s.contains("| workload"));
        assert!(s.contains("pagerank"));
        // all lines same width
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["k", "v"]).left_first();
        t.row_strs(&["x", "1"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| k | v |\n| :--- | ---: |\n| x | 1 |"));
    }
}
