//! Minimal error type (`anyhow` substitute for the offline image).
//!
//! A string-chain error: `anyhow!("...")` creates one, [`Context`] wraps
//! one with an outer description. `Display` shows the outermost message;
//! the alternate form (`{:#}`) and `Debug` render the full chain
//! outermost-first, which is what `main() -> Result<(), Error>` prints.

use std::fmt;

/// An error with a chain of context strings, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { chain: vec![m.into()] }
    }

    /// Wrap with an outer context description.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The full outermost-first chain.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to any displayable error (the `anyhow::Context` API
/// subset the crate uses).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).context(f()))
    }
}

/// Format an [`Error`] in place (`anyhow!` substitute).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Err::<(), _>("inner failure")
            .context("outer context")
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer context");
        assert_eq!(format!("{e:#}"), "outer context: inner failure");
        assert_eq!(format!("{e:?}"), "outer context: inner failure");
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad thing {} at {}", 7, "site");
        assert_eq!(format!("{e}"), "bad thing 7 at site");
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<()> = Err(Error::msg("x")).with_context(|| format!("step {}", 2));
        assert_eq!(format!("{:#}", r.unwrap_err()), "step 2: x");
    }
}
