//! Byte-size formatting and parsing (`"192GB"`, `"19.25MB"`, …) used by
//! the config system (Table 1 values) and reports.

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// Render a byte count with a binary-unit suffix.
pub fn fmt_bytes(n: u64) -> String {
    let (val, unit) = if n >= GIB {
        (n as f64 / GIB as f64, "GiB")
    } else if n >= MIB {
        (n as f64 / MIB as f64, "MiB")
    } else if n >= KIB {
        (n as f64 / KIB as f64, "KiB")
    } else {
        return format!("{n}B");
    };
    format!("{}{}", crate::util::fmt_f64(val, 2), unit)
}

/// Parse sizes like `4096`, `128KB`, `19.25MB`, `192GB`, `2GiB`
/// (case-insensitive; decimal and binary suffixes both mean binary here,
/// matching how the paper quotes capacities).
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let lower = t.to_ascii_lowercase();
    let strip3 = |a: &'static str, b: &'static str, c: &'static str| {
        lower.strip_suffix(a).or(lower.strip_suffix(b)).or(lower.strip_suffix(c))
    };
    let (num_part, mult) = if let Some(p) = strip3("gib", "gb", "g") {
        (p, GIB)
    } else if let Some(p) = strip3("mib", "mb", "m") {
        (p, MIB)
    } else if let Some(p) = strip3("kib", "kb", "k") {
        (p, KIB)
    } else if let Some(p) = lower.strip_suffix("b") {
        (p, 1)
    } else {
        (lower.as_str(), 1)
    };
    let v: f64 = num_part.trim().parse().map_err(|_| format!("bad size: {s:?}"))?;
    if v < 0.0 {
        return Err(format!("negative size: {s:?}"));
    }
    Ok((v * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plain() {
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("128KB").unwrap(), 128 * KIB);
        assert_eq!(parse_bytes("19.25MB").unwrap(), (19.25 * MIB as f64) as u64);
        assert_eq!(parse_bytes("192GB").unwrap(), 192 * GIB);
        assert_eq!(parse_bytes("2GiB").unwrap(), 2 * GIB);
        assert_eq!(parse_bytes(" 64 kb ").unwrap(), 64 * KIB);
    }

    #[test]
    fn parse_rejects() {
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-5MB").is_err());
        assert!(parse_bytes("").is_err());
    }

    #[test]
    fn fmt_roundtrips_scale() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2 * KIB), "2KiB");
        assert_eq!(fmt_bytes(19 * MIB + MIB / 4), "19.25MiB");
        assert_eq!(fmt_bytes(192 * GIB), "192GiB");
    }
}
