//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Xoshiro256StarStar` (Blackman & Vigna), the same
//! construction the `rand` ecosystem uses for reproducible simulation.
//! All simulation components take an explicit seed so every experiment in
//! `EXPERIMENTS.md` is exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into a full generator
/// state. Passes BigCrush when used directly; here it is only a seeder.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the crate-wide simulation PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method
    /// to avoid modulo bias.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// plenty fast for workload generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`); used for Poisson
    /// arrival processes in the serving benches.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `theta` using
    /// rejection-inversion (Hörmann & Derflinger). Good enough for the
    /// skewed function-popularity and key-popularity models.
    pub fn zipf(&mut self, n: u64, theta: f64) -> u64 {
        debug_assert!(n > 0);
        if theta <= 0.0 {
            return self.gen_range(n);
        }
        // Simple inverse-CDF over a precomputed-free approximation:
        // P(X <= k) ~ H(k)/H(n) with H harmonic-like integral.
        let h = |x: f64| -> f64 {
            if (theta - 1.0).abs() < 1e-9 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - theta) - 1.0) / (1.0 - theta)
            }
        };
        let hn = h(n as f64);
        let u = self.f64() * hn;
        let x = if (theta - 1.0).abs() < 1e-9 {
            u.exp() - 1.0
        } else {
            ((1.0 - theta) * u + 1.0).powf(1.0 / (1.0 - theta)) - 1.0
        };
        (x as u64).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// Split off an independent child generator (for per-thread use).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_std() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(5);
        let n = 1000u64;
        let mut counts = vec![0usize; n as usize];
        for _ in 0..100_000 {
            let k = r.zipf(n, 0.99);
            assert!(k < n);
            counts[k as usize] += 1;
        }
        // rank 0 should dominate the tail ranks heavily
        assert!(counts[0] > 20 * counts[500].max(1));
    }

    #[test]
    fn zipf_theta_zero_uniform() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.zipf(4, 0.0) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 1_000, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn fork_independent() {
        let mut a = Rng::new(1);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
