//! Function lifecycle: cold → warm → snapshotted → evicted.
//!
//! The paper's premise is that serverless invocations are short,
//! memory-intensive, and repeat often, so re-building a function's
//! working set dominates — Porter's shim profiles objects precisely so
//! later invocations skip rediscovery. TrEnv-style systems take the
//! next step: keep finished execution environments alive and share
//! their memory state across invocations *and nodes* through the CXL
//! pool. This module models that warm path:
//!
//! * [`warmpool`] — a per-node [`WarmPool`] keeps finished sandboxes
//!   alive under a byte budget, governed by a pluggable
//!   [`keepalive::KeepAlivePolicy`] (fixed TTL, LRU-under-pressure,
//!   or a per-function inter-arrival histogram);
//! * [`snapshot`] — a cluster-wide [`SnapshotStore`] demotes
//!   evicted-but-likely-to-return sandboxes into the shared cross-node
//!   CXL pool (leasing capacity from `cluster::pool::CxlPool` and
//!   debiting link bandwidth on snapshot/restore, exactly like
//!   migration bytes), so any node can restore a peer's snapshot
//!   instead of paying a full cold start + profile run.
//!
//! The state machine a sandbox moves through:
//!
//! ```text
//!             invoke (miss)                    finish
//!   [Cold] ──────────────────► running ──────────────────► [Warm]
//!     ▲                                                      │
//!     │ snapshot evicted /               TTL expiry / budget │
//!     │ never snapshotted                pressure (policy)   │
//!     │                                                      ▼
//!  [Evicted] ◄──────────────────────────────────── [Snapshotted]
//!                 store eviction (LRU / lease denied)   │
//!                                                       │ invoke on
//!                                                       ▼ any node
//!                                                    restore
//! ```
//!
//! Everything here is single-threaded virtual-time state (`&mut`
//! plumbing, `Vec` not `HashMap` where iteration order matters), so a
//! fleet run stays exactly reproducible under a fixed seed.

pub mod keepalive;
pub mod snapshot;
pub mod warmpool;

pub use keepalive::{policy_from_config, KeepAlivePolicy};
pub use snapshot::{AdmitOutcome, Snapshot, SnapshotMetrics, SnapshotStore};
pub use warmpool::{WarmPool, WarmPoolMetrics};

use std::sync::Arc;

use crate::shim::SandboxImage;

/// How an invocation's sandbox was obtained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartKind {
    /// A live sandbox was waiting in the node's warm pool.
    Warm,
    /// Restored from a CXL-resident snapshot (any node's).
    Restored,
    /// Full cold start: new sandbox, working set rebuilt from scratch.
    Cold,
}

impl StartKind {
    pub fn name(&self) -> &'static str {
        match self {
            StartKind::Warm => "warm",
            StartKind::Restored => "restored",
            StartKind::Cold => "cold",
        }
    }
}

/// A kept-alive execution environment: the shim's captured memory image
/// plus the lifecycle bookkeeping the keep-alive policies need.
///
/// The image is `Arc`-shared with the measured `ServiceShape` it came
/// from — keeping/evicting/snapshotting a sandbox on every finish must
/// not deep-copy the object list.
#[derive(Debug, Clone, PartialEq)]
pub struct Sandbox {
    pub function: String,
    pub image: Arc<SandboxImage>,
    /// Virtual time the sandbox finished its (latest) invocation and
    /// entered the pool — arrivals earlier than this cannot use it.
    pub created_ns: u64,
    /// A claimed sandbox is busy until its invocation finishes: a
    /// second concurrent arrival of the same function cannot share it
    /// and must cold-start (or restore) its own transient sandbox.
    pub busy_until_ns: u64,
    pub last_used_ns: u64,
    /// Completed invocations this environment has served.
    pub uses: u64,
}

impl Sandbox {
    pub fn new(function: &str, image: impl Into<Arc<SandboxImage>>, t_ns: u64) -> Sandbox {
        Sandbox {
            function: function.to_string(),
            image: image.into(),
            created_ns: t_ns,
            busy_until_ns: t_ns,
            last_used_ns: t_ns,
            uses: 1,
        }
    }

    /// Bytes the sandbox pins while warm (both tiers).
    pub fn bytes(&self) -> u64 {
        self.image.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_kind_names() {
        assert_eq!(StartKind::Warm.name(), "warm");
        assert_eq!(StartKind::Restored.name(), "restored");
        assert_eq!(StartKind::Cold.name(), "cold");
    }

    #[test]
    fn sandbox_bytes_follow_image() {
        let img = SandboxImage {
            dram_resident_bytes: 100,
            cxl_resident_bytes: 50,
            ..SandboxImage::default()
        };
        let sb = Sandbox::new("f", img, 7);
        assert_eq!(sb.bytes(), 150);
        assert_eq!(sb.uses, 1);
        assert_eq!(sb.created_ns, 7);
    }
}
