//! The per-node warm pool: finished sandboxes kept alive under a byte
//! budget.
//!
//! The pool holds at most one sandbox per function (the node's kept
//! execution environment; overlapping invocations of the same function
//! each cold-start their own transient sandbox and only the latest
//! finisher is kept). Lookup at dispatch time is a *warm hit* when a
//! sandbox for the function is present, already finished
//! (`created_ns <= t`), not claimed by a still-running invocation
//! (`busy_until_ns <= t`), and still inside its policy keep-alive
//! window.
//!
//! Two eviction paths, both returning the evicted sandboxes to the
//! caller so the cluster layer can demote them into the snapshot store:
//!
//! * **expiry** — [`WarmPool::advance`] reclaims sandboxes whose
//!   policy deadline passed;
//! * **pressure** — [`WarmPool::insert`] evicts lowest-rank sandboxes
//!   until the new total fits the budget (a sandbox larger than the
//!   whole budget is rejected outright and returned as evicted).
//!
//! Invariant (property-tested): `used_bytes() <= budget_bytes()` after
//! every operation, and `used_bytes()` equals the sum of live sandbox
//! sizes. State is plain `Vec`s so iteration order — and therefore the
//! fleet's determinism token — is reproducible.

use crate::lifecycle::keepalive::KeepAlivePolicy;
use crate::lifecycle::Sandbox;

/// Warm-pool counters, reported per node and rolled up fleet-wide.
#[derive(Debug, Clone, Copy, Default)]
pub struct WarmPoolMetrics {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions_expired: u64,
    pub evictions_pressure: u64,
    pub rejected_oversized: u64,
    pub peak_used_bytes: u64,
}

/// A node's keep-alive pool.
pub struct WarmPool {
    budget_bytes: u64,
    policy: Box<dyn KeepAlivePolicy>,
    live: Vec<Sandbox>,
    used_bytes: u64,
    pub metrics: WarmPoolMetrics,
}

impl WarmPool {
    pub fn new(budget_bytes: u64, policy: Box<dyn KeepAlivePolicy>) -> WarmPool {
        WarmPool {
            budget_bytes,
            policy,
            live: Vec::new(),
            used_bytes: 0,
            metrics: WarmPoolMetrics::default(),
        }
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Learning hook: observe one arrival (hit or miss) of `function`.
    pub fn note_invocation(&mut self, function: &str, t_ns: u64) {
        self.policy.note_invocation(function, t_ns);
    }

    fn usable(&self, sb: &Sandbox, t_ns: u64) -> bool {
        t_ns >= sb.created_ns && t_ns >= sb.busy_until_ns && t_ns <= self.policy.keep_until(sb)
    }

    /// Non-mutating peek: would an arrival of `function` at `t_ns` hit?
    pub fn contains(&self, function: &str, t_ns: u64) -> bool {
        self.live.iter().any(|sb| sb.function == function && self.usable(sb, t_ns))
    }

    /// Claim a warm sandbox for an arrival of `function` at `t_ns`.
    /// On a hit the sandbox's recency/use counters advance; a sandbox
    /// already claimed by an unfinished invocation (`busy_until_ns`)
    /// cannot be shared — the concurrent arrival misses.
    pub fn lookup(&mut self, function: &str, t_ns: u64) -> bool {
        let keep = &self.policy;
        let hit = self
            .live
            .iter_mut()
            .find(|sb| {
                sb.function == function
                    && t_ns >= sb.created_ns
                    && t_ns >= sb.busy_until_ns
                    && t_ns <= keep.keep_until(sb)
            })
            .map(|sb| {
                sb.last_used_ns = t_ns;
                sb.uses += 1;
            })
            .is_some();
        if hit {
            self.metrics.hits += 1;
        } else {
            self.metrics.misses += 1;
        }
        hit
    }

    /// Refresh a live sandbox after an invocation finished on it at
    /// `t_ns`: extends the keep-alive window and marks the sandbox busy
    /// through the finish time, so arrivals that overlapped the
    /// invocation miss instead of sharing one environment.
    pub fn touch(&mut self, function: &str, t_ns: u64) {
        if let Some(sb) = self.live.iter_mut().find(|sb| sb.function == function) {
            sb.last_used_ns = sb.last_used_ns.max(t_ns);
            sb.busy_until_ns = sb.busy_until_ns.max(t_ns);
        }
    }

    /// Reclaim every sandbox whose keep-alive deadline passed by
    /// `t_ns`, returning them (eviction candidates for the snapshot
    /// store).
    pub fn advance(&mut self, t_ns: u64) -> Vec<Sandbox> {
        let mut evicted = Vec::new();
        let mut i = 0;
        while i < self.live.len() {
            if self.policy.keep_until(&self.live[i]) < t_ns {
                let sb = self.live.remove(i);
                self.used_bytes -= sb.bytes();
                self.metrics.evictions_expired += 1;
                evicted.push(sb);
            } else {
                i += 1;
            }
        }
        evicted
    }

    /// Keep a finished sandbox. An existing sandbox for the same
    /// function is merged (newest image wins, use counts accumulate).
    /// Returns everything evicted to make room — including the new
    /// sandbox itself when it alone exceeds the whole budget.
    pub fn insert(&mut self, mut sb: Sandbox) -> Vec<Sandbox> {
        self.metrics.insertions += 1;
        if let Some(i) = self.live.iter().position(|s| s.function == sb.function) {
            let old = self.live.remove(i);
            self.used_bytes -= old.bytes();
            if old.created_ns > sb.created_ns {
                // an overlapping invocation finished later and was kept
                // first; preserve its fresher image
                sb.image = old.image;
                sb.created_ns = old.created_ns;
            }
            sb.uses += old.uses;
            sb.last_used_ns = sb.last_used_ns.max(old.last_used_ns);
            sb.busy_until_ns = sb.busy_until_ns.max(old.busy_until_ns);
        }
        let mut evicted = Vec::new();
        if sb.bytes() > self.budget_bytes {
            self.metrics.rejected_oversized += 1;
            evicted.push(sb);
            return evicted;
        }
        while self.used_bytes + sb.bytes() > self.budget_bytes {
            let victim = self
                .live
                .iter()
                .enumerate()
                .min_by(|(ai, a), (bi, b)| {
                    let (ra, rb) = (
                        self.policy.victim_rank(a, sb.last_used_ns),
                        self.policy.victim_rank(b, sb.last_used_ns),
                    );
                    ra.partial_cmp(&rb).unwrap_or(std::cmp::Ordering::Equal).then(ai.cmp(bi))
                })
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    let v = self.live.remove(i);
                    self.used_bytes -= v.bytes();
                    self.metrics.evictions_pressure += 1;
                    evicted.push(v);
                }
                None => break, // empty pool: sb fits by the check above
            }
        }
        self.used_bytes += sb.bytes();
        self.live.push(sb);
        self.metrics.peak_used_bytes = self.metrics.peak_used_bytes.max(self.used_bytes);
        debug_assert!(self.used_bytes <= self.budget_bytes);
        evicted
    }

    /// Live sandboxes, in insertion order (oldest first).
    pub fn sandboxes(&self) -> &[Sandbox] {
        &self.live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lifecycle::keepalive::{FixedTtl, LruUnderPressure};
    use crate::shim::SandboxImage;

    fn sb(function: &str, bytes: u64, t: u64) -> Sandbox {
        let image = SandboxImage {
            dram_resident_bytes: bytes,
            cxl_resident_bytes: 0,
            ..SandboxImage::default()
        };
        Sandbox::new(function, image, t)
    }

    fn pool(budget: u64, ttl: u64) -> WarmPool {
        WarmPool::new(budget, Box::new(FixedTtl { ttl_ns: ttl }))
    }

    #[test]
    fn hit_within_ttl_miss_after() {
        let mut p = pool(1000, 100);
        assert!(p.insert(sb("f", 10, 50)).is_empty());
        assert!(!p.lookup("f", 40), "arrival before the sandbox finished");
        assert!(p.lookup("f", 60));
        assert!(p.lookup("f", 160), "ttl refreshed by the hit at t=60");
        assert!(!p.lookup("f", 300));
        assert_eq!(p.metrics.hits, 2);
        assert_eq!(p.metrics.misses, 2);
    }

    #[test]
    fn busy_sandbox_not_shared_by_concurrent_arrivals() {
        let mut p = pool(1000, 10_000);
        p.insert(sb("f", 10, 100));
        // first arrival claims the sandbox; its invocation runs to 900
        assert!(p.lookup("f", 200));
        p.touch("f", 900);
        // overlapping arrival cannot share the claimed environment…
        assert!(!p.contains("f", 500));
        assert!(!p.lookup("f", 500));
        // …but once the invocation finished the sandbox is free again
        assert!(p.contains("f", 900));
        assert!(p.lookup("f", 901));
    }

    #[test]
    fn advance_expires_and_returns() {
        let mut p = pool(1000, 100);
        p.insert(sb("a", 10, 0));
        p.insert(sb("b", 20, 50));
        let evicted = p.advance(120);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].function, "a");
        assert_eq!(p.used_bytes(), 20);
        assert_eq!(p.metrics.evictions_expired, 1);
    }

    #[test]
    fn pressure_evicts_lru_first() {
        let mut p = WarmPool::new(100, Box::new(LruUnderPressure));
        p.insert(sb("old", 40, 10));
        p.insert(sb("mid", 40, 20));
        let evicted = p.insert(sb("new", 40, 30));
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].function, "old");
        assert!(p.used_bytes() <= 100);
        assert!(p.contains("mid", 30) && p.contains("new", 30));
    }

    #[test]
    fn oversized_sandbox_rejected() {
        let mut p = pool(100, 1000);
        let evicted = p.insert(sb("big", 200, 0));
        assert_eq!(evicted.len(), 1);
        assert_eq!(p.used_bytes(), 0);
        assert_eq!(p.metrics.rejected_oversized, 1);
    }

    #[test]
    fn zero_budget_keeps_nothing() {
        let mut p = pool(0, 1000);
        let evicted = p.insert(sb("f", 1, 0));
        assert_eq!(evicted.len(), 1);
        assert!(!p.contains("f", 1));
    }

    #[test]
    fn reinsert_merges_uses() {
        let mut p = pool(1000, 1000);
        p.insert(sb("f", 10, 0));
        assert!(p.lookup("f", 5));
        p.insert(sb("f", 30, 50));
        assert_eq!(p.len(), 1);
        assert_eq!(p.used_bytes(), 30);
        assert_eq!(p.sandboxes()[0].uses, 3); // 1 + hit + reinsert
    }
}
