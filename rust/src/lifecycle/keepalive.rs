//! Pluggable keep-alive policies for the per-node warm pool.
//!
//! A policy answers two questions about a warm sandbox:
//!
//! 1. *How long is it worth keeping while idle?* — [`KeepAlivePolicy::
//!    keep_until`] gives the deadline after which the pool reclaims it
//!    even without memory pressure.
//! 2. *Who goes first under pressure?* — [`KeepAlivePolicy::
//!    victim_rank`] orders live sandboxes when the pool exceeds its
//!    byte budget (lower rank = evicted earlier).
//!
//! Three policies ship, mirroring the keep-alive literature:
//! fixed TTL (the classic 10-minute rule), pure LRU-under-pressure
//! (never expire, evict least-recently-used when space is needed), and
//! a per-function inter-arrival histogram that sizes each function's
//! keep-alive window to a percentile of its observed idle times
//! (à la "Serverless in the Wild").

use std::collections::HashMap;

use crate::config::LifecycleConfig;
use crate::lifecycle::Sandbox;

/// A keep-alive policy: pure decision logic, no pool state.
pub trait KeepAlivePolicy: Send {
    fn name(&self) -> &'static str;

    /// Observe one arrival of `function` at virtual time `t_ns`
    /// (learning hook; the histogram policy builds its inter-arrival
    /// distribution from this).
    fn note_invocation(&mut self, function: &str, t_ns: u64);

    /// Deadline (virtual ns) after which an idle sandbox may be
    /// reclaimed without pressure. `u64::MAX` = keep forever.
    fn keep_until(&self, sandbox: &Sandbox) -> u64;

    /// Pressure-eviction order: the live sandbox with the lowest rank
    /// is evicted first. Ties break on pool insertion order.
    fn victim_rank(&self, sandbox: &Sandbox, now_ns: u64) -> f64;
}

/// Fixed TTL: every sandbox lives exactly `ttl_ns` past its last use;
/// pressure evictions go least-recently-used first.
pub struct FixedTtl {
    pub ttl_ns: u64,
}

impl KeepAlivePolicy for FixedTtl {
    fn name(&self) -> &'static str {
        "ttl"
    }

    fn note_invocation(&mut self, _function: &str, _t_ns: u64) {}

    fn keep_until(&self, sandbox: &Sandbox) -> u64 {
        sandbox.last_used_ns.saturating_add(self.ttl_ns)
    }

    fn victim_rank(&self, sandbox: &Sandbox, _now_ns: u64) -> f64 {
        sandbox.last_used_ns as f64
    }
}

/// LRU under pressure: sandboxes never expire on their own; the pool
/// only reclaims them when the byte budget forces it, least recently
/// used first.
pub struct LruUnderPressure;

impl KeepAlivePolicy for LruUnderPressure {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn note_invocation(&mut self, _function: &str, _t_ns: u64) {}

    fn keep_until(&self, _sandbox: &Sandbox) -> u64 {
        u64::MAX
    }

    fn victim_rank(&self, sandbox: &Sandbox, _now_ns: u64) -> f64 {
        sandbox.last_used_ns as f64
    }
}

/// Histogram keep-alive: per-function inter-arrival times are binned in
/// log₂ buckets; a sandbox is kept until the configured percentile of
/// its function's observed idle times (clamped to `[min_ns, max_ns]`),
/// so chatty functions get short windows and bursty-but-returning ones
/// long windows. Before any data exists the window is `fallback_ns`
/// (wired to `lifecycle.ttl_ns`, then clamped like any learned window).
pub struct IatHistogram {
    pub percentile: f64,
    pub min_ns: u64,
    pub max_ns: u64,
    pub fallback_ns: u64,
    /// function → (last arrival, log₂-binned IAT counts).
    seen: HashMap<String, (u64, [u64; 64])>,
}

impl IatHistogram {
    pub fn new(percentile: f64, min_ns: u64, max_ns: u64, fallback_ns: u64) -> IatHistogram {
        IatHistogram { percentile, min_ns, max_ns, fallback_ns, seen: HashMap::new() }
    }

    /// Upper edge of the histogram bin at `self.percentile`, or `None`
    /// with no observations yet.
    fn percentile_iat(&self, function: &str) -> Option<u64> {
        let (_, bins) = self.seen.get(function)?;
        let total: u64 = bins.iter().sum();
        if total == 0 {
            return None;
        }
        let target = (total as f64 * self.percentile).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in bins.iter().enumerate() {
            seen += c;
            if seen >= target {
                // bin i holds IATs in [2^i, 2^(i+1)): keep to the upper edge
                return Some(1u64 << (i + 1).min(63));
            }
        }
        None
    }

    fn window_ns(&self, function: &str) -> u64 {
        self.percentile_iat(function)
            .unwrap_or(self.fallback_ns)
            .clamp(self.min_ns, self.max_ns)
    }
}

impl KeepAlivePolicy for IatHistogram {
    fn name(&self) -> &'static str {
        "histogram"
    }

    fn note_invocation(&mut self, function: &str, t_ns: u64) {
        let entry = self.seen.entry(function.to_string()).or_insert((t_ns, [0u64; 64]));
        let (last, bins) = entry;
        if t_ns > *last {
            let iat = t_ns - *last;
            let bin = (63 - iat.leading_zeros() as usize).min(63);
            bins[bin] += 1;
        }
        *last = (*last).max(t_ns);
    }

    fn keep_until(&self, sandbox: &Sandbox) -> u64 {
        sandbox.last_used_ns.saturating_add(self.window_ns(&sandbox.function))
    }

    fn victim_rank(&self, sandbox: &Sandbox, _now_ns: u64) -> f64 {
        // evict the sandbox whose window expires soonest
        self.keep_until(sandbox) as f64
    }
}

/// Build the policy a `[lifecycle]` config names. The config is
/// validated before this is called, so unknown names are unreachable;
/// they still fall back to fixed TTL defensively.
pub fn policy_from_config(cfg: &LifecycleConfig) -> Box<dyn KeepAlivePolicy> {
    match cfg.policy.as_str() {
        "lru" => Box::new(LruUnderPressure),
        "histogram" => Box::new(IatHistogram::new(
            cfg.histogram_percentile,
            cfg.histogram_min_ns,
            cfg.histogram_max_ns,
            cfg.ttl_ns,
        )),
        _ => Box::new(FixedTtl { ttl_ns: cfg.ttl_ns }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::SandboxImage;

    fn sandbox(t: u64) -> Sandbox {
        Sandbox::new("f", SandboxImage::default(), t)
    }

    #[test]
    fn fixed_ttl_expires_after_last_use() {
        let p = FixedTtl { ttl_ns: 100 };
        let mut sb = sandbox(50);
        assert_eq!(p.keep_until(&sb), 150);
        sb.last_used_ns = 200;
        assert_eq!(p.keep_until(&sb), 300);
    }

    #[test]
    fn lru_never_expires_and_ranks_by_recency() {
        let p = LruUnderPressure;
        let old = sandbox(10);
        let fresh = sandbox(1000);
        assert_eq!(p.keep_until(&old), u64::MAX);
        assert!(p.victim_rank(&old, 2000) < p.victim_rank(&fresh, 2000));
    }

    #[test]
    fn histogram_learns_interarrival_window() {
        let mut p = IatHistogram::new(0.99, 1, u64::MAX, 5_000);
        // no data: fallback window
        assert_eq!(p.window_ns("f"), 5_000);
        // regular arrivals every ~1000ns → window is the 2^10 bin edge
        for i in 0..50u64 {
            p.note_invocation("f", i * 1000);
        }
        let w = p.window_ns("f");
        assert!(w >= 1000 && w <= 2048, "window {w} should cover the 1µs IAT");
        // a different function is unaffected
        assert_eq!(p.window_ns("g"), 5_000);
    }

    #[test]
    fn histogram_clamps_window() {
        let mut p = IatHistogram::new(0.99, 10_000, 20_000, 15_000);
        for i in 0..10u64 {
            p.note_invocation("f", i * 10); // tiny IATs
        }
        assert_eq!(p.window_ns("f"), 10_000); // clamped up to min
    }

    #[test]
    fn config_builds_named_policies() {
        let mut cfg = LifecycleConfig::default();
        for (name, expect) in [("ttl", "ttl"), ("lru", "lru"), ("histogram", "histogram")] {
            cfg.policy = name.to_string();
            assert_eq!(policy_from_config(&cfg).name(), expect);
        }
    }
}
