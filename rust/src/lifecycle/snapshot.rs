//! The cluster-wide snapshot store: evicted-but-likely-to-return
//! sandboxes demoted into the shared CXL pool.
//!
//! TrEnv's observation is that a pooled-memory fabric makes a sandbox
//! snapshot *location-free*: once the environment's memory image lives
//! in the CXL pool, any node can map it and resume, paying a restore
//! (promote the DRAM-hot set back over its link) instead of a full
//! cold start + profile run. The store models exactly that:
//!
//! * snapshots **lease capacity** from [`CxlPool`] like any in-flight
//!   invocation — the lease is held for the snapshot's whole lifetime
//!   and released when the store evicts it, so snapshot residency is
//!   visible in the pool occupancy the fleet report prints;
//! * snapshot writes and restore reads **debit link bandwidth** via
//!   [`CxlPool::record_traffic`], exactly as migration bytes do — a
//!   restore storm slows co-located demand traffic;
//! * the store's own budget is a configurable fraction of the pool, and
//!   it evicts least-recently-restored snapshots first (their leases are
//!   released back to the pool — property tests assert nothing leaks).
//!
//! One snapshot per function, deduplicated fleet-wide: the image is the
//! function's environment, not one node's private state.

use std::sync::Arc;

use crate::cluster::pool::CxlPool;
use crate::lifecycle::Sandbox;
use crate::shim::SandboxImage;

/// A CXL-resident sandbox snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub function: String,
    pub image: Arc<SandboxImage>,
    /// Pool capacity leased (the image's full resident set).
    pub lease_bytes: u64,
    /// Node whose memory segments back the image. If that node dies,
    /// the snapshot is orphaned — [`SnapshotStore::evict_donor`] drops
    /// it and later restores fall back to a cold start.
    pub donor_node: usize,
    pub taken_ns: u64,
    pub last_used_ns: u64,
    pub restores: u64,
}

/// Why an admission attempt did (or did not) create a snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    Admitted,
    AlreadyPresent,
    BelowMinUses,
    /// The image exceeds the store's whole budget — permanent for this
    /// function, callers should stop retrying.
    TooBig,
    /// The shared pool could not grant the lease right now — transient.
    PoolDenied,
}

impl AdmitOutcome {
    pub fn admitted(&self) -> bool {
        matches!(self, AdmitOutcome::Admitted)
    }
}

/// Store counters, surfaced in the fleet report.
#[derive(Debug, Clone, Copy, Default)]
pub struct SnapshotMetrics {
    pub snapshots_taken: u64,
    /// Bytes written over CXL links creating snapshots.
    pub snapshot_bytes: u64,
    pub restores: u64,
    /// Bytes read over CXL links restoring snapshots.
    pub restore_bytes: u64,
    /// Admissions refused because the pool could not grant the lease.
    pub lease_denied: u64,
    /// Snapshots evicted to make room (their leases were released).
    pub evicted: u64,
    pub peak_leased_bytes: u64,
}

/// The shared store.
pub struct SnapshotStore {
    /// Max bytes of pool capacity snapshots may hold at once.
    capacity_bytes: u64,
    /// Only sandboxes with at least this many completed uses are
    /// considered likely-to-return and worth snapshotting.
    min_uses: u64,
    restore_overhead_ns: u64,
    snaps: Vec<Snapshot>,
    leased_bytes: u64,
    pub metrics: SnapshotMetrics,
}

impl SnapshotStore {
    pub fn new(capacity_bytes: u64, min_uses: u64, restore_overhead_ns: u64) -> SnapshotStore {
        SnapshotStore {
            capacity_bytes,
            min_uses,
            restore_overhead_ns,
            snaps: Vec::new(),
            leased_bytes: 0,
            metrics: SnapshotMetrics::default(),
        }
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn leased_bytes(&self) -> u64 {
        self.leased_bytes
    }

    pub fn len(&self) -> usize {
        self.snaps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.snaps.is_empty()
    }

    /// Is a snapshot of `function` resident?
    pub fn has(&self, function: &str) -> bool {
        self.snaps.iter().any(|s| s.function == function)
    }

    /// The resident image (round-trip inspection).
    pub fn image(&self, function: &str) -> Option<&SandboxImage> {
        self.snaps.iter().find(|s| s.function == function).map(|s| s.image.as_ref())
    }

    /// Predicted restore latency for the routing signal (what a cold
    /// node would pay instead of a full cold start).
    pub fn restore_estimate_ns(&self, function: &str, link_bw_gbps: f64) -> Option<u64> {
        let s = self.snaps.iter().find(|s| s.function == function)?;
        Some(self.restore_overhead_ns + transfer_ns(s.image.transfer_bytes(), link_bw_gbps, 1.0))
    }

    /// Try to demote an evicted (or freshly kept) sandbox into the
    /// pool at virtual time `t_ns`, writing over `node`'s CXL link.
    pub fn admit(
        &mut self,
        sb: &Sandbox,
        t_ns: u64,
        node: usize,
        pool: &mut CxlPool,
    ) -> AdmitOutcome {
        if self.has(&sb.function) {
            return AdmitOutcome::AlreadyPresent;
        }
        if sb.uses < self.min_uses {
            return AdmitOutcome::BelowMinUses;
        }
        let lease = sb.image.resident_bytes();
        if lease > self.capacity_bytes {
            self.metrics.lease_denied += 1;
            return AdmitOutcome::TooBig;
        }
        // charge the pool FIRST: a denied admission must not have
        // evicted resident snapshots to make room it never used.
        // `try_lease` never advances virtual time — `t_ns` is usually
        // an invocation finish time in the future, and draining the
        // release queue up to it would free in-flight capacity early.
        if !pool.try_lease(lease) {
            self.metrics.lease_denied += 1;
            return AdmitOutcome::PoolDenied;
        }
        // make room in the store's own budget (LRU by last restore/use)
        while self.leased_bytes + lease > self.capacity_bytes {
            let victim = self
                .snaps
                .iter()
                .enumerate()
                .min_by_key(|(i, s)| (s.last_used_ns, *i))
                .map(|(i, _)| i);
            match victim {
                Some(i) => self.evict_at(i, t_ns, pool),
                None => break,
            }
        }
        let transfer = sb.image.transfer_bytes();
        pool.record_traffic(node, t_ns, transfer);
        self.leased_bytes += lease;
        self.metrics.snapshots_taken += 1;
        self.metrics.snapshot_bytes += transfer;
        self.metrics.peak_leased_bytes = self.metrics.peak_leased_bytes.max(self.leased_bytes);
        self.snaps.push(Snapshot {
            function: sb.function.clone(),
            image: sb.image.clone(),
            lease_bytes: lease,
            donor_node: node,
            taken_ns: t_ns,
            last_used_ns: t_ns,
            restores: 0,
        });
        AdmitOutcome::Admitted
    }

    fn evict_at(&mut self, i: usize, t_ns: u64, pool: &mut CxlPool) {
        let s = self.snaps.remove(i);
        self.leased_bytes -= s.lease_bytes;
        pool.release_at(t_ns, s.lease_bytes);
        self.metrics.evicted += 1;
    }

    /// Evict every snapshot donated by `node` — the node died, so the
    /// memory segments backing those images are gone. Returns the
    /// number evicted; each lease is released back to the pool (the
    /// lease-leak property holds across faults), and later restores of
    /// the affected functions miss the store and fall back to a cold
    /// start with a profile run instead of panicking.
    pub fn evict_donor(&mut self, node: usize, t_ns: u64, pool: &mut CxlPool) -> u64 {
        let mut evicted = 0;
        while let Some(i) = self.snaps.iter().position(|s| s.donor_node == node) {
            self.evict_at(i, t_ns, pool);
            evicted += 1;
        }
        evicted
    }

    /// Evict `function`'s snapshot (if any), releasing its lease.
    pub fn evict(&mut self, function: &str, t_ns: u64, pool: &mut CxlPool) -> bool {
        match self.snaps.iter().position(|s| s.function == function) {
            Some(i) => {
                self.evict_at(i, t_ns, pool);
                true
            }
            None => false,
        }
    }

    /// Restore `function` onto `node` at `t_ns`: debit the read traffic
    /// and return the startup latency (transfer inflated by the node's
    /// current `contention` factor, ≥ 1.0). `None` if no snapshot.
    pub fn restore(
        &mut self,
        function: &str,
        t_ns: u64,
        node: usize,
        pool: &mut CxlPool,
        link_bw_gbps: f64,
        contention: f64,
    ) -> Option<(u64, u64)> {
        let overhead = self.restore_overhead_ns;
        let s = self.snaps.iter_mut().find(|s| s.function == function)?;
        let transfer = s.image.transfer_bytes();
        s.last_used_ns = t_ns;
        s.restores += 1;
        self.metrics.restores += 1;
        self.metrics.restore_bytes += transfer;
        pool.record_traffic(node, t_ns, transfer);
        Some((overhead + transfer_ns(transfer, link_bw_gbps, contention), transfer))
    }

    /// Release every lease (end of run / teardown).
    pub fn release_all(&mut self, t_ns: u64, pool: &mut CxlPool) {
        while !self.snaps.is_empty() {
            self.evict_at(self.snaps.len() - 1, t_ns, pool);
        }
    }
}

/// Time to move `bytes` over a `bw_gbps` CXL link (1 GB/s ≈ 1 B/ns),
/// inflated by the current contention factor.
fn transfer_ns(bytes: u64, bw_gbps: f64, contention: f64) -> u64 {
    if bw_gbps <= 0.0 {
        return 0;
    }
    (bytes as f64 / bw_gbps * contention.max(1.0)).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sandbox(function: &str, dram: u64, cxl: u64, uses: u64) -> Sandbox {
        let image = SandboxImage {
            dram_resident_bytes: dram,
            cxl_resident_bytes: cxl,
            ..SandboxImage::default()
        };
        let mut sb = Sandbox::new(function, image, 0);
        sb.uses = uses;
        sb
    }

    fn pool(cap: u64) -> CxlPool {
        CxlPool::new(cap, 64.0, 30.0, 2, 1_000_000)
    }

    #[test]
    fn admit_leases_and_restore_debits() {
        let mut p = pool(10_000);
        let mut store = SnapshotStore::new(5_000, 1, 100);
        let sb = sandbox("f", 3_000, 1_000, 1);
        assert!(store.admit(&sb, 10, 0, &mut p).admitted());
        assert!(store.has("f"));
        assert_eq!(store.leased_bytes(), 4_000);
        assert!((p.occupancy() - 0.4).abs() < 1e-9);
        assert_eq!(store.metrics.snapshot_bytes, 3_000);
        // duplicate admit is a no-op
        assert_eq!(store.admit(&sb, 11, 0, &mut p), AdmitOutcome::AlreadyPresent);
        let (lat, bytes) = store.restore("f", 20, 1, &mut p, 30.0, 1.0).unwrap();
        assert_eq!(bytes, 3_000);
        assert_eq!(lat, 100 + 100); // 3000 B / 30 GB/s = 100ns + overhead
        assert_eq!(store.metrics.restore_bytes, 3_000);
        assert!(store.restore("g", 20, 1, &mut p, 30.0, 1.0).is_none());
    }

    #[test]
    fn store_budget_evicts_lru_and_releases_lease() {
        let mut p = pool(100_000);
        let mut store = SnapshotStore::new(5_000, 1, 0);
        assert!(store.admit(&sandbox("a", 3_000, 0, 1), 10, 0, &mut p).admitted());
        // touch a so b is the LRU after admit
        store.restore("a", 50, 0, &mut p, 30.0, 1.0);
        assert!(store.admit(&sandbox("b", 2_000, 0, 1), 60, 0, &mut p).admitted());
        // c (3000) forces an eviction: b (last_used 60) < a (last_used 50)?
        // no — a was restored at 50, b admitted at 60, so a is LRU.
        assert!(store.admit(&sandbox("c", 3_000, 0, 1), 100, 0, &mut p).admitted());
        assert!(!store.has("a"));
        assert!(store.has("b") && store.has("c"));
        assert_eq!(store.leased_bytes(), 5_000);
        p.advance(101);
        assert!((p.occupancy() - 0.05).abs() < 1e-9, "evicted lease must return to the pool");
        assert_eq!(store.metrics.evicted, 1);
    }

    #[test]
    fn pool_pressure_denies_lease_without_leak() {
        let mut p = pool(1_000);
        // someone else holds nearly everything
        p.acquire(0, 900);
        let mut store = SnapshotStore::new(10_000, 1, 0);
        assert_eq!(
            store.admit(&sandbox("f", 500, 0, 1), 10, 0, &mut p),
            AdmitOutcome::PoolDenied
        );
        assert_eq!(store.metrics.lease_denied, 1);
        assert_eq!(store.leased_bytes(), 0);
        p.advance(11);
        assert!((p.occupancy() - 0.9).abs() < 1e-9, "denied lease must not stay charged");
    }

    #[test]
    fn min_uses_gates_admission() {
        let mut p = pool(10_000);
        let mut store = SnapshotStore::new(5_000, 3, 0);
        assert_eq!(
            store.admit(&sandbox("f", 100, 0, 2), 0, 0, &mut p),
            AdmitOutcome::BelowMinUses
        );
        assert!(store.admit(&sandbox("f", 100, 0, 3), 0, 0, &mut p).admitted());
    }

    #[test]
    fn evict_donor_orphans_snapshots_without_leaking_leases() {
        let mut p = pool(100_000);
        let mut store = SnapshotStore::new(50_000, 1, 0);
        // node 0 donates a and b, node 1 donates c
        assert!(store.admit(&sandbox("a", 1_000, 0, 1), 10, 0, &mut p).admitted());
        assert!(store.admit(&sandbox("b", 2_000, 0, 1), 20, 0, &mut p).admitted());
        assert!(store.admit(&sandbox("c", 4_000, 0, 1), 30, 1, &mut p).admitted());
        assert_eq!(store.evict_donor(0, 40, &mut p), 2);
        assert!(!store.has("a") && !store.has("b"), "node 0's snapshots orphaned");
        assert!(store.has("c"), "node 1's snapshot survives");
        assert_eq!(store.leased_bytes(), 4_000);
        // orphaned leases returned to the pool — the PR 3 no-leak shape
        p.advance(41);
        assert!((p.occupancy() - 0.04).abs() < 1e-9, "orphaned leases must release");
        // restores of orphaned functions miss instead of panicking
        assert!(store.restore("a", 50, 1, &mut p, 30.0, 1.0).is_none());
        assert_eq!(store.evict_donor(0, 60, &mut p), 0, "idempotent");
    }

    #[test]
    fn release_all_drains_leases() {
        let mut p = pool(10_000);
        let mut store = SnapshotStore::new(10_000, 1, 0);
        store.admit(&sandbox("a", 1_000, 0, 1), 0, 0, &mut p);
        store.admit(&sandbox("b", 2_000, 0, 1), 0, 0, &mut p);
        store.release_all(5, &mut p);
        assert!(store.is_empty());
        assert_eq!(store.leased_bytes(), 0);
        p.advance(6);
        assert_eq!(p.occupancy(), 0.0);
    }
}
