//! Per-epoch time series: a columnar store plus the fleet sampler that
//! fills it on a virtual-time cadence.
//!
//! The sampler is driven from the cluster's event loop: `observe()` is
//! called with the current virtual time and a [`FleetSample`] value bag;
//! whenever one or more epoch boundaries have been crossed it emits one
//! point per series, stamped at the most recent boundary (even spacing,
//! no wall clock anywhere). Cumulative counters in the sample are turned
//! into per-epoch deltas, and per-function latency percentiles come from
//! [`crate::metrics::Histogram::interval`] — per-epoch, not cumulative.

use std::collections::BTreeMap;

use crate::metrics::Histogram;

/// One named series: parallel timestamp/value columns.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimeSeries {
    pub t_ns: Vec<u64>,
    pub values: Vec<f64>,
}

/// A set of named series, sorted by name for deterministic export.
#[derive(Debug, Clone, Default)]
pub struct SeriesSet {
    pub series: BTreeMap<String, TimeSeries>,
}

impl SeriesSet {
    pub fn new() -> SeriesSet {
        SeriesSet::default()
    }

    pub fn point(&mut self, name: &str, t_ns: u64, v: f64) {
        let s = self.series.entry(name.to_string()).or_default();
        s.t_ns.push(t_ns);
        s.values.push(v);
    }

    /// Number of distinct series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    /// Total number of points across all series.
    pub fn points(&self) -> u64 {
        self.series.values().map(|s| s.t_ns.len() as u64).sum()
    }

    pub fn get(&self, name: &str) -> Option<&TimeSeries> {
        self.series.get(name)
    }
}

/// Fleet state at one instant, gathered by the cluster from its nodes
/// and the CXL pool. Counter fields are cumulative since run start; the
/// sampler differences them into per-epoch rates.
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetSample {
    /// Peak DRAM mapped across nodes (best available residency proxy).
    pub dram_used_bytes: u64,
    pub dram_capacity_bytes: u64,
    /// CXL pool leased fraction, 0..1.
    pub pool_occupancy: f64,
    /// Worst per-node CXL link contention mapped to 0..1 utilization.
    pub link_utilization: f64,
    /// Summed queue backlog across nodes, in virtual ns of work.
    pub queue_depth_ns: u64,
    pub warm_pool_bytes: u64,
    pub active_nodes: u64,
    // cumulative counters
    pub completed: u64,
    pub promotions: u64,
    pub demotions: u64,
    pub ping_pongs: u64,
    pub migration_bytes: u64,
    pub cold_starts: u64,
    pub restores: u64,
}

fn frac(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// Epoch-driven sampler turning [`FleetSample`] snapshots into series.
#[derive(Debug)]
pub struct FleetSampler {
    on: bool,
    epoch_ns: u64,
    next_ns: u64,
    set: SeriesSet,
    last: FleetSample,
    lat: BTreeMap<String, Histogram>,
}

impl FleetSampler {
    pub fn disabled() -> FleetSampler {
        FleetSampler {
            on: false,
            epoch_ns: 1,
            next_ns: u64::MAX,
            set: SeriesSet::new(),
            last: FleetSample::default(),
            lat: BTreeMap::new(),
        }
    }

    pub fn new(epoch_ns: u64) -> FleetSampler {
        let epoch_ns = epoch_ns.max(1);
        FleetSampler { on: true, epoch_ns, next_ns: epoch_ns, ..FleetSampler::disabled() }
    }

    pub fn is_enabled(&self) -> bool {
        self.on
    }

    /// Feed one end-to-end latency into the per-function interval
    /// histogram (drained into p50/p99 points at each epoch).
    pub fn record_latency(&mut self, function: &str, e2e_ns: u64) {
        if self.on {
            self.lat.entry(function.to_string()).or_default().record(e2e_ns);
        }
    }

    /// Called with the current virtual time; emits one point per series
    /// when at least one epoch boundary has been crossed.
    pub fn observe(&mut self, t_ns: u64, s: &FleetSample) {
        if !self.on || t_ns < self.next_ns {
            return;
        }
        let mut at = self.next_ns;
        while self.next_ns <= t_ns {
            at = self.next_ns;
            self.next_ns += self.epoch_ns;
        }
        self.emit(at, s);
    }

    /// Force a final sample at end-of-run so short runs still produce
    /// at least one point per series.
    pub fn flush(&mut self, t_ns: u64, s: &FleetSample) {
        if self.on {
            self.emit(t_ns.max(1), s);
        }
    }

    fn emit(&mut self, at: u64, s: &FleetSample) {
        let set = &mut self.set;
        set.point("dram_occupancy", at, frac(s.dram_used_bytes, s.dram_capacity_bytes));
        set.point("pool_occupancy", at, s.pool_occupancy);
        set.point("cxl_link_utilization", at, s.link_utilization);
        set.point("queue_depth_ns", at, s.queue_depth_ns as f64);
        set.point("warm_pool_bytes", at, s.warm_pool_bytes as f64);
        set.point("active_nodes", at, s.active_nodes as f64);
        let d = |cur: u64, prev: u64| cur.saturating_sub(prev) as f64;
        set.point("completions_per_epoch", at, d(s.completed, self.last.completed));
        set.point("promotions_per_epoch", at, d(s.promotions, self.last.promotions));
        set.point("demotions_per_epoch", at, d(s.demotions, self.last.demotions));
        set.point("ping_pongs_per_epoch", at, d(s.ping_pongs, self.last.ping_pongs));
        set.point("migration_bytes_per_epoch", at, d(s.migration_bytes, self.last.migration_bytes));
        set.point("cold_starts_per_epoch", at, d(s.cold_starts, self.last.cold_starts));
        set.point("restores_per_epoch", at, d(s.restores, self.last.restores));
        for (name, h) in &self.lat {
            let iv = h.interval();
            if iv.count() > 0 {
                set.point(&format!("p50_ns:{name}"), at, iv.percentile(50.0) as f64);
                set.point(&format!("p99_ns:{name}"), at, iv.percentile(99.0) as f64);
            }
        }
        self.last = *s;
    }

    pub fn series(&self) -> &SeriesSet {
        &self.set
    }

    pub fn into_series(self) -> SeriesSet {
        self.set
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sampler_emits_nothing() {
        let mut sm = FleetSampler::disabled();
        sm.record_latency("kv", 100);
        sm.observe(1 << 40, &FleetSample::default());
        sm.flush(1 << 40, &FleetSample::default());
        assert!(sm.series().is_empty());
    }

    #[test]
    fn samples_land_on_epoch_boundaries_with_deltas() {
        let mut sm = FleetSampler::new(1_000);
        let mut s = FleetSample { completed: 5, pool_occupancy: 0.25, ..Default::default() };
        sm.observe(500, &s); // before the first boundary: nothing
        assert!(sm.series().is_empty());
        sm.observe(1_200, &s); // crossed t=1000
        s.completed = 9;
        sm.observe(3_700, &s); // crossed t=2000 and t=3000: one point at 3000
        let comp = sm.series().get("completions_per_epoch").unwrap();
        assert_eq!(comp.t_ns, vec![1_000, 3_000]);
        assert_eq!(comp.values, vec![5.0, 4.0]);
        let occ = sm.series().get("pool_occupancy").unwrap();
        assert_eq!(occ.values, vec![0.25, 0.25]);
    }

    #[test]
    fn per_function_percentiles_are_per_epoch() {
        let mut sm = FleetSampler::new(1_000);
        sm.record_latency("kv", 100);
        sm.record_latency("kv", 200);
        sm.observe(1_000, &FleetSample::default());
        // next epoch records nothing for kv: no p50 point is added
        sm.observe(2_000, &FleetSample::default());
        sm.record_latency("kv", 4_000);
        sm.observe(3_000, &FleetSample::default());
        let p50 = sm.series().get("p50_ns:kv").unwrap();
        assert_eq!(p50.t_ns, vec![1_000, 3_000]);
        assert_eq!(p50.values, vec![128.0, 4_096.0]);
        assert!(sm.series().get("p99_ns:kv").is_some());
    }

    #[test]
    fn flush_guarantees_points_on_short_runs() {
        let mut sm = FleetSampler::new(1 << 40);
        let s = FleetSample { active_nodes: 2, ..Default::default() };
        sm.flush(77, &s);
        assert!(sm.series().len() >= 5, "flush emits the full series set");
        assert_eq!(sm.series().get("active_nodes").unwrap().values, vec![2.0]);
    }
}
