//! Event taxonomy: everything the simulator can say about a moment in
//! virtual time.
//!
//! Every event carries the DES virtual clock (`t_ns`), the originating
//! node ([`FLEET`] for fleet-scoped events), and optionally a function
//! name, a free-form label, and a small numeric payload. A nonzero
//! `dur_ns` makes it a span (Chrome-trace `"X"`), zero an instant.

/// Node id sentinel for fleet-scoped events (autoscaler, CXL pool).
pub const FLEET: u64 = u64::MAX;

/// What happened. The stable string names key the Chrome-trace `cat`
/// field, the `telemetry summarize` rollup, and CI greps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// An invocation arrived at the gateway (instant, stamped with its
    /// eventual queue wait).
    Queued,
    /// Full invocation lifetime: arrival → finish (span; the label is
    /// the start classification: cold/warm/restored).
    Invocation,
    /// Sandbox startup paid before execution — cold init or snapshot
    /// restore (instant carrying `startup_ns`).
    Startup,
    /// A promote/demote batch applied for one invocation's replay,
    /// labeled with the migration policy.
    Migration,
    /// Warm-pool eviction (expiry or pressure) dropped a sandbox.
    WarmEvict,
    /// A sandbox image was admitted to the CXL snapshot store.
    SnapshotWrite,
    /// A snapshot restore seeded a sandbox (restore latency rides on
    /// the matching Startup event).
    SnapshotRestore,
    /// Per-function DRAM provisioning changed budget shares.
    Provision,
    /// The autoscaler added or retired nodes (label: up/down).
    Autoscale,
    /// CXL pool lease granted late (capacity wait) and/or short
    /// (shortage).
    PoolContention,
    /// Workload phase marker from the shim (machine-level runs).
    Phase,
    /// Machine-level aggregation tick that applied migrations, labeled
    /// with the migrator name.
    MachineEpoch,
    /// Fault injection applied a node or link transition (label: the
    /// [`crate::cluster::faults::FaultAction`] name — node_down,
    /// node_up, link_degrade, link_restore).
    Fault,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Queued => "queued",
            EventKind::Invocation => "invocation",
            EventKind::Startup => "startup",
            EventKind::Migration => "migration",
            EventKind::WarmEvict => "warm_evict",
            EventKind::SnapshotWrite => "snapshot_write",
            EventKind::SnapshotRestore => "snapshot_restore",
            EventKind::Provision => "provision",
            EventKind::Autoscale => "autoscale",
            EventKind::PoolContention => "pool_contention",
            EventKind::Phase => "phase",
            EventKind::MachineEpoch => "machine_epoch",
            EventKind::Fault => "fault",
        }
    }
}

/// One telemetry record. Virtual timestamps only — no wall clock — so
/// recording is deterministic and replays export identical traces.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryEvent {
    pub kind: EventKind,
    pub t_ns: u64,
    /// 0 = instant event, nonzero = span duration.
    pub dur_ns: u64,
    /// Originating node id, or [`FLEET`].
    pub node: u64,
    /// Function name; empty for node/fleet-scoped events.
    pub function: String,
    /// Free-form tag: start kind, policy name, scale direction, phase.
    pub label: String,
    /// Small numeric payload, rendered into Chrome-trace `args`.
    pub args: Vec<(&'static str, u64)>,
}

impl TelemetryEvent {
    pub fn new(kind: EventKind, t_ns: u64) -> TelemetryEvent {
        TelemetryEvent {
            kind,
            t_ns,
            dur_ns: 0,
            node: FLEET,
            function: String::new(),
            label: String::new(),
            args: Vec::new(),
        }
    }

    pub fn span(mut self, dur_ns: u64) -> TelemetryEvent {
        self.dur_ns = dur_ns;
        self
    }

    pub fn on_node(mut self, node: u64) -> TelemetryEvent {
        self.node = node;
        self
    }

    pub fn func(mut self, name: &str) -> TelemetryEvent {
        self.function = name.to_string();
        self
    }

    pub fn tag(mut self, label: &str) -> TelemetryEvent {
        self.label = label.to_string();
        self
    }

    pub fn arg(mut self, key: &'static str, v: u64) -> TelemetryEvent {
        self.args.push((key, v));
        self
    }

    /// Approximate retained heap+inline size — the unit of the sink's
    /// byte budget.
    pub fn cost_bytes(&self) -> u64 {
        (std::mem::size_of::<TelemetryEvent>()
            + self.function.len()
            + self.label.len()
            + self.args.capacity() * std::mem::size_of::<(&'static str, u64)>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_fills_fields() {
        let ev = TelemetryEvent::new(EventKind::Invocation, 500)
            .span(1_000)
            .on_node(3)
            .func("kv")
            .tag("warm")
            .arg("wait_ns", 42);
        assert_eq!(ev.kind.name(), "invocation");
        assert_eq!((ev.t_ns, ev.dur_ns, ev.node), (500, 1_000, 3));
        assert_eq!(ev.function, "kv");
        assert_eq!(ev.label, "warm");
        assert_eq!(ev.args, vec![("wait_ns", 42)]);
    }

    #[test]
    fn cost_scales_with_payload() {
        let small = TelemetryEvent::new(EventKind::Queued, 0);
        let big = TelemetryEvent::new(EventKind::Queued, 0)
            .func("a-much-longer-function-name")
            .arg("k", 1);
        assert!(big.cost_bytes() > small.cost_bytes());
        assert!(small.cost_bytes() >= std::mem::size_of::<TelemetryEvent>() as u64);
    }
}
