//! Exporters: Chrome trace-event JSON (Perfetto-compatible), CSV/JSON
//! time series, and a Prometheus-style text exposition of a
//! [`crate::metrics::Registry`].
//!
//! The Chrome artifact uses the *object* trace format —
//! `{"traceEvents": [...]}` — which explicitly allows extra top-level
//! keys, so one file both renders in Perfetto/`chrome://tracing` and
//! carries the columnar `series` plus a run `summary`. Timestamps are
//! virtual nanoseconds converted to the format's microsecond unit.

use std::collections::BTreeSet;

use crate::metrics::Registry;
use crate::util::json::Json;

use super::event::{EventKind, TelemetryEvent, FLEET};
use super::series::SeriesSet;
use super::sink::TelemetrySink;

/// Perfetto track (thread) lane per event family, so related events
/// stack on one timeline row per node.
fn lane(kind: EventKind) -> u64 {
    match kind {
        EventKind::Queued | EventKind::Invocation | EventKind::Startup => 1,
        EventKind::Migration | EventKind::MachineEpoch => 2,
        EventKind::WarmEvict | EventKind::SnapshotWrite | EventKind::SnapshotRestore => 3,
        EventKind::Provision | EventKind::Autoscale | EventKind::PoolContention => 4,
        EventKind::Phase => 5,
        EventKind::Fault => 6,
    }
}

fn lane_name(tid: u64) -> &'static str {
    match tid {
        1 => "invocations",
        2 => "migration",
        3 => "lifecycle",
        4 => "placement",
        5 => "phases",
        _ => "faults",
    }
}

/// Fleet-scoped events render as pid 0; node `n` as pid `n + 1`.
fn pid_of(node: u64) -> u64 {
    if node == FLEET {
        0
    } else {
        node + 1
    }
}

fn trace_event(ev: &TelemetryEvent) -> Json {
    let mut args: Vec<(&str, Json)> = Vec::with_capacity(ev.args.len() + 1);
    if !ev.label.is_empty() {
        args.push(("label", Json::str(ev.label.as_str())));
    }
    for (k, v) in &ev.args {
        args.push((k, Json::num(*v as f64)));
    }
    let name = if !ev.function.is_empty() {
        ev.function.as_str()
    } else if !ev.label.is_empty() {
        ev.label.as_str()
    } else {
        ev.kind.name()
    };
    let mut fields = vec![
        ("name", Json::str(name)),
        ("cat", Json::str(ev.kind.name())),
        ("ts", Json::num(ev.t_ns as f64 / 1_000.0)),
        ("pid", Json::num(pid_of(ev.node) as f64)),
        ("tid", Json::num(lane(ev.kind) as f64)),
        ("args", Json::obj(args)),
    ];
    if ev.dur_ns > 0 {
        fields.push(("ph", Json::str("X")));
        fields.push(("dur", Json::num(ev.dur_ns as f64 / 1_000.0)));
    } else {
        fields.push(("ph", Json::str("i")));
        fields.push(("s", Json::str("t")));
    }
    Json::obj(fields)
}

/// Build the combined Chrome trace-event document: spans/instants (one
/// process track per node), named tracks via metadata records, plus the
/// time series and summary as extra top-level keys.
pub fn chrome_trace(sink: &TelemetrySink, series: &SeriesSet, summary: Vec<(&str, Json)>) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(sink.len() + 8);
    let mut tracks: BTreeSet<(u64, u64)> = BTreeSet::new();
    for ev in sink.events() {
        tracks.insert((pid_of(ev.node), lane(ev.kind)));
        events.push(trace_event(ev));
    }
    for &(pid, tid) in &tracks {
        let pname = if pid == 0 { "fleet".to_string() } else { format!("node-{}", pid - 1) };
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(pname))])),
        ]));
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(vec![("name", Json::str(lane_name(tid)))])),
        ]));
    }
    let mut top = vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        ("series", series_json(series)),
    ];
    let mut sum = summary;
    sum.push(("events_total", Json::num(sink.total_events() as f64)));
    sum.push(("events_dropped", Json::num(sink.dropped_events() as f64)));
    sum.push(("series_count", Json::num(series.len() as f64)));
    top.push(("summary", Json::obj(sum)));
    Json::obj(top)
}

/// Series as JSON: `{name: {"t_ns": [...], "values": [...]}}`.
pub fn series_json(series: &SeriesSet) -> Json {
    Json::Obj(
        series
            .series
            .iter()
            .map(|(name, s)| {
                let j = Json::obj(vec![
                    ("t_ns", Json::arr(s.t_ns.iter().map(|&t| Json::num(t as f64)))),
                    ("values", Json::arr(s.values.iter().map(|&v| Json::num(v)))),
                ]);
                (name.clone(), j)
            })
            .collect(),
    )
}

/// Series as long-form CSV — `series,t_ns,value` — robust to series of
/// unequal length and pivot-friendly for plotting.
pub fn series_csv(series: &SeriesSet) -> String {
    let mut out = String::from("series,t_ns,value\n");
    for (name, s) in &series.series {
        for (t, v) in s.t_ns.iter().zip(&s.values) {
            if v.fract() == 0.0 && v.abs() < 9e15 {
                out.push_str(&format!("{name},{t},{}\n", *v as i64));
            } else {
                out.push_str(&format!("{name},{t},{v}\n"));
            }
        }
    }
    out
}

fn prom_name(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if matches!(s.chars().next(), None | Some('0'..='9')) {
        s.insert(0, '_');
    }
    s
}

fn prom_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Prometheus-style text exposition of a metrics registry: counters,
/// gauges, and histograms as summaries with p50/p99 quantiles.
pub fn prometheus_text(registry: &Registry) -> String {
    let mut out = String::new();
    for (name, v) in registry.counter_values() {
        let n = prom_name(&name);
        out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
    }
    for (name, v) in registry.gauge_values() {
        let n = prom_name(&name);
        out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", prom_f64(v)));
    }
    for (name, h) in registry.histogram_values() {
        let n = prom_name(&name);
        out.push_str(&format!("# TYPE {n} summary\n"));
        out.push_str(&format!("{n}{{quantile=\"0.5\"}} {}\n", h.percentile(50.0)));
        out.push_str(&format!("{n}{{quantile=\"0.99\"}} {}\n", h.percentile(99.0)));
        out.push_str(&format!("{n}_sum {}\n{n}_count {}\n", h.sum(), h.count()));
    }
    out
}

/// Human summary of an exported trace document (the `porter-cli
/// telemetry summarize` renderer). Accepts any Chrome trace-event
/// object-format file; the `series`/`summary` keys are optional.
pub fn summarize(doc: &Json) -> Result<String, String> {
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| "not a Chrome trace-event document (no traceEvents array)".to_string())?;
    let mut by_cat: std::collections::BTreeMap<String, (u64, f64)> = Default::default();
    let (mut t_min, mut t_max) = (f64::MAX, 0.0f64);
    let mut total = 0u64;
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str()) == Some("M") {
            continue;
        }
        total += 1;
        let cat = ev.get("cat").and_then(|c| c.as_str()).unwrap_or("?").to_string();
        let ts = ev.get("ts").and_then(|t| t.as_f64()).unwrap_or(0.0);
        let dur = ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0);
        let e = by_cat.entry(cat).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dur;
        t_min = t_min.min(ts);
        t_max = t_max.max(ts + dur);
    }
    let mut out = String::new();
    if total > 0 {
        out.push_str(&format!(
            "events: {total} spanning {:.3} ms of virtual time\n",
            (t_max - t_min.min(t_max)) / 1_000.0
        ));
        out.push_str(&format!("{:<18} {:>8} {:>14}\n", "kind", "count", "total dur"));
        for (cat, (n, dur_us)) in &by_cat {
            out.push_str(&format!(
                "{cat:<18} {n:>8} {:>14}\n",
                crate::bench::fmt_ns(dur_us * 1_000.0)
            ));
        }
    } else {
        out.push_str("events: 0\n");
    }
    if let Some(Json::Obj(series)) = doc.get("series") {
        out.push_str(&format!("series: {}\n", series.len()));
        for (name, s) in series {
            let vals: Vec<f64> = s
                .get("values")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
                .unwrap_or_default();
            let n = vals.len();
            let (mut lo, mut hi, mut sum) = (f64::MAX, f64::MIN, 0.0);
            for &v in &vals {
                lo = lo.min(v);
                hi = hi.max(v);
                sum += v;
            }
            if n > 0 {
                out.push_str(&format!(
                    "  {name}: n={n} min={} mean={} max={}\n",
                    prom_f64(lo),
                    prom_f64(sum / n as f64),
                    prom_f64(hi)
                ));
            }
        }
    }
    if let Some(summary) = doc.get("summary") {
        if let Some(d) = summary.get("events_dropped").and_then(|v| v.as_u64()) {
            out.push_str(&format!("dropped: {d}\n"));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::event::TelemetryEvent;
    use super::*;

    fn sample_sink() -> TelemetrySink {
        let mut sink = TelemetrySink::new(1 << 20);
        sink.push(
            TelemetryEvent::new(EventKind::Invocation, 1_000)
                .span(5_000)
                .on_node(0)
                .func("kv")
                .tag("cold")
                .arg("wait_ns", 250),
        );
        sink.push(TelemetryEvent::new(EventKind::Autoscale, 2_000).tag("up").arg("nodes", 3));
        sink
    }

    fn sample_series() -> SeriesSet {
        let mut set = SeriesSet::new();
        set.point("pool_occupancy", 1_000, 0.5);
        set.point("pool_occupancy", 2_000, 0.75);
        set.point("queue_depth_ns", 1_000, 12_345.0);
        set
    }

    #[test]
    fn chrome_trace_roundtrips_and_carries_series() {
        let doc = chrome_trace(&sample_sink(), &sample_series(), vec![("run", Json::str("test"))]);
        let parsed = Json::parse(&doc.to_string_pretty()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 events + 2 tracks × (process_name + thread_name)
        assert_eq!(events.len(), 2 + 4);
        let span = &events[0];
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("name").unwrap().as_str(), Some("kv"));
        assert_eq!(span.get("cat").unwrap().as_str(), Some("invocation"));
        assert_eq!(span.get("ts").unwrap().as_f64(), Some(1.0)); // µs
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(5.0));
        assert_eq!(span.get("pid").unwrap().as_u64(), Some(1)); // node 0
        assert_eq!(span.get("args").unwrap().get("wait_ns").unwrap().as_u64(), Some(250));
        let instant = &events[1];
        assert_eq!(instant.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(instant.get("pid").unwrap().as_u64(), Some(0)); // fleet
        let series = parsed.get("series").unwrap();
        let occ = series.get("pool_occupancy").unwrap();
        assert_eq!(occ.get("values").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(parsed.get("summary").unwrap().get("events_total").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn csv_is_long_form() {
        let csv = series_csv(&sample_series());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "series,t_ns,value");
        assert_eq!(lines.len(), 1 + 3);
        assert!(lines.contains(&"pool_occupancy,1000,0.5"));
        assert!(lines.contains(&"queue_depth_ns,1000,12345"));
    }

    #[test]
    fn prometheus_text_exposes_registry() {
        let r = Registry::default();
        r.counter("gateway.enqueued").add(7);
        r.gauge("pool.occupancy").set(0.5);
        r.histogram("e2e.latency").record(300);
        let text = prometheus_text(&r);
        assert!(text.contains("# TYPE gateway_enqueued counter\ngateway_enqueued 7\n"));
        assert!(text.contains("pool_occupancy 0.5\n"));
        assert!(text.contains("e2e_latency{quantile=\"0.5\"} 512\n"));
        assert!(text.contains("e2e_latency_count 1\n"));
    }

    #[test]
    fn summarize_renders_counts() {
        let doc = chrome_trace(&sample_sink(), &sample_series(), vec![]);
        let text = summarize(&doc).unwrap();
        assert!(text.contains("events: 2"), "{text}");
        assert!(text.contains("invocation"));
        assert!(text.contains("autoscale"));
        assert!(text.contains("series: 2"));
        assert!(summarize(&Json::str("nope")).is_err());
    }
}
