//! Bounded event sink: a byte-budgeted ring buffer with drop-oldest
//! semantics and a dropped-events counter.
//!
//! "Lock-cheap" by construction: the DES owns the sink through `&mut`
//! (single-threaded event loop), so recording is a branch, a `VecDeque`
//! push, and two integer adds — no atomics, no locks. A disabled sink
//! reduces every call to one branch, which is what lets default-off
//! configs stay bit-identical (and measurably free) versus a build
//! without the subsystem.

use std::collections::BTreeMap;
use std::collections::VecDeque;

use super::event::TelemetryEvent;

#[derive(Debug)]
pub struct TelemetrySink {
    on: bool,
    budget_bytes: u64,
    used_bytes: u64,
    events: VecDeque<TelemetryEvent>,
    total: u64,
    dropped: u64,
}

impl TelemetrySink {
    /// A sink that records nothing and costs one branch per call.
    pub fn disabled() -> TelemetrySink {
        TelemetrySink {
            on: false,
            budget_bytes: 0,
            used_bytes: 0,
            events: VecDeque::new(),
            total: 0,
            dropped: 0,
        }
    }

    /// An enabled sink retaining at most `budget_bytes` of events.
    pub fn new(budget_bytes: u64) -> TelemetrySink {
        TelemetrySink { on: true, budget_bytes, ..TelemetrySink::disabled() }
    }

    pub fn is_enabled(&self) -> bool {
        self.on
    }

    /// Record an event. Oldest events are evicted (and counted as
    /// dropped) until the retained set fits the byte budget; an event
    /// larger than the whole budget is dropped outright.
    pub fn push(&mut self, ev: TelemetryEvent) {
        if !self.on {
            return;
        }
        self.total += 1;
        let cost = ev.cost_bytes();
        if cost > self.budget_bytes {
            self.dropped += 1;
            return;
        }
        self.events.push_back(ev);
        self.used_bytes += cost;
        while self.used_bytes > self.budget_bytes {
            let old = self.events.pop_front().expect("over budget implies non-empty");
            self.used_bytes -= old.cost_bytes();
            self.dropped += 1;
        }
    }

    /// Splice a buffer of events recorded out-of-band — e.g. a shard
    /// worker's per-node buffer at the cluster's epoch barrier — into
    /// the sink in order, with the same budget accounting as `push`.
    pub fn append(&mut self, events: Vec<TelemetryEvent>) {
        for ev in events {
            self.push(ev);
        }
    }

    /// Events currently retained (oldest first).
    pub fn events(&self) -> impl Iterator<Item = &TelemetryEvent> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Every event ever pushed while enabled (retained + dropped).
    pub fn total_events(&self) -> u64 {
        self.total
    }

    pub fn dropped_events(&self) -> u64 {
        self.dropped
    }

    /// Retained event counts per kind name (sorted by name).
    pub fn kind_counts(&self) -> BTreeMap<&'static str, u64> {
        let mut m = BTreeMap::new();
        for ev in &self.events {
            *m.entry(ev.kind.name()).or_insert(0) += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::super::event::EventKind;
    use super::*;

    fn ev(t: u64) -> TelemetryEvent {
        TelemetryEvent::new(EventKind::Queued, t)
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let mut s = TelemetrySink::disabled();
        s.push(ev(1));
        assert_eq!((s.len(), s.total_events(), s.dropped_events()), (0, 0, 0));
        assert!(!s.is_enabled());
    }

    #[test]
    fn budget_drops_oldest_first() {
        let unit = ev(0).cost_bytes();
        let mut s = TelemetrySink::new(3 * unit);
        for t in 0..10 {
            s.push(ev(t));
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.total_events(), 10);
        assert_eq!(s.dropped_events(), 7);
        assert!(s.used_bytes() <= s.budget_bytes());
        // the three newest survive, oldest first
        let kept: Vec<u64> = s.events().map(|e| e.t_ns).collect();
        assert_eq!(kept, vec![7, 8, 9]);
    }

    #[test]
    fn oversized_event_is_dropped_not_stored() {
        let mut s = TelemetrySink::new(8);
        s.push(ev(1).func("way-too-big-for-an-8-byte-budget"));
        assert_eq!(s.len(), 0);
        assert_eq!(s.total_events(), 1);
        assert_eq!(s.dropped_events(), 1);
        assert_eq!(s.used_bytes(), 0);
    }

    #[test]
    fn append_splices_in_order_with_budget_accounting() {
        let unit = ev(0).cost_bytes();
        let mut s = TelemetrySink::new(3 * unit);
        s.push(ev(1));
        s.append(vec![ev(2), ev(3), ev(4)]);
        // same drop-oldest semantics as push: 4 submitted, 3 retained
        let kept: Vec<u64> = s.events().map(|e| e.t_ns).collect();
        assert_eq!(kept, vec![2, 3, 4]);
        assert_eq!(s.total_events(), 4);
        assert_eq!(s.dropped_events(), 1);
        // disabled sinks ignore spliced buffers too
        let mut off = TelemetrySink::disabled();
        off.append(vec![ev(9)]);
        assert_eq!(off.total_events(), 0);
    }

    #[test]
    fn kind_counts_roll_up() {
        let mut s = TelemetrySink::new(1 << 20);
        s.push(ev(1));
        s.push(ev(2));
        s.push(TelemetryEvent::new(EventKind::Migration, 3));
        let counts = s.kind_counts();
        assert_eq!(counts.get("queued"), Some(&2));
        assert_eq!(counts.get("migration"), Some(&1));
    }
}
