//! Virtual-time telemetry: structured events/spans, per-epoch time
//! series, and exporters (Chrome trace-event JSON, CSV, Prometheus
//! text).
//!
//! Design contract:
//! * **Deterministic.** Events are stamped with the DES virtual clock
//!   only — never a wall clock — so an identical run exports an
//!   identical trace, and recording never perturbs simulation state:
//!   the determinism token and every report field are bit-identical
//!   with telemetry on, off, or absent.
//! * **Bounded.** The [`TelemetrySink`] ring buffer enforces a byte
//!   budget with drop-oldest semantics and a dropped-events counter;
//!   `used_bytes() <= budget_bytes()` is a hard invariant
//!   (property-tested).
//! * **Default-off.** The `[telemetry]` config section gates every hook;
//!   disabled, each hook is a single branch.
//!
//! Layout: [`event`] (taxonomy), [`sink`] (ring buffer), [`series`]
//! (fleet sampler + columnar series), [`export`] (writers).

pub mod event;
pub mod export;
pub mod series;
pub mod sink;

pub use event::{EventKind, TelemetryEvent, FLEET};
pub use series::{FleetSample, FleetSampler, SeriesSet, TimeSeries};
pub use sink::TelemetrySink;

use crate::util::json::Json;

/// Everything a run collected: the event sink plus the sampled series.
/// Handed out by `cluster::simulate_full` / taken off a `Machine`.
#[derive(Debug)]
pub struct TelemetryReport {
    pub sink: TelemetrySink,
    pub series: SeriesSet,
}

impl TelemetryReport {
    pub fn empty() -> TelemetryReport {
        TelemetryReport { sink: TelemetrySink::disabled(), series: SeriesSet::new() }
    }

    pub fn is_enabled(&self) -> bool {
        self.sink.is_enabled()
    }

    /// The machine-readable counter line CI greps:
    /// `TELEMETRY events=N dropped=M series=K`.
    pub fn counter_line(&self) -> String {
        format!(
            "TELEMETRY events={} dropped={} series={}",
            self.sink.total_events(),
            self.sink.dropped_events(),
            self.series.len()
        )
    }

    /// Combined Chrome trace-event document (see [`export::chrome_trace`]).
    pub fn to_chrome_json(&self, summary: Vec<(&str, Json)>) -> Json {
        export::chrome_trace(&self.sink, &self.series, summary)
    }

    /// Long-form CSV of the time series.
    pub fn to_csv(&self) -> String {
        export::series_csv(&self.series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_line_matches_ci_grep() {
        let mut report = TelemetryReport::empty();
        assert_eq!(report.counter_line(), "TELEMETRY events=0 dropped=0 series=0");
        report.sink = TelemetrySink::new(1 << 20);
        report.sink.push(TelemetryEvent::new(EventKind::Queued, 1));
        report.series.point("pool_occupancy", 1, 0.5);
        assert_eq!(report.counter_line(), "TELEMETRY events=1 dropped=0 series=1");
    }

    #[test]
    fn empty_report_exports_valid_chrome_json() {
        let doc = TelemetryReport::empty().to_chrome_json(vec![]);
        let parsed = Json::parse(&doc.to_string_compact()).unwrap();
        assert!(parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }
}
