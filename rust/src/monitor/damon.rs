//! DAMON: Data Access MONitor, reimplemented in userspace over the
//! simulated access stream.
//!
//! The mechanism (after Park et al. and the kernel implementation):
//!
//! * The monitored address space is covered by a bounded set of
//!   *regions*. Each sampling interval, one page per region is sampled:
//!   if it was accessed during the interval the region's `nr_accesses`
//!   increments. Overhead is thus O(regions), not O(working set) — the
//!   "controllable overhead" property the paper leans on.
//! * Each aggregation interval, per-region counts are snapshotted and
//!   reset, then regions are *adaptively adjusted*: adjacent regions with
//!   similar counts merge, and large regions split, keeping the region
//!   count within `[min_regions, max_regions]`.
//!
//! Monitoring targets arrive via `on_alloc` (every shim-tracked mmap
//! object becomes a target region), mirroring DAMON's VMA targets.

use crate::config::MonitorConfig;
use crate::shim::object::MemoryObject;
use crate::sim::machine::AccessObserver;
use crate::util::prng::Rng;

/// One monitored region.
#[derive(Debug, Clone)]
struct Region {
    start: u64,
    end: u64,
    /// Page sampled in the current interval.
    sample_page: u64,
    accessed: bool,
    nr_accesses: u32,
}

/// Aggregated per-region counts at one aggregation boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionSnapshot {
    pub t_ns: f64,
    pub regions: Vec<(u64, u64, u32)>,
}

/// The monitor. Attach to a [`crate::sim::Machine`] as an observer.
pub struct Damon {
    cfg: MonitorConfig,
    page: u64,
    regions: Vec<Region>,
    rng: Rng,
    next_sample_ns: f64,
    next_agg_ns: f64,
    /// Total samples taken (overhead accounting: each sample is one
    /// page-table check in the kernel).
    pub samples_taken: u64,
    /// Aggregation history.
    pub snapshots: Vec<RegionSnapshot>,
    /// Index of the region the previous access landed in — spatial
    /// locality makes this hit most of the time, skipping the binary
    /// search on the hot path.
    last_region: usize,
    /// Flat copy of region start addresses, kept in sync with `regions`:
    /// the per-access binary search runs over this cache-dense u64 array
    /// instead of pointer-hopping 40-byte Region entries.
    starts: Vec<u64>,
}

impl Damon {
    pub fn new(cfg: &MonitorConfig, page: u64, seed: u64) -> Damon {
        Damon {
            cfg: cfg.clone(),
            page,
            regions: Vec::new(),
            rng: Rng::new(seed),
            next_sample_ns: cfg.sample_interval_ns as f64,
            next_agg_ns: cfg.aggregation_interval_ns as f64,
            samples_taken: 0,
            snapshots: Vec::new(),
            last_region: usize::MAX,
            starts: Vec::new(),
        }
    }

    fn rebuild_starts(&mut self) {
        self.starts.clear();
        self.starts.extend(self.regions.iter().map(|r| r.start));
    }

    fn pick_sample_page(&mut self, i: usize) {
        let r = &self.regions[i];
        let pages = ((r.end - r.start) / self.page).max(1);
        let p = r.start / self.page + self.rng.gen_range(pages);
        self.regions[i].sample_page = p;
        self.regions[i].accessed = false;
    }

    fn add_target(&mut self, start: u64, end: u64) {
        if end <= start {
            return;
        }
        let idx = self.regions.len();
        self.regions.push(Region { start, end, sample_page: 0, accessed: false, nr_accesses: 0 });
        self.pick_sample_page(idx);
        self.regions.sort_by_key(|r| r.start);
        self.rebuild_starts();
    }

    /// Region containing `addr`: last-region cache, then binary search
    /// over the flat starts array.
    #[inline]
    fn region_of(&mut self, addr: u64) -> Option<usize> {
        if let Some(r) = self.regions.get(self.last_region) {
            if addr >= r.start && addr < r.end {
                return Some(self.last_region);
            }
        }
        let i = self.starts.partition_point(|&s| s <= addr);
        if i == 0 {
            return None;
        }
        let r = &self.regions[i - 1];
        if addr < r.end {
            self.last_region = i - 1;
            Some(i - 1)
        } else {
            None
        }
    }

    fn end_sample_interval(&mut self) {
        for i in 0..self.regions.len() {
            self.samples_taken += 1;
            if self.regions[i].accessed {
                self.regions[i].nr_accesses = self.regions[i].nr_accesses.saturating_add(1);
            }
            self.pick_sample_page(i);
        }
    }

    fn aggregate(&mut self, t_ns: f64) {
        let snap = RegionSnapshot {
            t_ns,
            regions: self.regions.iter().map(|r| (r.start, r.end, r.nr_accesses)).collect(),
        };
        self.snapshots.push(snap);
        self.adjust_regions();
        for r in &mut self.regions {
            r.nr_accesses = 0;
        }
    }

    /// Adaptive region adjustment: merge similar neighbours, then split
    /// until the count is back in range.
    fn adjust_regions(&mut self) {
        // merge pass: adjacent regions (same target, i.e. contiguous)
        // whose counts differ by <= 10% of the larger (or both tiny)
        let min_regions = self.cfg.min_regions;
        let mut merged: Vec<Region> = Vec::with_capacity(self.regions.len());
        for r in self.regions.drain(..) {
            let n_merged = merged.len();
            match merged.last_mut() {
                Some(prev)
                    if prev.end == r.start
                        && close_counts(prev.nr_accesses, r.nr_accesses)
                        && n_merged > min_regions =>
                {
                    prev.end = r.end;
                    prev.nr_accesses = prev.nr_accesses.max(r.nr_accesses);
                }
                _ => merged.push(r),
            }
        }
        self.regions = merged;
        // split pass: split the largest regions until min_regions reached
        // (kernel splits each region in two while below max/2; we split
        // largest-first which converges to the same coverage)
        while self.regions.len() < self.cfg.max_regions / 2 {
            let (idx, _) = match self
                .regions
                .iter()
                .enumerate()
                .filter(|(_, r)| r.end - r.start >= 2 * self.page)
                .max_by_key(|(_, r)| r.end - r.start)
            {
                Some(x) => x,
                None => break,
            };
            let r = self.regions[idx].clone();
            let pages = (r.end - r.start) / self.page;
            let cut = r.start + (1 + self.rng.gen_range(pages - 1)) * self.page;
            self.regions[idx].end = cut;
            let right = Region {
                start: cut,
                end: r.end,
                sample_page: 0,
                accessed: false,
                nr_accesses: r.nr_accesses,
            };
            self.regions.insert(idx + 1, right);
            self.pick_sample_page(idx);
            self.pick_sample_page(idx + 1);
            if self.regions.len() >= self.cfg.max_regions {
                break;
            }
        }
        self.rebuild_starts();
    }

    pub fn n_regions(&self) -> usize {
        self.regions.len()
    }

    /// Total per-byte access weight for the half-open range `[lo, hi)`
    /// across all snapshots — the hint generator's input.
    pub fn range_heat(&self, lo: u64, hi: u64) -> f64 {
        let mut heat = 0.0;
        for snap in &self.snapshots {
            for &(s, e, n) in &snap.regions {
                let ov_lo = s.max(lo);
                let ov_hi = e.min(hi);
                if ov_hi > ov_lo && n > 0 {
                    // density: accesses spread over the region's bytes
                    heat += n as f64 * (ov_hi - ov_lo) as f64 / (e - s) as f64;
                }
            }
        }
        heat
    }
}

fn close_counts(a: u32, b: u32) -> bool {
    let hi = a.max(b);
    let lo = a.min(b);
    hi - lo <= hi / 10 || hi <= 1
}

impl AccessObserver for Damon {
    fn on_access(&mut self, t_ns: f64, addr: u64, _bytes: u32, _write: bool) {
        // roll sampling intervals forward to t
        while t_ns >= self.next_sample_ns {
            self.end_sample_interval();
            self.next_sample_ns += self.cfg.sample_interval_ns as f64;
            if self.next_agg_ns < self.next_sample_ns {
                self.aggregate(self.next_agg_ns);
                self.next_agg_ns += self.cfg.aggregation_interval_ns as f64;
            }
        }
        if let Some(i) = self.region_of(addr) {
            let r = &mut self.regions[i];
            if addr / self.page == r.sample_page {
                r.accessed = true;
            }
        }
    }

    fn on_alloc(&mut self, _t_ns: f64, obj: &MemoryObject) {
        // monitor mmap'd objects (DAMON's VMA targets); tiny brk chunks
        // fall below region granularity
        if obj.via_mmap {
            self.add_target(obj.start, obj.end());
        }
    }

    fn on_tick(&mut self, _t_ns: f64) {}

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MonitorConfig {
        MonitorConfig {
            sample_interval_ns: 100,
            aggregation_interval_ns: 10_000,
            min_regions: 4,
            max_regions: 64,
            heatmap_bins: 32,
            heatmap_time_bins: 16,
        }
    }

    fn obj(start: u64, bytes: u64) -> MemoryObject {
        MemoryObject {
            id: crate::shim::object::ObjectId(1),
            start,
            bytes,
            site: "t".into(),
            seq: 0,
            via_mmap: true,
        }
    }

    /// Drive the monitor directly with a synthetic hot/cold pattern.
    fn drive(damon: &mut Damon, hot_lo: u64, hot_hi: u64, cold_lo: u64, cold_hi: u64) {
        let mut rng = Rng::new(99);
        let mut t = 0.0;
        for _ in 0..200_000 {
            t += 25.0;
            let addr = if rng.chance(0.9) {
                hot_lo + rng.gen_range(hot_hi - hot_lo)
            } else {
                cold_lo + rng.gen_range(cold_hi - cold_lo)
            };
            damon.on_access(t, addr, 8, false);
        }
    }

    #[test]
    fn hot_range_gets_more_heat() {
        let base = crate::shim::intercept::MMAP_BASE;
        let mut damon = Damon::new(&cfg(), 4096, 7);
        damon.on_alloc(0.0, &obj(base, 1 << 22)); // 4MB object
        let hot = (base, base + (1 << 18)); // first 256KB hot
        let cold = (base + (1 << 18), base + (1 << 22));
        drive(&mut damon, hot.0, hot.1, cold.0, cold.1);
        assert!(!damon.snapshots.is_empty());
        let hot_heat = damon.range_heat(hot.0, hot.1) / (hot.1 - hot.0) as f64;
        let cold_heat = damon.range_heat(cold.0, cold.1) / (cold.1 - cold.0) as f64;
        assert!(
            hot_heat > 5.0 * cold_heat,
            "hot density {hot_heat} should dwarf cold {cold_heat}"
        );
    }

    #[test]
    fn region_count_stays_bounded() {
        let base = crate::shim::intercept::MMAP_BASE;
        let c = cfg();
        let mut damon = Damon::new(&c, 4096, 7);
        for i in 0..10 {
            damon.on_alloc(0.0, &obj(base + i * (1 << 24), 1 << 23));
        }
        drive(&mut damon, base, base + (1 << 20), base + (2 << 24), base + (3 << 24));
        assert!(damon.n_regions() >= c.min_regions, "{}", damon.n_regions());
        assert!(damon.n_regions() <= c.max_regions, "{}", damon.n_regions());
    }

    #[test]
    fn overhead_is_bounded_by_regions_not_accesses() {
        let base = crate::shim::intercept::MMAP_BASE;
        let c = cfg();
        let mut damon = Damon::new(&c, 4096, 7);
        damon.on_alloc(0.0, &obj(base, 1 << 26)); // 64MB
        drive(&mut damon, base, base + (1 << 26), base, base + (1 << 26));
        // samples = regions × elapsed/sample_interval, independent of the
        // 200k accesses driven
        let intervals = (200_000.0 * 25.0 / c.sample_interval_ns as f64) as u64;
        assert!(damon.samples_taken <= intervals * c.max_regions as u64);
    }

    #[test]
    fn unmonitored_addresses_ignored() {
        let mut damon = Damon::new(&cfg(), 4096, 7);
        // accesses before any target exist must not panic
        damon.on_access(10.0, 0xdead_beef, 8, false);
        assert_eq!(damon.n_regions(), 0);
    }

    #[test]
    fn range_heat_zero_for_untouched() {
        let base = crate::shim::intercept::MMAP_BASE;
        let mut damon = Damon::new(&cfg(), 4096, 7);
        damon.on_alloc(0.0, &obj(base, 1 << 20));
        drive(&mut damon, base, base + (1 << 20), base, base + (1 << 20));
        let other = damon.range_heat(base + (1 << 30), base + (2 << 30));
        assert_eq!(other, 0.0);
    }
}
