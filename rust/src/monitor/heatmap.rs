//! Address×time heatmaps — the paper's Fig. 4, as DAMO renders them —
//! plus the per-page epoch hotness tracker the migration engine consumes.
//!
//! Three sources:
//! * [`Heatmap::from_damon`] — what the paper's toolchain produces:
//!   bins region snapshot counts over (address, time).
//! * [`ExactHeatmap`] — a machine observer that bins every access; the
//!   ablation benchmark compares DAMON's picture against this ground
//!   truth to quantify sampling fidelity.
//! * [`PageHeat`] — page-granular access samples aggregated per *epoch*
//!   with exponential decay at every rollover; this is the hotness
//!   signal `mem::migrate`'s policies rank pages by.

use crate::mem::page::PageNo;
use crate::mem::soa::PageCol;
use crate::monitor::damon::RegionSnapshot;
use crate::sim::machine::AccessObserver;

/// A binned (address × time) intensity grid.
#[derive(Debug, Clone)]
pub struct Heatmap {
    pub addr_lo: u64,
    pub addr_hi: u64,
    pub t_lo: f64,
    pub t_hi: f64,
    pub addr_bins: usize,
    pub time_bins: usize,
    /// Row-major: `grid[time][addr]`.
    pub grid: Vec<f64>,
}

impl Heatmap {
    pub fn at(&self, t_bin: usize, a_bin: usize) -> f64 {
        self.grid[t_bin * self.addr_bins + a_bin]
    }

    pub fn max(&self) -> f64 {
        self.grid.iter().copied().fold(0.0, f64::max)
    }

    /// Build from DAMON aggregation snapshots over address window
    /// `[addr_lo, addr_hi)`.
    pub fn from_damon(
        snaps: &[RegionSnapshot],
        addr_lo: u64,
        addr_hi: u64,
        addr_bins: usize,
        time_bins: usize,
    ) -> Heatmap {
        assert!(addr_hi > addr_lo && addr_bins > 0 && time_bins > 0);
        let t_lo = snaps.first().map(|s| s.t_ns).unwrap_or(0.0);
        let t_hi = snaps.last().map(|s| s.t_ns).unwrap_or(1.0).max(t_lo + 1.0);
        let mut grid = vec![0.0; addr_bins * time_bins];
        let bin_bytes = ((addr_hi - addr_lo) as f64 / addr_bins as f64).max(1.0);
        for snap in snaps {
            let tb = (((snap.t_ns - t_lo) / (t_hi - t_lo) * time_bins as f64) as usize)
                .min(time_bins - 1);
            for &(s, e, n) in &snap.regions {
                if n == 0 {
                    continue;
                }
                let lo = s.max(addr_lo);
                let hi = e.min(addr_hi);
                if hi <= lo {
                    continue;
                }
                // spread the region's density over the bins it covers
                let density = n as f64 / (e - s) as f64;
                let b0 = ((lo - addr_lo) as f64 / bin_bytes) as usize;
                let b1 = (((hi - addr_lo) as f64 - 1.0) / bin_bytes) as usize;
                for b in b0..=b1.min(addr_bins - 1) {
                    let bin_lo = addr_lo + (b as f64 * bin_bytes) as u64;
                    let bin_hi = addr_lo + ((b + 1) as f64 * bin_bytes) as u64;
                    let ov = hi.min(bin_hi).saturating_sub(lo.max(bin_lo));
                    grid[tb * addr_bins + b] += density * ov as f64;
                }
            }
        }
        Heatmap { addr_lo, addr_hi, t_lo, t_hi, addr_bins, time_bins, grid }
    }

    /// ASCII rendering (time flows down, address left→right), `#`-scaled
    /// like DAMO's text plots.
    pub fn render_ascii(&self) -> String {
        const SHADES: &[u8] = b" .:-=+*#%@";
        let max = self.max().max(1e-12);
        let mut out = String::new();
        out.push_str(&format!(
            "addr [{:#x}..{:#x}) x {} bins, time [{:.1}ms..{:.1}ms] x {} rows\n",
            self.addr_lo,
            self.addr_hi,
            self.addr_bins,
            self.t_lo / 1e6,
            self.t_hi / 1e6,
            self.time_bins
        ));
        for t in 0..self.time_bins {
            out.push('|');
            for a in 0..self.addr_bins {
                let v = self.at(t, a) / max;
                let idx = ((v.sqrt()) * (SHADES.len() - 1) as f64).round() as usize;
                out.push(SHADES[idx.min(SHADES.len() - 1)] as char);
            }
            out.push_str("|\n");
        }
        out
    }

    /// CSV rows: `time_bin,addr_bin,value`.
    pub fn render_csv(&self) -> String {
        let mut out = String::from("time_bin,addr_bin,value\n");
        for t in 0..self.time_bins {
            for a in 0..self.addr_bins {
                let v = self.at(t, a);
                if v > 0.0 {
                    out.push_str(&format!("{t},{a},{v:.3}\n"));
                }
            }
        }
        out
    }

    /// Locality score in [0,1]: fraction of total heat concentrated in
    /// the hottest 10% of address bins (averaged over time). Strong
    /// locality (DL, Linpack, graphs) scores high; sparse patterns
    /// (Chameleon, image) score low. Used to verify Fig. 4's claim.
    pub fn locality_score(&self) -> f64 {
        let top_n = (self.addr_bins / 10).max(1);
        let mut per_bin = vec![0.0; self.addr_bins];
        for t in 0..self.time_bins {
            for a in 0..self.addr_bins {
                per_bin[a] += self.at(t, a);
            }
        }
        let total: f64 = per_bin.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        per_bin.sort_by(|x, y| y.partial_cmp(x).unwrap());
        per_bin[..top_n].iter().sum::<f64>() / total
    }
}

/// Exact binning observer (ground truth for the DAMON-fidelity ablation).
pub struct ExactHeatmap {
    addr_lo: u64,
    addr_hi: u64,
    addr_bins: usize,
    /// (time_bin_width, rows) grow as time advances.
    time_bin_ns: f64,
    rows: Vec<Vec<f64>>,
}

impl ExactHeatmap {
    pub fn new(addr_lo: u64, addr_hi: u64, addr_bins: usize, time_bin_ns: f64) -> ExactHeatmap {
        assert!(addr_hi > addr_lo && addr_bins > 0 && time_bin_ns > 0.0);
        ExactHeatmap { addr_lo, addr_hi, addr_bins, time_bin_ns, rows: Vec::new() }
    }

    pub fn finish(self) -> Heatmap {
        let time_bins = self.rows.len().max(1);
        let mut grid = vec![0.0; self.addr_bins * time_bins];
        for (t, row) in self.rows.iter().enumerate() {
            grid[t * self.addr_bins..(t + 1) * self.addr_bins].copy_from_slice(row);
        }
        Heatmap {
            addr_lo: self.addr_lo,
            addr_hi: self.addr_hi,
            t_lo: 0.0,
            t_hi: time_bins as f64 * self.time_bin_ns,
            addr_bins: self.addr_bins,
            time_bins,
            grid,
        }
    }
}

impl AccessObserver for ExactHeatmap {
    fn on_access(&mut self, t_ns: f64, addr: u64, _bytes: u32, _write: bool) {
        if addr < self.addr_lo || addr >= self.addr_hi {
            return;
        }
        let tb = (t_ns / self.time_bin_ns) as usize;
        while self.rows.len() <= tb {
            self.rows.push(vec![0.0; self.addr_bins]);
        }
        let ab = ((addr - self.addr_lo) as f64 / (self.addr_hi - self.addr_lo) as f64
            * self.addr_bins as f64) as usize;
        self.rows[tb][ab.min(self.addr_bins - 1)] += 1.0;
    }

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }
}

/// Slot sentinel: page has no tracked heat entry.
const NO_SLOT: u32 = u32::MAX;

/// Page-granular epoch hotness: per-page access samples accumulate into
/// a decayed heat score. At every epoch rollover the score is multiplied
/// by `decay` (0.5 by default — **counts halve**), and entries whose heat
/// falls below `min_heat` are dropped, so a page that stops being
/// touched ages out in a handful of epochs.
///
/// Storage is a struct-of-arrays slot slab: `slot_of` maps dense page id
/// → slot, and the parallel `pages`/`heat`/`samples`/`live` columns hold
/// the entries. Freed slots are recycled through a free list, and the
/// epoch rollover is one linear sweep over contiguous arrays (no hashing,
/// deterministic slot-order iteration).
///
/// One `PageHeat` tracks one invocation on one machine; [`PageHeat::reset`]
/// clears everything (heat *and* the epoch counter) so no stale hotness
/// leaks across invocations on the same server.
#[derive(Debug, Clone)]
pub struct PageHeat {
    /// page → slot index ([`NO_SLOT`] = untracked); valid only for live
    /// slots (cleared eagerly when a slot is freed).
    slot_of: PageCol<u32>,
    /// Parallel slot columns (equal length).
    pages: Vec<PageNo>,
    heat: Vec<f64>,
    samples: Vec<u32>,
    live: Vec<bool>,
    /// Recycled slot indices.
    free: Vec<u32>,
    live_count: usize,
    epoch: u64,
    decay: f64,
    min_heat: f64,
}

impl Default for PageHeat {
    fn default() -> Self {
        PageHeat::new()
    }
}

impl PageHeat {
    /// Documented default: heat halves each epoch, entries below half an
    /// access worth of heat are dropped.
    pub fn new() -> PageHeat {
        PageHeat::with_decay(0.5)
    }

    pub fn with_decay(decay: f64) -> PageHeat {
        assert!((0.0..1.0).contains(&decay), "decay must be in [0,1)");
        PageHeat {
            slot_of: PageCol::new(NO_SLOT),
            pages: Vec::new(),
            heat: Vec::new(),
            samples: Vec::new(),
            live: Vec::new(),
            free: Vec::new(),
            live_count: 0,
            epoch: 0,
            decay,
            min_heat: 0.5,
        }
    }

    /// Live slot for `page`, allocating (free list first) if untracked.
    fn slot_mut(&mut self, page: PageNo) -> usize {
        let s = self.slot_of.get(page);
        if s != NO_SLOT {
            return s as usize;
        }
        let s = match self.free.pop() {
            Some(s) => {
                let i = s as usize;
                self.pages[i] = page;
                self.heat[i] = 0.0;
                self.samples[i] = 0;
                self.live[i] = true;
                s
            }
            None => {
                self.pages.push(page);
                self.heat.push(0.0);
                self.samples.push(0);
                self.live.push(true);
                (self.pages.len() - 1) as u32
            }
        };
        self.slot_of.set(page, s);
        self.live_count += 1;
        s as usize
    }

    /// Record `samples` accesses to `page` within the current epoch.
    pub fn record(&mut self, page: PageNo, samples: u32) {
        if samples == 0 {
            return;
        }
        let s = self.slot_mut(page);
        self.heat[s] += samples as f64;
        self.samples[s] = self.samples[s].saturating_add(samples);
    }

    /// Decayed cumulative heat of a page (0.0 if never sampled).
    pub fn heat(&self, page: PageNo) -> f64 {
        match self.slot_of.get(page) {
            NO_SLOT => 0.0,
            s => self.heat[s as usize],
        }
    }

    /// Samples recorded for `page` in the current epoch only — the
    /// "accessed this epoch" signal TPP-style policies key off.
    pub fn epoch_samples(&self, page: PageNo) -> u32 {
        match self.slot_of.get(page) {
            NO_SLOT => 0,
            s => self.samples[s as usize],
        }
    }

    /// Close the current epoch: heat decays (halves by default), the
    /// per-epoch sample counters reset, cold entries age out. One linear
    /// sweep over the slot columns.
    pub fn roll_epoch(&mut self) {
        self.epoch += 1;
        for s in 0..self.live.len() {
            if !self.live[s] {
                continue;
            }
            self.heat[s] *= self.decay;
            self.samples[s] = 0;
            if self.heat[s] < self.min_heat {
                self.live[s] = false;
                self.slot_of.set(self.pages[s], NO_SLOT);
                self.free.push(s as u32);
                self.live_count -= 1;
            }
        }
    }

    /// Epochs completed so far.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Invocation boundary: drop all hotness and restart the epoch count.
    pub fn reset(&mut self) {
        self.slot_of.clear();
        self.pages.clear();
        self.heat.clear();
        self.samples.clear();
        self.live.clear();
        self.free.clear();
        self.live_count = 0;
        self.epoch = 0;
    }

    /// Number of pages currently tracked.
    pub fn len(&self) -> usize {
        self.live_count
    }

    pub fn is_empty(&self) -> bool {
        self.live_count == 0
    }

    /// Iterate over (page, decayed heat), slot order — deterministic,
    /// but not page-sorted (slots recycle).
    pub fn iter(&self) -> impl Iterator<Item = (PageNo, f64)> + '_ {
        (0..self.live.len())
            .filter(|&s| self.live[s])
            .map(|s| (self.pages[s], self.heat[s]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_heatmap_bins_correctly() {
        let mut h = ExactHeatmap::new(0, 1000, 10, 100.0);
        h.on_access(50.0, 5, 8, false); // t-bin 0, a-bin 0
        h.on_access(50.0, 999, 8, false); // t-bin 0, a-bin 9
        h.on_access(250.0, 500, 8, false); // t-bin 2, a-bin 5
        h.on_access(10.0, 5000, 8, false); // out of range: dropped
        let m = h.finish();
        assert_eq!(m.time_bins, 3);
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(0, 9), 1.0);
        assert_eq!(m.at(2, 5), 1.0);
        assert_eq!(m.grid.iter().sum::<f64>(), 3.0);
    }

    #[test]
    fn locality_score_separates_patterns() {
        // concentrated: all heat in one bin
        let mut conc = ExactHeatmap::new(0, 1000, 20, 100.0);
        for i in 0..100 {
            conc.on_access(i as f64, 10, 8, false);
        }
        // scattered: uniform
        let mut scat = ExactHeatmap::new(0, 1000, 20, 100.0);
        for i in 0..100 {
            scat.on_access(i as f64, (i * 10 % 1000) as u64, 8, false);
        }
        let cs = conc.finish().locality_score();
        let ss = scat.finish().locality_score();
        assert!(cs > 0.9, "concentrated={cs}");
        assert!(ss < 0.3, "scattered={ss}");
    }

    #[test]
    fn from_damon_spreads_region_density() {
        let snaps = vec![RegionSnapshot {
            t_ns: 1000.0,
            regions: vec![(0, 500, 10), (500, 1000, 0)],
        }];
        let m = Heatmap::from_damon(&snaps, 0, 1000, 10, 4);
        // first five address bins get heat, last five none
        assert!(m.at(m.time_bins - 1, 0) > 0.0 || m.at(0, 0) > 0.0);
        let left: f64 = (0..5).map(|a| (0..m.time_bins).map(|t| m.at(t, a)).sum::<f64>()).sum();
        let right: f64 = (5..10).map(|a| (0..m.time_bins).map(|t| m.at(t, a)).sum::<f64>()).sum();
        assert!(left > 0.0);
        assert_eq!(right, 0.0);
    }

    #[test]
    fn ascii_render_shape() {
        let snaps = vec![
            RegionSnapshot { t_ns: 0.0, regions: vec![(0, 100, 5)] },
            RegionSnapshot { t_ns: 100.0, regions: vec![(0, 100, 1)] },
        ];
        let m = Heatmap::from_damon(&snaps, 0, 100, 8, 2);
        let s = m.render_ascii();
        assert_eq!(s.lines().count(), 3); // header + 2 rows
        assert!(s.lines().nth(1).unwrap().starts_with('|'));
    }

    #[test]
    fn csv_only_nonzero() {
        let snaps = vec![RegionSnapshot { t_ns: 0.0, regions: vec![(0, 10, 3)] }];
        let m = Heatmap::from_damon(&snaps, 0, 100, 10, 1);
        let csv = m.render_csv();
        assert!(csv.lines().count() >= 2);
        assert!(!csv.contains(",9,")); // bin 9 untouched
    }

    fn page(i: u32) -> PageNo {
        PageNo { segment: crate::mem::page::Segment::Mmap, index: i }
    }

    #[test]
    fn page_heat_accumulates_within_epoch() {
        let mut h = PageHeat::new();
        h.record(page(1), 3);
        h.record(page(1), 2);
        assert_eq!(h.heat(page(1)), 5.0);
        assert_eq!(h.epoch_samples(page(1)), 5);
        assert_eq!(h.heat(page(2)), 0.0);
        assert_eq!(h.epoch(), 0);
        assert_eq!(h.len(), 1);
    }

    #[test]
    fn page_heat_halves_at_rollover_as_documented() {
        let mut h = PageHeat::new();
        h.record(page(7), 8);
        h.roll_epoch();
        assert_eq!(h.epoch(), 1);
        assert_eq!(h.heat(page(7)), 4.0, "counts must halve at the epoch boundary");
        assert_eq!(h.epoch_samples(page(7)), 0, "per-epoch samples must reset");
        h.roll_epoch();
        assert_eq!(h.heat(page(7)), 2.0);
        // heat from a new epoch stacks on the decayed residue
        h.record(page(7), 2);
        assert_eq!(h.heat(page(7)), 4.0);
        assert_eq!(h.epoch_samples(page(7)), 2);
    }

    #[test]
    fn page_heat_cold_entries_age_out() {
        let mut h = PageHeat::new();
        h.record(page(3), 1);
        // 1.0 → 0.5 → 0.25 < min_heat: dropped on the second rollover
        h.roll_epoch();
        assert_eq!(h.len(), 1);
        h.roll_epoch();
        assert_eq!(h.len(), 0, "cold page should have aged out");
        assert_eq!(h.heat(page(3)), 0.0);
    }

    #[test]
    fn page_heat_reset_leaks_nothing_across_invocations() {
        let mut h = PageHeat::new();
        h.record(page(1), 100);
        h.record(page(2), 50);
        h.roll_epoch();
        h.reset();
        assert!(h.is_empty(), "stale hotness must not survive an invocation boundary");
        assert_eq!(h.epoch(), 0);
        assert_eq!(h.heat(page(1)), 0.0);
        assert_eq!(h.epoch_samples(page(2)), 0);
    }

    #[test]
    fn page_heat_recycles_freed_slots_without_aliasing() {
        let mut h = PageHeat::new();
        h.record(page(1), 1);
        h.roll_epoch(); // 1.0 → 0.5: survives
        h.roll_epoch(); // 0.5 → 0.25: aged out, slot freed
        assert_eq!(h.len(), 0);
        h.record(page(2), 4);
        assert_eq!(h.len(), 1, "freed slot must be recycled");
        assert_eq!(h.heat(page(2)), 4.0);
        assert_eq!(h.heat(page(1)), 0.0, "old page must not alias the recycled slot");
        assert_eq!(h.epoch_samples(page(1)), 0);
    }

    #[test]
    fn page_heat_iter_reports_decayed_scores() {
        let mut h = PageHeat::new();
        h.record(page(1), 4);
        h.record(page(2), 16);
        h.roll_epoch();
        let mut got: Vec<(PageNo, f64)> = h.iter().collect();
        got.sort_by_key(|(p, _)| *p);
        assert_eq!(got, vec![(page(1), 2.0), (page(2), 8.0)]);
    }
}
