//! Workload monitoring: the paper's profiling toolchain rebuilt.
//!
//! * [`damon`] — a faithful reimplementation of DAMON's region-based
//!   sampling with adaptive region adjustment (Park et al.,
//!   Middleware'19; the kernel feature the paper records with).
//! * [`heatmap`] — DAMO-style address×time heatmaps (Fig. 4), from DAMON
//!   snapshots or exact access streams, plus the per-page epoch hotness
//!   tracker ([`heatmap::PageHeat`]) the migration engine consumes.
//! * [`boundness`] — the VTune "memory backend-boundness" proxy (Fig. 2's
//!   blue line) computed from the machine's stall accounting.

pub mod boundness;
pub mod damon;
pub mod heatmap;

pub use boundness::TopDown;
pub use damon::{Damon, RegionSnapshot};
pub use heatmap::{ExactHeatmap, Heatmap, PageHeat};
