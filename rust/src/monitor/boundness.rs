//! Memory backend-boundness — the paper's VTune metric (Fig. 2's blue
//! line), computed top-down from the machine's cycle accounting.
//!
//! VTune's "Memory Bound" = slots stalled on loads/stores across the
//! cache/memory hierarchy, split into latency- and bandwidth-bound. Our
//! machine accounts exactly those quantities directly.

use crate::sim::machine::RunReport;

/// Top-down breakdown of one run.
#[derive(Debug, Clone, PartialEq)]
pub struct TopDown {
    /// Share of wall time on pure compute.
    pub compute_frac: f64,
    /// Share stalled on memory (incl. LLC hits) — the headline
    /// "backend-boundness".
    pub memory_bound_frac: f64,
    /// Of the memory-bound share, the part attributable to queueing
    /// (bandwidth) vs. idle latency.
    pub latency_frac: f64,
    pub dram_traffic_frac: f64,
    pub cxl_traffic_frac: f64,
}

impl TopDown {
    pub fn from_report(r: &RunReport) -> TopDown {
        let wall = r.wall_ns.max(1e-12);
        let mem = r.stall_ns + r.hit_ns;
        let misses = (r.dram_misses + r.cxl_misses).max(1);
        TopDown {
            compute_frac: r.compute_ns / wall,
            memory_bound_frac: mem / wall,
            latency_frac: if mem > 0.0 { r.stall_ns / mem } else { 0.0 },
            dram_traffic_frac: r.dram_misses as f64 / misses as f64,
            cxl_traffic_frac: r.cxl_misses as f64 / misses as f64,
        }
    }

    /// Percentage for reports.
    pub fn memory_bound_pct(&self) -> f64 {
        self.memory_bound_frac * 100.0
    }

    /// Off-chip (DRAM/CXL-traffic) stall share — VTune's "DRAM Bound"
    /// sub-metric, the predictor of CXL sensitivity (Fig. 2 blue line):
    /// on-chip L3-hit time does not slow down when memory moves to CXL.
    pub fn offchip_bound_pct(&self) -> f64 {
        self.memory_bound_frac * self.latency_frac * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(compute: f64, stall: f64, hit: f64, dram: u64, cxl: u64) -> RunReport {
        RunReport {
            policy: "t".into(),
            wall_ns: compute + stall + hit,
            compute_ns: compute,
            stall_ns: stall,
            hit_ns: hit,
            migration_stall_ns: 0.0,
            accesses: 100,
            l3_hits: 50,
            l3_misses: dram + cxl,
            dram_misses: dram,
            cxl_misses: cxl,
            promotions: 0,
            demotions: 0,
            ping_pongs: 0,
            migration_bytes: 0,
            peak_dram_bytes: 0,
            peak_cxl_bytes: 0,
            overlapped_ns: 0.0,
            lane_switches: 0,
            prefetch_issued: 0,
            prefetch_useful: 0,
        }
    }

    #[test]
    fn fractions_sum_to_one() {
        let td = TopDown::from_report(&report(600.0, 300.0, 100.0, 10, 30));
        assert!((td.compute_frac + td.memory_bound_frac - 1.0).abs() < 1e-9);
        assert!((td.memory_bound_frac - 0.4).abs() < 1e-9);
        assert!((td.dram_traffic_frac - 0.25).abs() < 1e-9);
        assert!((td.cxl_traffic_frac - 0.75).abs() < 1e-9);
    }

    #[test]
    fn compute_only_run() {
        let td = TopDown::from_report(&report(1000.0, 0.0, 0.0, 0, 0));
        assert_eq!(td.memory_bound_frac, 0.0);
        assert_eq!(td.latency_frac, 0.0);
    }
}
