//! The serverless workload suite (derived from the suites the paper
//! ports to OpenFaaS: SeBS, FunctionBench, vSwarm, GAPBS).
//!
//! Every workload is a *real algorithm* — BFS truly traverses, LU truly
//! factorizes, the KV store truly serves gets — executed over
//! instrumented [`crate::shim::Env`] memory so the machine under test
//! sees the genuine access pattern. Each returns a checksum validated by
//! unit tests against an untraced reference.
//!
//! Granularity convention: data movement is emitted per element touch;
//! register-resident arithmetic between touches is accounted as bulk
//! `env.compute(cycles)`. This matches what the paper's tooling observes
//! (DAMON/VTune see memory traffic and stall cycles, not ALU µops).

pub mod bfs;
pub mod cc;
pub mod chameleon;
pub mod compression;
pub mod dl;
pub mod graph;
pub mod image;
pub mod json_ser;
pub mod kvstore;
pub mod linpack;
pub mod matmul;
pub mod pagerank;
pub mod registry;
pub mod sort;
pub mod txn_bench;

use crate::shim::env::Env;

/// A serverless function body.
pub trait Workload {
    /// Registry name (Fig. 2 x-axis label).
    fn name(&self) -> &str;

    /// Execute against an instrumented environment. Returns a checksum
    /// of the result so tests can verify the algorithm really ran.
    fn run(&self, env: &mut Env) -> u64;

    /// Rough live-data footprint in bytes (for scaling decisions).
    fn footprint_hint(&self) -> u64 {
        0
    }

    /// Independent lanes this workload's stream annotates (`env.lane`).
    /// 1 = sequential (the default): no useful overlap, the lane
    /// scheduler degenerates to the scalar clock. The machine runs
    /// `min(lanes.max_lanes, lane_hints())` lanes.
    fn lane_hints(&self) -> usize {
        1
    }

    /// Stable identity of this instance's *access stream*, the
    /// size-bucket half of the [`crate::trace::TraceStore`] key: two
    /// instances with equal `(name, trace_fingerprint)` must emit
    /// byte-identical event streams, so a stored trace can stand in for
    /// re-execution. Every registry workload overrides this to fold in
    /// all stream-shaping parameters (sizes, iteration counts, seeds);
    /// the default covers workloads fully determined by their
    /// footprint.
    fn trace_fingerprint(&self) -> u64 {
        mix(mix_str(0xF1D0, self.name()), self.footprint_hint())
    }
}

/// Mix a string into a running checksum byte-by-byte (fingerprints).
#[inline]
pub fn mix_str(h: u64, s: &str) -> u64 {
    s.bytes().fold(mix(h, s.len() as u64), |h, b| mix(h, b as u64))
}

/// Mix an f64 parameter into a fingerprint by bit pattern (exact —
/// unlike [`mix_f64`], which quantizes for checksum tolerance).
#[inline]
pub fn mix_bits(h: u64, v: f64) -> u64 {
    mix(h, v.to_bits())
}

/// Mix a u64 into a running checksum (order-sensitive).
#[inline]
pub fn mix(h: u64, v: u64) -> u64 {
    let mut x = h ^ v.wrapping_mul(0x9E3779B97F4A7C15);
    x ^= x >> 29;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^ (x >> 32)
}

/// Checksum an f64 with tolerance-friendly quantization (so tiny
/// float-order differences don't change the sum).
#[inline]
pub fn mix_f64(h: u64, v: f64) -> u64 {
    mix(h, (v * 1e6).round() as i64 as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_order_sensitive() {
        let a = mix(mix(0, 1), 2);
        let b = mix(mix(0, 2), 1);
        assert_ne!(a, b);
    }

    #[test]
    fn mix_f64_tolerates_noise() {
        let a = mix_f64(0, 1.0000000001);
        let b = mix_f64(0, 1.0000000002);
        assert_eq!(a, b);
        assert_ne!(mix_f64(0, 1.0), mix_f64(0, 1.1));
    }
}
