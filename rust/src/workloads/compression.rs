//! Compression (FunctionBench-derived): LZ77-style compression with a
//! rolling hash chain over synthetic markup-ish text. Sequential input
//! scan + random hash-table probes — mid-pack CXL sensitivity.

use crate::shim::env::Env;
use crate::workloads::{mix, Workload};

pub struct Compression {
    pub input_bytes: usize,
    pub seed: u64,
    /// Hash table size (power of two).
    pub table_size: usize,
}

impl Compression {
    pub fn new(input_bytes: usize) -> Compression {
        Compression { input_bytes, seed: 0x217, table_size: 1 << 16 }
    }

    /// Synthetic compressible text: words drawn zipf-style from a small
    /// vocabulary, so real matches exist.
    fn gen_input(&self) -> Vec<u8> {
        const VOCAB: &[&str] = &[
            "the", "serverless", "function", "memory", "tier", "cxl", "dram", "page", "hot",
            "cold", "placement", "latency", "bandwidth", "object", "porter", "lambda", "invoke",
            "request", "data", "cache",
        ];
        let mut rng = crate::util::prng::Rng::new(self.seed);
        let mut out = Vec::with_capacity(self.input_bytes + 16);
        while out.len() < self.input_bytes {
            let w = VOCAB[rng.zipf(VOCAB.len() as u64, 0.9) as usize];
            out.extend_from_slice(w.as_bytes());
            out.push(b' ');
        }
        out.truncate(self.input_bytes);
        out
    }

    /// Untraced reference compression.
    pub fn reference(&self) -> (usize, u64) {
        let input = self.gen_input();
        compress(&input, self.table_size)
    }
}

const MIN_MATCH: usize = 4;
const MAX_DIST: usize = 1 << 15;

fn hash4(bytes: &[u8], mask: usize) -> usize {
    let v = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    (v.wrapping_mul(2654435761) >> 16) as usize & mask
}

/// Returns (compressed length, checksum over tokens). The token stream
/// is (literal byte) or (dist, len) pairs.
fn compress(input: &[u8], table_size: usize) -> (usize, u64) {
    let mask = table_size - 1;
    let mut table = vec![usize::MAX; table_size];
    let mut h = 0u64;
    let mut out_len = 0usize;
    let mut i = 0usize;
    while i < input.len() {
        if i + MIN_MATCH <= input.len() {
            let slot = hash4(&input[i..], mask);
            let cand = table[slot];
            table[slot] = i;
            if cand != usize::MAX && i - cand <= MAX_DIST {
                // extend match
                let mut len = 0;
                while i + len < input.len() && input[cand + len] == input[i + len] && len < 255 {
                    len += 1;
                }
                if len >= MIN_MATCH {
                    h = mix(h, ((i - cand) as u64) << 16 | len as u64);
                    out_len += 3;
                    i += len;
                    continue;
                }
            }
        }
        h = mix(h, input[i] as u64);
        out_len += 1;
        i += 1;
    }
    (out_len, h)
}

impl Workload for Compression {
    fn name(&self) -> &str {
        "compression"
    }

    fn footprint_hint(&self) -> u64 {
        (self.input_bytes + self.table_size * 8) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = mix(mix(0xC0, self.input_bytes as u64), self.table_size as u64);
        mix(h, self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let input_v = self.gen_input();
        env.phase("load");
        let input = env.tvec_from(input_v, "compression/input");
        let mut table = env.tvec::<u64>(self.table_size, u64::MAX, "compression/table");
        let out = env.tvec::<u8>(self.input_bytes + 64, 0, "compression/out");

        env.phase("compress");
        let mask = self.table_size - 1;
        let mut h = 0u64;
        let mut out_len = 0usize;
        let mut i = 0usize;
        let data = input.raw().to_vec(); // real bytes for matching
        while i < data.len() {
            // traced read of the 4-byte window
            input.touch_range(i, (i + 4).min(data.len()), false, env);
            env.compute(8);
            if i + MIN_MATCH <= data.len() {
                let slot = hash4(&data[i..], mask);
                let cand = table.get(slot, env);
                table.set(slot, i as u64, env);
                if cand != u64::MAX && i - cand as usize <= MAX_DIST {
                    let cand = cand as usize;
                    let mut len = 0;
                    while i + len < data.len() && data[cand + len] == data[i + len] && len < 255 {
                        len += 1;
                    }
                    if len >= MIN_MATCH {
                        // traced read of the back-reference
                        input.touch_range(cand, cand + len, false, env);
                        env.compute(len as u64);
                        h = mix(h, ((i - cand) as u64) << 16 | len as u64);
                        out.touch_range(out_len, out_len + 3, true, env);
                        out_len += 3;
                        i += len;
                        continue;
                    }
                }
            }
            h = mix(h, data[i] as u64);
            out.touch_range(out_len, out_len + 1, true, env);
            out_len += 1;
            i += 1;
        }
        mix(h, out_len as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn compresses_redundant_text() {
        let w = Compression::new(64 * 1024);
        let (out_len, _) = w.reference();
        assert!(
            out_len < w.input_bytes / 2,
            "vocabulary text should compress >2x: {out_len} vs {}",
            w.input_bytes
        );
    }

    #[test]
    fn incompressible_input_stays_put() {
        let mut rng = crate::util::prng::Rng::new(1);
        let random: Vec<u8> = (0..32 * 1024).map(|_| rng.next_u64() as u8).collect();
        let (out_len, _) = compress(&random, 1 << 14);
        assert!(out_len as f64 > 0.9 * random.len() as f64);
    }

    #[test]
    fn traced_matches_reference() {
        let w = Compression::new(32 * 1024);
        let (out_len, h) = w.reference();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), mix(h, out_len as u64));
    }
}
