//! Graph substrate for the GAPBS-derived workloads: CSR representation +
//! synthetic generators.
//!
//! The paper evaluates BFS/PageRank on the Twitter graph. Twitter is not
//! shippable, so the RMAT/Kronecker generator (the GAPBS default for
//! synthetic inputs) reproduces its power-law degree skew: a small set of
//! celebrity vertices absorbs most edges, which is precisely the
//! structure that makes hot-object DRAM placement effective. A uniform
//! (Erdős–Rényi-style) generator provides the contrast case.

use crate::shim::env::{Env, TVec};
use crate::util::prng::Rng;

/// Compressed-sparse-row directed graph held in *untraced* memory — the
/// generator side. Workloads load it into traced memory via
/// [`CsrGraph::into_env`].
#[derive(Debug, Clone)]
pub struct CsrGraph {
    /// n+1 offsets into `targets`.
    pub offsets: Vec<u32>,
    pub targets: Vec<u32>,
}

impl CsrGraph {
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn m(&self) -> usize {
        self.targets.len()
    }

    pub fn degree(&self, v: usize) -> usize {
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.targets[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Structural fingerprint for Trace-IR keying: vertex/edge counts
    /// plus a strided adjacency sample, so generators with different
    /// scale, degree, or seed produce distinct fingerprints while the
    /// cost stays O(64) regardless of graph size.
    pub fn fingerprint(&self) -> u64 {
        use crate::workloads::mix;
        let mut h = mix(mix(0x6EA9, self.n() as u64), self.m() as u64);
        let step = (self.targets.len() / 64).max(1);
        let mut i = 0;
        while i < self.targets.len() {
            h = mix(h, self.targets[i] as u64);
            i += step;
        }
        h
    }

    /// Build a CSR from an edge list.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> CsrGraph {
        let mut deg = vec![0u32; n];
        for &(s, _) in edges {
            deg[s as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut targets = vec![0u32; edges.len()];
        let mut cursor = offsets.clone();
        for &(s, t) in edges {
            targets[cursor[s as usize] as usize] = t;
            cursor[s as usize] += 1;
        }
        CsrGraph { offsets, targets }
    }

    /// Reverse (transpose) graph — PageRank's pull direction.
    pub fn transpose(&self) -> CsrGraph {
        let n = self.n();
        let edges: Vec<(u32, u32)> = (0..n)
            .flat_map(|v| self.neighbors(v).iter().map(move |&t| (t, v as u32)))
            .collect();
        CsrGraph::from_edges(n, &edges)
    }

    /// Move the graph into traced memory: the function's working set as
    /// the shim sees it (two mmap'd objects: offsets and targets).
    pub fn into_env(&self, env: &mut Env, prefix: &str) -> TracedCsr {
        let offsets = env.tvec_from(self.offsets.clone(), &format!("{prefix}/offsets"));
        let targets = env.tvec_from(self.targets.clone(), &format!("{prefix}/targets"));
        TracedCsr { offsets, targets }
    }
}

/// CSR resident in traced memory.
pub struct TracedCsr {
    pub offsets: TVec<u32>,
    pub targets: TVec<u32>,
}

impl TracedCsr {
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn m(&self) -> usize {
        self.targets.len()
    }
}

/// RMAT (Kronecker) generator with GAPBS's (a,b,c,d) = (.57,.19,.19,.05).
/// Produces Twitter-like skew: degree distribution is power-law.
pub fn rmat(scale: u32, avg_degree: usize, seed: u64) -> CsrGraph {
    let n = 1usize << scale;
    let m = n * avg_degree;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut s, mut t) = (0usize, 0usize);
        for _ in 0..scale {
            s <<= 1;
            t <<= 1;
            let r = rng.f64();
            if r < 0.57 {
                // top-left quadrant
            } else if r < 0.76 {
                t |= 1;
            } else if r < 0.95 {
                s |= 1;
            } else {
                s |= 1;
                t |= 1;
            }
        }
        edges.push((s as u32, t as u32));
    }
    // GAPBS permutes vertex ids so degree is uncorrelated with id;
    // we keep raw RMAT ids: the correlation concentrates hot vertices at
    // low addresses, which is the structure the heatmaps (Fig. 4) show.
    CsrGraph::from_edges(n, &edges)
}

/// Uniform random graph: every edge endpoint uniform — the no-skew
/// contrast to RMAT.
pub fn uniform(n: usize, avg_degree: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let m = n * avg_degree;
    let edges: Vec<(u32, u32)> = (0..m)
        .map(|_| (rng.gen_range(n as u64) as u32, rng.gen_range(n as u64) as u32))
        .collect();
    CsrGraph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_builds_valid_csr() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (3, 0)]);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[3]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        assert_eq!(g.neighbors(3), &[0]);
    }

    #[test]
    fn transpose_reverses() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        let t = g.transpose();
        assert_eq!(t.neighbors(1), &[0]);
        let mut n2 = t.neighbors(2).to_vec();
        n2.sort();
        assert_eq!(n2, vec![0, 1]);
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(12, 8, 42);
        assert_eq!(g.n(), 4096);
        assert_eq!(g.m(), 4096 * 8);
        let mut degs: Vec<usize> = (0..g.n()).map(|v| g.degree(v)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // top 1% of vertices should hold a disproportionate share of edges
        let top: usize = degs[..g.n() / 100].iter().sum();
        assert!(
            top as f64 > 0.15 * g.m() as f64,
            "top1% share = {}",
            top as f64 / g.m() as f64
        );
        // and the max degree dwarfs the average
        assert!(degs[0] > 8 * 10);
    }

    #[test]
    fn uniform_is_flat() {
        let g = uniform(4096, 8, 7);
        let max_deg = (0..g.n()).map(|v| g.degree(v)).max().unwrap();
        assert!(max_deg < 40, "max degree {max_deg} too skewed for uniform");
    }

    #[test]
    fn generators_deterministic() {
        let a = rmat(8, 4, 1);
        let b = rmat(8, 4, 1);
        assert_eq!(a.targets, b.targets);
        let c = rmat(8, 4, 2);
        assert_ne!(a.targets, c.targets);
    }

    #[test]
    fn into_env_registers_objects() {
        use crate::trace::NullSink;
        let g = rmat(8, 4, 1);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let t = g.into_env(&mut env, "g");
        assert_eq!(t.n(), g.n());
        assert_eq!(env.objects().len(), 2);
        assert!(env.objects().iter().any(|o| o.site == "g/offsets"));
    }
}
