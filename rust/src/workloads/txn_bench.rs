//! TPC-C-flavoured transaction bench: warehouse-partitioned new-order
//! transactions over stock/customer/order tables. Each transaction is
//! confined to one partition and annotated onto its own lane
//! (`env.lane`), so transactions on different partitions are genuinely
//! independent — the workload built to stress the lane scheduler's
//! throughput-vs-latency frontier. A small fraction of "remote"
//! transactions touch a second partition and serialize against both
//! lanes, like TPC-C's remote payments.

use crate::shim::env::Env;
use crate::workloads::{mix, mix_bits, Workload};

pub struct TxnBench {
    /// Warehouse partitions (= annotated lanes, capped at 8).
    pub parts: usize,
    /// Stock items per partition.
    pub items_per_part: usize,
    /// Customers per partition.
    pub customers_per_part: usize,
    /// Transactions to run.
    pub txns: usize,
    /// Stock lines read+updated per transaction.
    pub lines_per_txn: usize,
    /// Fraction of transactions that also touch a remote partition.
    pub remote_frac: f64,
    pub seed: u64,
}

impl TxnBench {
    pub fn new(items_per_part: usize, txns: usize) -> TxnBench {
        TxnBench {
            parts: 8,
            items_per_part,
            customers_per_part: (items_per_part / 16).max(64),
            txns,
            lines_per_txn: 10,
            remote_frac: 0.05,
            seed: 0x7C2C,
        }
    }

    fn lanes(&self) -> usize {
        self.parts.clamp(1, 8)
    }
}

impl Workload for TxnBench {
    fn name(&self) -> &str {
        "txn_bench"
    }

    fn footprint_hint(&self) -> u64 {
        let stock = self.parts * self.items_per_part * 8;
        let customers = self.parts * self.customers_per_part * 8;
        let orders = self.txns * 2 * 8;
        (stock + customers + orders) as u64
    }

    fn lane_hints(&self) -> usize {
        self.lanes()
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = mix(mix(0x7C2C, self.parts as u64), self.items_per_part as u64);
        let h = mix(mix(h, self.customers_per_part as u64), self.txns as u64);
        let h = mix_bits(mix(h, self.lines_per_txn as u64), self.remote_frac);
        mix(h, self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let lanes = self.lanes();
        let n_stock = self.parts * self.items_per_part;
        let n_cust = self.parts * self.customers_per_part;
        env.phase("load");
        let mut stock = env.tvec::<u64>(n_stock, 0, "txn/stock");
        let mut customers = env.tvec::<u64>(n_cust, 0, "txn/customers");
        let mut orders = env.tvec::<u64>(self.txns * 2, 0, "txn/orders");
        // seed initial inventory and balances (traced: the function
        // materializes its tables from the payload)
        for i in 0..n_stock {
            stock.set(i, 100 + (i as u64 % 50), env);
            env.compute(2);
        }
        for c in 0..n_cust {
            customers.set(c, 1_000, env);
            env.compute(2);
        }

        env.phase("serve");
        let mut rng = crate::util::prng::Rng::new(self.seed);
        let mut h = 0u64;
        for t in 0..self.txns {
            // home partition round-robins → perfectly balanced lanes
            let p = t % self.parts;
            let lane = (p % lanes) as u8;
            let remote = rng.chance(self.remote_frac);
            let other = (p + 1 + rng.next_u64() as usize % (self.parts - 1).max(1)) % self.parts;
            // draw all randomness before annotating so the stream shape
            // is independent of lane folding
            let cust = p * self.customers_per_part
                + rng.next_u64() as usize % self.customers_per_part;
            if remote && self.parts > 1 {
                // remote txn: serialize against both partitions' lanes
                env.lane(lane, (1 << lane) | (1 << (other % lanes)));
            } else {
                // local txn: depends only on its own partition's history
                env.lane(lane, 1 << lane);
            }
            // read the customer, then read+decrement stock lines
            let mut total = customers.get(cust, env);
            env.compute(150); // parse + begin + index lookups
            for l in 0..self.lines_per_txn {
                let part = if remote && l == 0 { other } else { p };
                let item =
                    part * self.items_per_part + rng.next_u64() as usize % self.items_per_part;
                let qty = stock.get(item, env);
                env.compute(40);
                stock.set(item, if qty > 0 { qty - 1 } else { 90 }, env);
                total = total.wrapping_add(qty);
            }
            customers.set(cust, total, env);
            // append the order record
            orders.set(t * 2, cust as u64, env);
            orders.set(t * 2 + 1, total, env);
            env.compute(80); // commit bookkeeping
            h = mix(h, total);
        }
        mix(h, self.txns as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn runs_and_is_deterministic() {
        let w = TxnBench::new(2_000, 1_000);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let c = w.run(&mut env);
        assert_ne!(c, 0);
        let mut sink2 = NullSink::default();
        let mut env2 = Env::new(4096, &mut sink2);
        assert_eq!(c, w.run(&mut env2));
    }

    #[test]
    fn annotates_independent_lanes() {
        use crate::sim::Machine;
        use crate::config::MachineConfig;
        use crate::mem::tier::TierKind;
        let w = TxnBench::new(2_000, 1_000);
        assert_eq!(w.lane_hints(), 8);
        let mut m = Machine::all_in(&MachineConfig::default(), TierKind::Cxl);
        m.set_lanes(w.lane_hints());
        let mut env = Env::new(4096, &mut m);
        w.run(&mut env);
        let r = m.report();
        assert!(r.lane_switches > 0, "stream must carry lane annotations");
        assert!(r.overlapped_ns > 0.0, "independent txns must overlap");
    }

    #[test]
    fn footprint_scales_with_partitions() {
        let big = TxnBench { parts: 16, ..TxnBench::new(10_000, 1) };
        assert!(big.footprint_hint() > TxnBench::new(10_000, 1).footprint_hint());
    }
}
