//! JSON serialization/deserialization (FunctionBench-derived): build a
//! synthetic record batch, serialize with the crate's JSON writer, parse
//! it back, and fold a checksum. Allocation-churny, small hot set — low
//! CXL sensitivity.

use crate::shim::env::Env;
use crate::util::json::Json;
use crate::workloads::{mix, Workload};

pub struct JsonSer {
    pub records: usize,
    pub seed: u64,
}

impl JsonSer {
    pub fn new(records: usize) -> JsonSer {
        JsonSer { records, seed: 0x1503 }
    }
}

impl JsonSer {
    fn build(&self) -> Json {
        let mut rng = crate::util::prng::Rng::new(self.seed);
        Json::arr((0..self.records).map(|i| {
            Json::obj(vec![
                ("id", Json::num(i as f64)),
                ("user", Json::str(format!("user-{}", rng.gen_range(10_000)))),
                ("score", Json::num((rng.f64() * 1000.0).round() / 10.0)),
                ("active", Json::Bool(rng.chance(0.5))),
                (
                    "tags",
                    Json::arr(
                        (0..rng.gen_range(4))
                            .map(|_| Json::str(format!("t{}", rng.gen_range(100)))),
                    ),
                ),
            ])
        }))
    }

    pub fn reference_checksum(&self) -> u64 {
        let doc = self.build();
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        checksum(&parsed, text.len())
    }
}

fn checksum(doc: &Json, text_len: usize) -> u64 {
    let mut h = mix(0, text_len as u64);
    if let Json::Arr(items) = doc {
        for item in items {
            if let Some(v) = item.get("score").and_then(|s| s.as_f64()) {
                h = mix(h, (v * 10.0) as u64);
            }
        }
    }
    h
}

impl Workload for JsonSer {
    fn name(&self) -> &str {
        "json"
    }

    fn footprint_hint(&self) -> u64 {
        (self.records * 128) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        mix(mix(0x15, self.records as u64), self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        env.phase("build");
        let doc = self.build();
        env.compute((self.records * 120) as u64);

        env.phase("serialize");
        let text = doc.to_string_compact();
        let buf = env.tvec_from(text.clone().into_bytes(), "json/text");
        // serializer writes the buffer once
        buf.touch_range(0, buf.len(), true, env);
        env.compute((text.len() * 4) as u64);

        env.phase("parse");
        // parser scans the buffer once with per-token bookkeeping
        buf.touch_range(0, buf.len(), false, env);
        env.compute((text.len() * 10) as u64);
        let parsed = Json::parse(&text).unwrap();

        checksum(&parsed, text.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn roundtrip_checksum_stable() {
        let w = JsonSer::new(200);
        let expect = w.reference_checksum();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), expect);
        assert!(sink.accesses > 100);
    }

    #[test]
    fn output_grows_with_records() {
        let small = JsonSer::new(50).build().to_string_compact().len();
        let big = JsonSer::new(500).build().to_string_compact().len();
        assert!(big > 8 * small);
    }
}
