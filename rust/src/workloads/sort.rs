//! Sort (FunctionBench "sorting" class): bottom-up merge sort over a
//! large u64 array. Two streaming operands + one streaming output per
//! pass — bandwidth-bound with zero temporal reuse across passes, so the
//! CXL hit comes from bandwidth rather than latency.

use crate::shim::env::Env;
use crate::workloads::{mix, Workload};

pub struct Sort {
    pub n: usize,
    pub seed: u64,
}

impl Sort {
    pub fn new(n: usize) -> Sort {
        Sort { n, seed: 0x5027 }
    }

    fn gen(&self) -> Vec<u64> {
        let mut rng = crate::util::prng::Rng::new(self.seed);
        (0..self.n).map(|_| rng.next_u64()).collect()
    }

    pub fn reference_checksum(&self) -> u64 {
        let mut v = self.gen();
        v.sort_unstable();
        checksum(&v)
    }
}

fn checksum(v: &[u64]) -> u64 {
    // sample 64 evenly spaced elements of the sorted output
    let mut h = 0u64;
    let step = (v.len() / 64).max(1);
    for i in (0..v.len()).step_by(step) {
        h = mix(h, v[i]);
    }
    mix(h, v.len() as u64)
}

impl Workload for Sort {
    fn name(&self) -> &str {
        "sort"
    }

    fn footprint_hint(&self) -> u64 {
        (self.n * 16) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        mix(mix(0x50, self.n as u64), self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        env.phase("load");
        let mut a = env.tvec_from(self.gen(), "sort/a");
        let mut b = env.tvec::<u64>(self.n, 0, "sort/b");
        let n = self.n;

        env.phase("sort");
        let mut width = 1usize;
        let mut src_is_a = true;
        while width < n {
            // one merge pass: stream src (two runs at a time) → dst
            {
                let (src, dst) = if src_is_a { (&mut a, &mut b) } else { (&mut b, &mut a) };
                let mut lo = 0usize;
                while lo < n {
                    let mid = (lo + width).min(n);
                    let hi = (lo + 2 * width).min(n);
                    // traffic: read both runs, write the merged run
                    src.touch_range(lo, hi, false, env);
                    dst.touch_range(lo, hi, true, env);
                    env.compute(((hi - lo) * 3) as u64);
                    // real merge
                    let s = src.raw();
                    let mut merged = Vec::with_capacity(hi - lo);
                    let (mut i, mut j) = (lo, mid);
                    while i < mid && j < hi {
                        if s[i] <= s[j] {
                            merged.push(s[i]);
                            i += 1;
                        } else {
                            merged.push(s[j]);
                            j += 1;
                        }
                    }
                    merged.extend_from_slice(&s[i..mid]);
                    merged.extend_from_slice(&s[j..hi]);
                    dst.raw_mut()[lo..hi].copy_from_slice(&merged);
                    lo = hi;
                }
            }
            src_is_a = !src_is_a;
            width *= 2;
        }
        let result = if src_is_a { a.raw() } else { b.raw() };
        checksum(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn sorts_correctly() {
        let w = Sort::new(10_000);
        let expect = w.reference_checksum();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), expect);
    }

    #[test]
    fn non_power_of_two_sizes() {
        for n in [1, 2, 3, 1000, 1023, 1025] {
            let w = Sort { n, seed: 5 };
            let expect = w.reference_checksum();
            let mut sink = NullSink::default();
            let mut env = Env::new(4096, &mut sink);
            assert_eq!(w.run(&mut env), expect, "n={n}");
        }
    }

    #[test]
    fn traffic_is_n_log_n() {
        let count = |n: usize| {
            let w = Sort { n, seed: 5 };
            let mut sink = NullSink::default();
            let mut env = Env::new(4096, &mut sink);
            w.run(&mut env);
            sink.bytes
        };
        let b1 = count(1 << 12);
        let b2 = count(1 << 14);
        // 4× elements, +2 passes: bytes ratio ≈ 4 * 14/12 ≈ 4.7
        let ratio = b2 as f64 / b1 as f64;
        assert!(ratio > 4.0 && ratio < 6.0, "ratio={ratio}");
    }
}
