//! Connected components (GAPBS-derived): Shiloach–Vishkin style label
//! propagation over the undirected view of the graph.

use crate::shim::env::Env;
use crate::workloads::graph::CsrGraph;
use crate::workloads::{mix, Workload};

pub struct ConnectedComponents {
    pub graph: CsrGraph,
    pub cycles_per_edge: u64,
}

impl ConnectedComponents {
    pub fn new(graph: CsrGraph) -> ConnectedComponents {
        ConnectedComponents { graph, cycles_per_edge: 3 }
    }

    /// Untraced reference: union-find component count + labels checksum.
    pub fn reference(&self) -> (u64, u64) {
        let n = self.graph.n();
        let mut labels: Vec<u32> = (0..n as u32).collect();
        let mut changed = true;
        while changed {
            changed = false;
            for v in 0..n {
                for &t in self.graph.neighbors(v) {
                    let (a, b) = (labels[v], labels[t as usize]);
                    let m = a.min(b);
                    if labels[v] != m {
                        labels[v] = m;
                        changed = true;
                    }
                    if labels[t as usize] != m {
                        labels[t as usize] = m;
                        changed = true;
                    }
                }
            }
        }
        let mut uniq: Vec<u32> = labels.clone();
        uniq.sort_unstable();
        uniq.dedup();
        let sum: u64 = labels.iter().map(|&l| l as u64).sum();
        (uniq.len() as u64, sum)
    }
}

impl Workload for ConnectedComponents {
    fn name(&self) -> &str {
        "cc"
    }

    fn footprint_hint(&self) -> u64 {
        (self.graph.n() * 8 + self.graph.m() * 4) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        mix(mix(0xCC, self.graph.fingerprint()), self.cycles_per_edge)
    }

    fn run(&self, env: &mut Env) -> u64 {
        env.phase("load");
        let g = self.graph.into_env(env, "cc");
        let n = g.n();
        let mut labels = env.tvec_from((0..n as u32).collect(), "cc/labels");

        env.phase("propagate");
        let mut changed = true;
        while changed {
            changed = false;
            for v in 0..n {
                let lo = g.offsets.get(v, env) as usize;
                let hi = g.offsets.get(v + 1, env) as usize;
                g.targets.touch_range(lo, hi, false, env);
                for ei in lo..hi {
                    let t = g.targets.get_untraced(ei) as usize;
                    env.compute(self.cycles_per_edge);
                    let a = labels.get(v, env);
                    let b = labels.get(t, env);
                    let m = a.min(b);
                    if a != m {
                        labels.set(v, m, env);
                        changed = true;
                    }
                    if b != m {
                        labels.set(t, m, env);
                        changed = true;
                    }
                }
            }
        }

        env.phase("reduce");
        let mut sum = 0u64;
        labels.scan(0, n, env, |_, l| sum += l as u64);
        let mut uniq: Vec<u32> = labels.raw().to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        mix(mix(0, uniq.len() as u64), sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;
    use crate::workloads::graph::{rmat, CsrGraph};

    #[test]
    fn two_components_found() {
        // {0,1,2} and {3,4}
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let w = ConnectedComponents::new(g);
        let (count, sum) = w.reference();
        assert_eq!(count, 2);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), mix(mix(0, count), sum));
    }

    #[test]
    fn singleton_vertices_are_components() {
        let g = CsrGraph::from_edges(4, &[(0, 1)]);
        let w = ConnectedComponents::new(g);
        let (count, _) = w.reference();
        assert_eq!(count, 3); // {0,1}, {2}, {3}
    }

    #[test]
    fn traced_matches_reference_on_rmat() {
        let g = rmat(8, 4, 17);
        let w = ConnectedComponents::new(g);
        let (count, sum) = w.reference();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), mix(mix(0, count), sum));
        assert!(count >= 1);
    }
}
