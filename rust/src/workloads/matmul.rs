//! Matrix multiplication (FunctionBench-derived): blocked single-
//! precision GEMM, the workload the paper colocates against in Fig. 7
//! and the CPU analogue of the DL hot loop.
//!
//! Traffic convention: each block operand load/store is emitted at
//! cache-line granularity via `touch_range`; the register-blocked FMAs
//! inside a block-GEMM are bulk compute (SIMD width folded in).

use crate::shim::env::Env;
use crate::workloads::{mix, mix_f64, Workload};

pub struct MatMul {
    /// Square matrix dimension.
    pub n: usize,
    /// Block (tile) edge.
    pub block: usize,
    /// Effective FMA throughput: cycles per block-GEMM = b³ / simd_flops.
    pub simd_flops_per_cycle: u64,
    pub seed: u64,
}

impl MatMul {
    pub fn new(n: usize) -> MatMul {
        MatMul { n, block: 64, simd_flops_per_cycle: 16, seed: 0xA11CE }
    }

    fn gen(&self) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::prng::Rng::new(self.seed);
        let a: Vec<f32> = (0..self.n * self.n).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..self.n * self.n).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
        (a, b)
    }

    /// Untraced reference: checksum of C = A·B computed naively.
    pub fn reference_checksum(&self) -> u64 {
        let (a, b) = self.gen();
        let n = self.n;
        let mut c = vec![0f32; n * n];
        for i in 0..n {
            for k in 0..n {
                let aik = a[i * n + k];
                for j in 0..n {
                    c[i * n + j] += aik * b[k * n + j];
                }
            }
        }
        Self::checksum(&c, n)
    }

    fn checksum(c: &[f32], n: usize) -> u64 {
        // trace of C plus one corner — rounded to absorb FMA-order noise
        let trace: f64 = (0..n).map(|i| c[i * n + i] as f64).sum();
        let h = mix_f64(0, (trace * 100.0).round() / 100.0);
        mix_f64(h, ((c[n - 1] as f64) * 100.0).round() / 100.0)
    }
}

impl Workload for MatMul {
    fn name(&self) -> &str {
        "matmul"
    }

    fn footprint_hint(&self) -> u64 {
        (3 * self.n * self.n * 4) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = mix(mix(0xA7, self.n as u64), self.block as u64);
        mix(mix(h, self.simd_flops_per_cycle), self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let n = self.n;
        let b = self.block.min(n);
        assert_eq!(n % b, 0, "n must be a multiple of block");
        let (av, bv) = self.gen();
        env.phase("load");
        let a = env.tvec_from(av, "matmul/a");
        let bm = env.tvec_from(bv, "matmul/b");
        let mut c = env.tvec::<f32>(n * n, 0.0, "matmul/c");

        env.phase("gemm");
        let nb = n / b;
        let cycles_per_block_gemm = (b * b * b) as u64 / self.simd_flops_per_cycle;
        for bi in 0..nb {
            for bj in 0..nb {
                // C tile resident across the k loop: load once, store once
                for r in 0..b {
                    let row = (bi * b + r) * n + bj * b;
                    c.touch_range(row, row + b, false, env);
                }
                for bk in 0..nb {
                    // stream A(bi,bk) and B(bk,bj) tiles
                    for r in 0..b {
                        let arow = (bi * b + r) * n + bk * b;
                        a.touch_range(arow, arow + b, false, env);
                    }
                    for r in 0..b {
                        let brow = (bk * b + r) * n + bj * b;
                        bm.touch_range(brow, brow + b, false, env);
                    }
                    env.compute(cycles_per_block_gemm);
                    // the real arithmetic
                    let (ar, br, cr) = (a.raw(), bm.raw(), c.raw_mut());
                    for i in bi * b..(bi + 1) * b {
                        for k in bk * b..(bk + 1) * b {
                            let aik = ar[i * n + k];
                            let crow = &mut cr[i * n + bj * b..i * n + (bj + 1) * b];
                            let brow = &br[k * n + bj * b..k * n + (bj + 1) * b];
                            for (cv, bv) in crow.iter_mut().zip(brow) {
                                *cv += aik * bv;
                            }
                        }
                    }
                }
                for r in 0..b {
                    let row = (bi * b + r) * n + bj * b;
                    c.touch_range(row, row + b, true, env);
                }
            }
        }

        env.phase("reduce");
        Self::checksum(c.raw(), n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn blocked_matches_naive() {
        let w = MatMul { n: 128, block: 32, simd_flops_per_cycle: 16, seed: 7 };
        let expect = w.reference_checksum();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), expect);
    }

    #[test]
    fn traffic_scales_with_n_cubed_over_b() {
        let count = |n: usize, b: usize| {
            let w = MatMul { n, block: b, simd_flops_per_cycle: 16, seed: 1 };
            let mut sink = NullSink::default();
            let mut env = Env::new(4096, &mut sink);
            w.run(&mut env);
            sink.accesses
        };
        let small = count(64, 32);
        let big = count(128, 32);
        // n doubles → ~8× block-gemm count → ~8× traffic (C tiles minor)
        let ratio = big as f64 / small as f64;
        assert!(ratio > 5.0 && ratio < 9.0, "ratio={ratio}");
    }

    #[test]
    #[should_panic]
    fn rejects_unaligned_block() {
        let w = MatMul { n: 100, block: 64, simd_flops_per_cycle: 16, seed: 1 };
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        w.run(&mut env);
    }
}
