//! Workload registry: name → factory, with the two instance scales used
//! across the repo (Small for tests, Default for the figure benches).

use crate::workloads::bfs::Bfs;
use crate::workloads::cc::ConnectedComponents;
use crate::workloads::chameleon::Chameleon;
use crate::workloads::compression::Compression;
use crate::workloads::dl::{DlServe, DlTrain};
use crate::workloads::graph::rmat;
use crate::workloads::image::ImageProc;
use crate::workloads::json_ser::JsonSer;
use crate::workloads::kvstore::KvStore;
use crate::workloads::linpack::Linpack;
use crate::workloads::matmul::MatMul;
use crate::workloads::pagerank::PageRank;
use crate::workloads::sort::Sort;
use crate::workloads::txn_bench::TxnBench;
use crate::workloads::Workload;

/// Instance scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Seconds-fast instances for unit/integration tests.
    Small,
    /// The figure-bench instances: working sets sized well past the
    /// 19.25 MB LLC so tier placement matters, traces in the tens of
    /// millions of events.
    Default,
}

/// Graph seeds fixed so the "Twitter-like" input is identical across
/// profile and placement runs (ASLR-off analogue for inputs).
pub const GRAPH_SEED: u64 = 0x7417E2;

/// All registry names, in the order benches iterate them.
pub const NAMES: [&str; 14] = [
    "pagerank", "bfs", "cc", "kvstore", "linpack", "dl_train", "sort", "compression",
    "dl_serve", "matmul", "image", "chameleon", "json", "txn_bench",
];

/// Instantiate a workload by registry name.
pub fn build(name: &str, scale: Scale) -> Option<Box<dyn Workload + Send + Sync>> {
    let small = scale == Scale::Small;
    Some(match name {
        "bfs" => {
            // Default: parent array (32MiB) well past the 19.25MiB LLC —
            // the Twitter-like regime where hot-object placement pays.
            let g = if small { rmat(10, 8, GRAPH_SEED) } else { rmat(23, 6, GRAPH_SEED) };
            Box::new(Bfs::new(g, 0))
        }
        "pagerank" => {
            // Default: contrib/rank arrays 32MiB each (> LLC).
            let (g, iters) =
                if small { (rmat(10, 8, GRAPH_SEED), 3) } else { (rmat(22, 6, GRAPH_SEED), 2) };
            Box::new(PageRank::new(g, iters))
        }
        "cc" => {
            let g = if small { rmat(9, 6, GRAPH_SEED) } else { rmat(18, 8, GRAPH_SEED) };
            Box::new(ConnectedComponents::new(g))
        }
        "linpack" => {
            // Default uses a daxpy-ish narrow block: low arithmetic
            // intensity (the netlib-Linpack regime the paper observes as
            // heavily CXL-impacted), matrix 32MiB > LLC.
            let mut l = Linpack::new(if small { 128 } else { 2048 });
            if !small {
                l.block = 16;
            }
            Box::new(l)
        }
        "matmul" => Box::new(MatMul::new(if small { 128 } else { 1024 })),
        "chameleon" => {
            Box::new(if small { Chameleon::new(64, 16) } else { Chameleon::new(2000, 24) })
        }
        "image" => {
            Box::new(if small { ImageProc::new(128, 96) } else { ImageProc::new(3840, 2160) })
        }
        "compression" => Box::new(Compression::new(if small { 64 << 10 } else { 24 << 20 })),
        "json" => Box::new(JsonSer::new(if small { 200 } else { 40_000 })),
        "kvstore" => {
            Box::new(if small {
                KvStore::new(4_000, 20_000)
            } else {
                KvStore::new(6_000_000, 2_000_000)
            })
        }
        "sort" => Box::new(Sort::new(if small { 20_000 } else { 8_000_000 })),
        "dl_train" => {
            // Default: ResNet-scale parameter footprint (80MiB ≫ LLC);
            // Small keeps the PJRT-artifact geometry.
            Box::new(if small {
                DlTrain::new(2)
            } else {
                DlTrain {
                    layers: vec![768, 4096, 4096, 10],
                    batch: 64,
                    steps: 10,
                    flops_per_cycle: 16,
                }
            })
        }
        "txn_bench" => {
            // Default: 8-partition stock table 25.6MiB (> LLC), so CXL
            // residency stalls every new-order line — the lane
            // scheduler's frontier workload.
            Box::new(if small {
                TxnBench::new(2_000, 2_000)
            } else {
                TxnBench::new(400_000, 200_000)
            })
        }
        "dl_serve" => Box::new(if small {
            DlServe::new(4)
        } else {
            DlServe {
                layers: vec![768, 4096, 4096, 10],
                batch: 8,
                requests: 30,
                flops_per_cycle: 16,
            }
        }),
        _ => return None,
    })
}

/// The full Fig. 2 suite at the given scale.
pub fn suite(scale: Scale) -> Vec<Box<dyn Workload + Send + Sync>> {
    NAMES.iter().map(|n| build(n, scale).unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::env::Env;
    use crate::trace::NullSink;

    #[test]
    fn every_name_builds() {
        for name in NAMES {
            let w = build(name, Scale::Small).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(w.name(), name);
        }
        assert!(build("nonsense", Scale::Small).is_none());
    }

    #[test]
    fn small_suite_runs_everything() {
        for w in suite(Scale::Small) {
            let mut sink = NullSink::default();
            let (c, n_objs, n_accesses) = {
                let mut env = Env::new(4096, &mut sink);
                let c = w.run(&mut env);
                (c, env.objects().len(), env.access_count())
            };
            assert!(n_accesses > 0, "{} emitted no accesses", w.name());
            assert!(n_objs >= 1, "{} allocated nothing", w.name());
            std::hint::black_box(c);
        }
    }

    #[test]
    fn trace_fingerprints_fold_stream_parameters() {
        use crate::workloads::kvstore::KvStore;
        // same footprint, different op count → different streams, so the
        // TraceStore must key them apart
        let a = KvStore::new(50_000, 50_000);
        let b = KvStore::new(50_000, 100_000);
        assert_eq!(a.footprint_hint(), b.footprint_hint());
        assert_ne!(a.trace_fingerprint(), b.trace_fingerprint());
        // stable across instances with identical parameters
        assert_eq!(a.trace_fingerprint(), KvStore::new(50_000, 50_000).trace_fingerprint());
        // and distinct across the registry population
        let mut seen = std::collections::HashSet::new();
        for name in NAMES {
            let w = build(name, Scale::Small).unwrap();
            assert!(seen.insert(w.trace_fingerprint()), "{name}: fingerprint collision");
        }
    }

    #[test]
    fn names_unique() {
        let mut v = NAMES.to_vec();
        v.sort();
        v.dedup();
        assert_eq!(v.len(), NAMES.len());
    }
}
