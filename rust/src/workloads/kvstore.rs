//! In-memory KV store (vSwarm/RAMCloud-flavoured): open-addressing hash
//! table serving a zipf-skewed get/put mix. Random probes over a large
//! table with a strong hot set — high CXL sensitivity, and a clear
//! winner from hot-page DRAM placement.

use crate::shim::env::Env;
use crate::workloads::{mix, mix_bits, Workload};

pub struct KvStore {
    /// Number of resident keys.
    pub keys: usize,
    /// Operations to serve.
    pub ops: usize,
    /// Zipf skew of key popularity.
    pub theta: f64,
    /// Fraction of ops that are writes.
    pub write_frac: f64,
    pub value_words: usize,
    pub seed: u64,
}

impl KvStore {
    pub fn new(keys: usize, ops: usize) -> KvStore {
        KvStore { keys, ops, theta: 0.99, write_frac: 0.1, value_words: 4, seed: 0x5707E }
    }

    fn capacity(&self) -> usize {
        (self.keys * 2).next_power_of_two()
    }
}

#[inline]
fn khash(k: u64) -> u64 {
    let mut x = k.wrapping_mul(0x9E3779B97F4A7C15);
    x ^= x >> 32;
    x.wrapping_mul(0xD6E8FEB86659FD93)
}

impl Workload for KvStore {
    fn name(&self) -> &str {
        "kvstore"
    }

    fn footprint_hint(&self) -> u64 {
        (self.capacity() * (8 + self.value_words * 8)) as u64
    }

    fn lane_hints(&self) -> usize {
        4
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = mix(mix(0x52, self.keys as u64), self.ops as u64);
        let h = mix_bits(mix_bits(h, self.theta), self.write_frac);
        mix(mix(h, self.value_words as u64), self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let cap = self.capacity();
        let mask = (cap - 1) as u64;
        env.phase("load");
        // slot 0 of each entry: key+1 (0 = empty); values in a parallel arena
        let mut slots = env.tvec::<u64>(cap, 0, "kvstore/slots");
        let mut values = env.tvec::<u64>(cap * self.value_words, 0, "kvstore/values");

        // preload keys 0..keys (traced: the store is built by the function
        // from its input payload)
        for k in 0..self.keys as u64 {
            let mut idx = khash(k) & mask;
            loop {
                let cur = slots.get(idx as usize, env);
                env.compute(4);
                if cur == 0 {
                    slots.set(idx as usize, k + 1, env);
                    for wi in 0..self.value_words {
                        values.set(idx as usize * self.value_words + wi, khash(k ^ wi as u64), env);
                    }
                    break;
                }
                idx = (idx + 1) & mask;
            }
        }

        env.phase("serve");
        let mut rng = crate::util::prng::Rng::new(self.seed);
        let mut h = 0u64;
        let mut found = 0u64;
        for op in 0..self.ops {
            // zipf rank → key (rank 0 = hottest)
            let k = rng.zipf(self.keys as u64, self.theta);
            let is_write = rng.chance(self.write_frac);
            // independent request handling: reads round-robin over 4
            // lanes and depend only on their own lane's history; writes
            // serialize against every lane (store mutation ordering)
            let lane = (op % 4) as u8;
            env.lane(lane, if is_write { 0b1111 } else { 1 << lane });
            // per-request server work: parse, hash, build response
            env.compute(110);
            let mut idx = khash(k) & mask;
            loop {
                let cur = slots.get(idx as usize, env);
                env.compute(6);
                if cur == k + 1 {
                    if is_write {
                        let w = rng.next_u64();
                        values.set(idx as usize * self.value_words, w, env);
                        h = mix(h, w);
                    } else {
                        let v = values.get(idx as usize * self.value_words, env);
                        h = mix(h, v);
                    }
                    found += 1;
                    break;
                }
                if cur == 0 {
                    break; // miss (can't happen for k < keys, kept for safety)
                }
                idx = (idx + 1) & mask;
            }
        }
        mix(h, found)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn all_gets_hit() {
        let w = KvStore::new(1000, 5000);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let c = w.run(&mut env);
        assert_ne!(c, 0);
        // deterministic
        let mut sink2 = NullSink::default();
        let mut env2 = Env::new(4096, &mut sink2);
        assert_eq!(c, w.run(&mut env2));
    }

    #[test]
    fn skew_concentrates_accesses() {
        // With theta=0.99, the top key should be served far more often
        // than a mid-rank key; probe it via the RNG directly.
        let mut rng = crate::util::prng::Rng::new(1);
        let mut counts = vec![0u32; 1000];
        for _ in 0..100_000 {
            counts[rng.zipf(1000, 0.99) as usize] += 1;
        }
        assert!(counts[0] > 30 * counts[500].max(1));
    }

    #[test]
    fn footprint_scales_with_keys() {
        let big = KvStore::new(100_000, 1).footprint_hint();
        let small = KvStore::new(5_000, 1).footprint_hint();
        assert!(big > 10 * small);
    }
}
