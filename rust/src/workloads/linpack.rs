//! Linpack (FunctionBench-derived): solve a dense linear system via
//! blocked LU factorization (no pivoting — the generated system is made
//! strictly diagonally dominant, the standard benchmark trick) followed
//! by triangular solves.
//!
//! The paper singles out "linear equation solving" as one of the heavier
//! CXL victims: the trailing-matrix updates stream panels from memory at
//! O(n³/b) line traffic over an O(n²) footprint larger than the LLC.

use crate::shim::env::Env;
use crate::workloads::{mix, mix_f64, Workload};

pub struct Linpack {
    pub n: usize,
    pub block: usize,
    pub simd_flops_per_cycle: u64,
    pub seed: u64,
}

impl Linpack {
    pub fn new(n: usize) -> Linpack {
        Linpack { n, block: 64, simd_flops_per_cycle: 8, seed: 0x11A9 }
    }

    /// Diagonally dominant system: A = U(-1,1) + n·I, b = A·1.
    fn gen(&self) -> (Vec<f64>, Vec<f64>) {
        let n = self.n;
        let mut rng = crate::util::prng::Rng::new(self.seed);
        let mut a: Vec<f64> = (0..n * n).map(|_| rng.f64_in(-1.0, 1.0)).collect();
        for i in 0..n {
            a[i * n + i] += n as f64;
        }
        let b: Vec<f64> = (0..n).map(|i| a[i * n..(i + 1) * n].iter().sum()).collect();
        (a, b)
    }

    /// Factor in place (blocked, right-looking), then solve. Shared by
    /// the traced run and the untraced reference.
    fn lu_and_solve(a: &mut [f64], rhs: &[f64], n: usize, b: usize) -> Vec<f64> {
        let nb = n.div_ceil(b);
        for kb in 0..nb {
            let k0 = kb * b;
            let k1 = (k0 + b).min(n);
            // 1. unblocked LU of the diagonal block
            for k in k0..k1 {
                let pivot = a[k * n + k];
                for i in k + 1..k1 {
                    let l = a[i * n + k] / pivot;
                    a[i * n + k] = l;
                    for j in k + 1..k1 {
                        a[i * n + j] -= l * a[k * n + j];
                    }
                }
            }
            // 2a. row panel: U12 = L11⁻¹ · A[k0..k1][k1..n]
            for k in k0..k1 {
                for i in k + 1..k1 {
                    let l = a[i * n + k];
                    for j in k1..n {
                        a[i * n + j] -= l * a[k * n + j];
                    }
                }
            }
            // 2b. column panel: L21 = A[k1..n][k0..k1] · U11⁻¹
            for i in k1..n {
                for k in k0..k1 {
                    let mut v = a[i * n + k];
                    for p in k0..k {
                        v -= a[i * n + p] * a[p * n + k];
                    }
                    a[i * n + k] = v / a[k * n + k];
                }
            }
            // 3. trailing update: A22 -= L21 · U12
            for i in k1..n {
                for k in k0..k1 {
                    let l = a[i * n + k];
                    for j in k1..n {
                        a[i * n + j] -= l * a[k * n + j];
                    }
                }
            }
        }
        // forward substitution (L has unit diagonal)
        let mut y = rhs.to_vec();
        for i in 0..n {
            for j in 0..i {
                y[i] = y[i] - a[i * n + j] * y[j];
            }
        }
        // back substitution
        let mut x = y;
        for i in (0..n).rev() {
            for j in i + 1..n {
                x[i] = x[i] - a[i * n + j] * x[j];
            }
            x[i] /= a[i * n + i];
        }
        x
    }

    fn checksum(x: &[f64]) -> u64 {
        // solution should be ≈ 1 everywhere
        let max_err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        let sum: f64 = x.iter().sum();
        mix_f64(mix_f64(0, sum), (max_err * 1e3).round())
    }

    pub fn reference_checksum(&self) -> u64 {
        let (mut a, rhs) = self.gen();
        let x = Self::lu_and_solve(&mut a, &rhs, self.n, self.block);
        Self::checksum(&x)
    }
}

impl Workload for Linpack {
    fn name(&self) -> &str {
        "linpack"
    }

    fn footprint_hint(&self) -> u64 {
        (self.n * self.n * 8) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = mix(mix(0x11A9AC, self.n as u64), self.block as u64);
        mix(mix(h, self.simd_flops_per_cycle), self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let n = self.n;
        let b = self.block.min(n);
        let (av, rhs_v) = self.gen();
        env.phase("load");
        let mut a = env.tvec_from(av, "linpack/matrix");
        let rhs = env.tvec_from(rhs_v, "linpack/rhs");

        env.phase("factorize");
        // Emit the traffic of the blocked factorization: the trailing
        // update dominates — for every (i-row, k-panel) pair, one read
        // pass over rows of U12 and the updated row.
        let nb = n.div_ceil(b);
        for kb in 0..nb {
            let k0 = kb * b;
            let k1 = (k0 + b).min(n);
            // diagonal block: resident, one read+write pass
            for i in k0..k1 {
                a.touch_range(i * n + k0, i * n + k1, false, env);
                a.touch_range(i * n + k0, i * n + k1, true, env);
            }
            env.compute(((k1 - k0) as u64).pow(3) / 3 / self.simd_flops_per_cycle);
            // row panel update
            for i in k0..k1 {
                a.touch_range(i * n + k1, i * n + n, false, env);
                a.touch_range(i * n + k1, i * n + n, true, env);
            }
            let panel_flops = ((k1 - k0) as u64).pow(2) * (n - k1) as u64 / 2;
            env.compute(panel_flops / self.simd_flops_per_cycle);
            // column panel
            for i in k1..n {
                a.touch_range(i * n + k0, i * n + k1, false, env);
                a.touch_range(i * n + k0, i * n + k1, true, env);
            }
            let panel_flops = ((k1 - k0) as u64).pow(2) * (n - k1) as u64 / 2;
            env.compute(panel_flops / self.simd_flops_per_cycle);
            // trailing update: for each row i and panel row k, stream the
            // U12 row and the target row
            for i in k1..n {
                for k in k0..k1 {
                    a.touch_range(k * n + k1, k * n + n, false, env);
                    env.compute((n - k1) as u64 / self.simd_flops_per_cycle + 2);
                }
                a.touch_range(i * n + k1, i * n + n, true, env);
            }
        }
        // the real arithmetic, once (identical result to interleaving)
        let x = {
            let rhs_raw = rhs.raw().to_vec();
            Self::lu_and_solve(a.raw_mut(), &rhs_raw, n, b)
        };

        env.phase("solve");
        // triangular solves: one pass over the factored matrix
        for i in 0..n {
            a.touch_range(i * n, i * n + i + 1, false, env);
            env.compute(i as u64 / self.simd_flops_per_cycle + 1);
        }
        for i in (0..n).rev() {
            a.touch_range(i * n + i, i * n + n, false, env);
            env.compute((n - i) as u64 / self.simd_flops_per_cycle + 1);
        }

        Self::checksum(&x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn solves_accurately() {
        let w = Linpack { n: 96, block: 32, simd_flops_per_cycle: 8, seed: 3 };
        let (mut a, rhs) = w.gen();
        let x = Linpack::lu_and_solve(&mut a, &rhs, w.n, w.block);
        for v in &x {
            assert!((v - 1.0).abs() < 1e-9, "x={v}");
        }
    }

    #[test]
    fn blocked_equals_unblocked() {
        let w = Linpack { n: 64, block: 64, simd_flops_per_cycle: 8, seed: 5 };
        let (mut a1, rhs) = w.gen();
        let x1 = Linpack::lu_and_solve(&mut a1, &rhs, 64, 64); // single block = unblocked
        let (mut a2, _) = w.gen();
        let x2 = Linpack::lu_and_solve(&mut a2, &rhs, 64, 16);
        for (u, v) in x1.iter().zip(&x2) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn traced_matches_reference() {
        let w = Linpack { n: 128, block: 32, simd_flops_per_cycle: 8, seed: 9 };
        let expect = w.reference_checksum();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), expect);
    }

    #[test]
    fn non_multiple_block_sizes_work() {
        let w = Linpack { n: 100, block: 32, simd_flops_per_cycle: 8, seed: 11 };
        let (mut a, rhs) = w.gen();
        let x = Linpack::lu_and_solve(&mut a, &rhs, 100, 32);
        for v in &x {
            assert!((v - 1.0).abs() < 1e-8);
        }
    }
}
