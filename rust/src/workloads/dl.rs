//! DL training and serving workloads.
//!
//! Two halves, matching the paper's split between *memory behaviour* and
//! *function execution*:
//!
//! * **Simulation half (this module)**: [`DlTrain`] / [`DlServe`] emit
//!   the memory-access structure of MLP training/inference over traced
//!   objects — weights (hot, reused every step), activations (streamed,
//!   transient), gradients + optimizer state (training only) — with the
//!   FMA work as bulk compute. This is what Fig. 2/4/7 need: the access
//!   pattern, not the numerics.
//! * **Numerics half (`runtime::` + `python/compile/`)**: the same MLP is
//!   defined in JAX (L2) over the Pallas matmul kernel (L1), AOT-lowered
//!   to HLO, and executed natively via PJRT on the serving path
//!   (`examples/serve_dl.rs`). Python never runs at request time.
//!
//! The layer geometry below matches `python/compile/model.py`, so the
//! simulated traffic and the real executable describe the same network.

use crate::shim::env::Env;
use crate::workloads::{mix, Workload};

/// Default MLP geometry shared with python/compile/model.py.
pub const DEFAULT_LAYERS: [usize; 4] = [768, 1024, 1024, 10];

/// One training step = forward + backward + SGD update over every layer.
pub struct DlTrain {
    pub layers: Vec<usize>,
    pub batch: usize,
    pub steps: usize,
    /// f32 FMA throughput per cycle (SIMD).
    pub flops_per_cycle: u64,
}

impl DlTrain {
    pub fn new(steps: usize) -> DlTrain {
        DlTrain { layers: DEFAULT_LAYERS.to_vec(), batch: 64, steps, flops_per_cycle: 16 }
    }

    pub fn param_count(&self) -> usize {
        self.layers.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }
}


impl Workload for DlTrain {
    fn name(&self) -> &str {
        "dl_train"
    }

    fn footprint_hint(&self) -> u64 {
        // params ×3 (weights, grads, momentum) + activations
        (self.param_count() * 12 + self.batch * self.layers.iter().sum::<usize>() * 4) as u64
    }

    fn lane_hints(&self) -> usize {
        4
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = self.layers.iter().fold(0xD17, |h, &l| mix(h, l as u64));
        mix(mix(mix(h, self.batch as u64), self.steps as u64), self.flops_per_cycle)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let p = self.param_count();
        let act_elems: usize = self.batch * self.layers.iter().sum::<usize>();
        env.phase("init");
        let weights = env.tvec::<f32>(p, 0.01, "dl_train/weights");
        let grads = env.tvec::<f32>(p, 0.0, "dl_train/grads");
        let moment = env.tvec::<f32>(p, 0.0, "dl_train/momentum");
        let acts = env.tvec::<f32>(act_elems, 0.0, "dl_train/activations");
        let batches =
            env.tvec::<f32>(self.batch * self.layers[0] * 4, 0.5, "dl_train/input_batches");

        let mut h = 0u64;
        for step in 0..self.steps {
            env.phase("forward");
            // input batch load (rotating over a small batch pool)
            let in_off = (step % 4) * self.batch * self.layers[0];
            batches.touch_range(in_off, in_off + self.batch * self.layers[0], false, env);
            let mut w_off = 0usize;
            let mut a_off = 0usize;
            for l in 0..self.layers.len() - 1 {
                let (din, dout) = (self.layers[l], self.layers[l + 1]);
                let next_a = a_off + self.batch * din;
                // GEMM: acts[l] (m×k) · W_l (k×n) → acts[l+1] (m×n)
                acts.touch_range(a_off, a_off + self.batch * din, false, env);
                weights.touch_range(w_off, w_off + din * dout, false, env);
                acts.touch_range(next_a, next_a + self.batch * dout, true, env);
                env.compute((self.batch * din * dout) as u64 / self.flops_per_cycle);
                w_off += din * dout + dout;
                a_off = next_a;
            }
            env.phase("backward");
            // reverse pass: dW = aᵀ·δ and δ' = δ·Wᵀ per layer
            let mut w_end = p;
            for l in (0..self.layers.len() - 1).rev() {
                let (din, dout) = (self.layers[l], self.layers[l + 1]);
                w_end -= din * dout + dout;
                // read activations + weights, write grads
                acts.touch_range(a_off.saturating_sub(self.batch * din), a_off, false, env);
                weights.touch_range(w_end, w_end + din * dout, false, env);
                grads.touch_range(w_end, w_end + din * dout, true, env);
                env.compute(2 * (self.batch * din * dout) as u64 / self.flops_per_cycle);
                a_off = a_off.saturating_sub(self.batch * din);
            }
            env.phase("update");
            // SGD+momentum is embarrassingly parallel over parameter
            // chunks: each quarter streams weights/grads/momentum on its
            // own lane (the phase marker already joined the backward
            // pass, so 1<<c masks carry no stale history)
            let chunk = p / 4;
            for c in 0..4usize {
                let (lo, hi) = (c * chunk, if c == 3 { p } else { (c + 1) * chunk });
                env.lane(c as u8, 1 << c);
                weights.touch_range(lo, hi, false, env);
                grads.touch_range(lo, hi, false, env);
                moment.touch_range(lo, hi, false, env);
                moment.touch_range(lo, hi, true, env);
                weights.touch_range(lo, hi, true, env);
                env.compute(3 * (hi - lo) as u64 / self.flops_per_cycle);
            }
            h = mix(h, step as u64);
        }
        mix(h, p as u64)
    }
}

/// Inference: forward pass only, small batch, weights dominate traffic.
pub struct DlServe {
    pub layers: Vec<usize>,
    pub batch: usize,
    pub requests: usize,
    pub flops_per_cycle: u64,
}

impl DlServe {
    pub fn new(requests: usize) -> DlServe {
        DlServe { layers: DEFAULT_LAYERS.to_vec(), batch: 8, requests, flops_per_cycle: 16 }
    }

    pub fn param_count(&self) -> usize {
        self.layers.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }
}

impl Workload for DlServe {
    fn name(&self) -> &str {
        "dl_serve"
    }

    fn footprint_hint(&self) -> u64 {
        (self.param_count() * 4 + self.batch * self.layers.iter().sum::<usize>() * 4) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = self.layers.iter().fold(0xD15E, |h, &l| mix(h, l as u64));
        mix(mix(mix(h, self.batch as u64), self.requests as u64), self.flops_per_cycle)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let p = self.param_count();
        env.phase("init");
        let weights = env.tvec::<f32>(p, 0.01, "dl_serve/weights");
        let act_elems: usize = self.batch * self.layers.iter().sum::<usize>();
        let acts = env.tvec::<f32>(act_elems, 0.0, "dl_serve/activations");

        env.phase("serve");
        let mut h = 0u64;
        for r in 0..self.requests {
            let mut w_off = 0usize;
            let mut a_off = 0usize;
            for l in 0..self.layers.len() - 1 {
                let (din, dout) = (self.layers[l], self.layers[l + 1]);
                let next_a = a_off + self.batch * din;
                acts.touch_range(a_off, a_off + self.batch * din, false, env);
                weights.touch_range(w_off, w_off + din * dout, false, env);
                acts.touch_range(next_a, next_a + self.batch * dout, true, env);
                env.compute((self.batch * din * dout) as u64 / self.flops_per_cycle);
                w_off += din * dout + dout;
                a_off = next_a;
            }
            h = mix(h, r as u64);
        }
        mix(h, p as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn train_weights_are_hot() {
        let w = DlTrain::new(4);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        w.run(&mut env);
        // weights object should exist and training touches it every step
        let objs = env.objects();
        assert!(objs.iter().any(|o| o.site == "dl_train/weights"));
        assert!(sink.accesses > 0);
    }

    #[test]
    fn serve_traffic_scales_with_requests() {
        let count = |req| {
            let w = DlServe::new(req);
            let mut sink = NullSink::default();
            let mut env = Env::new(4096, &mut sink);
            w.run(&mut env);
            sink.bytes
        };
        let b1 = count(2);
        let b2 = count(8);
        assert!(b2 as f64 > 3.0 * b1 as f64);
    }

    #[test]
    fn param_count_matches_geometry() {
        let t = DlTrain::new(1);
        // 768·1024+1024 + 1024·1024+1024 + 1024·10+10
        assert_eq!(t.param_count(), 768 * 1024 + 1024 + 1024 * 1024 + 1024 + 1024 * 10 + 10);
    }

    #[test]
    fn deterministic() {
        let run = || {
            let w = DlTrain::new(2);
            let mut sink = NullSink::default();
            let mut env = Env::new(4096, &mut sink);
            (w.run(&mut env), sink.accesses, sink.bytes)
        };
        assert_eq!(run(), run());
    }
}
