//! Image processing (SeBS/FunctionBench-derived): thumbnail pipeline —
//! gaussian blur then 2× box downsample over a synthetic RGBA image.
//! Streaming row-major sweeps with a short vertical stencil: moderate
//! bandwidth demand, little temporal reuse (the paper's "sparse,
//! unpredictable" class alongside Chameleon).

use crate::shim::env::Env;
use crate::workloads::{mix, Workload};

pub struct ImageProc {
    pub width: usize,
    pub height: usize,
    pub seed: u64,
}

impl ImageProc {
    pub fn new(width: usize, height: usize) -> ImageProc {
        ImageProc { width, height, seed: 0x1A6E }
    }

    fn gen_pixels(&self) -> Vec<u32> {
        let mut rng = crate::util::prng::Rng::new(self.seed);
        (0..self.width * self.height).map(|_| rng.next_u64() as u32).collect()
    }

    /// Untraced reference pipeline.
    pub fn reference_checksum(&self) -> u64 {
        let src = self.gen_pixels();
        let blurred = blur3(&src, self.width, self.height);
        let thumb = downsample2(&blurred, self.width, self.height);
        checksum(&thumb)
    }
}

/// 3×3 box blur on packed RGBA (channel-wise).
fn blur3(src: &[u32], w: usize, h: usize) -> Vec<u32> {
    let mut out = vec![0u32; w * h];
    for y in 0..h {
        for x in 0..w {
            let mut acc = [0u32; 4];
            let mut cnt = 0u32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let (nx, ny) = (x as i64 + dx, y as i64 + dy);
                    if nx >= 0 && ny >= 0 && (nx as usize) < w && (ny as usize) < h {
                        let p = src[ny as usize * w + nx as usize];
                        for ch in 0..4 {
                            acc[ch] += (p >> (ch * 8)) & 0xFF;
                        }
                        cnt += 1;
                    }
                }
            }
            let mut px = 0u32;
            for ch in 0..4 {
                px |= (acc[ch] / cnt) << (ch * 8);
            }
            out[y * w + x] = px;
        }
    }
    out
}

/// 2×2 average downsample.
fn downsample2(src: &[u32], w: usize, h: usize) -> Vec<u32> {
    let (ow, oh) = (w / 2, h / 2);
    let mut out = vec![0u32; ow * oh];
    for y in 0..oh {
        for x in 0..ow {
            let mut acc = [0u32; 4];
            for dy in 0..2 {
                for dx in 0..2 {
                    let p = src[(y * 2 + dy) * w + x * 2 + dx];
                    for ch in 0..4 {
                        acc[ch] += (p >> (ch * 8)) & 0xFF;
                    }
                }
            }
            let mut px = 0u32;
            for ch in 0..4 {
                px |= (acc[ch] / 4) << (ch * 8);
            }
            out[y * ow + x] = px;
        }
    }
    out
}

fn checksum(px: &[u32]) -> u64 {
    px.iter().fold(0u64, |h, &p| mix(h, p as u64))
}

impl Workload for ImageProc {
    fn name(&self) -> &str {
        "image"
    }

    fn footprint_hint(&self) -> u64 {
        (self.width * self.height * 4 * 2) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        mix(mix(mix(0x16, self.width as u64), self.height as u64), self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let (w, h) = (self.width, self.height);
        env.phase("load");
        let src = env.tvec_from(self.gen_pixels(), "image/src");
        let mut blur = env.tvec::<u32>(w * h, 0, "image/blur");

        env.phase("blur");
        // traffic: per output row, read the 3 input rows + write output;
        // compute: 9 taps × 4 channels per pixel
        for y in 0..h {
            for dy in -1i64..=1 {
                let ny = y as i64 + dy;
                if ny >= 0 && (ny as usize) < h {
                    src.touch_range(ny as usize * w, (ny as usize + 1) * w, false, env);
                }
            }
            blur.touch_range(y * w, (y + 1) * w, true, env);
            env.compute((w * 40) as u64);
        }
        let blurred = blur3(src.raw(), w, h);
        blur.raw_mut().copy_from_slice(&blurred);

        env.phase("thumbnail");
        let (ow, oh) = (w / 2, h / 2);
        let mut thumb = env.tvec::<u32>(ow * oh, 0, "image/thumb");
        for y in 0..oh {
            blur.touch_range(y * 2 * w, (y * 2 + 2) * w, false, env);
            thumb.touch_range(y * ow, (y + 1) * ow, true, env);
            env.compute((ow * 12) as u64);
        }
        let t = downsample2(blur.raw(), w, h);
        thumb.raw_mut().copy_from_slice(&t);
        checksum(thumb.raw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn traced_matches_reference() {
        let w = ImageProc::new(64, 48);
        let expect = w.reference_checksum();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(w.run(&mut env), expect);
    }

    #[test]
    fn blur_preserves_constant_image() {
        let src = vec![0x40404040u32; 16 * 16];
        let out = blur3(&src, 16, 16);
        assert!(out.iter().all(|&p| p == 0x40404040), "constant image stays constant");
    }

    #[test]
    fn downsample_halves_dims() {
        let src = vec![0u32; 8 * 6];
        let out = downsample2(&src, 8, 6);
        assert_eq!(out.len(), 4 * 3);
    }

    #[test]
    fn downsample_averages() {
        // 2x2 image with channel-0 values 0,2,4,6 → avg 3
        let src = vec![0, 2, 4, 6];
        let out = downsample2(&src, 2, 2);
        assert_eq!(out[0] & 0xFF, 3);
    }
}
