//! BFS (GAPBS-derived): frontier-based top-down breadth-first search.
//!
//! Access pattern: sequential frontier scans + random neighbor lookups
//! into the `parent` array — the hot object is `parent` (and the CSR
//! offsets), which is what the paper's Fig. 4 heatmap shows as the
//! banded hot region.

use crate::shim::env::Env;
use crate::workloads::graph::CsrGraph;
use crate::workloads::{mix, Workload};

pub struct Bfs {
    pub graph: CsrGraph,
    pub source: u32,
    /// Cycles of address arithmetic per traversed edge.
    pub cycles_per_edge: u64,
}

impl Bfs {
    pub fn new(graph: CsrGraph, source: u32) -> Bfs {
        Bfs { graph, source, cycles_per_edge: 4 }
    }

    /// Untraced reference BFS for correctness tests.
    pub fn reference_depth_histogram(&self) -> Vec<u32> {
        let n = self.graph.n();
        let mut depth = vec![u32::MAX; n];
        let mut q = std::collections::VecDeque::new();
        depth[self.source as usize] = 0;
        q.push_back(self.source);
        while let Some(v) = q.pop_front() {
            for &t in self.graph.neighbors(v as usize) {
                if depth[t as usize] == u32::MAX {
                    depth[t as usize] = depth[v as usize] + 1;
                    q.push_back(t);
                }
            }
        }
        let max_d = depth.iter().filter(|&&d| d != u32::MAX).max().copied().unwrap_or(0);
        let mut hist = vec![0u32; max_d as usize + 1];
        for &d in &depth {
            if d != u32::MAX {
                hist[d as usize] += 1;
            }
        }
        hist
    }
}

impl Workload for Bfs {
    fn name(&self) -> &str {
        "bfs"
    }

    fn footprint_hint(&self) -> u64 {
        (self.graph.n() * 8 + self.graph.m() * 4) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = mix(0xBF5, self.graph.fingerprint());
        mix(mix(h, self.source as u64), self.cycles_per_edge)
    }

    fn run(&self, env: &mut Env) -> u64 {
        env.phase("load");
        let g = self.graph.into_env(env, "bfs");
        let n = g.n();
        let mut parent = env.tvec::<u32>(n, u32::MAX, "bfs/parent");
        let mut frontier = env.tvec::<u32>(n, 0, "bfs/frontier");
        let mut next = env.tvec::<u32>(n, 0, "bfs/next");

        env.phase("traverse");
        parent.set(self.source as usize, self.source, env);
        frontier.set(0, self.source, env);
        let mut frontier_len = 1usize;
        let mut visited = 1u64;
        let mut depth_sum = 0u64;
        let mut depth = 0u64;
        while frontier_len > 0 {
            depth += 1;
            let mut next_len = 0usize;
            for fi in 0..frontier_len {
                let v = frontier.get(fi, env) as usize;
                let lo = g.offsets.get(v, env) as usize;
                let hi = g.offsets.get(v + 1, env) as usize;
                // neighbor list streams at line granularity
                g.targets.touch_range(lo, hi, false, env);
                for ei in lo..hi {
                    let t = g.targets.get_untraced(ei) as usize;
                    env.compute(self.cycles_per_edge);
                    if parent.get(t, env) == u32::MAX {
                        parent.set(t, v as u32, env);
                        next.set(next_len, t as u32, env);
                        next_len += 1;
                        visited += 1;
                        depth_sum += depth;
                    }
                }
            }
            std::mem::swap(&mut frontier, &mut next);
            frontier_len = next_len;
        }
        mix(mix(0, visited), depth_sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;
    use crate::workloads::graph::{rmat, uniform, CsrGraph};

    #[test]
    fn bfs_visits_reachable_set() {
        // path graph 0→1→2→3 plus disconnected 4
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3)]);
        let w = Bfs::new(g, 0);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let c = w.run(&mut env);
        // visited=4, depth_sum=1+2+3=6
        assert_eq!(c, mix(mix(0, 4), 6));
    }

    #[test]
    fn bfs_matches_reference_on_rmat() {
        let g = rmat(10, 8, 3);
        let w = Bfs::new(g, 0);
        let hist = w.reference_depth_histogram();
        let reachable: u32 = hist.iter().sum();
        let depth_sum: u64 =
            hist.iter().enumerate().map(|(d, &c)| d as u64 * c as u64).sum();
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let c = w.run(&mut env);
        assert_eq!(c, mix(mix(0, reachable as u64), depth_sum));
        assert!(reachable > 100, "rmat giant component should be reachable");
    }

    #[test]
    fn bfs_deterministic_across_runs() {
        let run = || {
            let g = uniform(512, 4, 9);
            let w = Bfs::new(g, 1);
            let mut sink = NullSink::default();
            let mut env = Env::new(4096, &mut sink);
            (w.run(&mut env), env.access_count())
        };
        assert_eq!(run(), run());
    }
}
