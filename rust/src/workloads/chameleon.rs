//! Chameleon (FunctionBench-derived): HTML table rendering from a
//! template — string-heavy, small hot working set, the paper's example
//! of a *sparse, unpredictable* access pattern with minimal CXL
//! sensitivity (Fig. 2 low end, Fig. 4 scattered heatmap).

use crate::shim::env::Env;
use crate::workloads::{mix, Workload};

pub struct Chameleon {
    /// Table dimensions to render.
    pub rows: usize,
    pub cols: usize,
    pub seed: u64,
}

impl Chameleon {
    pub fn new(rows: usize, cols: usize) -> Chameleon {
        Chameleon { rows, cols, seed: 0xC0FFEE }
    }

    fn cell_value(&self, r: usize, c: usize) -> u64 {
        crate::workloads::mix(self.seed, (r * self.cols + c) as u64) % 100_000
    }
}

impl Workload for Chameleon {
    fn name(&self) -> &str {
        "chameleon"
    }

    fn footprint_hint(&self) -> u64 {
        (self.rows * self.cols * 12) as u64
    }

    fn trace_fingerprint(&self) -> u64 {
        mix(mix(mix(0xCA, self.rows as u64), self.cols as u64), self.seed)
    }

    fn run(&self, env: &mut Env) -> u64 {
        env.phase("render");
        // output buffer grows like a rope; model as chunked appends
        let cap = self.rows * self.cols * 16 + 1024;
        let mut out = env.tvec::<u8>(cap, 0, "chameleon/out");
        let mut pos = 0usize;
        let mut emit = |bytes: &[u8], out: &mut crate::shim::env::TVec<u8>, env: &mut Env| {
            for &b in bytes {
                out.set(pos, b, env);
                pos += 1;
            }
        };
        let mut h = 0u64;
        emit(b"<table>", &mut out, env);
        let mut numbuf = [0u8; 20];
        for r in 0..self.rows {
            emit(b"<tr>", &mut out, env);
            for c in 0..self.cols {
                emit(b"<td>", &mut out, env);
                let v = self.cell_value(r, c);
                env.compute(30); // template engine per-cell interpretation
                let s = format_u64(v, &mut numbuf);
                emit(s, &mut out, env);
                emit(b"</td>", &mut out, env);
                h = mix(h, v);
            }
            emit(b"</tr>", &mut out, env);
        }
        emit(b"</table>", &mut out, env);
        mix(h, pos as u64)
    }
}

/// Format into a stack buffer without allocating.
fn format_u64(mut v: u64, buf: &mut [u8; 20]) -> &[u8] {
    if v == 0 {
        buf[0] = b'0';
        return &buf[..1];
    }
    let mut i = 20;
    while v > 0 {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
    }
    buf.copy_within(i..20, 0);
    let len = 20 - i;
    &buf[..len]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn renders_valid_html() {
        let w = Chameleon::new(10, 5);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let c1 = w.run(&mut env);
        // deterministic
        let mut sink2 = NullSink::default();
        let mut env2 = Env::new(4096, &mut sink2);
        assert_eq!(c1, w.run(&mut env2));
        assert!(sink.accesses > 10 * 5 * 9); // at least the tag bytes
    }

    #[test]
    fn output_scales_with_table() {
        let count = |r, c| {
            let w = Chameleon::new(r, c);
            let mut sink = NullSink::default();
            let mut env = Env::new(4096, &mut sink);
            w.run(&mut env);
            sink.accesses
        };
        assert!(count(20, 10) > 3 * count(10, 5));
    }

    #[test]
    fn format_u64_works() {
        let mut b = [0u8; 20];
        assert_eq!(format_u64(0, &mut b), b"0");
        let mut b = [0u8; 20];
        assert_eq!(format_u64(12345, &mut b), b"12345");
        let mut b = [0u8; 20];
        assert_eq!(format_u64(u64::MAX, &mut b), b"18446744073709551615");
    }
}
