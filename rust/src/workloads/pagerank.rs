//! PageRank (GAPBS-derived): pull-based power iteration on the transpose
//! graph.
//!
//! Access pattern: for every vertex, a random gather over incoming
//! neighbors' contributions — the `contrib` array takes skewed random
//! reads (hot on RMAT's celebrity vertices) while the CSR arrays stream
//! sequentially. This is the workload the paper uses for its Fig. 5
//! static-placement result (up to ~26% improvement over pure CXL).

use crate::shim::env::Env;
use crate::workloads::graph::CsrGraph;
use crate::workloads::{mix, mix_bits, mix_f64, Workload};

pub struct PageRank {
    pub graph: CsrGraph,
    pub iterations: usize,
    pub damping: f64,
    /// FMA + bookkeeping cycles per gathered edge.
    pub cycles_per_edge: u64,
}

impl PageRank {
    pub fn new(graph: CsrGraph, iterations: usize) -> PageRank {
        PageRank { graph, iterations, damping: 0.85, cycles_per_edge: 3 }
    }

    /// Untraced reference for correctness tests (identical arithmetic).
    pub fn reference_ranks(&self) -> Vec<f64> {
        let n = self.graph.n();
        let tg = self.graph.transpose();
        let out_deg: Vec<u32> = (0..n).map(|v| self.graph.degree(v) as u32).collect();
        let mut rank = vec![1.0 / n as f64; n];
        let base = (1.0 - self.damping) / n as f64;
        for _ in 0..self.iterations {
            let contrib: Vec<f64> = (0..n)
                .map(|v| if out_deg[v] > 0 { rank[v] / out_deg[v] as f64 } else { 0.0 })
                .collect();
            for v in 0..n {
                let sum: f64 = tg.neighbors(v).iter().map(|&u| contrib[u as usize]).sum();
                rank[v] = base + self.damping * sum;
            }
        }
        rank
    }
}

impl Workload for PageRank {
    fn name(&self) -> &str {
        "pagerank"
    }

    fn footprint_hint(&self) -> u64 {
        (self.graph.n() * (8 + 8 + 4 + 4) + self.graph.m() * 4) as u64
    }

    fn lane_hints(&self) -> usize {
        4
    }

    fn trace_fingerprint(&self) -> u64 {
        let h = mix(0x9A6E, self.graph.fingerprint());
        let h = mix(h, self.iterations as u64);
        mix(mix_bits(h, self.damping), self.cycles_per_edge)
    }

    fn run(&self, env: &mut Env) -> u64 {
        let n = self.graph.n();
        env.phase("load");
        // pull direction: CSR of the transpose
        let tg = self.graph.transpose().into_env(env, "pagerank");
        let out_deg = env.tvec_from(
            (0..n).map(|v| self.graph.degree(v) as u32).collect(),
            "pagerank/out_deg",
        );
        let mut rank = env.tvec::<f64>(n, 1.0 / n as f64, "pagerank/rank");
        let mut contrib = env.tvec::<f64>(n, 0.0, "pagerank/contrib");

        env.phase("iterate");
        let base = (1.0 - self.damping) / n as f64;
        for _ in 0..self.iterations {
            // contribution pass: sequential, and it must see every
            // gather of the previous iteration — join all lanes
            env.lane(0, 0b1111);
            for v in 0..n {
                let d = out_deg.get(v, env);
                let r = rank.get(v, env);
                env.compute(4);
                contrib.set(v, if d > 0 { r / d as f64 } else { 0.0 }, env);
            }
            // gather pass: per-vertex gathers are independent (read
            // contrib, write rank[v]) — round-robin over 4 lanes, each
            // joining lane 0 so no gather precedes the contribution pass
            for v in 0..n {
                env.lane((v % 4) as u8, 0b0001 | (1 << (v % 4)));
                let lo = tg.offsets.get(v, env) as usize;
                let hi = tg.offsets.get(v + 1, env) as usize;
                tg.targets.touch_range(lo, hi, false, env);
                let mut sum = 0.0;
                for ei in lo..hi {
                    let u = tg.targets.get_untraced(ei) as usize;
                    sum += contrib.get(u, env);
                    env.compute(self.cycles_per_edge);
                }
                rank.set(v, base + self.damping * sum, env);
            }
        }

        env.phase("reduce");
        let mut checksum = 0u64;
        let mut total = 0.0;
        rank.scan(0, n, env, |_, r| total += r);
        checksum = mix_f64(checksum, total);
        // top rank value is a sharper signal than the (≈1.0) total
        let max = (0..n).map(|v| rank.get_untraced(v)).fold(f64::MIN, f64::max);
        mix_f64(checksum, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;
    use crate::workloads::graph::rmat;

    #[test]
    fn ranks_sum_to_one() {
        let g = rmat(9, 6, 5);
        let pr = PageRank::new(g, 8);
        let ranks = pr.reference_ranks();
        let total: f64 = ranks.iter().sum();
        // dangling mass leaks a bit below 1.0 but stays in range
        assert!(total > 0.5 && total <= 1.0 + 1e-9, "total={total}");
    }

    #[test]
    fn traced_matches_reference() {
        let g = rmat(8, 5, 11);
        let pr = PageRank::new(g, 5);
        let ranks = pr.reference_ranks();
        let total: f64 = ranks.iter().sum();
        let max = ranks.iter().copied().fold(f64::MIN, f64::max);
        let expect = mix_f64(mix_f64(0, total), max);
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        assert_eq!(pr.run(&mut env), expect);
    }

    #[test]
    fn high_degree_vertices_rank_higher() {
        let g = rmat(10, 8, 13);
        let tg = g.transpose();
        let pr = PageRank::new(g, 10);
        let ranks = pr.reference_ranks();
        // vertex with max in-degree should out-rank the median vertex
        let vmax = (0..tg.n()).max_by_key(|&v| tg.degree(v)).unwrap();
        let mut sorted = ranks.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        assert!(ranks[vmax] > 10.0 * median, "{} vs {}", ranks[vmax], median);
    }
}
