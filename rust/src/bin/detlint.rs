//! detlint — standalone driver for the determinism lints.
//!
//! Usage:
//!   detlint [--config <detlint.toml>]
//!
//! Without `--config`, looks for `detlint.toml` in `.` then `..`, so it
//! works from the repo root and from `rust/` (CI's working directory).
//! `porter-cli detlint` is the same entry point. Exit status: 0 clean,
//! 1 violations or directive errors, 2 usage/config errors.

fn main() {
    let mut config: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--config" => match args.next() {
                Some(p) => config = Some(p),
                None => {
                    eprintln!("detlint: --config requires a path");
                    std::process::exit(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: detlint [--config <detlint.toml>]");
                println!("checks rust/src and rust/benches against the determinism lints D1-D5");
                std::process::exit(0);
            }
            other => {
                eprintln!("detlint: unknown argument {other:?}");
                std::process::exit(2);
            }
        }
    }
    std::process::exit(porter::analysis::cli_main(config.as_deref()));
}
