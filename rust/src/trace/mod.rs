//! Memory-access traces: the event stream every workload emits and every
//! consumer (cost-model machine, DAMON monitor, heatmap, recorder)
//! consumes.
//!
//! Workloads *stream* events — they are real algorithms whose data
//! structures are instrumented (`shim::env`), so traces never need to be
//! materialized for single-tenant runs. The [`ir`] module defines the
//! Trace-IR ([`AccessTrace`]): a compact, versioned, JSON-serializable
//! recording of one stream, replayable into any sink with the
//! replay-identity guarantee (replayed runs reproduce live `RunReport`s
//! and checksums exactly). The [`store`] module keys canonical
//! recordings process-wide so every layer records once and replays
//! many.

pub mod ir;
pub mod recorder;
pub mod store;

pub use ir::{
    interleave, relocation_stride, AccessTrace, PackedEvent, PhaseSummary, TraceRecorder,
    TRACE_IR_VERSION,
};
pub use recorder::RecordedTrace;
pub use store::{record_workload, TraceKey, TraceStore};

use crate::shim::object::MemoryObject;

/// Consumer of a workload's instrumented execution.
///
/// Calls arrive in program order. `access` granularity is whatever the
/// workload touched (an element, a line, a buffer chunk); consumers
/// split/merge to their own granularity (the cache model works on lines,
/// DAMON on regions, tiers on pages).
pub trait Sink {
    /// A tracked allocation entered the address space.
    fn alloc(&mut self, obj: &MemoryObject);
    /// A tracked allocation was released.
    fn free(&mut self, obj: &MemoryObject);
    /// A memory access at `addr` covering `bytes` bytes.
    fn access(&mut self, addr: u64, bytes: u32, write: bool);
    /// Pure compute between memory operations, in core cycles.
    fn compute(&mut self, cycles: u64);
    /// Named phase marker (e.g. "build", "iterate") for heatmap axes.
    fn phase(&mut self, _name: &str) {}
    /// Lane annotation: subsequent events run on `lane`, after every
    /// event previously charged to a lane in `after_mask` (bit i = lane
    /// i). Sinks without a lane model ignore it — the default no-op is
    /// what keeps lane-annotated streams bit-identical on the scalar
    /// clock when `[lanes]` is disabled.
    fn lane(&mut self, _lane: u8, _after_mask: u64) {}
}

/// A sink that discards everything — used to measure workload-side
/// overhead and as a placeholder in tests.
#[derive(Debug, Default, Clone)]
pub struct NullSink {
    pub accesses: u64,
    pub bytes: u64,
    pub compute_cycles: u64,
    pub allocs: u64,
}

impl Sink for NullSink {
    fn alloc(&mut self, _obj: &MemoryObject) {
        self.allocs += 1;
    }

    fn free(&mut self, _obj: &MemoryObject) {}

    fn access(&mut self, _addr: u64, bytes: u32, _write: bool) {
        self.accesses += 1;
        self.bytes += bytes as u64;
    }

    fn compute(&mut self, cycles: u64) {
        self.compute_cycles += cycles;
    }
}

/// Fan a stream out to two sinks (e.g. machine + recorder).
pub struct TeeSink<'a> {
    pub a: &'a mut dyn Sink,
    pub b: &'a mut dyn Sink,
}

impl<'a> Sink for TeeSink<'a> {
    fn alloc(&mut self, obj: &MemoryObject) {
        self.a.alloc(obj);
        self.b.alloc(obj);
    }

    fn free(&mut self, obj: &MemoryObject) {
        self.a.free(obj);
        self.b.free(obj);
    }

    fn access(&mut self, addr: u64, bytes: u32, write: bool) {
        self.a.access(addr, bytes, write);
        self.b.access(addr, bytes, write);
    }

    fn compute(&mut self, cycles: u64) {
        self.a.compute(cycles);
        self.b.compute(cycles);
    }

    fn phase(&mut self, name: &str) {
        self.a.phase(name);
        self.b.phase(name);
    }

    fn lane(&mut self, lane: u8, after_mask: u64) {
        self.a.lane(lane, after_mask);
        self.b.lane(lane, after_mask);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::object::{MemoryObject, ObjectId};

    fn obj() -> MemoryObject {
        MemoryObject {
            id: ObjectId(1),
            start: 0x1000,
            bytes: 4096,
            site: "test".into(),
            seq: 0,
            via_mmap: true,
        }
    }

    #[test]
    fn null_sink_counts() {
        let mut s = NullSink::default();
        s.alloc(&obj());
        s.access(0x1000, 8, false);
        s.access(0x1008, 8, true);
        s.compute(100);
        assert_eq!(s.accesses, 2);
        assert_eq!(s.bytes, 16);
        assert_eq!(s.compute_cycles, 100);
        assert_eq!(s.allocs, 1);
    }

    #[test]
    fn tee_duplicates() {
        let mut a = NullSink::default();
        let mut b = NullSink::default();
        {
            let mut tee = TeeSink { a: &mut a, b: &mut b };
            tee.access(0x10, 4, false);
            tee.compute(7);
            tee.phase("p");
        }
        assert_eq!(a.accesses, 1);
        assert_eq!(b.accesses, 1);
        assert_eq!(a.compute_cycles, 7);
        assert_eq!(b.compute_cycles, 7);
    }
}
