//! The Trace-IR: a compact, versioned representation of one workload's
//! access stream — record the stream once, simulate it everywhere.
//!
//! [`AccessTrace`] holds an interned event stream (alloc / free / access
//! / compute / phase / tick): READ/WRITE events carry absolute addresses
//! in memory and *delta-encoded* addresses in the JSON serialization;
//! ALLOC/FREE/PHASE events index side tables so objects and phase names
//! are stored once. A trace replays into any [`Sink`] — a `NullSink`, a
//! full [`crate::sim::Machine`], a colocation interleaver — and the
//! replay-identity invariant says: *a replayed run produces the exact
//! same `RunReport` and checksum as the live run that recorded it*
//! (property-tested across the workload registry).
//!
//! [`TraceRecorder`] is the recording sink. Its default mode merges
//! consecutive compute events to keep ad-hoc recordings small; the
//! *exact* mode ([`TraceRecorder::exact`]) preserves the live call
//! sequence bit-for-bit, which is what the canonical record-once
//! recordings use so replays accumulate floating-point time in the same
//! order as the live run.
//!
//! Transforms derive new traces without re-executing the workload:
//! [`AccessTrace::truncated`] (quick-mode prefixes),
//! [`AccessTrace::scaled`] (N back-to-back invocations of a warm
//! working set), and [`interleave`] (relocated round-robin merge of
//! colocated tenants).

use crate::shim::object::{MemoryObject, ObjectId};
use crate::trace::Sink;
use crate::util::json::Json;

/// Serialization-format version; [`AccessTrace::from_json`] rejects
/// anything else. v2 added LANE events (lane id + happens-after mask).
pub const TRACE_IR_VERSION: u64 = 2;

pub(crate) const KIND_READ: u8 = 0;
pub(crate) const KIND_WRITE: u8 = 1;
pub(crate) const KIND_COMPUTE: u8 = 2;
pub(crate) const KIND_ALLOC: u8 = 3;
pub(crate) const KIND_FREE: u8 = 4;
pub(crate) const KIND_PHASE: u8 = 5;
pub(crate) const KIND_TICK: u8 = 6;
pub(crate) const KIND_LANE: u8 = 7;

/// One packed event, 16 bytes. For READ/WRITE `a` is the address and
/// `b` the byte count; for COMPUTE `a` is the cycle count; for
/// ALLOC/FREE/PHASE `a` indexes the side tables; TICK carries nothing;
/// for LANE `a` is the happens-after mask and `b` the lane id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackedEvent {
    pub(crate) a: u64,
    pub(crate) b: u32,
    pub(crate) kind: u8,
}

/// Per-phase rollup (merged by phase name, first-appearance order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseSummary {
    pub name: String,
    pub accesses: u64,
    pub bytes: u64,
    pub compute_cycles: u64,
    pub allocs: u64,
    pub frees: u64,
}

/// A recorded access stream: versioned, interned, replayable.
#[derive(Debug, Clone, PartialEq)]
pub struct AccessTrace {
    /// Format version ([`TRACE_IR_VERSION`]).
    pub version: u64,
    /// Registry name of the workload that produced the stream (empty
    /// for ad-hoc recordings).
    pub workload: String,
    /// Page size of the recording environment — replays against a
    /// machine with a different page size would see different mmap
    /// alignment, so the [`crate::trace::TraceStore`] keys on this.
    pub page_bytes: u64,
    /// The workload's result checksum, stored alongside the stream so
    /// replay fidelity stays verifiable without re-executing.
    pub checksum: u64,
    pub events: Vec<PackedEvent>,
    /// Interned object side table, in allocation order (= the shim's
    /// allocation log).
    pub objects: Vec<MemoryObject>,
    /// Interned phase-name side table.
    pub phases: Vec<String>,
}

impl Default for AccessTrace {
    fn default() -> Self {
        AccessTrace {
            version: TRACE_IR_VERSION,
            workload: String::new(),
            page_bytes: 0,
            checksum: 0,
            events: Vec::new(),
            objects: Vec::new(),
            phases: Vec::new(),
        }
    }
}

impl AccessTrace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    // ---- builder API (what the recorder and the transforms use; also
    // ---- public so property tests can generate arbitrary streams) ----

    pub fn push_access(&mut self, addr: u64, bytes: u32, write: bool) {
        let kind = if write { KIND_WRITE } else { KIND_READ };
        self.events.push(PackedEvent { a: addr, b: bytes, kind });
    }

    pub fn push_compute(&mut self, cycles: u64) {
        self.events.push(PackedEvent { a: cycles, b: 0, kind: KIND_COMPUTE });
    }

    /// Intern `obj` into the side table and push its ALLOC event.
    pub fn push_alloc(&mut self, obj: &MemoryObject) {
        let idx = self.objects.len() as u64;
        self.objects.push(obj.clone());
        self.events.push(PackedEvent { a: idx, b: 0, kind: KIND_ALLOC });
    }

    /// Push a FREE for an object previously interned by [`push_alloc`];
    /// unknown objects are ignored (frees of untracked state).
    ///
    /// [`push_alloc`]: AccessTrace::push_alloc
    pub fn push_free(&mut self, obj: &MemoryObject) {
        if let Some(idx) = self.objects.iter().position(|o| o.id == obj.id) {
            self.push_free_idx(idx as u64);
        }
    }

    pub(crate) fn push_free_idx(&mut self, idx: u64) {
        self.events.push(PackedEvent { a: idx, b: 0, kind: KIND_FREE });
    }

    /// Intern the phase name (deduplicated) and push a PHASE marker.
    pub fn push_phase(&mut self, name: &str) {
        let idx = match self.phases.iter().position(|p| p == name) {
            Some(i) => i as u64,
            None => {
                self.phases.push(name.to_string());
                (self.phases.len() - 1) as u64
            }
        };
        self.events.push(PackedEvent { a: idx, b: 0, kind: KIND_PHASE });
    }

    /// Aggregation-tick marker. Plain sinks ignore it on replay (the
    /// machine ticks itself off its virtual clock); it exists so
    /// observer-driven replays and future consumers can carry the
    /// recording cadence through the serialization round-trip.
    pub fn push_tick(&mut self) {
        self.events.push(PackedEvent { a: 0, b: 0, kind: KIND_TICK });
    }

    /// Lane annotation (v2): subsequent events run on `lane`, after the
    /// lanes in `after_mask`. Sinks without a lane model replay it as a
    /// no-op, so v2 traces stay replay-identical on the scalar clock.
    /// Masks must stay under 2^53 (the f64-backed JSON codec) — lane
    /// ids are capped at 64 well before that matters.
    pub fn push_lane(&mut self, lane: u8, after_mask: u64) {
        self.events.push(PackedEvent { a: after_mask, b: lane as u32, kind: KIND_LANE });
    }

    // ---- replay ----

    /// Replay the whole recording into a sink.
    pub fn replay(&self, sink: &mut dyn Sink) {
        self.replay_range(sink, 0, self.events.len());
    }

    /// Replay a half-open event range — the colocation interleaver uses
    /// this to alternate chunks from multiple recordings.
    pub fn replay_range(&self, sink: &mut dyn Sink, start: usize, end: usize) {
        self.replay_range_relocated(sink, start, end, 0);
    }

    /// Replay with all addresses shifted by `offset` bytes. Colocated
    /// tenants are separate processes whose identical virtual layouts
    /// map to distinct physical pages; relocation reproduces that
    /// distinction on the shared machine. `offset` must be
    /// page-aligned.
    pub fn replay_range_relocated(
        &self,
        sink: &mut dyn Sink,
        start: usize,
        end: usize,
        offset: u64,
    ) {
        for e in &self.events[start..end.min(self.events.len())] {
            match e.kind {
                KIND_READ => sink.access(e.a + offset, e.b, false),
                KIND_WRITE => sink.access(e.a + offset, e.b, true),
                KIND_COMPUTE => sink.compute(e.a),
                KIND_ALLOC | KIND_FREE => {
                    let mut obj = self.objects[e.a as usize].clone();
                    obj.start += offset;
                    if e.kind == KIND_ALLOC {
                        sink.alloc(&obj);
                    } else {
                        sink.free(&obj);
                    }
                }
                KIND_PHASE => sink.phase(&self.phases[e.a as usize]),
                KIND_TICK => {}
                KIND_LANE => sink.lane(e.b as u8, e.a),
                _ => unreachable!(),
            }
        }
    }

    // ---- stream statistics ----

    pub fn n_accesses(&self) -> u64 {
        self.events.iter().filter(|e| e.kind == KIND_READ || e.kind == KIND_WRITE).count() as u64
    }

    /// Total bytes touched by accesses.
    pub fn bytes_accessed(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind == KIND_READ || e.kind == KIND_WRITE)
            .map(|e| e.b as u64)
            .sum()
    }

    /// Total compute cycles.
    pub fn compute_cycles(&self) -> u64 {
        self.events.iter().filter(|e| e.kind == KIND_COMPUTE).map(|e| e.a).sum()
    }

    /// Largest within-segment extent (bytes above the heap or mmap base)
    /// touched by any access or object. A relocation offset larger than
    /// this cannot collide with another tenant's pages, while keeping
    /// both segments' page tables compact.
    pub fn footprint_extent(&self) -> u64 {
        use crate::shim::intercept::{HEAP_BASE, MMAP_BASE};
        let seg_extent = |addr: u64| {
            if addr >= MMAP_BASE {
                addr - MMAP_BASE
            } else {
                addr.saturating_sub(HEAP_BASE)
            }
        };
        let a = self
            .events
            .iter()
            .filter(|e| e.kind == KIND_READ || e.kind == KIND_WRITE)
            .map(|e| seg_extent(e.a + e.b as u64))
            .max()
            .unwrap_or(0);
        let o = self.objects.iter().map(|o| seg_extent(o.end())).max().unwrap_or(0);
        a.max(o)
    }

    /// In-memory size estimate: what the `trace.bytes` metric reports.
    pub fn encoded_bytes(&self) -> u64 {
        let events = self.events.len() as u64 * std::mem::size_of::<PackedEvent>() as u64;
        let objects: u64 = self.objects.iter().map(|o| 40 + o.site.len() as u64).sum();
        let phases: u64 = self.phases.iter().map(|p| p.len() as u64).sum();
        events + objects + phases
    }

    /// Per-phase rollups, merged by name in first-appearance order.
    /// Events before the first PHASE marker aggregate under `"(pre)"`.
    /// Phase names are interned, so buckets index the phase table
    /// directly — no per-event allocation on multi-million-event
    /// traces.
    pub fn phase_summaries(&self) -> Vec<PhaseSummary> {
        // slot 0 = "(pre)"; slot i+1 = self.phases[i]
        let mut sums: Vec<Option<PhaseSummary>> = vec![None; self.phases.len() + 1];
        let mut order: Vec<usize> = Vec::new();
        let mut cur = 0usize;
        for e in &self.events {
            if e.kind == KIND_PHASE {
                cur = e.a as usize + 1;
            }
            let slot = &mut sums[cur];
            if slot.is_none() {
                order.push(cur);
                let name =
                    if cur == 0 { "(pre)".to_string() } else { self.phases[cur - 1].clone() };
                *slot = Some(PhaseSummary {
                    name,
                    accesses: 0,
                    bytes: 0,
                    compute_cycles: 0,
                    allocs: 0,
                    frees: 0,
                });
            }
            let s = slot.as_mut().expect("initialized above");
            match e.kind {
                KIND_READ | KIND_WRITE => {
                    s.accesses += 1;
                    s.bytes += e.b as u64;
                }
                KIND_COMPUTE => s.compute_cycles += e.a,
                KIND_ALLOC => s.allocs += 1,
                KIND_FREE => s.frees += 1,
                _ => {}
            }
        }
        order.into_iter().map(|i| sums[i].take().expect("aggregated")).collect()
    }

    // ---- transforms ----

    /// Prefix of the stream: the quick-mode transform. The object and
    /// phase tables are carried whole, so later FREE/PHASE indices stay
    /// valid; accesses whose ALLOC got cut replay as untracked
    /// first-touch addresses, exactly like live workload bookkeeping
    /// outside the shim.
    pub fn truncated(&self, max_events: usize) -> AccessTrace {
        let mut out = self.clone();
        out.events.truncate(max_events);
        out
    }

    /// The stream repeated `rounds` times back-to-back: one cold round
    /// followed by warm rounds that skip ALLOC/FREE (the working set is
    /// already mapped — re-mapping would double-count tier residency).
    /// Models N invocations replaying against a kept sandbox.
    pub fn scaled(&self, rounds: u32) -> AccessTrace {
        let mut out = self.clone();
        for _ in 1..rounds {
            for e in &self.events {
                if e.kind != KIND_ALLOC && e.kind != KIND_FREE {
                    out.events.push(*e);
                }
            }
        }
        out
    }

    // ---- serialization ----

    /// Serialize to the versioned JSON form. READ/WRITE addresses are
    /// delta-encoded against the previous access (signed, zigzag-free —
    /// JSON numbers carry the sign); all magnitudes stay under 2^53 so
    /// the f64-backed codec is exact.
    pub fn to_json(&self) -> Json {
        let mut events = Vec::with_capacity(self.events.len());
        let mut prev: i64 = 0;
        for e in &self.events {
            let ev = match e.kind {
                KIND_READ | KIND_WRITE => {
                    let addr = e.a as i64;
                    let delta = addr - prev;
                    prev = addr;
                    Json::arr([
                        Json::num(e.kind as f64),
                        Json::num(delta as f64),
                        Json::num(e.b as f64),
                    ])
                }
                KIND_TICK => Json::arr([Json::num(e.kind as f64)]),
                KIND_LANE => Json::arr([
                    Json::num(e.kind as f64),
                    Json::num(e.b as f64),
                    Json::num(e.a as f64),
                ]),
                _ => Json::arr([Json::num(e.kind as f64), Json::num(e.a as f64)]),
            };
            events.push(ev);
        }
        let objects = self.objects.iter().map(|o| {
            Json::obj(vec![
                ("id", Json::num(o.id.0 as f64)),
                ("start", Json::num(o.start as f64)),
                ("bytes", Json::num(o.bytes as f64)),
                ("site", Json::str(o.site.clone())),
                ("seq", Json::num(o.seq as f64)),
                ("via_mmap", Json::Bool(o.via_mmap)),
            ])
        });
        Json::obj(vec![
            ("version", Json::num(self.version as f64)),
            ("workload", Json::str(self.workload.clone())),
            ("page_bytes", Json::num(self.page_bytes as f64)),
            ("checksum", Json::str(format!("{:#018x}", self.checksum))),
            ("objects", Json::arr(objects)),
            ("phases", Json::arr(self.phases.iter().map(|p| Json::str(p.clone())))),
            ("events", Json::Arr(events)),
        ])
    }

    /// Parse the JSON form back; rejects unknown versions and malformed
    /// streams.
    pub fn from_json(j: &Json) -> Result<AccessTrace, String> {
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| "trace: missing version".to_string())?;
        if version != TRACE_IR_VERSION {
            return Err(format!(
                "trace: unsupported IR version {version} (this build reads {TRACE_IR_VERSION})"
            ));
        }
        let workload =
            j.get("workload").and_then(Json::as_str).unwrap_or_default().to_string();
        let page_bytes = j.get("page_bytes").and_then(Json::as_u64).unwrap_or(0);
        let checksum_text = j
            .get("checksum")
            .and_then(Json::as_str)
            .ok_or_else(|| "trace: missing checksum".to_string())?;
        let checksum = u64::from_str_radix(
            checksum_text.strip_prefix("0x").unwrap_or(checksum_text),
            16,
        )
        .map_err(|_| format!("trace: bad checksum {checksum_text:?}"))?;
        let mut objects = Vec::new();
        for (i, o) in j
            .get("objects")
            .and_then(Json::as_arr)
            .ok_or_else(|| "trace: missing objects".to_string())?
            .iter()
            .enumerate()
        {
            let field_u64 = |k: &str| {
                o.get(k).and_then(Json::as_u64).ok_or_else(|| format!("trace: objects[{i}].{k}"))
            };
            objects.push(MemoryObject {
                id: ObjectId(field_u64("id")? as u32),
                start: field_u64("start")?,
                bytes: field_u64("bytes")?,
                site: o
                    .get("site")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("trace: objects[{i}].site"))?
                    .to_string(),
                seq: field_u64("seq")?,
                via_mmap: o
                    .get("via_mmap")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| format!("trace: objects[{i}].via_mmap"))?,
            });
        }
        let phases: Vec<String> = j
            .get("phases")
            .and_then(Json::as_arr)
            .ok_or_else(|| "trace: missing phases".to_string())?
            .iter()
            .filter_map(|p| p.as_str().map(str::to_string))
            .collect();
        let raw_events = j
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| "trace: missing events".to_string())?;
        let mut events = Vec::with_capacity(raw_events.len());
        let mut prev: i64 = 0;
        for (i, ev) in raw_events.iter().enumerate() {
            let parts =
                ev.as_arr().ok_or_else(|| format!("trace: events[{i}] is not an array"))?;
            let kind = parts
                .first()
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("trace: events[{i}] missing kind"))? as u8;
            let num_at = |k: usize| {
                parts
                    .get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("trace: events[{i}] missing field {k}"))
            };
            let e = match kind {
                KIND_READ | KIND_WRITE => {
                    let addr = prev + num_at(1)? as i64;
                    if addr < 0 {
                        return Err(format!("trace: events[{i}] delta underflows"));
                    }
                    prev = addr;
                    PackedEvent { a: addr as u64, b: num_at(2)? as u32, kind }
                }
                KIND_COMPUTE => PackedEvent { a: num_at(1)? as u64, b: 0, kind },
                KIND_ALLOC | KIND_FREE => {
                    let idx = num_at(1)? as u64;
                    if idx as usize >= objects.len() {
                        return Err(format!("trace: events[{i}] object index out of range"));
                    }
                    PackedEvent { a: idx, b: 0, kind }
                }
                KIND_PHASE => {
                    let idx = num_at(1)? as u64;
                    if idx as usize >= phases.len() {
                        return Err(format!("trace: events[{i}] phase index out of range"));
                    }
                    PackedEvent { a: idx, b: 0, kind }
                }
                KIND_TICK => PackedEvent { a: 0, b: 0, kind },
                KIND_LANE => {
                    PackedEvent { a: num_at(2)? as u64, b: num_at(1)? as u32, kind }
                }
                other => return Err(format!("trace: events[{i}] unknown kind {other}")),
            };
            events.push(e);
        }
        Ok(AccessTrace { version, workload, page_bytes, checksum, events, objects, phases })
    }
}

/// Relocation stride for running `traces` as separate tenants on one
/// machine: past the largest footprint, page-aligned, plus one guard
/// page.
pub fn relocation_stride(traces: &[&AccessTrace], page_bytes: u64) -> u64 {
    traces
        .iter()
        .map(|t| t.footprint_extent())
        .max()
        .unwrap_or(0)
        .next_multiple_of(page_bytes.max(1))
        + page_bytes
}

/// Merge colocated tenants into one relocated round-robin stream of
/// `chunk` events per turn: tenant `i`'s addresses shift by
/// `i × stride`, its objects are re-interned under fresh ids, and its
/// phase markers gain a `t{i}/` prefix. The merged trace replays
/// through a single machine, reproducing shared-LLC and shared-tier
/// contention without per-tenant clock bookkeeping (use
/// [`crate::sim::colocate`] when per-tenant slowdowns are the metric).
pub fn interleave(traces: &[&AccessTrace], chunk: usize, page_bytes: u64) -> AccessTrace {
    assert!(!traces.is_empty(), "interleave of zero traces");
    assert!(chunk > 0, "interleave chunk must be >= 1");
    let stride = relocation_stride(traces, page_bytes);
    let mut out = AccessTrace {
        workload: traces
            .iter()
            .map(|t| if t.workload.is_empty() { "?" } else { t.workload.as_str() })
            .collect::<Vec<_>>()
            .join("+"),
        page_bytes,
        ..AccessTrace::default()
    };
    // per-tenant map: original object index → merged object index
    let mut obj_map: Vec<std::collections::HashMap<u64, u64>> =
        vec![std::collections::HashMap::new(); traces.len()];
    let mut cursors = vec![0usize; traces.len()];
    // only tenants with events count toward completion — an empty
    // trace is already done (it would otherwise never decrement)
    let mut remaining = traces.iter().filter(|t| !t.events.is_empty()).count();
    while remaining > 0 {
        for (i, t) in traces.iter().enumerate() {
            if cursors[i] >= t.events.len() {
                continue;
            }
            let offset = i as u64 * stride;
            let end = (cursors[i] + chunk).min(t.events.len());
            for e in &t.events[cursors[i]..end] {
                match e.kind {
                    KIND_READ | KIND_WRITE => {
                        out.push_access(e.a + offset, e.b, e.kind == KIND_WRITE);
                    }
                    KIND_COMPUTE => out.push_compute(e.a),
                    KIND_ALLOC => {
                        let mut obj = t.objects[e.a as usize].clone();
                        obj.start += offset;
                        obj.id = ObjectId(out.objects.len() as u32);
                        obj_map[i].insert(e.a, out.objects.len() as u64);
                        out.push_alloc(&obj);
                    }
                    KIND_FREE => {
                        if let Some(&idx) = obj_map[i].get(&e.a) {
                            out.push_free_idx(idx);
                        }
                    }
                    KIND_PHASE => {
                        out.push_phase(&format!("t{i}/{}", t.phases[e.a as usize]));
                    }
                    KIND_TICK => out.push_tick(),
                    KIND_LANE => out.push_lane(e.b as u8, e.a),
                    _ => unreachable!(),
                }
            }
            cursors[i] = end;
            if cursors[i] >= t.events.len() {
                remaining -= 1;
            }
        }
    }
    out
}

/// Sink that records the stream into an [`AccessTrace`].
#[derive(Debug)]
pub struct TraceRecorder {
    trace: AccessTrace,
    /// Merge consecutive compute events to keep recordings small.
    pending_compute: u64,
    merge_compute: bool,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

impl TraceRecorder {
    /// Compact recorder: consecutive compute events merge.
    pub fn new() -> TraceRecorder {
        TraceRecorder { trace: AccessTrace::default(), pending_compute: 0, merge_compute: true }
    }

    /// Exact recorder: the event stream mirrors the live Sink call
    /// sequence one-for-one, so a replay performs the identical f64
    /// clock arithmetic — required for the replay-identity invariant.
    pub fn exact() -> TraceRecorder {
        TraceRecorder { trace: AccessTrace::default(), pending_compute: 0, merge_compute: false }
    }

    fn flush_compute(&mut self) {
        if self.pending_compute > 0 {
            self.trace.push_compute(self.pending_compute);
            self.pending_compute = 0;
        }
    }

    pub fn finish(mut self) -> AccessTrace {
        self.flush_compute();
        self.trace
    }
}

impl Sink for TraceRecorder {
    fn alloc(&mut self, obj: &MemoryObject) {
        self.flush_compute();
        self.trace.push_alloc(obj);
    }

    fn free(&mut self, obj: &MemoryObject) {
        self.flush_compute();
        // frees are rare relative to accesses; the id lookup is linear
        self.trace.push_free(obj);
    }

    fn access(&mut self, addr: u64, bytes: u32, write: bool) {
        self.flush_compute();
        self.trace.push_access(addr, bytes, write);
    }

    fn compute(&mut self, cycles: u64) {
        if self.merge_compute {
            self.pending_compute += cycles;
        } else {
            self.trace.push_compute(cycles);
        }
    }

    fn phase(&mut self, name: &str) {
        self.flush_compute();
        self.trace.push_phase(name);
    }

    fn lane(&mut self, lane: u8, after_mask: u64) {
        self.flush_compute();
        self.trace.push_lane(lane, after_mask);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    fn obj(id: u32) -> MemoryObject {
        MemoryObject {
            id: ObjectId(id),
            start: 0x7f00_0000_0000 + 0x1000 * id as u64,
            bytes: 4096,
            site: format!("site{id}"),
            seq: id as u64,
            via_mmap: true,
        }
    }

    fn sample() -> AccessTrace {
        let mut t =
            AccessTrace { workload: "sample".into(), page_bytes: 4096, ..Default::default() };
        t.push_alloc(&obj(0));
        t.push_phase("build");
        t.push_access(0x7f00_0000_0000, 8, false);
        t.push_compute(40);
        t.push_access(0x7f00_0000_0010, 8, true);
        t.push_tick();
        t.push_phase("iterate");
        t.push_access(0x7f00_0000_0008, 16, false);
        t.push_free(&obj(0));
        t.checksum = 0xDEAD_BEEF_F00D_CAFE;
        t
    }

    #[test]
    fn json_roundtrip_exact() {
        let t = sample();
        let back = AccessTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        // pretty form parses identically too
        let pretty = Json::parse(&t.to_json().to_string_pretty()).unwrap();
        assert_eq!(AccessTrace::from_json(&pretty).unwrap(), t);
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut j = sample().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::num(99.0));
        }
        let err = AccessTrace::from_json(&j).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn phase_summaries_merge_by_name() {
        let mut t = AccessTrace::default();
        t.push_access(0x10, 4, false); // (pre)
        t.push_phase("a");
        t.push_access(0x20, 8, false);
        t.push_compute(5);
        t.push_phase("b");
        t.push_compute(7);
        t.push_phase("a"); // re-entered: merges with the first "a"
        t.push_access(0x30, 2, true);
        let s = t.phase_summaries();
        assert_eq!(
            s.iter().map(|p| p.name.as_str()).collect::<Vec<_>>(),
            vec!["(pre)", "a", "b"]
        );
        assert_eq!(s[1].accesses, 2);
        assert_eq!(s[1].bytes, 10);
        assert_eq!(s[1].compute_cycles, 5);
        assert_eq!(s[2].compute_cycles, 7);
    }

    #[test]
    fn truncate_and_scale() {
        let t = sample();
        let cut = t.truncated(3);
        assert_eq!(cut.events.len(), 3);
        assert_eq!(cut.objects.len(), t.objects.len(), "side tables carried whole");
        let tripled = t.scaled(3);
        // warm rounds drop the 1 alloc + 1 free
        assert_eq!(tripled.events.len(), t.events.len() * 3 - 2 * 2);
        assert_eq!(tripled.n_accesses(), t.n_accesses() * 3);
        assert_eq!(tripled.compute_cycles(), t.compute_cycles() * 3);
        // scaling by 1 is the identity
        assert_eq!(t.scaled(1), t);
    }

    #[test]
    fn interleave_relocates_and_remaps() {
        let mut a = AccessTrace { workload: "a".into(), ..Default::default() };
        a.push_alloc(&obj(0));
        a.push_access(0x7f00_0000_0000, 8, false);
        a.push_phase("p");
        a.push_free(&obj(0));
        let mut b = AccessTrace { workload: "b".into(), ..Default::default() };
        b.push_alloc(&obj(0));
        b.push_access(0x7f00_0000_0040, 8, true);
        let merged = interleave(&[&a, &b], 2, 4096);
        assert_eq!(merged.workload, "a+b");
        assert_eq!(merged.objects.len(), 2);
        assert_ne!(merged.objects[0].id, merged.objects[1].id, "ids re-interned");
        assert_ne!(
            merged.objects[0].start, merged.objects[1].start,
            "tenants relocated apart"
        );
        assert_eq!(merged.n_accesses(), 2);
        assert_eq!(merged.phases, vec!["t0/p".to_string()]);
        let mut sink = NullSink::default();
        merged.replay(&mut sink);
        assert_eq!(sink.accesses, 2);
        assert_eq!(sink.allocs, 2);
    }

    #[test]
    fn interleave_tolerates_empty_tenants() {
        let mut a = AccessTrace::default();
        a.push_access(0x10, 4, false);
        let empty = AccessTrace::default();
        // an event-less tenant must not hang the round-robin
        let merged = interleave(&[&a, &empty], 4, 4096);
        assert_eq!(merged.n_accesses(), 1);
    }

    #[test]
    fn exact_recorder_preserves_compute_sequence() {
        let mut rec = TraceRecorder::exact();
        rec.compute(10);
        rec.compute(20);
        rec.access(0x10, 4, false);
        let t = rec.finish();
        assert_eq!(t.events.len(), 3, "exact mode must not merge computes");
        assert_eq!(t.compute_cycles(), 30);
    }

    #[test]
    fn lane_survives_roundtrip_and_replays_as_noop() {
        let mut t = AccessTrace::default();
        t.push_lane(3, 0b1011);
        t.push_access(0x10, 4, false);
        t.push_lane(0, 0);
        let back = AccessTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        // sinks without a lane model (NullSink) replay it as a no-op
        let mut sink = NullSink::default();
        back.replay(&mut sink);
        assert_eq!(sink.accesses, 1);
        // the exact recorder re-captures the annotation
        let mut rec = TraceRecorder::exact();
        back.replay(&mut rec);
        let again = rec.finish();
        assert_eq!(again.events, t.events);
    }

    #[test]
    fn tick_survives_roundtrip_and_replays_as_noop() {
        let mut t = AccessTrace::default();
        t.push_tick();
        t.push_access(0x10, 4, false);
        let back = AccessTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        let mut sink = NullSink::default();
        back.replay(&mut sink);
        assert_eq!(sink.accesses, 1);
    }
}
