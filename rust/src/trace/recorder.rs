//! Compact trace recording and replay.
//!
//! Events are packed 16 bytes each; a few-million-event workload instance
//! records in tens of MB, which is what the colocation experiments and
//! offline heatmap processing use.

use crate::shim::object::MemoryObject;
use crate::trace::Sink;

const KIND_READ: u8 = 0;
const KIND_WRITE: u8 = 1;
const KIND_COMPUTE: u8 = 2;
const KIND_ALLOC: u8 = 3;
const KIND_FREE: u8 = 4;
const KIND_PHASE: u8 = 5;

/// One packed event. For READ/WRITE `a` is the address and `b` the byte
/// count; for COMPUTE `a` is the cycle count; for ALLOC/FREE/PHASE `a`
/// indexes the side tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackedEvent {
    a: u64,
    b: u32,
    kind: u8,
}

/// A finished recording: events plus object/phase side tables.
#[derive(Debug, Clone, Default)]
pub struct RecordedTrace {
    pub events: Vec<PackedEvent>,
    pub objects: Vec<MemoryObject>,
    pub phases: Vec<String>,
}

impl RecordedTrace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn n_accesses(&self) -> u64 {
        self.events.iter().filter(|e| e.kind == KIND_READ || e.kind == KIND_WRITE).count() as u64
    }

    /// Replay the recording into another sink.
    pub fn replay(&self, sink: &mut dyn Sink) {
        self.replay_range(sink, 0, self.events.len());
    }

    /// Replay a half-open event range — the colocation interleaver uses
    /// this to alternate chunks from multiple recordings.
    pub fn replay_range(&self, sink: &mut dyn Sink, start: usize, end: usize) {
        self.replay_range_relocated(sink, start, end, 0);
    }

    /// Replay with all addresses shifted by `offset` bytes. Colocated
    /// tenants are separate processes whose identical virtual layouts map
    /// to distinct physical pages; relocation reproduces that distinction
    /// on the shared machine. `offset` must be page-aligned.
    pub fn replay_range_relocated(
        &self,
        sink: &mut dyn Sink,
        start: usize,
        end: usize,
        offset: u64,
    ) {
        for e in &self.events[start..end.min(self.events.len())] {
            match e.kind {
                KIND_READ => sink.access(e.a + offset, e.b, false),
                KIND_WRITE => sink.access(e.a + offset, e.b, true),
                KIND_COMPUTE => sink.compute(e.a),
                KIND_ALLOC | KIND_FREE => {
                    let mut obj = self.objects[e.a as usize].clone();
                    obj.start += offset;
                    if e.kind == KIND_ALLOC {
                        sink.alloc(&obj);
                    } else {
                        sink.free(&obj);
                    }
                }
                KIND_PHASE => sink.phase(&self.phases[e.a as usize]),
                _ => unreachable!(),
            }
        }
    }

    /// Largest within-segment extent (bytes above the heap or mmap base)
    /// touched by any access or object. A relocation offset larger than
    /// this cannot collide with another tenant's pages, while keeping
    /// both segments' page tables compact.
    pub fn footprint_extent(&self) -> u64 {
        use crate::shim::intercept::{HEAP_BASE, MMAP_BASE};
        let seg_extent = |addr: u64| {
            if addr >= MMAP_BASE {
                addr - MMAP_BASE
            } else {
                addr.saturating_sub(HEAP_BASE)
            }
        };
        let a = self
            .events
            .iter()
            .filter(|e| e.kind == KIND_READ || e.kind == KIND_WRITE)
            .map(|e| seg_extent(e.a + e.b as u64))
            .max()
            .unwrap_or(0);
        let o = self.objects.iter().map(|o| seg_extent(o.end())).max().unwrap_or(0);
        a.max(o)
    }

    /// Total bytes touched by accesses.
    pub fn bytes_accessed(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind == KIND_READ || e.kind == KIND_WRITE)
            .map(|e| e.b as u64)
            .sum()
    }

    /// Total compute cycles.
    pub fn compute_cycles(&self) -> u64 {
        self.events.iter().filter(|e| e.kind == KIND_COMPUTE).map(|e| e.a).sum()
    }
}

/// Sink that records the stream.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    trace: RecordedTrace,
    /// Merge consecutive compute events to keep recordings small.
    pending_compute: u64,
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::default()
    }

    fn flush_compute(&mut self) {
        if self.pending_compute > 0 {
            let ev = PackedEvent { a: self.pending_compute, b: 0, kind: KIND_COMPUTE };
            self.trace.events.push(ev);
            self.pending_compute = 0;
        }
    }

    pub fn finish(mut self) -> RecordedTrace {
        self.flush_compute();
        self.trace
    }
}

impl Sink for TraceRecorder {
    fn alloc(&mut self, obj: &MemoryObject) {
        self.flush_compute();
        let idx = self.trace.objects.len() as u64;
        self.trace.objects.push(obj.clone());
        self.trace.events.push(PackedEvent { a: idx, b: 0, kind: KIND_ALLOC });
    }

    fn free(&mut self, obj: &MemoryObject) {
        self.flush_compute();
        // find by id in the side table (frees are rare relative to accesses)
        if let Some(idx) = self.trace.objects.iter().position(|o| o.id == obj.id) {
            self.trace.events.push(PackedEvent { a: idx as u64, b: 0, kind: KIND_FREE });
        }
    }

    fn access(&mut self, addr: u64, bytes: u32, write: bool) {
        self.flush_compute();
        self.trace.events.push(PackedEvent {
            a: addr,
            b: bytes,
            kind: if write { KIND_WRITE } else { KIND_READ },
        });
    }

    fn compute(&mut self, cycles: u64) {
        self.pending_compute += cycles;
    }

    fn phase(&mut self, name: &str) {
        self.flush_compute();
        let idx = self.trace.phases.len() as u64;
        self.trace.phases.push(name.to_string());
        self.trace.events.push(PackedEvent { a: idx, b: 0, kind: KIND_PHASE });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::object::{MemoryObject, ObjectId};
    use crate::trace::NullSink;

    fn obj(id: u32) -> MemoryObject {
        MemoryObject {
            id: ObjectId(id),
            start: 0x1000 * id as u64,
            bytes: 4096,
            site: format!("site{id}"),
            seq: id as u64,
            via_mmap: true,
        }
    }

    #[test]
    fn record_replay_roundtrip() {
        let mut rec = TraceRecorder::new();
        rec.alloc(&obj(1));
        rec.access(0x1000, 8, false);
        rec.compute(10);
        rec.compute(20); // merged
        rec.access(0x1008, 4, true);
        rec.phase("iterate");
        rec.free(&obj(1));
        let trace = rec.finish();
        assert_eq!(trace.n_accesses(), 2);
        assert_eq!(trace.compute_cycles(), 30);
        assert_eq!(trace.bytes_accessed(), 12);

        let mut sink = NullSink::default();
        trace.replay(&mut sink);
        assert_eq!(sink.accesses, 2);
        assert_eq!(sink.bytes, 12);
        assert_eq!(sink.compute_cycles, 30);
        assert_eq!(sink.allocs, 1);
    }

    #[test]
    fn compute_merging_compacts() {
        let mut rec = TraceRecorder::new();
        for _ in 0..1000 {
            rec.compute(1);
        }
        rec.access(0x10, 1, false);
        let trace = rec.finish();
        assert_eq!(trace.events.len(), 2); // one merged compute + one access
        assert_eq!(trace.compute_cycles(), 1000);
    }

    #[test]
    fn replay_range_partial() {
        let mut rec = TraceRecorder::new();
        for i in 0..10 {
            rec.access(i * 64, 8, false);
        }
        let trace = rec.finish();
        let mut sink = NullSink::default();
        trace.replay_range(&mut sink, 2, 5);
        assert_eq!(sink.accesses, 3);
    }
}
