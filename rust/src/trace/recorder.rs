//! Compatibility surface for the pre-IR trace API.
//!
//! The recorder and its packed-event storage were folded into
//! [`crate::trace::ir`] when the Trace-IR landed: there is exactly one
//! trace representation in the tree, [`AccessTrace`], and
//! [`TraceRecorder`] is the Sink that builds it. `RecordedTrace` is the
//! old name, kept as an alias so existing call sites (colocation,
//! benches, property tests) read unchanged. The replay-fidelity tests
//! below predate the IR and pin its behaviour.

pub use crate::trace::ir::{AccessTrace, TraceRecorder};

/// The pre-IR name for a finished recording.
pub type RecordedTrace = AccessTrace;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::object::{MemoryObject, ObjectId};
    use crate::trace::{NullSink, Sink};

    fn obj(id: u32) -> MemoryObject {
        MemoryObject {
            id: ObjectId(id),
            start: 0x1000 * id as u64,
            bytes: 4096,
            site: format!("site{id}"),
            seq: id as u64,
            via_mmap: true,
        }
    }

    #[test]
    fn record_replay_roundtrip() {
        let mut rec = TraceRecorder::new();
        rec.alloc(&obj(1));
        rec.access(0x1000, 8, false);
        rec.compute(10);
        rec.compute(20); // merged
        rec.access(0x1008, 4, true);
        rec.phase("iterate");
        rec.free(&obj(1));
        let trace = rec.finish();
        assert_eq!(trace.n_accesses(), 2);
        assert_eq!(trace.compute_cycles(), 30);
        assert_eq!(trace.bytes_accessed(), 12);

        let mut sink = NullSink::default();
        trace.replay(&mut sink);
        assert_eq!(sink.accesses, 2);
        assert_eq!(sink.bytes, 12);
        assert_eq!(sink.compute_cycles, 30);
        assert_eq!(sink.allocs, 1);
    }

    #[test]
    fn compute_merging_compacts() {
        let mut rec = TraceRecorder::new();
        for _ in 0..1000 {
            rec.compute(1);
        }
        rec.access(0x10, 1, false);
        let trace = rec.finish();
        assert_eq!(trace.events.len(), 2); // one merged compute + one access
        assert_eq!(trace.compute_cycles(), 1000);
    }

    #[test]
    fn replay_range_partial() {
        let mut rec = TraceRecorder::new();
        for i in 0..10 {
            rec.access(i * 64, 8, false);
        }
        let trace = rec.finish();
        let mut sink = NullSink::default();
        trace.replay_range(&mut sink, 2, 5);
        assert_eq!(sink.accesses, 3);
    }
}
