//! Process-wide record-once/replay-many registry of canonical traces.
//!
//! The paper's methodology is *observe the access pattern once, then
//! re-evaluate placements against it*. The [`TraceStore`] is that shape
//! as infrastructure: the first execution of a `(workload, size)` pair
//! records its [`AccessTrace`] (usually for free, teed off the live run
//! by [`crate::shim::Env`]'s recording mode); every later invocation —
//! repeat servings, other nodes' profile runs, bench sweep cells —
//! replays the stored stream instead of re-executing the algorithm.
//!
//! Keys are `(workload name, trace fingerprint, page size)`:
//! [`crate::workloads::Workload::trace_fingerprint`] folds every
//! stream-shaping parameter of the instance, so two instances share a
//! trace only when their access streams are provably identical; the
//! page size is included because mmap alignment (and therefore
//! addresses) depends on it.
//!
//! The store is process-global ([`TraceStore::global`]) — in the fleet
//! simulation that is exactly the win: node B's profile run of a
//! function node A already measured replays A's trace. The
//! `[trace] live_execution = true` config escape hatch bypasses the
//! store entirely and restores legacy re-execution.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::placement::provision::DemandCurve;
use crate::trace::ir::AccessTrace;
use crate::trace::NullSink;
use crate::workloads::Workload;

/// Identity of a canonical recording.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TraceKey {
    pub workload: String,
    pub fingerprint: u64,
    pub page_bytes: u64,
}

impl TraceKey {
    pub fn of(body: &dyn Workload, page_bytes: u64) -> TraceKey {
        TraceKey {
            workload: body.name().to_string(),
            fingerprint: body.trace_fingerprint(),
            page_bytes,
        }
    }
}

/// Store-level counters (also mirrored into the per-server metrics
/// `Registry` by the serving path).
#[derive(Debug, Default)]
pub struct TraceStoreMetrics {
    /// Recording runs performed (cumulative work — racing workers that
    /// both record the same key each count one).
    pub records: AtomicU64,
    /// Replays served from the store.
    pub replays: AtomicU64,
    /// In-memory bytes of the recordings currently retained (only
    /// traces the store actually kept count here; bounded-out and
    /// duplicate recordings do not).
    pub bytes: AtomicU64,
    /// Demand curves built from what-if ladder replays
    /// (`placement::provision`) and memo hits served without replaying.
    pub curve_builds: AtomicU64,
    pub curve_hits: AtomicU64,
}

/// The registry. Cheap to query (one mutex around a hash map; traces
/// are `Arc`-shared out so replays never hold the lock).
#[derive(Debug, Default)]
pub struct TraceStore {
    traces: Mutex<HashMap<TraceKey, Arc<AccessTrace>>>,
    /// Memoized latency-vs-DRAM curves, keyed by the trace key plus the
    /// machine/ladder fingerprint
    /// ([`crate::placement::provision::curve_fingerprint`]) so a config
    /// change can never serve a stale curve.
    curves: Mutex<HashMap<(TraceKey, u64), Arc<DemandCurve>>>,
    pub metrics: TraceStoreMetrics,
}

static GLOBAL: OnceLock<TraceStore> = OnceLock::new();

impl TraceStore {
    pub fn new() -> TraceStore {
        TraceStore::default()
    }

    /// The process-wide store.
    pub fn global() -> &'static TraceStore {
        GLOBAL.get_or_init(TraceStore::new)
    }

    /// Look up a trace for replay; counts a replay on hit.
    pub fn get(&self, key: &TraceKey) -> Option<Arc<AccessTrace>> {
        let hit = self.peek(key);
        if hit.is_some() {
            self.metrics.replays.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Look up a trace without counting a replay (curve construction
    /// reads the stream for what-if analysis, not to serve a request).
    pub fn peek(&self, key: &TraceKey) -> Option<Arc<AccessTrace>> {
        self.traces.lock().unwrap().get(key).cloned()
    }

    /// Memoized demand curve for `(key, config_fp)`; counts a hit.
    pub fn curve(&self, key: &TraceKey, config_fp: u64) -> Option<Arc<DemandCurve>> {
        let hit = self.curves.lock().unwrap().get(&(key.clone(), config_fp)).cloned();
        if hit.is_some() {
            self.metrics.curve_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Register a freshly built demand curve (first insert wins —
    /// curves are deterministic, so concurrent builders agree).
    pub fn insert_curve(
        &self,
        key: TraceKey,
        config_fp: u64,
        curve: DemandCurve,
    ) -> Arc<DemandCurve> {
        self.metrics.curve_builds.fetch_add(1, Ordering::Relaxed);
        let curve = Arc::new(curve);
        let mut map = self.curves.lock().unwrap();
        if let Some(existing) = map.get(&(key.clone(), config_fp)) {
            return existing.clone();
        }
        map.insert((key, config_fp), curve.clone());
        curve
    }

    /// `(curve_builds, curve_hits)` counter snapshot.
    pub fn curve_counts(&self) -> (u64, u64) {
        (
            self.metrics.curve_builds.load(Ordering::Relaxed),
            self.metrics.curve_hits.load(Ordering::Relaxed),
        )
    }

    /// Number of cached traces.
    pub fn len(&self) -> usize {
        self.traces.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(records, replays, bytes)` counter snapshot.
    pub fn counts(&self) -> (u64, u64, u64) {
        (
            self.metrics.records.load(Ordering::Relaxed),
            self.metrics.replays.load(Ordering::Relaxed),
            self.metrics.bytes.load(Ordering::Relaxed),
        )
    }

    /// Register a fresh recording. The first insert under a key wins
    /// (recordings are deterministic, so concurrent racers produce the
    /// same trace); at `max_cached` entries new keys record but are not
    /// retained, bounding memory on unbounded sweep populations. The
    /// `bytes` counter tracks retained recordings only, so it reflects
    /// actual store residency.
    pub fn insert(&self, key: TraceKey, trace: AccessTrace, max_cached: usize) -> Arc<AccessTrace> {
        self.metrics.records.fetch_add(1, Ordering::Relaxed);
        let encoded = trace.encoded_bytes();
        let trace = Arc::new(trace);
        let mut map = self.traces.lock().unwrap();
        if let Some(existing) = map.get(&key) {
            return existing.clone();
        }
        if map.len() >= max_cached {
            return trace; // caller keeps its copy; nothing evicted
        }
        map.insert(key, trace.clone());
        self.metrics.bytes.fetch_add(encoded, Ordering::Relaxed);
        trace
    }

    /// Get-or-record: replay hit when cached, otherwise execute the
    /// workload once against a recording environment (no machine — the
    /// stream a workload emits is sink-independent) and cache it.
    /// Returns `(trace, recorded_now)`.
    pub fn obtain(
        &self,
        w: &dyn Workload,
        page_bytes: u64,
        max_cached: usize,
    ) -> (Arc<AccessTrace>, bool) {
        let key = TraceKey::of(w, page_bytes);
        if let Some(t) = self.get(&key) {
            return (t, false);
        }
        let trace = record_workload(w, page_bytes);
        (self.insert(key, trace, max_cached), true)
    }

    /// Drop all cached traces and curves (tests). Resets the residency
    /// counter; the cumulative records/replays counters are left alone.
    pub fn clear(&self) {
        self.traces.lock().unwrap().clear();
        self.curves.lock().unwrap().clear();
        self.metrics.bytes.store(0, Ordering::Relaxed);
    }
}

/// Record one workload's canonical trace by executing it against a
/// recording environment over a null sink — the cheapest possible live
/// run. The stream a workload emits depends only on the workload (the
/// shim's addresses are deterministic), so a machine-teed recording and
/// this one are byte-identical.
pub fn record_workload(w: &dyn Workload, page_bytes: u64) -> AccessTrace {
    let mut sink = NullSink::default();
    let mut env = crate::shim::env::Env::new_recording(page_bytes, &mut sink);
    let checksum = w.run(&mut env);
    let mut trace = env.finish_recording().expect("recording env always yields a trace");
    trace.workload = w.name().to_string();
    trace.page_bytes = page_bytes;
    trace.checksum = checksum;
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::json_ser::JsonSer;

    #[test]
    fn obtain_records_once_then_replays() {
        let store = TraceStore::new();
        let w = JsonSer::new(20);
        let (a, recorded) = store.obtain(&w, 4096, 16);
        assert!(recorded);
        assert!(a.n_accesses() > 0);
        assert_eq!(a.workload, "json");
        let (b, recorded) = store.obtain(&w, 4096, 16);
        assert!(!recorded, "second obtain must replay");
        assert!(Arc::ptr_eq(&a, &b));
        let (records, replays, bytes) = store.counts();
        assert_eq!((records, replays), (1, 1));
        assert_eq!(bytes, a.encoded_bytes());
    }

    #[test]
    fn distinct_sizes_get_distinct_traces() {
        let store = TraceStore::new();
        let (a, _) = store.obtain(&JsonSer::new(20), 4096, 16);
        let (b, _) = store.obtain(&JsonSer::new(40), 4096, 16);
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(b.n_accesses() > a.n_accesses());
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn page_size_is_part_of_the_key() {
        let store = TraceStore::new();
        let w = JsonSer::new(20);
        store.obtain(&w, 4096, 16);
        let (_, recorded) = store.obtain(&w, 8192, 16);
        assert!(recorded, "different page size must not share a trace");
    }

    #[test]
    fn max_cached_bounds_retention() {
        let store = TraceStore::new();
        let (retained, _) = store.obtain(&JsonSer::new(10), 4096, 1);
        let bytes_after_first = store.counts().2;
        assert_eq!(bytes_after_first, retained.encoded_bytes());
        let (_, recorded) = store.obtain(&JsonSer::new(11), 4096, 1);
        assert!(recorded);
        assert_eq!(store.len(), 1, "store stays at its bound");
        // bounded-out recordings count as records but not residency
        assert_eq!(store.counts().2, bytes_after_first, "bytes tracks retained traces only");
        // the bounded-out key records again on the next request
        let (_, recorded) = store.obtain(&JsonSer::new(11), 4096, 1);
        assert!(recorded);
        assert_eq!(store.counts().0, 3, "every recording run counts");
    }

    #[test]
    fn recorded_checksum_matches_live_run() {
        let w = JsonSer::new(15);
        let trace = record_workload(&w, 4096);
        let mut sink = crate::trace::NullSink::default();
        let mut env = crate::shim::env::Env::new(4096, &mut sink);
        let live = w.run(&mut env);
        assert_eq!(trace.checksum, live);
    }
}
