//! The §3 pipeline in one call: profile (record + DAMON) → hint → replay
//! with static placement → compare against the pure-CXL and all-DRAM
//! endpoints. This is what Fig. 5 and the §1 headline claim measure.

use crate::config::Config;
use crate::mem::tier::TierKind;
use crate::monitor::damon::Damon;
use crate::placement::hints::PlacementHint;
use crate::placement::policies::HintedPlacer;
use crate::sim::machine::{Machine, RunReport};
use crate::workloads::Workload;

/// Results of the profile→place experiment for one workload.
#[derive(Debug, Clone)]
pub struct StaticPlacementResult {
    pub workload: String,
    pub all_dram: RunReport,
    pub all_cxl: RunReport,
    pub hinted: RunReport,
    pub hint: PlacementHint,
    /// Checksums of each run — placement must never change results.
    pub checksums: [u64; 3],
}

impl StaticPlacementResult {
    /// Slowdown vs. all-DRAM, in percent (Fig. 2 metric).
    pub fn cxl_slowdown_pct(&self) -> f64 {
        self.all_cxl.slowdown_pct_vs(&self.all_dram)
    }

    pub fn hinted_slowdown_pct(&self) -> f64 {
        self.hinted.slowdown_pct_vs(&self.all_dram)
    }

    /// Fig. 5 metric: execution-time reduction of hinted placement
    /// relative to pure CXL, in percent.
    pub fn improvement_over_cxl_pct(&self) -> f64 {
        (1.0 - self.hinted.wall_ns / self.all_cxl.wall_ns) * 100.0
    }
}

/// Run the full §3 experiment for one workload.
///
/// Pass 1 (record): run on the pure-CXL machine with DAMON attached —
/// the paper's record phase also executes in the emulated-CXL testbed.
/// Pass 2 (replay): regenerate hints from DAMON + the shim log, then run
/// again with hot objects statically pinned to DRAM. Endpoints run
/// without monitoring. The workload's own determinism (fixed seeds,
/// ASLR-off address layout) makes the two passes see identical objects.
pub fn profile_and_place(cfg: &Config, workload: &dyn Workload) -> StaticPlacementResult {
    // --- endpoints ---
    let (all_dram, sum_dram) = run_plain(cfg, workload, TierKind::Dram);

    // --- record phase (pure CXL + DAMON) ---
    let mut machine = Machine::all_in(&cfg.machine, TierKind::Cxl);
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine.attach_observer(Box::new(Damon::new(&cfg.monitor, cfg.machine.page_bytes, 0xDA11)));
    let mut env = crate::shim::env::Env::new(cfg.machine.page_bytes, &mut machine);
    let sum_cxl = workload.run(&mut env);
    let objects: Vec<_> = env.objects().to_vec();
    drop(env);
    let all_cxl = machine.report();
    let damon = machine
        .take_observers()
        .pop()
        .unwrap()
        .into_any()
        .downcast::<Damon>()
        .expect("observer is damon");

    // --- hint generation (offline tuner step) ---
    let hint = PlacementHint::generate(
        workload.name(),
        &damon,
        &objects,
        cfg.porter.dram_budget_frac,
        cfg.porter.hot_threshold,
    );

    // --- replay phase (static placement by hint) ---
    let mut machine = Machine::new(&cfg.machine, Box::new(HintedPlacer::new(hint.clone())));
    let mut env = crate::shim::env::Env::new(cfg.machine.page_bytes, &mut machine);
    let sum_hint = workload.run(&mut env);
    drop(env);
    let hinted = machine.report();

    StaticPlacementResult {
        workload: workload.name().to_string(),
        all_dram,
        all_cxl,
        hinted,
        hint,
        checksums: [sum_dram, sum_cxl, sum_hint],
    }
}

/// One unmonitored run with everything in a single tier.
pub fn run_plain(cfg: &Config, workload: &dyn Workload, tier: TierKind) -> (RunReport, u64) {
    let mut machine = Machine::all_in(&cfg.machine, tier);
    let mut env = crate::shim::env::Env::new(cfg.machine.page_bytes, &mut machine);
    let sum = workload.run(&mut env);
    drop(env);
    (machine.report(), sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::graph::rmat;
    use crate::workloads::pagerank::PageRank;

    #[test]
    fn static_placement_recovers_most_of_cxl_penalty() {
        let cfg = Config::default();
        // small-but-LLC-busting pagerank
        let g = rmat(15, 8, crate::workloads::registry::GRAPH_SEED);
        let w = PageRank::new(g, 2);
        let r = profile_and_place(&cfg, &w);
        // placement must not change the computation
        assert_eq!(r.checksums[0], r.checksums[1]);
        assert_eq!(r.checksums[0], r.checksums[2]);
        // ordering: dram <= hinted <= cxl (with real margins)
        assert!(
            r.cxl_slowdown_pct() > 3.0,
            "pagerank should suffer on CXL: {:.1}%",
            r.cxl_slowdown_pct()
        );
        assert!(
            r.hinted_slowdown_pct() < r.cxl_slowdown_pct(),
            "hints must help: hinted {:.1}% vs cxl {:.1}%",
            r.hinted_slowdown_pct(),
            r.cxl_slowdown_pct()
        );
        assert!(r.improvement_over_cxl_pct() > 0.0);
        // some DRAM was actually used, but not everything
        assert!(r.hinted.peak_dram_bytes > 0);
        assert!(r.hinted.peak_cxl_bytes > 0);
    }
}
