//! The §3 pipeline in one call: profile (record + DAMON) → hint → replay
//! with static placement → compare against the pure-CXL and all-DRAM
//! endpoints. This is what Fig. 5 and the §1 headline claim measure.

use crate::config::Config;
use crate::mem::tier::TierKind;
use crate::monitor::damon::Damon;
use crate::placement::hints::PlacementHint;
use crate::placement::policies::HintedPlacer;
use crate::sim::machine::{Machine, RunReport};
use crate::trace::{record_workload, AccessTrace};
use crate::workloads::Workload;

/// Results of the profile→place experiment for one workload.
#[derive(Debug, Clone)]
pub struct StaticPlacementResult {
    pub workload: String,
    pub all_dram: RunReport,
    pub all_cxl: RunReport,
    pub hinted: RunReport,
    pub hint: PlacementHint,
    /// Checksums of each run — placement must never change results.
    pub checksums: [u64; 3],
}

impl StaticPlacementResult {
    /// Slowdown vs. all-DRAM, in percent (Fig. 2 metric).
    pub fn cxl_slowdown_pct(&self) -> f64 {
        self.all_cxl.slowdown_pct_vs(&self.all_dram)
    }

    pub fn hinted_slowdown_pct(&self) -> f64 {
        self.hinted.slowdown_pct_vs(&self.all_dram)
    }

    /// Fig. 5 metric: execution-time reduction of hinted placement
    /// relative to pure CXL, in percent.
    pub fn improvement_over_cxl_pct(&self) -> f64 {
        (1.0 - self.hinted.wall_ns / self.all_cxl.wall_ns) * 100.0
    }
}

/// Run the full §3 experiment for one workload: record its canonical
/// trace once, then [`profile_and_place_trace`] replays it for every
/// pass — the workload algorithm executes exactly once.
pub fn profile_and_place(cfg: &Config, workload: &dyn Workload) -> StaticPlacementResult {
    let trace = record_workload(workload, cfg.machine.page_bytes);
    profile_and_place_trace(cfg, &trace)
}

/// The §3 pipeline over a pre-recorded trace — what the ablation and
/// figure benches call per sweep cell so the workload is executed once
/// per *workload*, not once per cell.
///
/// Pass 1 (record): replay on the pure-CXL machine with DAMON attached
/// — the paper's record phase also executes in the emulated-CXL
/// testbed. Pass 2 (replay): regenerate hints from DAMON + the trace's
/// interned object table, then replay again with hot objects statically
/// pinned to DRAM. Endpoints replay without monitoring. The IR stream
/// is identical across passes by construction — the property the
/// paper gets from ASLR-off determinism, here structural.
pub fn profile_and_place_trace(cfg: &Config, trace: &AccessTrace) -> StaticPlacementResult {
    // --- endpoints ---
    let all_dram = replay_plain(cfg, trace, TierKind::Dram);

    // --- record phase (pure CXL + DAMON) ---
    let mut machine = Machine::all_in(&cfg.machine, TierKind::Cxl);
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine.attach_observer(Box::new(Damon::new(&cfg.monitor, cfg.machine.page_bytes, 0xDA11)));
    machine.replay(trace);
    let all_cxl = machine.report();
    let damon = machine
        .take_observers()
        .pop()
        .unwrap()
        .into_any()
        .downcast::<Damon>()
        .expect("observer is damon");

    // --- hint generation (offline tuner step) ---
    let hint = PlacementHint::generate(
        &trace.workload,
        &damon,
        &trace.objects,
        cfg.porter.dram_budget_frac,
        cfg.porter.hot_threshold,
    );

    // --- replay phase (static placement by hint) ---
    let mut machine = Machine::new(&cfg.machine, Box::new(HintedPlacer::new(hint.clone())));
    machine.replay(trace);
    let hinted = machine.report();

    StaticPlacementResult {
        workload: trace.workload.clone(),
        all_dram,
        all_cxl,
        hinted,
        hint,
        checksums: [trace.checksum; 3],
    }
}

/// One unmonitored run with everything in a single tier.
pub fn run_plain(cfg: &Config, workload: &dyn Workload, tier: TierKind) -> (RunReport, u64) {
    let mut machine = Machine::all_in(&cfg.machine, tier);
    let mut env = crate::shim::env::Env::new(cfg.machine.page_bytes, &mut machine);
    let sum = workload.run(&mut env);
    drop(env);
    (machine.report(), sum)
}

/// One unmonitored *replay* with everything in a single tier — the
/// record-once/replay-many counterpart of [`run_plain`].
pub fn replay_plain(cfg: &Config, trace: &AccessTrace, tier: TierKind) -> RunReport {
    let mut machine = Machine::all_in(&cfg.machine, tier);
    machine.replay(trace);
    machine.report()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::graph::rmat;
    use crate::workloads::pagerank::PageRank;

    #[test]
    fn replayed_endpoints_match_live_runs() {
        let cfg = Config::default();
        let g = rmat(12, 6, crate::workloads::registry::GRAPH_SEED);
        let w = PageRank::new(g, 1);
        let trace = record_workload(&w, cfg.machine.page_bytes);
        for tier in [TierKind::Dram, TierKind::Cxl] {
            let (live, sum) = run_plain(&cfg, &w, tier);
            assert_eq!(trace.checksum, sum, "recorded checksum matches the live run");
            assert_eq!(replay_plain(&cfg, &trace, tier), live, "{tier:?}: replay-identity");
        }
    }

    #[test]
    fn static_placement_recovers_most_of_cxl_penalty() {
        let cfg = Config::default();
        // small-but-LLC-busting pagerank
        let g = rmat(15, 8, crate::workloads::registry::GRAPH_SEED);
        let w = PageRank::new(g, 2);
        let r = profile_and_place(&cfg, &w);
        // placement must not change the computation
        assert_eq!(r.checksums[0], r.checksums[1]);
        assert_eq!(r.checksums[0], r.checksums[2]);
        // ordering: dram <= hinted <= cxl (with real margins)
        assert!(
            r.cxl_slowdown_pct() > 3.0,
            "pagerank should suffer on CXL: {:.1}%",
            r.cxl_slowdown_pct()
        );
        assert!(
            r.hinted_slowdown_pct() < r.cxl_slowdown_pct(),
            "hints must help: hinted {:.1}% vs cxl {:.1}%",
            r.hinted_slowdown_pct(),
            r.cxl_slowdown_pct()
        );
        assert!(r.improvement_over_cxl_pct() > 0.0);
        // some DRAM was actually used, but not everything
        assert!(r.hinted.peak_dram_bytes > 0);
        assert!(r.hinted.peak_cxl_bytes > 0);
    }
}
