//! Per-function DRAM provisioning: what-if trace replays → latency
//! curves → fleet-wide budget allocation.
//!
//! The paper's core argument is that DRAM/CXL should be provisioned "in
//! a fine-grained, application-specific manner"; a global
//! `dram_budget_frac` is exactly the naive provisioning it critiques.
//! This module turns the Trace-IR store into that fine-grained
//! optimizer:
//!
//! * [`DemandCurve`] — one function's latency-vs-DRAM curve, built by
//!   replaying its stored [`AccessTrace`] through [`sim::Machine`] at a
//!   ladder of DRAM ratios (what-if runs are nearly free once the trace
//!   exists). Curves interpolate between ladder points, are monotone
//!   non-increasing in latency by construction, and expose a
//!   marginal-utility view (Δlatency per ΔMiB).
//! * [`BudgetAllocator`] — partitions a node's DRAM across its resident
//!   functions by greedy marginal-utility descent (knapsack-style),
//!   honoring optional per-function SLO floors, and compares itself
//!   against uniform provisioning (every function at the same ladder
//!   ratio — the global-`dram_budget_frac` analog) at equal DRAM.
//! * Curve memoization lives in the process-wide
//!   [`TraceStore`], keyed by the trace key plus a
//!   machine/ladder fingerprint, so node B's tuner reuses node A's
//!   what-if replays exactly like it reuses recordings.
//!
//! [`sim::Machine`]: crate::sim::Machine

use std::sync::Arc;

use crate::config::{MachineConfig, ProvisionConfig};
use crate::placement::policies::FirstTouchDram;
use crate::sim::Machine;
use crate::trace::{AccessTrace, TraceKey, TraceStore};
use crate::util::bytes::MIB;
use crate::workloads::{mix, mix_bits, Workload};

/// One measured ladder point of a [`DemandCurve`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    /// Ladder ratio (fraction of the function's footprint).
    pub ratio: f64,
    /// Granted DRAM in bytes (0 at ratio 0: no reserved DRAM; the
    /// measuring machine still holds the one-page floor every grant
    /// has, so the 0-point wall is the all-CXL-but-one-page endpoint).
    pub dram_bytes: u64,
    /// Replayed wall time at this grant.
    pub wall_ns: f64,
}

/// A function's latency-vs-DRAM demand curve.
#[derive(Debug, Clone, PartialEq)]
pub struct DemandCurve {
    /// Workload/function name the curve belongs to.
    pub function: String,
    /// Footprint the ladder ratios scale against (bytes).
    pub footprint: u64,
    /// Page size of the measuring machine (floor alignment).
    pub page_bytes: u64,
    /// Ladder points, ascending in `dram_bytes`, `wall_ns` monotone
    /// non-increasing (enforced at construction).
    pub points: Vec<CurvePoint>,
}

impl DemandCurve {
    /// Build from raw measured points: sorts by grant size, clamps wall
    /// times monotone non-increasing (a bigger grant can never be
    /// *worse* — measurement noise from placement artifacts must not
    /// produce negative marginal utility), and equalizes duplicate-grant
    /// runs so interpolation never divides by a zero-width segment.
    pub fn new(
        function: &str,
        footprint: u64,
        page_bytes: u64,
        mut points: Vec<CurvePoint>,
    ) -> DemandCurve {
        assert!(!points.is_empty(), "demand curve needs at least one point");
        assert!(footprint > 0, "demand curve needs a nonzero footprint");
        points.sort_by(|a, b| {
            (a.dram_bytes, a.ratio).partial_cmp(&(b.dram_bytes, b.ratio)).expect("finite ratios")
        });
        for i in 1..points.len() {
            points[i].wall_ns = points[i].wall_ns.min(points[i - 1].wall_ns);
        }
        // duplicate-grant runs (tiny footprints quantize ladder ratios
        // onto the same page count): every point of the run takes the
        // run's minimum, which after the clamp is the last one's wall
        let mut i = 0;
        while i < points.len() {
            let mut j = i;
            while j + 1 < points.len() && points[j + 1].dram_bytes == points[i].dram_bytes {
                j += 1;
            }
            let min_wall = points[j].wall_ns;
            for p in &mut points[i..=j] {
                p.wall_ns = min_wall;
            }
            i = j + 1;
        }
        DemandCurve { function: function.to_string(), footprint, page_bytes, points }
    }

    /// Interpolated wall time at an arbitrary DRAM grant: clamped to
    /// the endpoints outside the ladder, piecewise-linear between
    /// points. Monotone non-increasing in `dram_bytes` because the
    /// points are.
    pub fn wall_at(&self, dram_bytes: u64) -> f64 {
        let pts = &self.points;
        if dram_bytes <= pts[0].dram_bytes {
            return pts[0].wall_ns;
        }
        for w in pts.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if dram_bytes <= b.dram_bytes {
                if b.dram_bytes == a.dram_bytes {
                    return b.wall_ns;
                }
                let t = (dram_bytes - a.dram_bytes) as f64 / (b.dram_bytes - a.dram_bytes) as f64;
                return a.wall_ns + (b.wall_ns - a.wall_ns) * t;
            }
        }
        pts[pts.len() - 1].wall_ns
    }

    /// Marginal utility of the upgrade out of point `idx`: wall time
    /// saved per MiB of extra DRAM moving to point `idx + 1` (0 at the
    /// ladder top or across a zero-width segment).
    pub fn marginal_utility_per_mib(&self, idx: usize) -> f64 {
        match (self.points.get(idx), self.points.get(idx + 1)) {
            (Some(a), Some(b)) if b.dram_bytes > a.dram_bytes => {
                (a.wall_ns - b.wall_ns) / ((b.dram_bytes - a.dram_bytes) as f64 / MIB as f64)
            }
            _ => 0.0,
        }
    }

    /// Smallest DRAM grant whose interpolated wall time meets
    /// `target_ns` (page-aligned up, capped at the ladder top), or
    /// `None` when even the full-footprint grant misses the target —
    /// the SLO-floor primitive the allocator honors.
    pub fn bytes_for_target(&self, target_ns: f64) -> Option<u64> {
        let pts = &self.points;
        if pts[0].wall_ns <= target_ns {
            return Some(pts[0].dram_bytes);
        }
        for w in pts.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if b.wall_ns <= target_ns {
                // a.wall > target >= b.wall, so the segment has width
                let t = (a.wall_ns - target_ns) / (a.wall_ns - b.wall_ns);
                let raw = a.dram_bytes as f64 + (b.dram_bytes - a.dram_bytes) as f64 * t;
                let aligned =
                    (raw.ceil() as u64).next_multiple_of(self.page_bytes.max(1));
                return Some(aligned.min(b.dram_bytes));
            }
        }
        None
    }

    /// The ladder-top wall time (the best this curve can do).
    pub fn best_wall_ns(&self) -> f64 {
        self.points[self.points.len() - 1].wall_ns
    }
}

/// Replay one trace on a machine whose DRAM is capped at `dram_bytes`
/// (one-page floor — a grant of 0 still leaves the kernel a page), with
/// first-touch placement and no migrator: the static what-if both the
/// curve builder and the provisioning benches measure with.
pub fn measure_wall(trace: &AccessTrace, machine: &MachineConfig, dram_bytes: u64) -> f64 {
    let mut mcfg = machine.clone();
    mcfg.dram_bytes = dram_bytes.max(mcfg.page_bytes);
    let mut m = Machine::new(&mcfg, Box::new(FirstTouchDram::default()));
    m.replay(trace);
    m.report().wall_ns
}

/// Footprint a trace's ladder scales against: the interned objects'
/// total bytes (the shim's view of the working set), floored at the
/// untracked-access extent and one page.
pub fn trace_footprint(trace: &AccessTrace, page_bytes: u64) -> u64 {
    let objects: u64 = trace.objects.iter().map(|o| o.bytes).sum();
    objects.max(trace.footprint_extent()).max(page_bytes.max(1))
}

/// Build a function's demand curve by replaying `trace` at every ladder
/// ratio. Deterministic: same trace + machine + ladder → bit-identical
/// curve.
pub fn build_curve(
    function: &str,
    trace: &AccessTrace,
    machine: &MachineConfig,
    ladder: &[f64],
) -> DemandCurve {
    let page = machine.page_bytes.max(1);
    let footprint = trace_footprint(trace, page);
    let points = ladder
        .iter()
        .map(|&ratio| {
            let dram_bytes = if ratio <= 0.0 {
                0
            } else {
                ((footprint as f64 * ratio).ceil() as u64).next_multiple_of(page).min(
                    footprint.next_multiple_of(page),
                )
            };
            CurvePoint { ratio, dram_bytes, wall_ns: measure_wall(trace, machine, dram_bytes) }
        })
        .collect();
    DemandCurve::new(function, footprint, page, points)
}

/// Fingerprint of everything besides the trace that shapes a curve:
/// the machine's latency/bandwidth/cache parameters and the ladder.
/// Part of the memoization key so a config change can never serve a
/// stale curve.
pub fn curve_fingerprint(machine: &MachineConfig, ladder: &[f64]) -> u64 {
    let mut h = mix(0xC057_0D1A, machine.page_bytes);
    for v in [
        machine.dram_latency_ns,
        machine.dram_bw_gbps,
        machine.cxl_latency_ns,
        machine.cxl_bw_gbps,
        machine.freq_ghz,
        machine.mlp,
        machine.l3_hit_ns,
        machine.l3_bytes as f64,
    ] {
        h = mix_bits(h, v);
    }
    h = mix(h, machine.cache_line);
    h = mix(h, machine.l3_ways as u64);
    // CXL capacity shapes low-DRAM rungs (the spill tier can fill);
    // DRAM capacity is deliberately excluded — measure_wall overrides
    // it per rung, so curves are shareable across node DRAM sizes
    h = mix(h, machine.cxl_bytes);
    h = mix(h, ladder.len() as u64);
    for &r in ladder {
        h = mix_bits(h, r);
    }
    h
}

/// Memoized curve for a trace already in the store (the tuner path:
/// the engine recorded the canonical trace before shipping the
/// profile). `None` when the store no longer holds the trace (bounded
/// out) — the caller simply skips provisioning for that function.
pub fn curve_for_key(
    store: &TraceStore,
    key: &TraceKey,
    machine: &MachineConfig,
    ladder: &[f64],
) -> Option<Arc<DemandCurve>> {
    let fp = curve_fingerprint(machine, ladder);
    if let Some(c) = store.curve(key, fp) {
        return Some(c);
    }
    let trace = store.peek(key)?;
    let curve = build_curve(&key.workload, &trace, machine, ladder);
    Some(store.insert_curve(key.clone(), fp, curve))
}

/// Memoized curve for a workload, recording its trace first if needed
/// (the CLI/bench path). Returns `(curve, built_now)`.
pub fn obtain_curve(
    store: &TraceStore,
    w: &dyn Workload,
    machine: &MachineConfig,
    ladder: &[f64],
    max_cached: usize,
) -> (Arc<DemandCurve>, bool) {
    let key = TraceKey::of(w, machine.page_bytes);
    let fp = curve_fingerprint(machine, ladder);
    if let Some(c) = store.curve(&key, fp) {
        return (c, false);
    }
    let (trace, _) = store.obtain(w, machine.page_bytes, max_cached);
    let curve = build_curve(&key.workload, &trace, machine, ladder);
    (store.insert_curve(key, fp, curve), true)
}

/// One function's claim on a node's DRAM.
#[derive(Debug, Clone)]
pub struct FunctionDemand {
    pub curve: Arc<DemandCurve>,
    /// Minimum grant required to meet the function's SLO target
    /// (from [`DemandCurve::bytes_for_target`]); honored before the
    /// greedy descent, capacity permitting.
    pub floor_bytes: Option<u64>,
    /// Relative invocation weight (scales marginal utility and the
    /// predicted-total accounting; 1.0 = equal traffic).
    pub weight: f64,
}

impl FunctionDemand {
    pub fn new(curve: Arc<DemandCurve>) -> FunctionDemand {
        FunctionDemand { curve, floor_bytes: None, weight: 1.0 }
    }
}

/// One function's allocated budget.
#[derive(Debug, Clone)]
pub struct FunctionBudget {
    pub function: String,
    pub dram_bytes: u64,
    /// `dram_bytes / footprint` — what replaces the global
    /// `dram_budget_frac` in `PlacementHint::generate`.
    pub frac: f64,
    pub predicted_wall_ns: f64,
    /// This function's floor was honored (an SLO floor was requested
    /// and the grant covers it).
    pub floor_met: bool,
}

/// The allocator's full answer, including the uniform baseline it beat
/// (or fell back to).
#[derive(Debug, Clone)]
pub struct Allocation {
    /// Per-function budgets, in the demands' input order.
    pub budgets: Vec<FunctionBudget>,
    /// DRAM the optimized allocation actually consumes (≤ capacity).
    pub used_bytes: u64,
    /// Weighted total predicted wall time of the optimized allocation.
    pub predicted_wall_ns: f64,
    /// Uniform-on-ladder baseline at the same capacity: every function
    /// at the largest common ladder ratio that fits — the
    /// global-`dram_budget_frac` analog, quantized to the ladder.
    pub uniform_ratio: f64,
    pub uniform_used_bytes: u64,
    pub uniform_wall_ns: f64,
    /// The greedy descent predicted worse than uniform (non-concave
    /// curves can defeat single-step greedy), so the uniform allocation
    /// was returned instead. `predicted_wall_ns ≤ uniform_wall_ns`
    /// holds whenever no SLO floor blocks the switch: a fallback is
    /// refused if uniform would un-honor a floor greedy satisfied —
    /// floor satisfaction outranks raw latency.
    pub fell_back_to_uniform: bool,
}

impl Allocation {
    /// DRAM returned to the pool relative to uniform provisioning at
    /// the same capacity (0 when the optimizer spent as much or fell
    /// back). Non-negative by construction.
    pub fn dram_saved_bytes(&self) -> u64 {
        self.uniform_used_bytes.saturating_sub(self.used_bytes)
    }
}

/// Greedy marginal-utility budget allocator.
#[derive(Debug, Clone)]
pub struct BudgetAllocator {
    /// See [`crate::config::ProvisionConfig::min_gain_frac`].
    pub min_gain_frac: f64,
    /// Compare against (and fall back to) the uniform-on-ladder
    /// allocation. On by default; property tests disable it to check
    /// the greedy arm's per-function monotonicity in isolation.
    pub uniform_fallback: bool,
}

impl Default for BudgetAllocator {
    fn default() -> Self {
        BudgetAllocator { min_gain_frac: 0.01, uniform_fallback: true }
    }
}

impl BudgetAllocator {
    pub fn from_config(cfg: &ProvisionConfig) -> BudgetAllocator {
        BudgetAllocator { min_gain_frac: cfg.min_gain_frac, uniform_fallback: true }
    }

    /// Partition `capacity_bytes` of DRAM across `demands`.
    ///
    /// Invariants (property-tested):
    /// * never over-commits: `used_bytes ≤ capacity_bytes` (given every
    ///   curve's first point is the 0-byte grant, as built curves are);
    /// * the greedy arm is monotone in capacity — more DRAM never
    ///   shrinks any function's budget (upgrades are a fixed,
    ///   capacity-independent sequence; capacity only decides the
    ///   prefix length, because the descent *stops* at the first
    ///   non-fitting upgrade instead of skipping it);
    /// * `predicted_wall_ns ≤ uniform_wall_ns` when the fallback is on
    ///   and no SLO floor blocks it (uniform is never allowed to
    ///   un-honor a floor the greedy arm satisfied).
    pub fn allocate(&self, capacity_bytes: u64, demands: &[FunctionDemand]) -> Allocation {
        assert!(!demands.is_empty(), "allocate over an empty fleet");
        let n = demands.len();
        let bytes_at = |d: &FunctionDemand, level: usize| d.curve.points[level].dram_bytes;
        let wall_at_level = |d: &FunctionDemand, level: usize| d.curve.points[level].wall_ns;

        // start every function at its ladder floor (the 0-byte grant)
        let mut levels = vec![0usize; n];
        let mut used: u64 = demands.iter().map(|d| bytes_at(d, 0)).sum();

        // SLO floors first, in input order, capacity permitting: raise
        // to the smallest ladder point covering the floor
        for (i, d) in demands.iter().enumerate() {
            let Some(floor) = d.floor_bytes else { continue };
            while levels[i] + 1 < d.curve.points.len() && bytes_at(d, levels[i]) < floor {
                let delta = bytes_at(d, levels[i] + 1) - bytes_at(d, levels[i]);
                if used + delta > capacity_bytes {
                    break;
                }
                used += delta;
                levels[i] += 1;
            }
        }

        // greedy marginal-utility descent over single ladder steps
        loop {
            let mut best: Option<(f64, usize)> = None;
            for (i, d) in demands.iter().enumerate() {
                let l = levels[i];
                if l + 1 >= d.curve.points.len() {
                    continue;
                }
                let gain = wall_at_level(d, l) - wall_at_level(d, l + 1);
                // an upgrade must be worth its DRAM: at least
                // min_gain_frac of the function's zero-DRAM wall
                if gain < self.min_gain_frac * wall_at_level(d, 0) || gain <= 0.0 {
                    continue;
                }
                let delta = (bytes_at(d, l + 1) - bytes_at(d, l)).max(1);
                let utility = gain * d.weight / delta as f64;
                // strict > keeps ties on the earliest (input-order)
                // function: deterministic and capacity-independent
                if best.is_none_or(|(u, _)| utility > u) {
                    best = Some((utility, i));
                }
            }
            let Some((_, i)) = best else { break };
            let delta = bytes_at(&demands[i], levels[i] + 1) - bytes_at(&demands[i], levels[i]);
            if used + delta > capacity_bytes {
                // stop (don't skip): keeps the upgrade sequence a
                // capacity-independent prefix → monotone budgets
                break;
            }
            used += delta;
            levels[i] += 1;
        }

        let total_wall = |lv: &[usize]| -> f64 {
            demands.iter().zip(lv).map(|(d, &l)| d.weight * wall_at_level(d, l)).sum()
        };
        let mut predicted = total_wall(&levels);

        // uniform-on-ladder baseline at the same capacity (only
        // meaningful when every curve shares the ladder shape)
        let aligned = demands.iter().all(|d| d.curve.points.len() == demands[0].curve.points.len());
        let uniform_level = if aligned {
            (0..demands[0].curve.points.len())
                .rev()
                .find(|&k| demands.iter().map(|d| bytes_at(d, k)).sum::<u64>() <= capacity_bytes)
                .unwrap_or(0)
        } else {
            0
        };
        let uniform_levels = vec![uniform_level; n];
        let (uniform_used, uniform_wall, uniform_ratio) = if aligned {
            (
                demands.iter().map(|d| bytes_at(d, uniform_level)).sum::<u64>(),
                total_wall(&uniform_levels),
                demands[0].curve.points[uniform_level].ratio,
            )
        } else {
            (used, predicted, 0.0)
        };

        // the fallback may not silently un-honor an SLO floor the
        // greedy arm satisfied: uniform must meet every floor greedy met
        let uniform_meets_floors = demands.iter().enumerate().all(|(i, d)| match d.floor_bytes {
            Some(f) => bytes_at(d, uniform_level) >= f || bytes_at(d, levels[i]) < f,
            None => true,
        });
        let mut fell_back = false;
        if self.uniform_fallback
            && aligned
            && uniform_used <= capacity_bytes
            && uniform_meets_floors
            && uniform_wall < predicted
        {
            levels = uniform_levels;
            used = uniform_used;
            predicted = uniform_wall;
            fell_back = true;
        }

        let budgets = demands
            .iter()
            .zip(&levels)
            .map(|(d, &l)| {
                let dram_bytes = bytes_at(d, l);
                FunctionBudget {
                    function: d.curve.function.clone(),
                    dram_bytes,
                    frac: (dram_bytes as f64 / d.curve.footprint as f64).clamp(0.0, 1.0),
                    predicted_wall_ns: wall_at_level(d, l),
                    floor_met: d.floor_bytes.is_some_and(|f| dram_bytes >= f),
                }
            })
            .collect();
        Allocation {
            budgets,
            used_bytes: used,
            predicted_wall_ns: predicted,
            uniform_ratio,
            uniform_used_bytes: uniform_used,
            uniform_wall_ns: uniform_wall,
            fell_back_to_uniform: fell_back,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::workloads::kvstore::KvStore;

    /// Synthetic curve over the default 6-rung ladder.
    fn curve(name: &str, footprint: u64, walls: [f64; 6]) -> Arc<DemandCurve> {
        let ladder = Config::default().provision.ladder;
        let points = ladder
            .iter()
            .zip(walls)
            .map(|(&ratio, wall_ns)| CurvePoint {
                ratio,
                dram_bytes: if ratio <= 0.0 { 0 } else { (footprint as f64 * ratio) as u64 },
                wall_ns,
            })
            .collect();
        Arc::new(DemandCurve::new(name, footprint, 4096, points))
    }

    #[test]
    fn curve_clamps_monotone_and_interpolates() {
        // a noisy bump at 0.25 must be clamped down
        let c = curve("f", 1 << 20, [100.0, 80.0, 85.0, 60.0, 50.0, 50.0]);
        let walls: Vec<f64> = c.points.iter().map(|p| p.wall_ns).collect();
        assert!(walls.windows(2).all(|w| w[1] <= w[0]), "{walls:?}");
        assert_eq!(c.wall_at(0), 100.0);
        assert_eq!(c.wall_at(u64::MAX), 50.0);
        // halfway between ratio 0 (100) and 0.125 (80): 90
        let mid = c.wall_at((1 << 20) / 16);
        assert!((mid - 90.0).abs() < 1e-9, "{mid}");
        // interpolation stays monotone over arbitrary queries
        let mut prev = f64::INFINITY;
        for b in (0..=(1 << 20)).step_by(4096) {
            let w = c.wall_at(b);
            assert!(w <= prev + 1e-12);
            prev = w;
        }
    }

    #[test]
    fn bytes_for_target_finds_smallest_grant() {
        let fp = 1u64 << 20;
        let c = curve("f", fp, [100.0, 80.0, 70.0, 60.0, 50.0, 40.0]);
        assert_eq!(c.bytes_for_target(200.0), Some(0));
        assert!(c.bytes_for_target(30.0).is_none(), "unreachable target");
        let need = c.bytes_for_target(65.0).unwrap();
        assert!(c.wall_at(need) <= 65.0);
        assert_eq!(need % 4096, 0, "page aligned");
        // one page less must miss the target (minimality up to a page)
        assert!(c.wall_at(need.saturating_sub(4096)) > 65.0);
    }

    #[test]
    fn marginal_utility_reflects_segment_slope() {
        let fp = 8 * MIB;
        let c = curve("f", fp, [100.0, 80.0, 70.0, 60.0, 50.0, 50.0]);
        // 0 → 0.125·8MiB = 1MiB for 20ns: 20 ns/MiB
        assert!((c.marginal_utility_per_mib(0) - 20.0).abs() < 1e-9);
        // flat tail: zero utility
        assert_eq!(c.marginal_utility_per_mib(4), 0.0);
        assert_eq!(c.marginal_utility_per_mib(5), 0.0);
    }

    #[test]
    fn allocator_prefers_the_steep_curve_and_respects_capacity() {
        let fp = 8 * MIB;
        // hot-skewed: most of the win in the first rungs, flat tail
        let hot = FunctionDemand::new(curve("hot", fp, [200.0, 120.0, 90.0, 80.0, 79.0, 79.0]));
        // streaming: latency barely moves with DRAM
        let stream =
            FunctionDemand::new(curve("stream", fp, [210.0, 208.0, 206.0, 204.0, 202.0, 200.0]));
        let alloc = BudgetAllocator::default().allocate(4 * MIB, &[hot, stream]);
        assert!(alloc.used_bytes <= 4 * MIB);
        assert!(
            alloc.budgets[0].dram_bytes > alloc.budgets[1].dram_bytes,
            "hot-skewed must out-budget streaming: {:?}",
            alloc.budgets
        );
        assert!(alloc.predicted_wall_ns <= alloc.uniform_wall_ns);
    }

    #[test]
    fn flat_tails_return_capacity_as_savings() {
        let fp = 8 * MIB;
        let hot = FunctionDemand::new(curve("hot", fp, [200.0, 120.0, 90.0, 88.0, 88.0, 88.0]));
        let warm = FunctionDemand::new(curve("warm", fp, [150.0, 100.0, 80.0, 78.0, 78.0, 78.0]));
        // plenty of capacity: uniform maxes the ladder, the optimizer
        // stops where marginal gains die → nonzero savings
        let alloc = BudgetAllocator::default().allocate(16 * MIB, &[hot, warm]);
        assert!(alloc.dram_saved_bytes() > 0, "{alloc:?}");
        assert!(alloc.predicted_wall_ns <= alloc.uniform_wall_ns);
        assert!(!alloc.fell_back_to_uniform);
    }

    #[test]
    fn floors_are_honored_before_greedy() {
        let fp = 8 * MIB;
        let a = FunctionDemand {
            floor_bytes: Some(4 * MIB), // needs ratio 0.5
            ..FunctionDemand::new(curve("slo", fp, [100.0, 99.0, 98.0, 97.0, 96.0, 95.0]))
        };
        let b = FunctionDemand::new(curve("fast", fp, [500.0, 100.0, 50.0, 40.0, 39.0, 39.0]));
        let alloc = BudgetAllocator { min_gain_frac: 0.0, uniform_fallback: false }
            .allocate(6 * MIB, &[a, b]);
        assert!(alloc.budgets[0].floor_met, "{:?}", alloc.budgets);
        assert!(alloc.budgets[0].dram_bytes >= 4 * MIB);
        assert!(alloc.used_bytes <= 6 * MIB);
    }

    #[test]
    fn built_curve_is_deterministic_and_monotone() {
        let cfg = Config::default();
        let w = KvStore::new(20_000, 40_000);
        let trace = crate::trace::record_workload(&w, cfg.machine.page_bytes);
        let ladder = &cfg.provision.ladder;
        let a = build_curve("kv", &trace, &cfg.machine, ladder);
        let b = build_curve("kv", &trace, &cfg.machine, ladder);
        assert_eq!(a, b, "what-if replays are deterministic");
        assert_eq!(a.points.len(), ladder.len());
        assert_eq!(a.points[0].dram_bytes, 0);
        assert!(a.points.windows(2).all(|w| w[1].wall_ns <= w[0].wall_ns));
        assert!(
            a.points[0].wall_ns > a.best_wall_ns(),
            "kvstore must be DRAM-sensitive: {:?}",
            a.points
        );
    }

    #[test]
    fn curve_memoization_hits_on_second_obtain() {
        let store = TraceStore::new();
        let cfg = Config::default();
        let w = KvStore::new(21_000, 42_000);
        let (a, built) = obtain_curve(&store, &w, &cfg.machine, &cfg.provision.ladder, 16);
        assert!(built);
        let (b, built) = obtain_curve(&store, &w, &cfg.machine, &cfg.provision.ladder, 16);
        assert!(!built, "second obtain must hit the memo");
        assert!(Arc::ptr_eq(&a, &b));
        let (builds, hits) = store.curve_counts();
        assert_eq!((builds, hits), (1, 1));
        // a different ladder is a different curve
        let (_, built) =
            obtain_curve(&store, &w, &cfg.machine, &[0.0, 0.5, 1.0], 16);
        assert!(built, "ladder is part of the memo key");
    }
}
