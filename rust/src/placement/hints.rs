//! Placement-hint generation: DAMON hot regions ∩ shim object log.
//!
//! The paper (§3.2): "Since for each mmap intercept there is a memory
//! address range and each sample has a memory address associated with it,
//! we can combine with the profiled hot regions observed over time to get
//! placement hints." Objects are keyed by *allocation site + sequence*
//! rather than raw addresses, which is the §4.2 "resistance to payload
//! changing" fix: addresses move between invocations, call sites don't.

use std::collections::HashMap;

use crate::mem::tier::TierKind;
use crate::monitor::damon::Damon;
use crate::shim::object::MemoryObject;
use crate::util::json::Json;

/// Heat classification of one object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HeatClass {
    Hot,
    Warm,
    Cold,
}

impl HeatClass {
    /// §3's rule: hot → DRAM, cold/warm → CXL.
    pub fn tier(self) -> TierKind {
        match self {
            HeatClass::Hot => TierKind::Dram,
            HeatClass::Warm | HeatClass::Cold => TierKind::Cxl,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            HeatClass::Hot => "hot",
            HeatClass::Warm => "warm",
            HeatClass::Cold => "cold",
        }
    }
}

/// Measured heat of one object from the profile run.
#[derive(Debug, Clone)]
pub struct ObjectHeat {
    pub site: String,
    pub seq: u64,
    pub bytes: u64,
    /// DAMON heat (sampled accesses attributed to the object).
    pub heat: f64,
    /// Heat per byte — the ranking key.
    pub density: f64,
    pub class: HeatClass,
}

/// The function's cached placement metadata.
#[derive(Debug, Clone, Default)]
pub struct PlacementHint {
    pub function: String,
    pub objects: Vec<ObjectHeat>,
    /// Lookup: (site, seq) → index. Seq disambiguates same-site
    /// allocations; lookups fall back to site-only.
    by_key: HashMap<(String, u64), usize>,
    by_site: HashMap<String, usize>,
}

impl PlacementHint {
    /// Build from a finished profile run.
    ///
    /// Ranking: objects sorted by heat density; the densest objects are
    /// `Hot` until `dram_budget_frac` of the total footprint is used;
    /// objects with non-trivial heat after that are `Warm`; the rest
    /// `Cold`.
    pub fn generate(
        function: &str,
        damon: &Damon,
        objects: &[MemoryObject],
        dram_budget_frac: f64,
        hot_threshold: f64,
    ) -> PlacementHint {
        let mut heats: Vec<ObjectHeat> = objects
            .iter()
            .map(|o| {
                let heat = damon.range_heat(o.start, o.end());
                ObjectHeat {
                    site: o.site.clone(),
                    seq: o.seq,
                    bytes: o.bytes,
                    heat,
                    density: heat / o.bytes.max(1) as f64,
                    class: HeatClass::Cold,
                }
            })
            .collect();
        let total_bytes: u64 = heats.iter().map(|h| h.bytes).sum();
        let budget = (total_bytes as f64 * dram_budget_frac) as u64;
        let max_density = heats.iter().map(|h| h.density).fold(0.0, f64::max).max(1e-12);
        // densest first
        let mut order: Vec<usize> = (0..heats.len()).collect();
        order.sort_by(|&a, &b| heats[b].density.partial_cmp(&heats[a].density).unwrap());
        let mut used = 0u64;
        for &i in &order {
            let h = &mut heats[i];
            if h.heat <= 0.0 {
                h.class = HeatClass::Cold;
            } else if used + h.bytes <= budget && h.density >= hot_threshold * max_density {
                h.class = HeatClass::Hot;
                used += h.bytes;
            } else if h.density >= 0.01 * max_density {
                h.class = HeatClass::Warm;
            } else {
                h.class = HeatClass::Cold;
            }
        }
        let mut hint = PlacementHint {
            function: function.to_string(),
            objects: heats,
            by_key: HashMap::new(),
            by_site: HashMap::new(),
        };
        hint.rebuild_index();
        hint
    }

    fn rebuild_index(&mut self) {
        self.by_key = self
            .objects
            .iter()
            .enumerate()
            .map(|(i, h)| ((h.site.clone(), h.seq), i))
            .collect();
        // site-only fallback keeps the *hottest* instance of the site
        self.by_site.clear();
        for (i, h) in self.objects.iter().enumerate() {
            let e = self.by_site.entry(h.site.clone()).or_insert(i);
            if self.objects[*e].density < h.density {
                *e = i;
            }
        }
    }

    /// Look up the class for a new allocation (next invocation).
    pub fn classify(&self, obj: &MemoryObject) -> Option<HeatClass> {
        self.by_key
            .get(&(obj.site.clone(), obj.seq))
            .or_else(|| self.by_site.get(&obj.site))
            .map(|&i| self.objects[i].class)
    }

    pub fn hot_bytes(&self) -> u64 {
        self.objects.iter().filter(|h| h.class == HeatClass::Hot).map(|h| h.bytes).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Serialize for the tuner's hint cache.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("function", Json::str(self.function.clone())),
            (
                "objects",
                Json::arr(self.objects.iter().map(|h| {
                    Json::obj(vec![
                        ("site", Json::str(h.site.clone())),
                        ("seq", Json::num(h.seq as f64)),
                        ("bytes", Json::num(h.bytes as f64)),
                        ("heat", Json::num(h.heat)),
                        ("class", Json::str(h.class.name())),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<PlacementHint, String> {
        let function = v.get("function").and_then(|f| f.as_str()).ok_or("missing function")?;
        let objects = v
            .get("objects")
            .and_then(|o| o.as_arr())
            .ok_or("missing objects")?
            .iter()
            .map(|o| -> Result<ObjectHeat, String> {
                let site = o.get("site").and_then(|s| s.as_str()).ok_or("site")?.to_string();
                let seq = o.get("seq").and_then(|s| s.as_u64()).ok_or("seq")?;
                let bytes = o.get("bytes").and_then(|s| s.as_u64()).ok_or("bytes")?;
                let heat = o.get("heat").and_then(|s| s.as_f64()).ok_or("heat")?;
                let class = match o.get("class").and_then(|s| s.as_str()) {
                    Some("hot") => HeatClass::Hot,
                    Some("warm") => HeatClass::Warm,
                    Some("cold") => HeatClass::Cold,
                    _ => return Err("class".into()),
                };
                let density = heat / bytes.max(1) as f64;
                Ok(ObjectHeat { site, seq, bytes, heat, density, class })
            })
            .collect::<Result<Vec<_>, _>>()?;
        let mut hint = PlacementHint {
            function: function.to_string(),
            objects,
            by_key: HashMap::new(),
            by_site: HashMap::new(),
        };
        hint.rebuild_index();
        Ok(hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MonitorConfig;
    use crate::shim::object::ObjectId;
    use crate::sim::machine::AccessObserver;

    fn obj(id: u32, start: u64, bytes: u64, site: &str) -> MemoryObject {
        MemoryObject {
            id: ObjectId(id),
            start,
            bytes,
            site: site.into(),
            seq: id as u64,
            via_mmap: true,
        }
    }

    fn profiled_hint(hot_frac_budget: f64) -> (PlacementHint, MemoryObject, MemoryObject) {
        let base = crate::shim::intercept::MMAP_BASE;
        let hot = obj(0, base, 1 << 20, "fn/hot");
        let cold = obj(1, base + (1 << 20), 8 << 20, "fn/cold");
        let mcfg = MonitorConfig {
            sample_interval_ns: 100,
            aggregation_interval_ns: 10_000,
            ..Default::default()
        };
        let mut damon = Damon::new(&mcfg, 4096, 3);
        damon.on_alloc(0.0, &hot);
        damon.on_alloc(0.0, &cold);
        let mut rng = crate::util::prng::Rng::new(5);
        let mut t = 0.0;
        for _ in 0..100_000 {
            t += 30.0;
            let addr = if rng.chance(0.95) {
                hot.start + rng.gen_range(hot.bytes)
            } else {
                cold.start + rng.gen_range(cold.bytes)
            };
            damon.on_access(t, addr, 8, false);
        }
        let objs = vec![hot.clone(), cold.clone()];
        (PlacementHint::generate("fn", &damon, &objs, hot_frac_budget, 0.1), hot, cold)
    }

    #[test]
    fn hot_object_classified_hot() {
        let (hint, hot, cold) = profiled_hint(0.35);
        assert_eq!(hint.classify(&hot), Some(HeatClass::Hot));
        let cold_class = hint.classify(&cold).unwrap();
        assert_ne!(cold_class, HeatClass::Hot);
        assert_eq!(cold_class.tier(), TierKind::Cxl);
    }

    #[test]
    fn zero_budget_means_no_hot() {
        let (hint, hot, _) = profiled_hint(0.0);
        assert_ne!(hint.classify(&hot), Some(HeatClass::Hot));
    }

    #[test]
    fn site_fallback_survives_address_change() {
        let (hint, hot, _) = profiled_hint(0.35);
        // same site, different seq/address — the §4.2 payload-change case
        let moved = obj(9, crate::shim::intercept::MMAP_BASE + (64 << 20), 1 << 20, "fn/hot");
        assert_eq!(hint.classify(&moved), hint.classify(&hot));
    }

    #[test]
    fn unknown_object_unclassified() {
        let (hint, _, _) = profiled_hint(0.35);
        let unknown = obj(7, 0x100, 64, "other/site");
        assert_eq!(hint.classify(&unknown), None);
    }

    #[test]
    fn json_roundtrip() {
        let (hint, hot, _) = profiled_hint(0.35);
        let j = hint.to_json();
        let parsed = PlacementHint::from_json(&j).unwrap();
        assert_eq!(parsed.function, "fn");
        assert_eq!(parsed.objects.len(), hint.objects.len());
        assert_eq!(parsed.classify(&hot), hint.classify(&hot));
    }

    #[test]
    fn hot_bytes_respects_budget() {
        let (hint, _, _) = profiled_hint(0.35);
        let total: u64 = hint.objects.iter().map(|o| o.bytes).sum();
        assert!(hint.hot_bytes() <= (total as f64 * 0.35) as u64 + 1);
    }
}
