//! The page-placement policies compared across the experiments.
//!
//! * `FixedPlacer` (in `mem::tiered`): AllDram / AllCxl — Fig. 2's
//!   endpoints.
//! * [`FirstTouchDram`]: the kernel default — local DRAM until pressure,
//!   then spill to CXL.
//! * [`HintedPlacer`]: §3's static placement — hot objects (per the
//!   cached [`PlacementHint`]) to DRAM, cold/warm to CXL.
//! * [`TppMigrator`]: TPP-like [7] reactive promotion/demotion — the
//!   state-of-the-art kernel baseline the paper positions against.

use crate::mem::page::PageNo;
use crate::mem::tier::TierKind;
use crate::mem::tiered::{Migration, PagePlacer, TieredMemory};
use crate::placement::hints::PlacementHint;
use crate::shim::object::MemoryObject;
use crate::sim::machine::Migrator;

/// Kernel-default NUMA-local first touch: allocate in DRAM while it has
/// headroom, spill to CXL beyond the pressure threshold.
pub struct FirstTouchDram {
    /// DRAM occupancy above which new pages go to CXL.
    pub pressure: f64,
}

impl Default for FirstTouchDram {
    fn default() -> Self {
        FirstTouchDram { pressure: 0.90 }
    }
}

impl PagePlacer for FirstTouchDram {
    fn place(&mut self, _obj: &MemoryObject, _page_idx: u64, mem: &TieredMemory) -> TierKind {
        if mem.tier(TierKind::Dram).occupancy() < self.pressure {
            TierKind::Dram
        } else {
            TierKind::Cxl
        }
    }

    fn name(&self) -> &str {
        "first-touch-dram"
    }
}

/// §3 static placement from a cached hint. Objects the hint does not
/// know follow `unknown_tier` (CXL in the §3 experiment, DRAM for
/// Porter's SLO-safe first invocation).
pub struct HintedPlacer {
    pub hint: PlacementHint,
    pub unknown_tier: TierKind,
}

impl HintedPlacer {
    pub fn new(hint: PlacementHint) -> HintedPlacer {
        HintedPlacer { hint, unknown_tier: TierKind::Cxl }
    }
}

impl PagePlacer for HintedPlacer {
    fn place(&mut self, obj: &MemoryObject, _page_idx: u64, _mem: &TieredMemory) -> TierKind {
        match self.hint.classify(obj) {
            Some(class) => class.tier(),
            None => self.unknown_tier,
        }
    }

    fn name(&self) -> &str {
        "static-hint"
    }
}

/// TPP-like reactive migration: promote CXL pages that exceed an access
/// threshold within an aggregation window; demote idle DRAM pages when
/// DRAM occupancy crosses the watermark. Placement side is first-touch.
pub struct TppMigrator {
    /// Window accesses to qualify for promotion.
    pub promote_threshold: u32,
    /// Keep this fraction of DRAM free (demotion watermark).
    pub free_watermark: f64,
    /// Demotion candidates must have been idle at least this many ticks.
    pub idle_ticks_min: u8,
    /// Cap on migrations per tick (kernel rate limit).
    pub max_moves_per_tick: usize,
}

impl Default for TppMigrator {
    fn default() -> Self {
        TppMigrator {
            promote_threshold: 3,
            free_watermark: 0.10,
            idle_ticks_min: 2,
            max_moves_per_tick: 512,
        }
    }
}

impl Migrator for TppMigrator {
    fn plan(&mut self, mem: &TieredMemory) -> Vec<Migration> {
        let mut moves = Vec::new();
        let page_bytes = mem.page_bytes();
        let dram = mem.tier(TierKind::Dram);
        let free_target = (dram.params.capacity as f64 * self.free_watermark) as u64;
        let mut dram_free = dram.free_bytes();

        // promotion scan: hot CXL pages → DRAM while room remains
        let mut promote: Vec<(PageNo, u32)> = mem
            .pages
            .iter_mapped()
            .filter(|(_, m)| {
                m.tier() == Some(TierKind::Cxl)
                    && m.window_accesses >= self.promote_threshold as u16
            })
            .map(|(p, m)| (p, m.window_accesses as u32))
            .collect();
        promote.sort_by_key(|&(_, w)| std::cmp::Reverse(w));
        for (p, _) in promote.into_iter().take(self.max_moves_per_tick) {
            if dram_free < page_bytes + free_target {
                break;
            }
            moves.push(Migration { page: p, from: TierKind::Cxl, to: TierKind::Dram });
            dram_free -= page_bytes;
        }

        // demotion scan: if DRAM is above watermark, push the coldest
        // idle pages to CXL
        if dram_free < free_target {
            let mut need = free_target - dram_free;
            let mut demote: Vec<(PageNo, u8)> = mem
                .pages
                .iter_mapped()
                .filter(|(_, m)| {
                    m.tier() == Some(TierKind::Dram)
                        && m.idle_ticks >= self.idle_ticks_min
                        && m.window_accesses == 0
                })
                .map(|(p, m)| (p, m.idle_ticks))
                .collect();
            demote.sort_by_key(|&(_, idle)| std::cmp::Reverse(idle));
            for (p, _) in demote.into_iter().take(self.max_moves_per_tick) {
                moves.push(Migration { page: p, from: TierKind::Dram, to: TierKind::Cxl });
                need = need.saturating_sub(page_bytes);
                if need == 0 {
                    break;
                }
            }
        }
        moves
    }

    fn name(&self) -> &str {
        "tpp-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::shim::object::ObjectId;

    fn obj(id: u32, start: u64, bytes: u64, site: &str) -> MemoryObject {
        MemoryObject {
            id: ObjectId(id),
            start,
            bytes,
            site: site.into(),
            seq: id as u64,
            via_mmap: true,
        }
    }

    fn tiny_cfg(dram_pages: u64) -> MachineConfig {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = dram_pages * cfg.page_bytes;
        cfg.cxl_bytes = 1 << 30;
        cfg
    }

    #[test]
    fn first_touch_spills_under_pressure() {
        let cfg = tiny_cfg(10);
        let mut mem = TieredMemory::new(&cfg);
        let mut placer = FirstTouchDram { pressure: 0.5 };
        let o = obj(0, crate::shim::intercept::MMAP_BASE, 10 * cfg.page_bytes, "t");
        mem.map_object(&o, &mut placer);
        // after 5 pages DRAM hits 50% occupancy → remainder goes to CXL
        assert_eq!(mem.used(TierKind::Dram), 5 * cfg.page_bytes);
        assert_eq!(mem.used(TierKind::Cxl), 5 * cfg.page_bytes);
    }

    #[test]
    fn tpp_promotes_hot_cxl_pages() {
        let cfg = tiny_cfg(100);
        let mut mem = TieredMemory::new(&cfg);
        let o = obj(0, crate::shim::intercept::MMAP_BASE, 4 * cfg.page_bytes, "t");
        mem.map_object(&o, &mut crate::mem::tiered::FixedPlacer { kind: TierKind::Cxl });
        // heat up page 0
        let p0 = mem.pages.page_of(o.start);
        for _ in 0..10 {
            mem.pages.touch(p0);
        }
        let mut tpp = TppMigrator::default();
        let plan = tpp.plan(&mem);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].page, p0);
        assert_eq!(plan[0].to, TierKind::Dram);
    }

    #[test]
    fn tpp_demotes_idle_pages_under_watermark_pressure() {
        let cfg = tiny_cfg(4); // 4 DRAM pages, watermark 10% → needs ~1 free
        let mut mem = TieredMemory::new(&cfg);
        let o = obj(0, crate::shim::intercept::MMAP_BASE, 4 * cfg.page_bytes, "t");
        mem.map_object(&o, &mut crate::mem::tiered::FixedPlacer { kind: TierKind::Dram });
        // everything idle
        for _ in 0..3 {
            mem.end_window();
        }
        let mut tpp = TppMigrator::default();
        let plan = tpp.plan(&mem);
        assert!(!plan.is_empty());
        assert!(plan.iter().all(|m| m.to == TierKind::Cxl));
    }

    #[test]
    fn tpp_respects_rate_limit() {
        let cfg = tiny_cfg(10_000);
        let mut mem = TieredMemory::new(&cfg);
        let o = obj(0, crate::shim::intercept::MMAP_BASE, 2048 * cfg.page_bytes, "t");
        mem.map_object(&o, &mut crate::mem::tiered::FixedPlacer { kind: TierKind::Cxl });
        let first = mem.pages.page_of(o.start);
        for i in 0..2048u32 {
            let p = PageNo { index: first.index + i, ..first };
            for _ in 0..5 {
                mem.pages.touch(p);
            }
        }
        let mut tpp = TppMigrator { max_moves_per_tick: 64, ..Default::default() };
        assert_eq!(tpp.plan(&mem).len(), 64);
    }
}
