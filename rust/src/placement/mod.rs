//! Placement: turning profiles into tier decisions (§3 of the paper).
//!
//! * [`hints`] — match DAMON's hot regions against the shim's object log
//!   to classify each object hot/warm/cold and produce a
//!   [`hints::PlacementHint`] (the metadata Porter caches per function).
//! * [`policies`] — the page placers the experiments compare: AllDram,
//!   AllCxl, FirstTouchDram, hint-driven static placement, and a
//!   TPP-like promotion/demotion migrator as the kernel-baseline.
//! * [`static_place`] — the §3 profile→place pipeline in one call.
//! * [`provision`] — per-function DRAM provisioning: what-if trace
//!   replays build latency-vs-DRAM [`provision::DemandCurve`]s, and a
//!   [`provision::BudgetAllocator`] partitions a node's DRAM across its
//!   resident functions by greedy marginal-utility descent, replacing
//!   the global `dram_budget_frac` with per-function budgets.

pub mod hints;
pub mod policies;
pub mod provision;
pub mod static_place;

pub use hints::{HeatClass, ObjectHeat, PlacementHint};
pub use policies::{FirstTouchDram, HintedPlacer, TppMigrator};
pub use provision::{Allocation, BudgetAllocator, DemandCurve, FunctionDemand};
pub use static_place::{profile_and_place, StaticPlacementResult};
