//! Execution model: translates a workload's access/compute stream into
//! virtual time on a machine with an L3 cache and DRAM/CXL tiers.
//!
//! This is the substitution for the paper's physical testbed (Table 1):
//! the same workloads that ran on the dual-socket Xeon run here against
//! an analytic cache + tier latency model. `Machine` implements
//! [`crate::trace::Sink`], so workloads stream straight into it.

pub mod cache;
pub mod colocate;
pub mod lanes;
pub mod machine;
pub mod prefetch;

pub use cache::Cache;
pub use colocate::{colocate, ColocationReport};
pub use lanes::LaneScheduler;
pub use machine::{Machine, RunReport};
pub use prefetch::StridePrefetcher;
