//! The machine: cache + tiered memory + virtual clock.
//!
//! `Machine` implements [`Sink`], so a workload streamed into it is
//! "executed on" the simulated testbed: compute ops advance the clock at
//! core frequency, accesses filter through the LLC, misses pay the
//! resident tier's (possibly contended) latency. Attachable observers
//! (DAMON, heatmap) watch the time-annotated access stream, and an
//! optional [`Migrator`] is ticked at aggregation intervals to move pages
//! between tiers at runtime (§4's promotion/demotion thread).

use crate::config::MachineConfig;
use crate::mem::tier::TierKind;
use crate::mem::tiered::{FixedPlacer, Migration, PagePlacer, TieredMemory};
use crate::shim::object::MemoryObject;
use crate::sim::cache::Cache;
use crate::sim::lanes::LaneScheduler;
use crate::sim::prefetch::StridePrefetcher;
use crate::trace::Sink;

/// Time-annotated observer of the access stream (DAMON, heatmaps).
pub trait AccessObserver {
    fn on_access(&mut self, t_ns: f64, addr: u64, bytes: u32, write: bool);
    fn on_alloc(&mut self, _t_ns: f64, _obj: &MemoryObject) {}
    fn on_free(&mut self, _t_ns: f64, _obj: &MemoryObject) {}
    fn on_phase(&mut self, _t_ns: f64, _name: &str) {}
    /// Called at every aggregation tick with the current virtual time.
    fn on_tick(&mut self, _t_ns: f64) {}
    /// Downcast support so callers can take concrete observers back off
    /// the machine after a run (`Box<dyn Any>::downcast::<Damon>()`).
    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any>;
}

/// Runtime page-migration policy, ticked at aggregation intervals.
pub trait Migrator {
    /// Inspect page metadata and return the migrations to perform.
    fn plan(&mut self, mem: &TieredMemory) -> Vec<Migration>;
    fn name(&self) -> &str;
    /// Called right after the machine applies a plan, with exactly the
    /// moves `TieredMemory::migrate` accepted — the ground truth for
    /// any counters the migrator keeps (predicting acceptance would
    /// drift the moment migrate() grows a new rejection rule).
    fn note_applied(&mut self, _applied: &[Migration]) {}
    /// Engine-level counters (epoch/ping-pong/deferred accounting);
    /// plain migrators report none.
    fn metrics(&self) -> Option<crate::mem::migrate::MigrationMetrics> {
        None
    }
}

/// Final accounting of one run. `PartialEq` is exact (f64 bit
/// semantics): the replay-identity invariant asserts a replayed run
/// reproduces the live run's report field-for-field.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub policy: String,
    pub wall_ns: f64,
    pub compute_ns: f64,
    pub stall_ns: f64,
    pub hit_ns: f64,
    pub migration_stall_ns: f64,
    pub accesses: u64,
    pub l3_hits: u64,
    pub l3_misses: u64,
    pub dram_misses: u64,
    pub cxl_misses: u64,
    pub promotions: u64,
    pub demotions: u64,
    /// Pages the migration engine re-moved within its ping-pong window
    /// (0 for plain migrators).
    pub ping_pongs: u64,
    /// Bytes actually copied between tiers by applied migrations.
    pub migration_bytes: u64,
    pub peak_dram_bytes: u64,
    pub peak_cxl_bytes: u64,
    /// Latency hidden by lane overlap: serial-sum cost minus the wall
    /// advance it produced. 0 when `[lanes]` is off.
    pub overlapped_ns: f64,
    /// Lane annotations applied (0 when `[lanes]` is off).
    pub lane_switches: u64,
    /// Lines the stride prefetcher issued / that turned demand misses
    /// into hits. 0 when the prefetcher is off.
    pub prefetch_issued: u64,
    pub prefetch_useful: u64,
}

impl RunReport {
    /// Memory backend-boundness: share of wall time stalled on memory
    /// traffic (the paper's VTune metric, Fig. 2's blue line).
    pub fn boundness(&self) -> f64 {
        if self.wall_ns <= 0.0 {
            0.0
        } else {
            (self.stall_ns + self.hit_ns) / self.wall_ns
        }
    }

    /// Slowdown of this run relative to a baseline run, in percent.
    pub fn slowdown_pct_vs(&self, base: &RunReport) -> f64 {
        (self.wall_ns / base.wall_ns - 1.0) * 100.0
    }

    pub fn l3_hit_rate(&self) -> f64 {
        let t = self.l3_hits + self.l3_misses;
        if t == 0 {
            0.0
        } else {
            self.l3_hits as f64 / t as f64
        }
    }
}

/// The simulated testbed.
pub struct Machine {
    cfg: MachineConfig,
    pub cache: Cache,
    pub mem: TieredMemory,
    placer: Box<dyn PagePlacer>,
    migrator: Option<Box<dyn Migrator>>,
    observers: Vec<Box<dyn AccessObserver>>,
    /// Optional telemetry sink: migration epochs and phase markers,
    /// stamped with the virtual clock. Recording never advances the
    /// clock, so an instrumented run's `RunReport` is bit-identical to
    /// an uninstrumented one (replay-identity preserved).
    telemetry: Option<crate::telemetry::TelemetrySink>,
    clock_ns: f64,
    compute_ns: f64,
    stall_ns: f64,
    hit_ns: f64,
    migration_stall_ns: f64,
    accesses: u64,
    dram_misses: u64,
    cxl_misses: u64,
    peak_dram: u64,
    peak_cxl: u64,
    tick_interval_ns: f64,
    next_tick_ns: f64,
    line_bytes: u64,
    inv_mlp: f64,
    /// Hardware stream-prefetcher model: expected next line numbers of
    /// recently detected sequential miss streams. A miss matching an
    /// entry is bandwidth-bound (the prefetcher already issued it);
    /// other misses pay demand latency.
    streams: [u64; 8],
    stream_cursor: usize,
    /// Lane scheduler (`[lanes]`): per-lane clocks with a max merge.
    /// `None` keeps the scalar clock on exactly the pre-lane arithmetic
    /// — every lane hook below is a single `if let` branch.
    lanes: Option<LaneScheduler>,
    /// Stride prefetcher (`[lanes] prefetch`): turns confirmed-stride
    /// misses into ahead-of-use installs that debit tier bandwidth.
    prefetcher: Option<StridePrefetcher>,
    /// Scratch buffer for prefetch candidates (reused across accesses).
    pf_buf: Vec<u64>,
}

/// Effective overlap depth of the stream prefetcher: a detected stream
/// hides all but 1/DEPTH of the demand latency, bottoming out at the
/// line transfer time (bandwidth-bound).
const PREFETCH_DEPTH: f64 = 16.0;

impl Machine {
    pub fn new(cfg: &MachineConfig, placer: Box<dyn PagePlacer>) -> Machine {
        let cache = Cache::new(cfg.l3_bytes, cfg.cache_line, cfg.l3_ways);
        let mem = TieredMemory::new(cfg);
        Machine {
            cache,
            mem,
            placer,
            migrator: None,
            observers: Vec::new(),
            telemetry: None,
            clock_ns: 0.0,
            compute_ns: 0.0,
            stall_ns: 0.0,
            hit_ns: 0.0,
            migration_stall_ns: 0.0,
            accesses: 0,
            dram_misses: 0,
            cxl_misses: 0,
            peak_dram: 0,
            peak_cxl: 0,
            tick_interval_ns: 100_000.0,
            next_tick_ns: 100_000.0,
            line_bytes: cfg.cache_line,
            inv_mlp: 1.0 / cfg.mlp,
            streams: [u64::MAX; 8],
            stream_cursor: 0,
            lanes: None,
            prefetcher: None,
            pf_buf: Vec::new(),
            cfg: cfg.clone(),
        }
    }

    /// Convenience: machine that places everything in one tier (the
    /// Fig. 2 pure-DRAM / pure-CXL endpoints).
    pub fn all_in(cfg: &MachineConfig, kind: TierKind) -> Machine {
        Machine::new(cfg, Box::new(FixedPlacer { kind }))
    }

    pub fn config(&self) -> &MachineConfig {
        &self.cfg
    }

    pub fn attach_observer(&mut self, obs: Box<dyn AccessObserver>) {
        self.observers.push(obs);
    }

    /// Take back the observers (to extract heatmaps/DAMON results).
    pub fn take_observers(&mut self) -> Vec<Box<dyn AccessObserver>> {
        std::mem::take(&mut self.observers)
    }

    pub fn set_migrator(&mut self, m: Box<dyn Migrator>) {
        self.migrator = Some(m);
    }

    /// Attach a telemetry sink (machine-level migration-epoch and phase
    /// events).
    pub fn set_telemetry(&mut self, sink: crate::telemetry::TelemetrySink) {
        self.telemetry = Some(sink);
    }

    /// Take the sink back off the machine to export what it collected.
    pub fn take_telemetry(&mut self) -> Option<crate::telemetry::TelemetrySink> {
        self.telemetry.take()
    }

    pub fn set_tick_interval_ns(&mut self, ns: f64) {
        assert!(ns > 0.0);
        self.tick_interval_ns = ns;
        self.next_tick_ns = self.clock_ns + ns;
    }

    /// Enable lane scheduling with `k` in-flight lanes (annotation lane
    /// ids fold modulo `k`). Call before streaming — clocks start at the
    /// machine's current time.
    pub fn set_lanes(&mut self, k: usize) {
        let mut s = LaneScheduler::new(k);
        s.reset_to(self.clock_ns);
        self.lanes = Some(s);
    }

    /// Enable the stride prefetcher (`degree` lines per confirmed miss,
    /// first line `distance` strides ahead).
    pub fn set_prefetcher(&mut self, degree: usize, distance: usize) {
        self.prefetcher = Some(StridePrefetcher::new(degree, distance));
    }

    pub fn clock_ns(&self) -> f64 {
        self.clock_ns
    }

    /// Jump the clock (colocation interleaving restores per-stream
    /// clocks; only forward jumps affect the bandwidth windows).
    pub fn set_clock_ns(&mut self, t: f64) {
        self.clock_ns = t;
        if let Some(s) = &mut self.lanes {
            s.reset_to(t);
        }
    }

    #[inline]
    fn maybe_tick(&mut self) {
        while self.clock_ns >= self.next_tick_ns {
            self.next_tick_ns += self.tick_interval_ns;
            // migration pass
            if let Some(mut mig) = self.migrator.take() {
                let plan = mig.plan(&self.mem);
                let mut applied = Vec::with_capacity(plan.len());
                for m in plan {
                    if self.mem.migrate(m) {
                        // a page copy reads from the source tier and
                        // writes to the destination tier
                        let pb = self.mem.page_bytes();
                        let t = self.clock_ns;
                        self.mem.tier_mut(m.from).bw.record(t, pb);
                        self.mem.tier_mut(m.to).bw.record(t, pb);
                        applied.push(m);
                    }
                }
                mig.note_applied(&applied);
                if !applied.is_empty() {
                    if let Some(sink) = &mut self.telemetry {
                        let promoted =
                            applied.iter().filter(|m| m.to == TierKind::Dram).count() as u64;
                        let demoted = applied.len() as u64 - promoted;
                        sink.push(
                            crate::telemetry::TelemetryEvent::new(
                                crate::telemetry::EventKind::MachineEpoch,
                                self.clock_ns as u64,
                            )
                            .tag(mig.name())
                            .arg("promoted", promoted)
                            .arg("demoted", demoted)
                            .arg("bytes", applied.len() as u64 * self.mem.page_bytes()),
                        );
                    }
                }
                let moved = applied.len() as u64;
                if moved > 0 {
                    // copy cost: page transfer at the slower tier's
                    // bandwidth + one latency each way; only a fraction
                    // stalls the app (background thread does the rest)
                    let pb = self.mem.page_bytes();
                    let per_page = self.mem.tier(TierKind::Cxl).params.transfer_ns(pb)
                        + self.mem.tier(TierKind::Dram).params.latency_ns
                        + self.mem.tier(TierKind::Cxl).params.latency_ns;
                    let stall = per_page * moved as f64 * self.cfg.migration_stall_frac;
                    self.clock_ns += stall;
                    self.migration_stall_ns += stall;
                }
                self.migrator = Some(mig);
            }
            for obs in &mut self.observers {
                obs.on_tick(self.clock_ns);
            }
            self.mem.end_window();
        }
    }

    /// Drive the machine from a recorded access stream instead of a
    /// live workload. The trace's events arrive through the same
    /// [`Sink`] path as live execution — same cache, same observers,
    /// same migrator ticks — so a replay against an identically
    /// configured machine produces an identical [`RunReport`] (the
    /// Trace-IR replay-identity invariant, property-tested across the
    /// workload registry).
    pub fn replay(&mut self, trace: &crate::trace::AccessTrace) {
        trace.replay(self);
    }

    /// Finish the run and produce the report.
    pub fn report(&self) -> RunReport {
        let ping_pongs =
            self.migrator.as_ref().and_then(|m| m.metrics()).map(|m| m.ping_pongs).unwrap_or(0);
        RunReport {
            policy: self.placer.name().to_string(),
            wall_ns: self.clock_ns,
            compute_ns: self.compute_ns,
            stall_ns: self.stall_ns,
            hit_ns: self.hit_ns,
            migration_stall_ns: self.migration_stall_ns,
            accesses: self.accesses,
            l3_hits: self.cache.hits,
            l3_misses: self.cache.misses,
            dram_misses: self.dram_misses,
            cxl_misses: self.cxl_misses,
            promotions: self.mem.promotions,
            demotions: self.mem.demotions,
            ping_pongs,
            migration_bytes: (self.mem.promotions + self.mem.demotions) * self.mem.page_bytes(),
            peak_dram_bytes: self.peak_dram,
            peak_cxl_bytes: self.peak_cxl,
            overlapped_ns: self.lanes.as_ref().map_or(0.0, |s| s.overlapped_ns()),
            lane_switches: self.lanes.as_ref().map_or(0, |s| s.switches()),
            prefetch_issued: self.prefetcher.as_ref().map_or(0, |p| p.issued),
            prefetch_useful: self.prefetcher.as_ref().map_or(0, |p| p.useful),
        }
    }
}

impl Sink for Machine {
    fn alloc(&mut self, obj: &MemoryObject) {
        self.mem.map_object(obj, self.placer.as_mut());
        self.peak_dram = self.peak_dram.max(self.mem.used(TierKind::Dram));
        self.peak_cxl = self.peak_cxl.max(self.mem.used(TierKind::Cxl));
        // an mmap syscall is not free: ~1µs of kernel time
        self.clock_ns += 1_000.0;
        // a syscall is a full barrier: every lane joins
        if let Some(s) = &mut self.lanes {
            s.barrier(self.clock_ns);
        }
        for obs in &mut self.observers {
            obs.on_alloc(self.clock_ns, obj);
        }
    }

    fn free(&mut self, obj: &MemoryObject) {
        // brk heaps don't shrink in practice; release mmap regions only.
        if obj.via_mmap {
            self.mem.unmap_object(obj, |_| false);
        }
        self.clock_ns += 1_000.0;
        if let Some(s) = &mut self.lanes {
            s.barrier(self.clock_ns);
        }
        for obs in &mut self.observers {
            obs.on_free(self.clock_ns, obj);
        }
    }

    #[inline]
    fn access(&mut self, addr: u64, bytes: u32, write: bool) {
        self.accesses += 1;
        // costs accrue on the current lane's clock; without lanes that
        // *is* the scalar clock, keeping the disabled path bit-identical
        let clock = match &self.lanes {
            Some(s) => s.now(),
            None => self.clock_ns,
        };
        if !self.observers.is_empty() {
            for obs in &mut self.observers {
                obs.on_access(clock, addr, bytes, write);
            }
        }
        let line_bytes = self.line_bytes;
        let inv_mlp = self.inv_mlp;
        let mem = &mut self.mem;
        let streams = &mut self.streams;
        let stream_cursor = &mut self.stream_cursor;
        let prefetcher = &mut self.prefetcher;
        let pf_buf = &mut self.pf_buf;
        pf_buf.clear();
        let mut stall = 0.0;
        let mut dram_misses = 0u64;
        let mut cxl_misses = 0u64;
        let (hits, misses) = self.cache.access(addr, bytes, |line_addr| {
            let p = mem.pages.page_of(line_addr);
            let page_bytes = mem.page_bytes();
            // untracked addresses (workload bookkeeping outside the shim)
            // map on first touch to local DRAM — the kernel default
            let (kind, was_unmapped) = mem.pages.touch_and_map(p);
            if was_unmapped {
                mem.tier_mut(TierKind::Dram).used_bytes += page_bytes;
            }
            // stream-prefetch check: is this line the successor of a
            // recent sequential miss stream?
            let line_no = line_addr / line_bytes;
            let prefetched = match streams.iter().position(|&s| s == line_no) {
                Some(i) => {
                    streams[i] = line_no + 1;
                    true
                }
                None => {
                    streams[*stream_cursor] = line_no + 1;
                    *stream_cursor = (*stream_cursor + 1) % streams.len();
                    false
                }
            };
            if let Some(pf) = prefetcher {
                pf.on_miss(line_no, pf_buf);
            }
            let tier = mem.tier_mut(kind);
            tier.bw.record(clock + stall, line_bytes);
            let factor = tier.bw.factor();
            let cost = if prefetched {
                // prefetcher hides demand latency down to the line
                // transfer time; contention inflates both terms
                (tier.params.latency_ns / PREFETCH_DEPTH).max(tier.params.transfer_ns(line_bytes))
                    * factor
            } else {
                (tier.params.latency_ns * factor + tier.params.transfer_ns(line_bytes)) * inv_mlp
            };
            stall += cost;
            match kind {
                TierKind::Dram => dram_misses += 1,
                TierKind::Cxl => cxl_misses += 1,
            }
        });
        // install confirmed-stride prefetches: already-mapped pages
        // only (a prefetch never faults a page in), off the critical
        // path but debiting the target tier's bandwidth like any fetch
        for i in 0..self.pf_buf.len() {
            let line_no = self.pf_buf[i];
            let p = self.mem.pages.page_of(line_no * line_bytes);
            if let Some(kind) = self.mem.pages.tier_of(p) {
                self.cache.install_line(line_no);
                self.mem.tier_mut(kind).bw.record(clock, line_bytes);
            }
        }
        if misses == 0 && hits > 0 {
            if let Some(pf) = &mut self.prefetcher {
                pf.note_hit(addr / line_bytes);
            }
        }
        let hit_cost = hits as f64 * self.cfg.l3_hit_ns;
        match &mut self.lanes {
            Some(s) => {
                s.advance(stall + hit_cost);
                self.clock_ns = s.wall_ns();
            }
            None => self.clock_ns += stall + hit_cost,
        }
        self.stall_ns += stall;
        self.hit_ns += hit_cost;
        self.dram_misses += dram_misses;
        self.cxl_misses += cxl_misses;
        let before = self.clock_ns;
        self.maybe_tick();
        if self.clock_ns > before {
            // migration stalled the whole invocation: lanes join
            if let Some(s) = &mut self.lanes {
                s.barrier(self.clock_ns);
            }
        }
    }

    #[inline]
    fn compute(&mut self, cycles: u64) {
        let ns = cycles as f64 / self.cfg.cycles_per_ns();
        match &mut self.lanes {
            Some(s) => {
                s.advance(ns);
                self.clock_ns = s.wall_ns();
            }
            None => self.clock_ns += ns,
        }
        self.compute_ns += ns;
        let before = self.clock_ns;
        self.maybe_tick();
        if self.clock_ns > before {
            if let Some(s) = &mut self.lanes {
                s.barrier(self.clock_ns);
            }
        }
    }

    fn lane(&mut self, lane: u8, after_mask: u64) {
        // one branch when `[lanes]` is off — annotated streams stay
        // bit-identical on the scalar clock
        if let Some(s) = &mut self.lanes {
            s.switch(lane, after_mask);
        }
    }

    fn phase(&mut self, name: &str) {
        // a phase marker is a program-order checkpoint: lanes join, so
        // work after the marker can't overlap work before it
        if let Some(s) = &mut self.lanes {
            s.barrier(self.clock_ns);
        }
        let t = self.clock_ns;
        for obs in &mut self.observers {
            obs.on_phase(t, name);
        }
        if let Some(sink) = &mut self.telemetry {
            sink.push(
                crate::telemetry::TelemetryEvent::new(
                    crate::telemetry::EventKind::Phase,
                    t as u64,
                )
                .tag(name),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::env::Env;

    fn cfg() -> MachineConfig {
        MachineConfig::default()
    }

    /// A pointer-chasing microworkload: every access misses once the
    /// working set exceeds L3. The chase order is a random full cycle so
    /// there are no short loops that would stay cache-resident.
    fn chase(env: &mut Env, n: usize, iters: usize) {
        let mut rng = crate::util::prng::Rng::new(0xC4A5E);
        let mut perm: Vec<u64> = (0..n as u64).collect();
        rng.shuffle(&mut perm);
        let mut data = vec![0u64; n];
        for k in 0..n {
            data[perm[k] as usize] = perm[(k + 1) % n];
        }
        let v = env.tvec_from(data, "chase");
        let mut idx = perm[0];
        for _ in 0..iters {
            idx = v.get(idx as usize, env);
            env.compute(4);
        }
        std::hint::black_box(idx);
    }

    #[test]
    fn cxl_slower_than_dram_for_random_access() {
        let n = 4_000_000; // 32MB of u64 > 19.25MB L3
        let run = |kind| {
            let mut m = Machine::all_in(&cfg(), kind);
            let mut env = Env::new(4096, &mut m);
            chase(&mut env, n, 200_000);
            m.report()
        };
        let dram = run(TierKind::Dram);
        let cxl = run(TierKind::Cxl);
        assert!(cxl.wall_ns > dram.wall_ns * 1.1, "dram={} cxl={}", dram.wall_ns, cxl.wall_ns);
        assert!(dram.boundness() > 0.5, "chase should be memory-bound: {}", dram.boundness());
        assert!(cxl.cxl_misses > 0 && cxl.dram_misses == 0);
        assert!(dram.dram_misses > 0 && dram.cxl_misses == 0);
    }

    #[test]
    fn compute_heavy_sees_little_cxl_impact() {
        let run = |kind| {
            let mut m = Machine::all_in(&cfg(), kind);
            let mut env = Env::new(4096, &mut m);
            let v = env.tvec::<u64>(1024, 1, "small");
            for i in 0..50_000 {
                let x = v.get(i % 1024, &mut env);
                env.compute(200 + (x % 2));
            }
            m.report()
        };
        let dram = run(TierKind::Dram);
        let cxl = run(TierKind::Cxl);
        let slowdown = cxl.slowdown_pct_vs(&dram);
        assert!(slowdown < 5.0, "slowdown={slowdown}");
        assert!(dram.boundness() < 0.2);
    }

    #[test]
    fn clock_advances_with_compute() {
        let mut m = Machine::all_in(&cfg(), TierKind::Dram);
        m.compute(2600); // 2600 cycles @2.6GHz = 1000ns
        assert!((m.clock_ns() - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn report_accounting_consistent() {
        let mut m = Machine::all_in(&cfg(), TierKind::Dram);
        let mut env = Env::new(4096, &mut m);
        chase(&mut env, 100_000, 10_000);
        let r = m.report();
        assert_eq!(r.accesses, 10_000);
        assert_eq!(r.l3_hits + r.l3_misses, r.dram_misses + r.cxl_misses + r.l3_hits);
        // wall = compute + stall + hits + alloc syscalls + migration
        let explained = r.compute_ns + r.stall_ns + r.hit_ns + r.migration_stall_ns;
        assert!(r.wall_ns >= explained);
        assert!(r.wall_ns - explained < 10_000.0); // just the 1µs mmap costs
    }

    #[test]
    fn untracked_access_defaults_to_dram() {
        let mut m = Machine::all_in(&cfg(), TierKind::Cxl);
        m.access(crate::shim::intercept::HEAP_BASE + 0x100, 8, false);
        let r = m.report();
        assert_eq!(r.dram_misses, 1);
    }

    struct PromoteAll;
    impl Migrator for PromoteAll {
        fn plan(&mut self, mem: &TieredMemory) -> Vec<Migration> {
            mem.pages
                .iter_mapped()
                .filter(|(_, m)| m.tier() == Some(TierKind::Cxl) && m.window_accesses > 0)
                .map(|(p, _)| Migration { page: p, from: TierKind::Cxl, to: TierKind::Dram })
                .collect()
        }
        fn name(&self) -> &str {
            "promote-all"
        }
    }

    #[test]
    fn replay_reproduces_live_report_exactly() {
        let record = || {
            let mut live = Machine::all_in(&cfg(), TierKind::Cxl);
            let mut env = Env::new_recording(4096, &mut live);
            chase(&mut env, 100_000, 20_000);
            let trace = env.finish_recording().expect("recording env");
            (live.report(), trace)
        };
        let (live_report, trace) = record();
        let mut replayed = Machine::all_in(&cfg(), TierKind::Cxl);
        replayed.replay(&trace);
        assert_eq!(replayed.report(), live_report, "replay-identity invariant");
        // and replays are deterministic among themselves
        let mut again = Machine::all_in(&cfg(), TierKind::Cxl);
        again.replay(&trace);
        assert_eq!(again.report(), live_report);
    }

    #[test]
    fn telemetry_sink_does_not_perturb_the_run() {
        let run = |with_sink: bool| {
            let mut m = Machine::all_in(&cfg(), TierKind::Cxl);
            m.set_tick_interval_ns(10_000.0);
            m.set_migrator(Box::new(PromoteAll));
            if with_sink {
                m.set_telemetry(crate::telemetry::TelemetrySink::new(1 << 20));
            }
            let mut env = Env::new(4096, &mut m);
            env.phase("chase");
            let v = env.tvec::<u64>(512, 0, "hot");
            for i in 0..20_000 {
                let _ = v.get(i % 512, &mut env);
                env.compute(10);
            }
            let sink = m.take_telemetry();
            (m.report(), sink)
        };
        let (plain, none) = run(false);
        let (instrumented, sink) = run(true);
        assert!(none.is_none());
        // exact equality, f64 bits included: recording is pure observation
        assert_eq!(instrumented, plain, "telemetry must not perturb RunReport");
        let sink = sink.unwrap();
        assert!(sink.total_events() > 0);
        let kinds = sink.kind_counts();
        assert!(kinds.contains_key("machine_epoch"), "migration epochs recorded: {kinds:?}");
        assert!(kinds.contains_key("phase"), "phase markers recorded: {kinds:?}");
    }

    /// Two independent lanes: a pointer chase on lane 0, pure compute on
    /// lane 1. Nothing serializes them, so the compute should hide under
    /// the chase's stalls.
    fn laned_stream(env: &mut Env) {
        let mut rng = crate::util::prng::Rng::new(0x7A9E5);
        let n = 4_000_000;
        let mut perm: Vec<u64> = (0..n as u64).collect();
        rng.shuffle(&mut perm);
        let mut data = vec![0u64; n];
        for k in 0..n {
            data[perm[k] as usize] = perm[(k + 1) % n];
        }
        let v = env.tvec_from(data, "chase");
        let mut idx = perm[0];
        for _ in 0..5_000 {
            env.lane(0, 0b01); // chase depends only on itself
            idx = v.get(idx as usize, env);
            env.lane(1, 0b10); // compute depends only on itself
            env.compute(500);
        }
        std::hint::black_box(idx);
    }

    #[test]
    fn lanes_hide_stalls_under_compute() {
        let run = |k: usize| {
            let mut m = Machine::all_in(&cfg(), TierKind::Cxl);
            if k > 0 {
                m.set_lanes(k);
            }
            let mut env = Env::new(4096, &mut m);
            laned_stream(&mut env);
            m.report()
        };
        let serial = run(0);
        let laned = run(2);
        assert!(serial.overlapped_ns == 0.0 && serial.lane_switches == 0);
        assert!(laned.overlapped_ns > 0.0, "independent lanes must overlap");
        assert!(laned.lane_switches > 0);
        assert!(
            laned.wall_ns < serial.wall_ns,
            "laned {} !< serial {}",
            laned.wall_ns,
            serial.wall_ns
        );
        // hiding latency is not erasing it: compute is identical, and
        // stall only drifts through contention-window timing (lane-local
        // bandwidth timestamps), not through dropped costs
        assert_eq!(laned.compute_ns, serial.compute_ns);
        let drift = (laned.stall_ns - serial.stall_ns).abs();
        assert!(drift < 0.1 * serial.stall_ns, "stall drift {drift}");
        assert!(laned.wall_ns + laned.overlapped_ns >= serial.wall_ns * 0.9);
    }

    #[test]
    fn lane_annotations_are_inert_when_disabled() {
        let run = |annotated: bool| {
            let mut m = Machine::all_in(&cfg(), TierKind::Cxl);
            let mut env = Env::new(4096, &mut m);
            if annotated {
                laned_stream(&mut env);
            } else {
                // the identical stream minus the lane annotations
                let mut rng = crate::util::prng::Rng::new(0x7A9E5);
                let n = 4_000_000;
                let mut perm: Vec<u64> = (0..n as u64).collect();
                rng.shuffle(&mut perm);
                let mut data = vec![0u64; n];
                for k in 0..n {
                    data[perm[k] as usize] = perm[(k + 1) % n];
                }
                let v = env.tvec_from(data, "chase");
                let mut idx = perm[0];
                for _ in 0..5_000 {
                    idx = v.get(idx as usize, &mut env);
                    env.compute(500);
                }
                std::hint::black_box(idx);
            }
            m.report()
        };
        // exact equality, f64 bits included: the lane hook must be a
        // no-op branch on the scalar clock
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn lane_replay_reproduces_live_report_exactly() {
        let machine = || {
            let mut m = Machine::all_in(&cfg(), TierKind::Cxl);
            m.set_lanes(4);
            m.set_prefetcher(4, 2);
            m
        };
        let mut live = machine();
        let mut env = Env::new_recording(4096, &mut live);
        laned_stream(&mut env);
        let trace = env.finish_recording().expect("recording env");
        let live_report = live.report();
        assert!(live_report.overlapped_ns > 0.0);
        let mut replayed = machine();
        replayed.replay(&trace);
        assert_eq!(replayed.report(), live_report, "lane replay-identity");
    }

    #[test]
    fn prefetcher_turns_stride_misses_into_hits() {
        let run = |pf: bool| {
            let mut m = Machine::all_in(&cfg(), TierKind::Cxl);
            if pf {
                m.set_prefetcher(4, 2);
            }
            let mut env = Env::new(4096, &mut m);
            let v = env.tvec::<u64>(2_000_000, 1, "seq"); // 16MB, streamed
            let mut sum = 0u64;
            for i in (0..2_000_000).step_by(8) {
                sum = sum.wrapping_add(v.get(i, &mut env)); // one access per line
                env.compute(2);
            }
            std::hint::black_box(sum);
            m.report()
        };
        let base = run(false);
        let pf = run(true);
        assert_eq!(base.prefetch_issued, 0);
        assert!(pf.prefetch_issued > 0, "stride stream must trigger issues");
        assert!(pf.prefetch_useful > 0, "prefetched lines must be hit");
        assert!(pf.l3_misses < base.l3_misses, "prefetch converts misses to hits");
        assert!(pf.wall_ns < base.wall_ns, "pf {} !< base {}", pf.wall_ns, base.wall_ns);
    }

    #[test]
    fn migrator_promotes_hot_pages() {
        let mut m = Machine::all_in(&cfg(), TierKind::Cxl);
        m.set_tick_interval_ns(10_000.0);
        m.set_migrator(Box::new(PromoteAll));
        let mut env = Env::new(4096, &mut m);
        let v = env.tvec::<u64>(512, 0, "hot"); // one page worth
        for i in 0..20_000 {
            let _ = v.get(i % 512, &mut env);
            env.compute(10);
        }
        let r = m.report();
        assert!(r.promotions > 0, "hot page should be promoted");
        assert!(r.migration_stall_ns > 0.0);
    }
}
