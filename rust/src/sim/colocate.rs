//! Multi-tenant colocation (§4.2 "Multi-tenancy resource contention",
//! Fig. 7).
//!
//! Recorded traces of colocated functions are replayed through one shared
//! machine in fine-grained interleaved chunks: tenants contend for the
//! shared LLC (extra misses) and for per-tier bandwidth (queueing
//! inflation). Each tenant keeps its own virtual clock; the reported
//! per-tenant wall time is compared against its standalone run to get the
//! paper's "percent of slowdown when colocated".

use crate::config::MachineConfig;
use crate::mem::tier::TierKind;
use crate::sim::machine::{Machine, RunReport};
use crate::trace::RecordedTrace;

/// Result of a colocated run.
#[derive(Debug, Clone)]
pub struct ColocationReport {
    /// Per-tenant wall time when colocated.
    pub colocated_wall_ns: Vec<f64>,
    /// Per-tenant standalone wall time (same placement policy).
    pub solo_wall_ns: Vec<f64>,
    pub tier: TierKind,
}

impl ColocationReport {
    /// Percent slowdown of tenant `i` vs. running alone.
    pub fn slowdown_pct(&self, i: usize) -> f64 {
        (self.colocated_wall_ns[i] / self.solo_wall_ns[i] - 1.0) * 100.0
    }
}

/// Replay each trace alone to get the solo baselines.
fn solo_runs(cfg: &MachineConfig, tier: TierKind, traces: &[&RecordedTrace]) -> Vec<RunReport> {
    traces
        .iter()
        .map(|t| {
            let mut m = Machine::all_in(cfg, tier);
            t.replay(&mut m);
            m.report()
        })
        .collect()
}

/// Run `traces` colocated with everything placed in `tier`, interleaving
/// `chunk` events at a time.
pub fn colocate(
    cfg: &MachineConfig,
    tier: TierKind,
    traces: &[&RecordedTrace],
    chunk: usize,
) -> ColocationReport {
    assert!(!traces.is_empty());
    let solo = solo_runs(cfg, tier, traces);

    let mut machine = Machine::all_in(cfg, tier);
    let n = traces.len();
    // Tenants are separate processes: relocate each one past the largest
    // footprint so their pages are physically distinct on the machine
    // (same stride rule as the IR-level `trace::ir::interleave`
    // transform; this interleaver additionally keeps per-tenant clocks
    // so standalone-vs-colocated slowdown is measurable).
    let stride = crate::trace::ir::relocation_stride(traces, cfg.page_bytes);
    let mut cursors = vec![0usize; n];
    let mut clocks = vec![0.0f64; n];
    let mut done = 0usize;
    // Round-robin in chunks, favouring the tenant with the smallest
    // virtual clock so concurrent progress stays realistic.
    while done < n {
        // pick unfinished tenant with min clock
        let i = (0..n)
            .filter(|&i| cursors[i] < traces[i].len())
            .min_by(|&a, &b| clocks[a].partial_cmp(&clocks[b]).unwrap())
            .unwrap();
        machine.set_clock_ns(clocks[i]);
        let end = (cursors[i] + chunk).min(traces[i].len());
        traces[i].replay_range_relocated(&mut machine, cursors[i], end, i as u64 * stride);
        cursors[i] = end;
        clocks[i] = machine.clock_ns();
        if cursors[i] >= traces[i].len() {
            done += 1;
        }
    }

    ColocationReport {
        colocated_wall_ns: clocks,
        solo_wall_ns: solo.iter().map(|r| r.wall_ns).collect(),
        tier,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::env::Env;
    use crate::trace::TraceRecorder;
    use crate::util::prng::Rng;

    /// Record a random-access workload trace over `n` u64s.
    fn record_random(n: usize, accesses: usize, seed: u64) -> RecordedTrace {
        let mut rec = TraceRecorder::new();
        let mut env = Env::new(4096, &mut rec);
        let v = env.tvec::<u64>(n, 1, "buf");
        let mut rng = Rng::new(seed);
        for _ in 0..accesses {
            let i = rng.usize_in(0, n);
            let _ = v.get(i, &mut env);
            env.compute(6);
        }
        rec.finish()
    }

    #[test]
    fn colocation_slows_tenants_down() {
        let cfg = MachineConfig::default();
        // working sets big enough to fight over the LLC
        let a = record_random(3_000_000, 120_000, 1);
        let b = record_random(3_000_000, 120_000, 2);
        let rep = colocate(&cfg, TierKind::Cxl, &[&a, &b], 256);
        for i in 0..2 {
            assert!(
                rep.slowdown_pct(i) > 0.0,
                "tenant {i} should slow down: {}",
                rep.slowdown_pct(i)
            );
        }
    }

    #[test]
    fn cxl_colocation_hurts_more_than_dram() {
        // Fig. 7's headline shape.
        let cfg = MachineConfig::default();
        let a = record_random(3_000_000, 150_000, 3);
        let b = record_random(3_000_000, 150_000, 4);
        let dram = colocate(&cfg, TierKind::Dram, &[&a, &b], 256);
        let cxl = colocate(&cfg, TierKind::Cxl, &[&a, &b], 256);
        let dram_avg = (dram.slowdown_pct(0) + dram.slowdown_pct(1)) / 2.0;
        let cxl_avg = (cxl.slowdown_pct(0) + cxl.slowdown_pct(1)) / 2.0;
        assert!(cxl_avg > dram_avg, "cxl={cxl_avg:.1}% dram={dram_avg:.1}%");
    }

    #[test]
    fn single_tenant_colocation_matches_solo() {
        let cfg = MachineConfig::default();
        let a = record_random(100_000, 20_000, 5);
        let rep = colocate(&cfg, TierKind::Dram, &[&a], 256);
        // one tenant: "colocated" == solo modulo chunking (exact here)
        let sd = rep.slowdown_pct(0);
        assert!(sd.abs() < 1.0, "sd={sd}");
    }
}
