//! Lane-based latency hiding: per-lane virtual clocks with a max merge.
//!
//! The scalar clock in [`crate::sim::Machine`] charges every access and
//! compute cost in program order — a CXL miss stalls *everything* that
//! follows it. Real functions are not that serial: independent request
//! handling, parallel gathers, and decoupled streaming all let compute
//! drain while a slow-tier miss is outstanding. The [`LaneScheduler`]
//! models that slack with K in-flight *lanes* per invocation: the
//! workload annotates its stream with lane ids plus happens-after masks
//! (see the `Sink::lane` hook), every cost is charged to the current
//! lane's clock, and wall time is the max over lanes instead of the sum.
//! A miss on lane A only stalls lanes whose mask includes A.
//!
//! The scheduler never touches the disabled path: a machine without one
//! performs bit-identical arithmetic to the pre-lane simulator, which is
//! what keeps the `[lanes]`-off determinism guarantees (report + fleet
//! token) intact.

/// Per-invocation lane state: K virtual clocks, a current lane, and the
/// serial-vs-overlapped accounting the `LANES` counters report.
#[derive(Debug, Clone)]
pub struct LaneScheduler {
    /// Per-lane virtual clocks (ns). Lane ids from annotations fold into
    /// this range by modulo, so workloads can annotate up to 64 logical
    /// lanes regardless of the configured K.
    clocks: Vec<f64>,
    /// Running max over the clocks — the lane-merged wall frontier.
    wall: f64,
    /// Lane the next access/compute cost is charged to.
    cur: usize,
    /// Sum of every charged cost: what the scalar clock would have
    /// accumulated for the same stream.
    serial_ns: f64,
    /// Wall advance attributable to lane execution (excludes barriers).
    lane_wall_ns: f64,
    /// Lane-switch annotations applied.
    switches: u64,
}

impl LaneScheduler {
    pub fn new(lanes: usize) -> LaneScheduler {
        let lanes = lanes.max(1);
        LaneScheduler {
            clocks: vec![0.0; lanes],
            wall: 0.0,
            cur: 0,
            serial_ns: 0.0,
            lane_wall_ns: 0.0,
            switches: 0,
        }
    }

    pub fn lanes(&self) -> usize {
        self.clocks.len()
    }

    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Current lane's clock: the timestamp subsequent costs extend and
    /// the time observers/bandwidth debits should be stamped with.
    #[inline]
    pub fn now(&self) -> f64 {
        self.clocks[self.cur]
    }

    /// Lane-merged wall frontier (max over lanes).
    #[inline]
    pub fn wall_ns(&self) -> f64 {
        self.wall
    }

    /// Latency hidden by the lanes so far: the serial-sum cost minus the
    /// wall advance it actually produced. Zero with one lane.
    pub fn overlapped_ns(&self) -> f64 {
        (self.serial_ns - self.lane_wall_ns).max(0.0)
    }

    /// Apply a lane annotation: events now run on `lane`, after every
    /// event previously charged to a lane in `after_mask` (bit i = lane
    /// i; ids and mask bits beyond K fold by modulo). The happens-after
    /// edge is a clock merge — the target lane can never start before
    /// the lanes it depends on have drained.
    #[inline]
    pub fn switch(&mut self, lane: u8, after_mask: u64) {
        let k = self.clocks.len();
        self.cur = lane as usize % k;
        let mut t = self.clocks[self.cur];
        let mut mask = after_mask;
        while mask != 0 {
            let bit = mask.trailing_zeros() as usize;
            t = t.max(self.clocks[bit % k]);
            mask &= mask - 1;
        }
        self.clocks[self.cur] = t;
        self.switches += 1;
    }

    /// Charge `ns` of cost to the current lane.
    #[inline]
    pub fn advance(&mut self, ns: f64) {
        let c = self.clocks[self.cur] + ns;
        self.clocks[self.cur] = c;
        if c > self.wall {
            self.lane_wall_ns += c - self.wall;
            self.wall = c;
        }
        self.serial_ns += ns;
    }

    /// Synchronization barrier (alloc/free syscalls, migration stalls):
    /// every lane joins at `t` — no lane may run past a point the whole
    /// invocation is known to have reached.
    #[inline]
    pub fn barrier(&mut self, t: f64) {
        for c in &mut self.clocks {
            if *c < t {
                *c = t;
            }
        }
        if t > self.wall {
            self.wall = t;
        }
    }

    /// Hard reset of every lane clock to `t` (colocation restores a
    /// stream's clock, possibly backward; overlap accounting keeps its
    /// history).
    pub fn reset_to(&mut self, t: f64) {
        for c in &mut self.clocks {
            *c = t;
        }
        self.wall = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_lane_is_serial() {
        let mut s = LaneScheduler::new(1);
        s.advance(10.0);
        s.switch(3, 0xFF); // folds to lane 0; merge is a no-op
        s.advance(5.0);
        assert_eq!(s.wall_ns(), 15.0);
        assert_eq!(s.overlapped_ns(), 0.0);
    }

    #[test]
    fn independent_lanes_overlap() {
        let mut s = LaneScheduler::new(2);
        s.switch(0, 0b01);
        s.advance(100.0);
        s.switch(1, 0b10); // independent of lane 0
        s.advance(80.0);
        // wall is the max, not the sum; 80ns hid under the 100ns stall
        assert_eq!(s.wall_ns(), 100.0);
        assert_eq!(s.overlapped_ns(), 80.0);
    }

    #[test]
    fn happens_after_mask_serializes() {
        let mut s = LaneScheduler::new(4);
        s.switch(0, 0b0001);
        s.advance(100.0);
        s.switch(1, 0b0011); // lane 1 waits for lane 0
        s.advance(50.0);
        assert_eq!(s.wall_ns(), 150.0);
        assert_eq!(s.overlapped_ns(), 0.0);
    }

    #[test]
    fn barrier_joins_all_lanes() {
        let mut s = LaneScheduler::new(2);
        s.switch(0, 0);
        s.advance(100.0);
        s.barrier(100.0);
        s.switch(1, 0b10);
        s.advance(10.0);
        // lane 1 starts at the barrier, not at 0
        assert_eq!(s.wall_ns(), 110.0);
        assert_eq!(s.overlapped_ns(), 0.0);
    }

    #[test]
    fn lane_ids_fold_modulo_k() {
        let mut s = LaneScheduler::new(2);
        s.switch(5, 0); // 5 % 2 == 1
        s.advance(7.0);
        assert_eq!(s.now(), 7.0);
        s.switch(0, 1 << 7); // mask bit 7 folds to lane 1
        assert_eq!(s.now(), 7.0, "merge pulled lane 0 up to lane 1's clock");
    }

    #[test]
    fn overlap_never_negative() {
        let mut s = LaneScheduler::new(3);
        for i in 0..30u8 {
            s.switch(i % 3, 1 << (i % 3));
            s.advance((i as f64) * 1.5);
        }
        assert!(s.overlapped_ns() >= 0.0);
        assert!(s.wall_ns() <= 30.0 * 29.0 / 2.0 * 1.5);
    }
}
