//! Stride prefetcher: ahead-of-use line fetches into the LLC model.
//!
//! Complements the lane scheduler's latency *hiding* with latency
//! *avoidance*: a constant-stride miss pattern (two consecutive equal
//! strides) arms the prefetcher, which then issues `degree` line fetches
//! `distance` strides ahead of the demand stream. Issued lines are
//! installed into [`crate::sim::Cache`] without touching its demand
//! hit/miss counters, and the machine debits their transfer against the
//! same per-tier bandwidth model contention uses — prefetch traffic is
//! not free, it just moves off the critical path.
//!
//! Distinct from the in-machine *stream* heuristic (which only discounts
//! the latency of misses it would have covered): this prefetcher turns
//! future misses into hits outright, at the price of real bandwidth.

/// Bounded ring of recently issued prefetches, for usefulness
/// accounting: a demand hit on a pending line counts as `useful`.
const PENDING_CAP: usize = 64;

#[derive(Debug, Clone)]
pub struct StridePrefetcher {
    /// Lines issued per confirmed-stride miss.
    degree: usize,
    /// Strides of lead the first issued line gets over the miss.
    distance: usize,
    last_line: u64,
    last_stride: i64,
    armed: bool,
    pending: [u64; PENDING_CAP],
    head: usize,
    pub issued: u64,
    pub useful: u64,
}

impl StridePrefetcher {
    pub fn new(degree: usize, distance: usize) -> StridePrefetcher {
        StridePrefetcher {
            degree: degree.clamp(1, PENDING_CAP),
            distance: distance.max(1),
            last_line: u64::MAX,
            last_stride: 0,
            armed: false,
            pending: [u64::MAX; PENDING_CAP],
            head: 0,
            issued: 0,
            useful: 0,
        }
    }

    /// Observe a demand miss on `line_no`; when the stride is confirmed,
    /// push the line numbers to fetch into `out` (the caller installs
    /// them into the cache and debits their tier's bandwidth).
    #[inline]
    pub fn on_miss(&mut self, line_no: u64, out: &mut Vec<u64>) {
        if self.last_line != u64::MAX {
            let stride = line_no.wrapping_sub(self.last_line) as i64;
            if stride != 0 && stride == self.last_stride {
                if self.armed {
                    for i in 0..self.degree {
                        let steps = (self.distance + i) as i64;
                        let target = line_no.wrapping_add((stride * steps) as u64);
                        out.push(target);
                        self.pending[self.head] = target;
                        self.head = (self.head + 1) % PENDING_CAP;
                        self.issued += 1;
                    }
                } else {
                    self.armed = true;
                }
            } else {
                self.armed = false;
            }
            self.last_stride = stride;
        }
        self.last_line = line_no;
    }

    /// A demand access hit the cache on `line_no`: if we prefetched it,
    /// count it useful (once) and retire the pending entry.
    #[inline]
    pub fn note_hit(&mut self, line_no: u64) -> bool {
        if let Some(i) = self.pending.iter().position(|&l| l == line_no) {
            self.pending[i] = u64::MAX;
            self.useful += 1;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_stride_arms_and_issues() {
        let mut p = StridePrefetcher::new(4, 2);
        let mut out = Vec::new();
        p.on_miss(100, &mut out); // first miss: no stride yet
        p.on_miss(101, &mut out); // stride 1 observed
        assert!(out.is_empty());
        p.on_miss(102, &mut out); // stride 1 confirmed → armed
        assert!(out.is_empty());
        p.on_miss(103, &mut out); // armed + confirmed → issue
        assert_eq!(out, vec![105, 106, 107, 108]);
        assert_eq!(p.issued, 4);
    }

    #[test]
    fn random_pattern_stays_quiet() {
        let mut p = StridePrefetcher::new(4, 2);
        let mut out = Vec::new();
        for l in [10u64, 500, 37, 9000, 42, 77] {
            p.on_miss(l, &mut out);
        }
        assert!(out.is_empty());
        assert_eq!(p.issued, 0);
    }

    #[test]
    fn negative_stride_supported() {
        let mut p = StridePrefetcher::new(2, 1);
        let mut out = Vec::new();
        for l in [100u64, 98, 96, 94] {
            p.on_miss(l, &mut out);
        }
        assert_eq!(out, vec![92, 90]);
    }

    #[test]
    fn useful_counted_once() {
        let mut p = StridePrefetcher::new(1, 1);
        let mut out = Vec::new();
        for l in [10u64, 11, 12, 13] {
            p.on_miss(l, &mut out);
        }
        assert_eq!(out, vec![14]);
        assert!(p.note_hit(14));
        assert!(!p.note_hit(14), "retired entries do not double-count");
        assert_eq!(p.useful, 1);
    }

    #[test]
    fn stride_break_disarms() {
        let mut p = StridePrefetcher::new(2, 1);
        let mut out = Vec::new();
        for l in [10u64, 11, 12, 13] {
            p.on_miss(l, &mut out);
        }
        let issued_before = p.issued;
        out.clear();
        p.on_miss(500, &mut out); // break
        p.on_miss(501, &mut out); // new stride observed
        assert!(out.is_empty(), "re-arming needs the stride confirmed again");
        assert_eq!(p.issued, issued_before);
    }
}
