//! Set-associative LRU last-level cache model.
//!
//! Only the LLC is modeled: the paper's boundness metric cares about
//! traffic that leaves the socket (LLC misses → DRAM/CXL); inner levels
//! are folded into the compute cost. 19.25 MB / 64 B / 11-way (Table 1's
//! Xeon Gold 6126) is the default geometry.

/// LRU set-associative cache over line addresses.
#[derive(Debug, Clone)]
pub struct Cache {
    line_shift: u32,
    ways: usize,
    sets: usize,
    /// Flat tag store: `tags[set * ways + i]`, ordered MRU→LRU. 0 = empty
    /// (tags store line_addr + 1 so 0 can't collide). Note: a u32
    /// set-quotient encoding was tried and reverted — the non-power-of-2
    /// set count makes the quotient a hardware division on every access,
    /// costing more than the halved tag traffic saved (§Perf).
    tags: Vec<u64>,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(capacity_bytes: u64, line_bytes: u64, ways: u32) -> Cache {
        assert!(line_bytes.is_power_of_two());
        let ways = ways.max(1) as usize;
        let lines = (capacity_bytes / line_bytes).max(1) as usize;
        let sets = (lines / ways).max(1);
        Cache {
            line_shift: line_bytes.trailing_zeros(),
            ways,
            sets,
            tags: vec![0; sets * ways],
            hits: 0,
            misses: 0,
        }
    }

    pub fn line_bytes(&self) -> u64 {
        1 << self.line_shift
    }

    pub fn capacity_bytes(&self) -> u64 {
        (self.sets * self.ways) as u64 * self.line_bytes()
    }

    /// Access one line address; returns true on hit. On miss the line is
    /// filled, evicting the LRU way.
    #[inline]
    pub fn access_line(&mut self, line_addr: u64) -> bool {
        let set = (line_addr as usize) % self.sets;
        let base = set * self.ways;
        let tag = line_addr + 1;
        let slot = &mut self.tags[base..base + self.ways];
        // MRU-ordered search
        if let Some(pos) = slot.iter().position(|&t| t == tag) {
            slot[..=pos].rotate_right(1);
            self.hits += 1;
            true
        } else {
            slot.rotate_right(1);
            slot[0] = tag;
            self.misses += 1;
            false
        }
    }

    /// Access a byte range; calls `on_miss(line_addr)` for each missing
    /// line. Returns (hit_lines, missed_lines).
    ///
    /// The line split is hoisted: the overwhelmingly common case — a
    /// range inside one cache line — resolves with a single first==last
    /// branch instead of setting up the multi-line loop (§Perf; the
    /// hotpath bench pair `cache_access_bytes_{one_line,straddle}` pins
    /// both shapes).
    #[inline]
    pub fn access(&mut self, addr: u64, bytes: u32, mut on_miss: impl FnMut(u64)) -> (u32, u32) {
        let first = addr >> self.line_shift;
        let last = (addr + bytes.max(1) as u64 - 1) >> self.line_shift;
        if first == last {
            return if self.access_line(first) {
                (1, 0)
            } else {
                on_miss(first << self.line_shift);
                (0, 1)
            };
        }
        let mut hits = 0;
        let mut misses = 0;
        for line in first..=last {
            if self.access_line(line) {
                hits += 1;
            } else {
                misses += 1;
                on_miss(line << self.line_shift);
            }
        }
        (hits, misses)
    }

    /// Install a line without touching the demand hit/miss counters (a
    /// prefetch fill, not a demand access). Present lines are left where
    /// they are — a prefetch must not refresh demand recency; absent
    /// lines evict the set's LRU way.
    #[inline]
    pub fn install_line(&mut self, line_addr: u64) {
        let set = (line_addr as usize) % self.sets;
        let base = set * self.ways;
        let tag = line_addr + 1;
        let slot = &mut self.tags[base..base + self.ways];
        if !slot.contains(&tag) {
            slot.rotate_right(1);
            slot[0] = tag;
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Drop all contents (between tenants in sequential experiments).
    pub fn flush(&mut self) {
        self.tags.iter_mut().for_each(|t| *t = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let c = Cache::new(19 * 1024 * 1024 + 256 * 1024, 64, 11);
        assert_eq!(c.line_bytes(), 64);
        // capacity preserved to within one set's worth
        let cap = c.capacity_bytes();
        assert!(cap <= 19 * 1024 * 1024 + 256 * 1024);
        assert!(cap > 18 * 1024 * 1024);
    }

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024 * 64, 64, 4);
        assert!(!c.access_line(42)); // cold miss
        for _ in 0..10 {
            assert!(c.access_line(42));
        }
        assert_eq!(c.hits, 10);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        // 1 set, 2 ways
        let mut c = Cache::new(128, 64, 2);
        assert_eq!(c.sets, 1);
        c.access_line(1);
        c.access_line(2);
        c.access_line(1); // 1 is MRU
        c.access_line(3); // evicts 2 (LRU)
        assert!(c.access_line(1), "1 should survive");
        assert!(!c.access_line(2), "2 was evicted");
    }

    #[test]
    fn range_access_spans_lines() {
        let mut c = Cache::new(1024 * 64, 64, 4);
        let mut missed = Vec::new();
        let (h, m) = c.access(60, 8, |line| missed.push(line)); // straddles lines 0 and 1
        assert_eq!(h + m, 2);
        assert_eq!(m, 2);
        assert_eq!(missed, vec![0, 64]);
        let (h2, m2) = c.access(60, 8, |_| {});
        assert_eq!(h2, 2);
        assert_eq!(m2, 0);
    }

    #[test]
    fn working_set_behaviour() {
        // 64KB cache: 32KB working set fits, 1MB does not
        let mut c = Cache::new(64 * 1024, 64, 8);
        let small: Vec<u64> = (0..512).collect(); // 512 lines = 32KB
        for _ in 0..4 {
            for &l in &small {
                c.access_line(l);
            }
        }
        let small_hit = c.hit_rate();
        assert!(small_hit > 0.7, "{small_hit}");

        c.reset_stats();
        c.flush();
        let big: Vec<u64> = (0..16384).collect(); // 1MB
        for _ in 0..4 {
            for &l in &big {
                c.access_line(l);
            }
        }
        assert!(c.hit_rate() < small_hit);
    }

    #[test]
    fn install_line_fills_without_counting() {
        let mut c = Cache::new(4096, 64, 4);
        c.install_line(9);
        assert_eq!(c.hits + c.misses, 0, "prefetch fills are not demand traffic");
        assert!(c.access_line(9), "installed line hits on demand");
        // installing a present line does not disturb the set
        c.install_line(9);
        assert!(c.access_line(9));
    }

    #[test]
    fn one_line_fast_path_matches_loop_shape() {
        let mut c = Cache::new(1024 * 64, 64, 4);
        let mut missed = Vec::new();
        let (h, m) = c.access(128, 8, |line| missed.push(line)); // inside line 2
        assert_eq!((h, m), (0, 1));
        assert_eq!(missed, vec![128]);
        let (h2, m2) = c.access(130, 4, |_| {});
        assert_eq!((h2, m2), (1, 0));
    }

    #[test]
    fn flush_empties() {
        let mut c = Cache::new(4096, 64, 4);
        c.access_line(7);
        c.flush();
        assert!(!c.access_line(7));
    }
}
