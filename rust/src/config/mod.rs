//! Configuration system: a TOML-subset parser plus the typed simulation
//! and Porter configs (defaults mirror the paper's Table 1 testbed).

pub mod toml;

use crate::util::bytes::{parse_bytes, GIB, KIB, MIB};
use crate::util::table::Table;
use toml::TomlDoc;

/// Hardware/machine model parameters — defaults are the paper's Table 1
/// testbed plus the CXL latency from Pond [9] / TPP [7].
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    /// CPU model string (documentation only).
    pub cpu_model: String,
    /// Sockets × cores (paper: 2 × 24).
    pub sockets: u32,
    pub cores_per_socket: u32,
    /// Nominal core frequency (paper: 2.60 GHz) — converts cycles↔time.
    pub freq_ghz: f64,
    /// L3 capacity (paper: 19.25 MB), associativity, line size.
    pub l3_bytes: u64,
    pub l3_ways: u32,
    pub cache_line: u64,
    /// Local-DRAM capacity and tier model (paper: 192 GB DDR4-2133).
    pub dram_bytes: u64,
    pub dram_latency_ns: f64,
    pub dram_bw_gbps: f64,
    /// CXL tier: capacity, added port/controller latency (~70 ns above
    /// DRAM per the paper's §2.2 citing Pond), bandwidth.
    pub cxl_bytes: u64,
    pub cxl_latency_ns: f64,
    pub cxl_bw_gbps: f64,
    /// OS page size used for placement/migration granularity.
    pub page_bytes: u64,
    /// Average memory-level parallelism: how many outstanding LLC misses
    /// overlap. Divides raw miss latency into effective stall time.
    pub mlp: f64,
    /// Cost charged per LLC-hit line (folds L1/L2/L3 hit latencies).
    pub l3_hit_ns: f64,
    /// Fraction of page-migration cost that stalls the application (the
    /// rest is hidden behind Porter's background migration thread).
    pub migration_stall_frac: f64,
}

impl Default for MachineConfig {
    fn default() -> Self {
        MachineConfig {
            cpu_model: "Intel(R) Xeon Gold 6126 CPU @ 2.60GHz".to_string(),
            sockets: 2,
            cores_per_socket: 24,
            freq_ghz: 2.60,
            l3_bytes: (19.25 * MIB as f64) as u64,
            l3_ways: 11,
            cache_line: 64,
            dram_bytes: 192 * GIB,
            // DDR4-2133 loaded latency on SKX-era parts.
            dram_latency_ns: 90.0,
            dram_bw_gbps: 60.0,
            // "CXL-memory acts as a CPU-less NUMA node … latency of
            // around 70ns introduced by the CXL port and controller".
            cxl_bytes: 512 * GIB,
            cxl_latency_ns: 90.0 + 70.0,
            cxl_bw_gbps: 30.0,
            page_bytes: 4 * KIB,
            mlp: 4.0,
            l3_hit_ns: 1.2,
            migration_stall_frac: 0.2,
        }
    }
}

impl MachineConfig {
    pub fn total_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }

    /// Cycles per nanosecond.
    pub fn cycles_per_ns(&self) -> f64 {
        self.freq_ghz
    }

    /// Render the Table 1 equivalent for `porter-cli config --show`.
    pub fn render_table(&self) -> String {
        let mut t = Table::new(&["Hardware", "Specification"]).aligns(&[
            crate::util::table::Align::Left,
            crate::util::table::Align::Left,
        ]);
        t.row_strs(&["CPU", &self.cpu_model]);
        t.row(vec!["Cores".into(), format!("{} * {} cores", self.sockets, self.cores_per_socket)]);
        t.row(vec!["L3 cache".into(), crate::util::bytes::fmt_bytes(self.l3_bytes)]);
        t.row(vec![
            "Memory (DRAM tier)".into(),
            format!(
                "{} @ {}ns / {}GB/s",
                crate::util::bytes::fmt_bytes(self.dram_bytes),
                self.dram_latency_ns,
                self.dram_bw_gbps
            ),
        ]);
        t.row(vec![
            "Memory (CXL tier)".into(),
            format!(
                "{} @ {}ns / {}GB/s",
                crate::util::bytes::fmt_bytes(self.cxl_bytes),
                self.cxl_latency_ns,
                self.cxl_bw_gbps
            ),
        ]);
        t.row(vec!["Page size".into(), crate::util::bytes::fmt_bytes(self.page_bytes)]);
        t.render()
    }
}

/// DAMON monitor knobs (mirrors the kernel interface).
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorConfig {
    /// Sampling interval in trace-time nanoseconds.
    pub sample_interval_ns: u64,
    /// Aggregation interval: after this many samples-worth of time,
    /// access counts are aggregated into a snapshot and regions adjusted.
    pub aggregation_interval_ns: u64,
    pub min_regions: usize,
    pub max_regions: usize,
    /// Heatmap resolution (address bins × time bins).
    pub heatmap_bins: usize,
    pub heatmap_time_bins: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            sample_interval_ns: 5_000,
            aggregation_interval_ns: 100_000,
            min_regions: 10,
            max_regions: 1000,
            heatmap_bins: 64,
            heatmap_time_bins: 48,
        }
    }
}

/// Porter middleware knobs (§4).
#[derive(Debug, Clone, PartialEq)]
pub struct PorterConfig {
    /// Number of simulated servers behind the balancer.
    pub servers: usize,
    /// Engine worker threads per server.
    pub workers_per_server: usize,
    /// Per-function DRAM budget fraction used by the hint generator:
    /// hottest objects up to this fraction of the function's footprint
    /// go to DRAM.
    pub dram_budget_frac: f64,
    /// Fraction of accesses an object must absorb (relative to the
    /// hottest object) to be classified hot.
    pub hot_threshold: f64,
    /// First-invocation placement when no hint exists (paper: DRAM for
    /// best SLO, load permitting).
    pub first_touch_dram: bool,
    /// DRAM occupancy above which first-touch falls back to CXL.
    pub dram_pressure_high: f64,
    /// Enable the runtime promotion/demotion thread.
    pub migration_enabled: bool,
    /// Accesses within an aggregation window to promote a CXL page.
    pub promote_threshold: u32,
    /// Watermark of free DRAM the demotion loop maintains (TPP-style).
    pub demote_free_watermark: f64,
    /// Default SLO multiplier over all-DRAM latency (e.g. 1.10 → 10%
    /// over ideal is acceptable).
    pub slo_factor: f64,
}

impl Default for PorterConfig {
    fn default() -> Self {
        PorterConfig {
            servers: 2,
            workers_per_server: 4,
            dram_budget_frac: 0.35,
            hot_threshold: 0.02,
            first_touch_dram: true,
            dram_pressure_high: 0.90,
            migration_enabled: true,
            promote_threshold: 3,
            demote_free_watermark: 0.10,
            slo_factor: 1.10,
        }
    }
}

/// Runtime page-migration engine knobs (`mem::migrate` — the epoch loop
/// behind §4's promotion/demotion thread). The engine consumes per-page
/// access samples at every aggregation tick, closes an *epoch* every
/// `epoch_ticks` ticks, asks the configured policy for a plan, and
/// throttles the plan to the per-epoch bandwidth budget.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationConfig {
    /// Master switch (combined with `porter.migration_enabled` on the
    /// serving path).
    pub enabled: bool,
    /// Which policy plans migrations: "tpp" (active/inactive lists with
    /// demotion watermarks), "hybrid" (frequency buckets with an
    /// occupancy-adaptive promotion threshold), "naive" (flat hot
    /// threshold), or "none".
    pub policy: String,
    /// Epoch length, in aggregation ticks.
    pub epoch_ticks: u32,
    /// Per-epoch migration bandwidth budget in bytes (page moves beyond
    /// it are deferred to later epochs).
    pub budget_bytes: u64,
    /// Decayed-heat score a CXL page needs to qualify for promotion
    /// (naive policy; also the hybrid bucket floor).
    pub promote_heat: f64,
    /// Samples within one epoch to qualify for promotion (tpp policy —
    /// TPP's "second NUMA-hint fault" filter).
    pub promote_samples: u32,
    /// Demotion watermarks on free DRAM: demote below `watermark_low`
    /// free until `watermark_high` free is restored.
    pub watermark_low: f64,
    pub watermark_high: f64,
    /// Epochs without an access before an active page turns inactive
    /// (tpp policy).
    pub active_epochs: u32,
    /// Number of log₂ heat buckets (hybrid policy).
    pub buckets: usize,
    /// DRAM occupancy the hybrid policy steers toward.
    pub target_occupancy: f64,
    /// A page re-migrated within this many epochs counts as a ping-pong.
    pub ping_pong_epochs: u64,
}

impl Default for MigrationConfig {
    fn default() -> Self {
        MigrationConfig {
            enabled: true,
            policy: "tpp".to_string(),
            epoch_ticks: 4,
            budget_bytes: 8 * MIB,
            // promote/watermark defaults deliberately equal the legacy
            // `[porter]` defaults (promote_threshold = 3,
            // demote_free_watermark = 0.10) so the porter-fallback
            // bridge is a no-op on a default config.
            promote_heat: 3.0,
            promote_samples: 3,
            watermark_low: 0.10,
            watermark_high: 0.15,
            active_epochs: 2,
            buckets: 8,
            target_occupancy: 0.90,
            ping_pong_epochs: 2,
        }
    }
}

impl MigrationConfig {
    /// Honour the legacy `[porter]` migration knobs
    /// (`promote_threshold`, `demote_free_watermark`) that tuned the
    /// pre-engine migrator: whenever the corresponding `[migration]`
    /// key was left at its default, the porter value takes over, so
    /// existing configs keep steering the serving path instead of being
    /// silently ignored. The "was it set?" test is value-equality with
    /// the default — and because the two sections' defaults are kept
    /// identical, a fully-default config is unaffected; only a config
    /// that tunes `[porter]` while leaving `[migration]` alone is
    /// bridged.
    pub fn with_porter_fallbacks(&self, porter: &PorterConfig) -> MigrationConfig {
        let defaults = MigrationConfig::default();
        let mut cfg = self.clone();
        if cfg.promote_samples == defaults.promote_samples {
            cfg.promote_samples = porter.promote_threshold.max(1);
        }
        if cfg.promote_heat == defaults.promote_heat {
            cfg.promote_heat = porter.promote_threshold as f64;
        }
        if cfg.watermark_low == defaults.watermark_low {
            cfg.watermark_low = porter.demote_free_watermark;
            cfg.watermark_high = cfg.watermark_high.max(cfg.watermark_low);
        }
        cfg
    }
}

/// Trace-IR knobs (`trace::` — the record-once/replay-many core).
///
/// Default-on: the first execution of a `(workload, size)` pair records
/// its canonical [`crate::trace::AccessTrace`]; every later invocation
/// replays it, with the replay-identity invariant guaranteeing
/// identical `RunReport`s and checksums. `live_execution = true` is the
/// escape hatch that restores legacy re-execution on every invocation.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Master switch for the Trace-IR record/replay path.
    pub enabled: bool,
    /// Force live workload execution on every invocation (bypasses the
    /// `TraceStore` entirely; legacy behaviour).
    pub live_execution: bool,
    /// Upper bound on cached canonical traces; keys beyond the bound
    /// record but are not retained.
    pub max_cached: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: true, live_execution: false, max_cached: 128 }
    }
}

/// Per-function DRAM-provisioning knobs (`placement::provision` — the
/// what-if optimizer that replaces the global `porter.dram_budget_frac`
/// with per-function budgets).
///
/// Default-off: with `enabled = false` the tuner keeps handing every
/// function the same global budget fraction and legacy runs stay
/// bit-identical. When enabled, the offline tuner builds a per-function
/// latency-vs-DRAM [`crate::placement::provision::DemandCurve`] by
/// replaying the function's stored Trace-IR at every `ladder` ratio
/// (memoized in the [`crate::trace::TraceStore`]), and a knapsack-style
/// [`crate::placement::provision::BudgetAllocator`] partitions the
/// server's DRAM across its resident functions by greedy
/// marginal-utility descent on an epoch cadence.
#[derive(Debug, Clone, PartialEq)]
pub struct ProvisionConfig {
    /// Master switch for per-function DRAM provisioning.
    pub enabled: bool,
    /// DRAM ratios (fractions of each function's footprint) the what-if
    /// replays sample. Must start at 0, end at 1, strictly increase.
    pub ladder: Vec<f64>,
    /// Re-allocation cadence: the tuner re-runs the allocator every
    /// this many submitted profiles (new functions always trigger one).
    pub epoch_profiles: u64,
    /// A ladder upgrade must cut the function's wall time by at least
    /// this fraction of its zero-DRAM wall to be worth DRAM — the knob
    /// that lets flat curve tails return capacity instead of hoarding.
    pub min_gain_frac: f64,
    /// Derive per-function DRAM floors from SLO targets (best observed
    /// wall × `porter.slo_factor`) before the greedy descent.
    pub slo_floors: bool,
}

impl Default for ProvisionConfig {
    fn default() -> Self {
        ProvisionConfig {
            enabled: false,
            ladder: vec![0.0, 0.125, 0.25, 0.5, 0.75, 1.0],
            epoch_profiles: 4,
            min_gain_frac: 0.01,
            slo_floors: true,
        }
    }
}

/// Parse a provisioning ladder from its comma-separated TOML form
/// (`ladder = "0,0.125,0.25,0.5,1"` — the TOML subset has no arrays).
pub fn parse_ladder(text: &str) -> Result<Vec<f64>, String> {
    text.split(',')
        .map(|s| {
            let s = s.trim();
            s.parse::<f64>().map_err(|_| format!("provision.ladder: bad ratio {s:?}"))
        })
        .collect()
}

/// Function-lifecycle knobs (`lifecycle::` — warm pools, keep-alive
/// policies, and CXL-resident snapshots).
///
/// When `enabled`, sandbox lifetime is modeled explicitly: every
/// invocation either hits a live sandbox in the node's warm pool
/// (no startup cost), restores a CXL-resident snapshot (transfer +
/// `restore_overhead_ns`), or pays the full `cluster.cold_start_ns`.
/// When disabled (the default), the fleet keeps the legacy optimistic
/// model — a sandbox is implicitly kept forever once a node has run
/// the function.
#[derive(Debug, Clone, PartialEq)]
pub struct LifecycleConfig {
    pub enabled: bool,
    /// Per-node warm-pool byte budget (0 = keep-alive disabled; every
    /// invocation cold-starts or restores).
    pub warm_pool_bytes: u64,
    /// Keep-alive policy: "ttl" | "lru" | "histogram".
    pub policy: String,
    /// Fixed keep-alive window (ttl policy; histogram fallback).
    pub ttl_ns: u64,
    /// Histogram policy: keep-alive at this percentile of the observed
    /// per-function inter-arrival times, clamped to [min, max].
    pub histogram_percentile: f64,
    pub histogram_min_ns: u64,
    pub histogram_max_ns: u64,
    /// Demote evicted sandboxes into the shared CXL pool as snapshots.
    pub snapshot: bool,
    /// Fraction of the cluster CXL pool snapshots may lease at once.
    pub snapshot_capacity_frac: f64,
    /// Completed uses before a sandbox counts as likely-to-return.
    pub snapshot_min_uses: u64,
    /// Fixed restore cost on top of the snapshot transfer time.
    pub restore_overhead_ns: u64,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            enabled: false,
            warm_pool_bytes: 512 * MIB,
            policy: "ttl".to_string(),
            // 10 virtual seconds: generous against the benches' sub-second
            // horizons, so budget pressure (not expiry) dominates there.
            ttl_ns: 10_000_000_000,
            histogram_percentile: 0.99,
            histogram_min_ns: 1_000_000,
            histogram_max_ns: 60_000_000_000,
            snapshot: false,
            snapshot_capacity_frac: 0.25,
            snapshot_min_uses: 1,
            // half-RTT handshake + page-table setup; the dominant restore
            // cost is the transfer itself, debited against the link.
            restore_overhead_ns: 50_000,
        }
    }
}

/// Fleet-simulation knobs (`cluster::` — multi-node Porter with an
/// open-loop load generator and a shared cross-node CXL pool).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Initial node count.
    pub nodes: usize,
    /// Autoscaler bounds.
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// Porter servers per node and virtual engine workers per server.
    pub servers_per_node: usize,
    pub workers_per_server: usize,
    /// Node-local DRAM tier (split across the node's servers).
    pub dram_per_node: u64,
    /// Cluster-wide shared CXL pool capacity (TrEnv-style: one pool,
    /// every node's capacity tier draws from it).
    pub cxl_pool: u64,
    /// Pool backplane bandwidth (shared by all nodes) and per-node CXL
    /// link bandwidth; both feed `mem::bwmodel` contention factors.
    pub cxl_pool_bw_gbps: f64,
    pub cxl_link_bw_gbps: f64,
    /// Averaging window for the pool bandwidth models.
    pub bw_window_ns: u64,
    /// Arrival shape: poisson | bursty | diurnal | replay.
    pub arrivals: String,
    /// Trace file for `arrivals = "replay"` (compact Azure-style bins).
    pub trace_path: String,
    /// Mean offered load and open-loop generation horizon.
    pub rate_per_s: f64,
    pub duration_s: f64,
    /// Function population size (taken from the workload registry) and
    /// invocation popularity skew.
    pub functions: usize,
    pub zipf_theta: f64,
    /// PRNG seed: the whole fleet run is deterministic given this.
    pub seed: u64,
    /// Sandbox cold-start penalty added to un-hinted invocations.
    pub cold_start_ns: u64,
    /// Hint-locality routing: a node without a warm hint is charged this
    /// many mean-service-times of phantom backlog at node-pick time.
    pub hint_affinity: f64,
    /// Autoscaler: enable, signal thresholds, evaluation cadence.
    pub autoscale: bool,
    /// Scale up when queued work per worker exceeds this many evaluation
    /// intervals...
    pub scale_up_backlog: f64,
    /// ...or when the windowed SLO violation rate exceeds this.
    pub scale_up_violation: f64,
    /// Scale down when queued work per worker falls below this.
    pub scale_down_idle: f64,
    pub autoscale_interval_ns: u64,
    pub cooldown_ns: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 2,
            min_nodes: 1,
            max_nodes: 16,
            servers_per_node: 1,
            workers_per_server: 4,
            dram_per_node: 32 * GIB,
            cxl_pool: 512 * GIB,
            cxl_pool_bw_gbps: 64.0,
            cxl_link_bw_gbps: 30.0,
            bw_window_ns: 1_000_000,
            arrivals: "poisson".to_string(),
            trace_path: String::new(),
            rate_per_s: 400.0,
            duration_s: 1.0,
            functions: 6,
            zipf_theta: 0.9,
            seed: 42,
            cold_start_ns: 250_000,
            hint_affinity: 2.0,
            autoscale: true,
            scale_up_backlog: 2.0,
            scale_up_violation: 0.25,
            scale_down_idle: 0.05,
            autoscale_interval_ns: 100_000_000,
            cooldown_ns: 200_000_000,
        }
    }
}

/// Virtual-time telemetry knobs (`telemetry::` — the observability
/// subsystem: event sink, fleet sampler, exporters).
///
/// Default-off: with `enabled = false` every telemetry hook is a single
/// branch and runs are bit-identical to a build without the subsystem.
/// Events carry virtual timestamps only (no wall clock), so even an
/// enabled run preserves the determinism token.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch for event/series collection.
    pub enabled: bool,
    /// Event-sink byte budget: the ring buffer retains at most this many
    /// bytes of events, dropping oldest (counted) beyond it.
    pub buffer_bytes: u64,
    /// Fleet-sampler epoch in virtual nanoseconds (one point per series
    /// per epoch).
    pub epoch_ns: u64,
    /// Record per-invocation span events (the byte-heavy part; series
    /// sampling continues regardless).
    pub spans: bool,
    /// Default export path for the Chrome-trace document; empty defers
    /// to `--telemetry-out`.
    pub out: String,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            enabled: false,
            buffer_bytes: 8 * MIB,
            // 10 virtual ms: ~100 points over the default 1 s horizon.
            epoch_ns: 10_000_000,
            spans: true,
            out: String::new(),
        }
    }
}

/// Simulator execution knobs (`[sim]` — host-side only).
///
/// These control how fast the discrete-event loop *runs*, never what
/// it computes: any shard count or batch width must reproduce the
/// 1-shard report and determinism token bit for bit (the cluster
/// layer's epoch-barrier design enforces this; `batch_ns` changes the
/// admission horizon and so may legitimately alter a schedule's exact
/// timeline, but never varies with `shards`).
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// Worker threads the fleet's nodes are sharded across during the
    /// dispatch phase (1 = run in-line on the calling thread).
    pub shards: usize,
    /// Virtual-time width of one event batch — the epoch-barrier
    /// cadence of the sharded loop.
    pub batch_ns: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            shards: 1,
            // 1 virtual ms: singleton batches at interactive arrival
            // rates, real amortization at fleet-scale rates
            batch_ns: 1_000_000,
        }
    }
}

/// Fault-injection knobs (`[faults]` — `cluster::faults`).
///
/// Default-off: with `enabled = false` the cluster builds no schedule,
/// every fault hook is a single branch, and runs are bit-identical to
/// a build without the subsystem (determinism token included).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsConfig {
    /// Master switch for fault injection.
    pub enabled: bool,
    /// Scripted schedule DSL (see `FaultSchedule::parse`), e.g.
    /// `"down@0.02:1,up@0.04:1"`. Empty = generate a seeded schedule
    /// from the knobs below.
    pub spec: String,
    /// PRNG seed for the generated schedule (independent of the
    /// arrival seed so the two can vary separately).
    pub seed: u64,
    /// Node down/up pairs in a generated schedule.
    pub downs: u32,
    /// Link degrade/restore pairs in a generated schedule.
    pub degrades: u32,
    /// Fraction of nominal link bandwidth left while degraded, (0, 1].
    pub derate: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            enabled: false,
            spec: String::new(),
            seed: 0xFA17,
            downs: 1,
            degrades: 1,
            derate: 0.5,
        }
    }
}

/// Lane-scheduler knobs (`[lanes]` — `sim::lanes` + `sim::prefetch`).
///
/// Default-off: with `enabled = false` the machine keeps its scalar
/// clock, every lane hook is a single branch, and runs are bit-identical
/// to a build without the subsystem (determinism token included).
#[derive(Debug, Clone, PartialEq)]
pub struct LanesConfig {
    /// Master switch for lane-based latency hiding.
    pub enabled: bool,
    /// In-flight lanes per invocation (K). The effective lane count is
    /// `min(max_lanes, workload.lane_hints())`, so sequential workloads
    /// stay serial no matter how high this is set. 1..=64.
    pub max_lanes: usize,
    /// Enable the stride prefetcher alongside the lanes.
    pub prefetch: bool,
    /// Lines issued per confirmed-stride miss, 1..=64.
    pub prefetch_degree: usize,
    /// Strides of lead the first issued line gets over the miss, >= 1.
    pub prefetch_distance: usize,
}

impl Default for LanesConfig {
    fn default() -> Self {
        LanesConfig {
            enabled: false,
            max_lanes: 4,
            prefetch: false,
            prefetch_degree: 4,
            prefetch_distance: 2,
        }
    }
}

/// Top-level config bundle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub machine: MachineConfig,
    pub monitor: MonitorConfig,
    pub porter: PorterConfig,
    pub migration: MigrationConfig,
    pub trace: TraceConfig,
    pub provision: ProvisionConfig,
    pub lifecycle: LifecycleConfig,
    pub cluster: ClusterConfig,
    pub telemetry: TelemetryConfig,
    pub sim: SimConfig,
    pub faults: FaultsConfig,
    pub lanes: LanesConfig,
}

impl Config {
    /// Load from a TOML-subset file; unknown keys are rejected so typos
    /// fail loudly.
    pub fn from_toml_str(text: &str) -> Result<Config, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Config::default();
        for (section, key, value) in doc.entries() {
            let path = format!("{section}.{key}");
            match path.as_str() {
                "machine.cpu_model" => cfg.machine.cpu_model = value.as_str()?.to_string(),
                "machine.sockets" => cfg.machine.sockets = value.as_u64()? as u32,
                "machine.cores_per_socket" => cfg.machine.cores_per_socket = value.as_u64()? as u32,
                "machine.freq_ghz" => cfg.machine.freq_ghz = value.as_f64()?,
                "machine.l3" => cfg.machine.l3_bytes = parse_bytes(value.as_str()?)?,
                "machine.l3_ways" => cfg.machine.l3_ways = value.as_u64()? as u32,
                "machine.cache_line" => cfg.machine.cache_line = value.as_u64()?,
                "machine.dram" => cfg.machine.dram_bytes = parse_bytes(value.as_str()?)?,
                "machine.dram_latency_ns" => cfg.machine.dram_latency_ns = value.as_f64()?,
                "machine.dram_bw_gbps" => cfg.machine.dram_bw_gbps = value.as_f64()?,
                "machine.cxl" => cfg.machine.cxl_bytes = parse_bytes(value.as_str()?)?,
                "machine.cxl_latency_ns" => cfg.machine.cxl_latency_ns = value.as_f64()?,
                "machine.cxl_bw_gbps" => cfg.machine.cxl_bw_gbps = value.as_f64()?,
                "machine.page" => cfg.machine.page_bytes = parse_bytes(value.as_str()?)?,
                "machine.mlp" => cfg.machine.mlp = value.as_f64()?,
                "machine.l3_hit_ns" => cfg.machine.l3_hit_ns = value.as_f64()?,
                "machine.migration_stall_frac" => {
                    cfg.machine.migration_stall_frac = value.as_f64()?
                }
                "monitor.sample_interval_ns" => cfg.monitor.sample_interval_ns = value.as_u64()?,
                "monitor.aggregation_interval_ns" => {
                    cfg.monitor.aggregation_interval_ns = value.as_u64()?
                }
                "monitor.min_regions" => cfg.monitor.min_regions = value.as_u64()? as usize,
                "monitor.max_regions" => cfg.monitor.max_regions = value.as_u64()? as usize,
                "monitor.heatmap_bins" => cfg.monitor.heatmap_bins = value.as_u64()? as usize,
                "monitor.heatmap_time_bins" => {
                    cfg.monitor.heatmap_time_bins = value.as_u64()? as usize
                }
                "porter.servers" => cfg.porter.servers = value.as_u64()? as usize,
                "porter.workers_per_server" => {
                    cfg.porter.workers_per_server = value.as_u64()? as usize
                }
                "porter.dram_budget_frac" => cfg.porter.dram_budget_frac = value.as_f64()?,
                "porter.hot_threshold" => cfg.porter.hot_threshold = value.as_f64()?,
                "porter.first_touch_dram" => cfg.porter.first_touch_dram = value.as_bool()?,
                "porter.dram_pressure_high" => cfg.porter.dram_pressure_high = value.as_f64()?,
                "porter.migration_enabled" => cfg.porter.migration_enabled = value.as_bool()?,
                "porter.promote_threshold" => cfg.porter.promote_threshold = value.as_u64()? as u32,
                "porter.demote_free_watermark" => {
                    cfg.porter.demote_free_watermark = value.as_f64()?
                }
                "porter.slo_factor" => cfg.porter.slo_factor = value.as_f64()?,
                "migration.enabled" => cfg.migration.enabled = value.as_bool()?,
                "migration.policy" => cfg.migration.policy = value.as_str()?.to_string(),
                "migration.epoch_ticks" => cfg.migration.epoch_ticks = value.as_u64()? as u32,
                "migration.budget" => cfg.migration.budget_bytes = parse_bytes(value.as_str()?)?,
                "migration.promote_heat" => cfg.migration.promote_heat = value.as_f64()?,
                "migration.promote_samples" => {
                    cfg.migration.promote_samples = value.as_u64()? as u32
                }
                "migration.watermark_low" => cfg.migration.watermark_low = value.as_f64()?,
                "migration.watermark_high" => cfg.migration.watermark_high = value.as_f64()?,
                "migration.active_epochs" => cfg.migration.active_epochs = value.as_u64()? as u32,
                "migration.buckets" => cfg.migration.buckets = value.as_u64()? as usize,
                "migration.target_occupancy" => cfg.migration.target_occupancy = value.as_f64()?,
                "migration.ping_pong_epochs" => cfg.migration.ping_pong_epochs = value.as_u64()?,
                "trace.enabled" => cfg.trace.enabled = value.as_bool()?,
                "trace.live_execution" => cfg.trace.live_execution = value.as_bool()?,
                "trace.max_cached" => cfg.trace.max_cached = value.as_u64()? as usize,
                "provision.enabled" => cfg.provision.enabled = value.as_bool()?,
                "provision.ladder" => cfg.provision.ladder = parse_ladder(value.as_str()?)?,
                "provision.epoch_profiles" => cfg.provision.epoch_profiles = value.as_u64()?,
                "provision.min_gain_frac" => cfg.provision.min_gain_frac = value.as_f64()?,
                "provision.slo_floors" => cfg.provision.slo_floors = value.as_bool()?,
                "lifecycle.enabled" => cfg.lifecycle.enabled = value.as_bool()?,
                "lifecycle.warm_pool" => {
                    cfg.lifecycle.warm_pool_bytes = parse_bytes(value.as_str()?)?
                }
                "lifecycle.policy" => cfg.lifecycle.policy = value.as_str()?.to_string(),
                "lifecycle.ttl_ns" => cfg.lifecycle.ttl_ns = value.as_u64()?,
                "lifecycle.histogram_percentile" => {
                    cfg.lifecycle.histogram_percentile = value.as_f64()?
                }
                "lifecycle.histogram_min_ns" => cfg.lifecycle.histogram_min_ns = value.as_u64()?,
                "lifecycle.histogram_max_ns" => cfg.lifecycle.histogram_max_ns = value.as_u64()?,
                "lifecycle.snapshot" => cfg.lifecycle.snapshot = value.as_bool()?,
                "lifecycle.snapshot_capacity_frac" => {
                    cfg.lifecycle.snapshot_capacity_frac = value.as_f64()?
                }
                "lifecycle.snapshot_min_uses" => {
                    cfg.lifecycle.snapshot_min_uses = value.as_u64()?
                }
                "lifecycle.restore_overhead_ns" => {
                    cfg.lifecycle.restore_overhead_ns = value.as_u64()?
                }
                "cluster.nodes" => cfg.cluster.nodes = value.as_u64()? as usize,
                "cluster.min_nodes" => cfg.cluster.min_nodes = value.as_u64()? as usize,
                "cluster.max_nodes" => cfg.cluster.max_nodes = value.as_u64()? as usize,
                "cluster.servers_per_node" => {
                    cfg.cluster.servers_per_node = value.as_u64()? as usize
                }
                "cluster.workers_per_server" => {
                    cfg.cluster.workers_per_server = value.as_u64()? as usize
                }
                "cluster.dram_per_node" => {
                    cfg.cluster.dram_per_node = parse_bytes(value.as_str()?)?
                }
                "cluster.cxl_pool" => cfg.cluster.cxl_pool = parse_bytes(value.as_str()?)?,
                "cluster.cxl_pool_bw_gbps" => cfg.cluster.cxl_pool_bw_gbps = value.as_f64()?,
                "cluster.cxl_link_bw_gbps" => cfg.cluster.cxl_link_bw_gbps = value.as_f64()?,
                "cluster.bw_window_ns" => cfg.cluster.bw_window_ns = value.as_u64()?,
                "cluster.arrivals" => cfg.cluster.arrivals = value.as_str()?.to_string(),
                "cluster.trace_path" => cfg.cluster.trace_path = value.as_str()?.to_string(),
                "cluster.rate_per_s" => cfg.cluster.rate_per_s = value.as_f64()?,
                "cluster.duration_s" => cfg.cluster.duration_s = value.as_f64()?,
                "cluster.functions" => cfg.cluster.functions = value.as_u64()? as usize,
                "cluster.zipf_theta" => cfg.cluster.zipf_theta = value.as_f64()?,
                "cluster.seed" => cfg.cluster.seed = value.as_u64()?,
                "cluster.cold_start_ns" => cfg.cluster.cold_start_ns = value.as_u64()?,
                "cluster.hint_affinity" => cfg.cluster.hint_affinity = value.as_f64()?,
                "cluster.autoscale" => cfg.cluster.autoscale = value.as_bool()?,
                "cluster.scale_up_backlog" => cfg.cluster.scale_up_backlog = value.as_f64()?,
                "cluster.scale_up_violation" => {
                    cfg.cluster.scale_up_violation = value.as_f64()?
                }
                "cluster.scale_down_idle" => cfg.cluster.scale_down_idle = value.as_f64()?,
                "cluster.autoscale_interval_ns" => {
                    cfg.cluster.autoscale_interval_ns = value.as_u64()?
                }
                "cluster.cooldown_ns" => cfg.cluster.cooldown_ns = value.as_u64()?,
                "telemetry.enabled" => cfg.telemetry.enabled = value.as_bool()?,
                "telemetry.buffer" => cfg.telemetry.buffer_bytes = parse_bytes(value.as_str()?)?,
                "telemetry.epoch_ns" => cfg.telemetry.epoch_ns = value.as_u64()?,
                "telemetry.spans" => cfg.telemetry.spans = value.as_bool()?,
                "telemetry.out" => cfg.telemetry.out = value.as_str()?.to_string(),
                "sim.shards" => cfg.sim.shards = value.as_u64()? as usize,
                "sim.batch_ns" => cfg.sim.batch_ns = value.as_u64()?,
                "faults.enabled" => cfg.faults.enabled = value.as_bool()?,
                "faults.spec" => cfg.faults.spec = value.as_str()?.to_string(),
                "faults.seed" => cfg.faults.seed = value.as_u64()?,
                "faults.downs" => cfg.faults.downs = value.as_u64()? as u32,
                "faults.degrades" => cfg.faults.degrades = value.as_u64()? as u32,
                "faults.derate" => cfg.faults.derate = value.as_f64()?,
                "lanes.enabled" => cfg.lanes.enabled = value.as_bool()?,
                "lanes.max_lanes" => cfg.lanes.max_lanes = value.as_u64()? as usize,
                "lanes.prefetch" => cfg.lanes.prefetch = value.as_bool()?,
                "lanes.prefetch_degree" => {
                    cfg.lanes.prefetch_degree = value.as_u64()? as usize
                }
                "lanes.prefetch_distance" => {
                    cfg.lanes.prefetch_distance = value.as_u64()? as usize
                }
                _ => return Err(format!("unknown config key: {path}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Config::from_toml_str(&text)
    }

    pub fn validate(&self) -> Result<(), String> {
        let m = &self.machine;
        if m.page_bytes == 0 || !m.page_bytes.is_power_of_two() {
            return Err("machine.page must be a power of two".into());
        }
        if m.cache_line == 0 || !m.cache_line.is_power_of_two() {
            return Err("machine.cache_line must be a power of two".into());
        }
        if m.cxl_latency_ns < m.dram_latency_ns {
            return Err("cxl latency must be >= dram latency".into());
        }
        if m.l3_bytes < m.cache_line * m.l3_ways as u64 {
            return Err("l3 too small for associativity".into());
        }
        if m.mlp < 1.0 {
            return Err("machine.mlp must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&m.migration_stall_frac) {
            return Err("machine.migration_stall_frac must be in [0,1]".into());
        }
        let p = &self.porter;
        for (name, v) in [
            ("dram_budget_frac", p.dram_budget_frac),
            ("hot_threshold", p.hot_threshold),
            ("dram_pressure_high", p.dram_pressure_high),
            ("demote_free_watermark", p.demote_free_watermark),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("porter.{name} must be in [0,1]"));
            }
        }
        if p.servers == 0 || p.workers_per_server == 0 {
            return Err("porter.servers/workers must be >= 1".into());
        }
        if self.monitor.min_regions == 0 || self.monitor.max_regions < self.monitor.min_regions {
            return Err("monitor regions range invalid".into());
        }
        let mg = &self.migration;
        if !matches!(mg.policy.as_str(), "tpp" | "hybrid" | "naive" | "none") {
            return Err(format!(
                "migration.policy must be one of tpp|hybrid|naive|none, got {:?}",
                mg.policy
            ));
        }
        if mg.epoch_ticks == 0 {
            return Err("migration.epoch_ticks must be >= 1".into());
        }
        if mg.budget_bytes < m.page_bytes {
            return Err("migration.budget must cover at least one page".into());
        }
        for (name, v) in [
            ("watermark_low", mg.watermark_low),
            ("watermark_high", mg.watermark_high),
            ("target_occupancy", mg.target_occupancy),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("migration.{name} must be in [0,1]"));
            }
        }
        if mg.watermark_low > mg.watermark_high {
            return Err("migration.watermark_low must be <= watermark_high".into());
        }
        if mg.promote_heat < 0.0 {
            return Err("migration.promote_heat must be >= 0".into());
        }
        if mg.buckets == 0 {
            return Err("migration.buckets must be >= 1".into());
        }
        if self.trace.max_cached == 0 {
            return Err("trace.max_cached must be >= 1".into());
        }
        let pv = &self.provision;
        if pv.enabled && (!self.trace.enabled || self.trace.live_execution) {
            // the optimizer's demand curves are built from stored
            // Trace-IR recordings; without the replay path it would
            // silently no-op with every metric at zero
            return Err(
                "provision.enabled requires the Trace-IR replay path \
                 (trace.enabled = true, trace.live_execution = false)"
                    .into(),
            );
        }
        if pv.ladder.len() < 2 {
            return Err("provision.ladder needs at least two ratios".into());
        }
        if pv.ladder[0] != 0.0 {
            return Err("provision.ladder must start at 0 (the zero-DRAM endpoint)".into());
        }
        if *pv.ladder.last().expect("len checked") != 1.0 {
            return Err("provision.ladder must end at 1 (the full-footprint endpoint)".into());
        }
        if pv.ladder.iter().any(|r| !r.is_finite() || !(0.0..=1.0).contains(r)) {
            return Err("provision.ladder ratios must be finite and in [0,1]".into());
        }
        for w in pv.ladder.windows(2) {
            if w[1] <= w[0] {
                return Err("provision.ladder must be strictly increasing".into());
            }
        }
        if pv.epoch_profiles == 0 {
            return Err("provision.epoch_profiles must be >= 1".into());
        }
        if !(0.0..1.0).contains(&pv.min_gain_frac) {
            return Err("provision.min_gain_frac must be in [0,1)".into());
        }
        let lc = &self.lifecycle;
        if !matches!(lc.policy.as_str(), "ttl" | "lru" | "histogram") {
            return Err(format!(
                "lifecycle.policy must be one of ttl|lru|histogram, got {:?}",
                lc.policy
            ));
        }
        if !(0.0..=1.0).contains(&lc.snapshot_capacity_frac) {
            return Err("lifecycle.snapshot_capacity_frac must be in [0,1]".into());
        }
        if !(lc.histogram_percentile > 0.0 && lc.histogram_percentile <= 1.0) {
            return Err("lifecycle.histogram_percentile must be in (0,1]".into());
        }
        if lc.histogram_min_ns > lc.histogram_max_ns {
            return Err("lifecycle.histogram_min_ns must be <= histogram_max_ns".into());
        }
        if lc.ttl_ns == 0 {
            return Err("lifecycle.ttl_ns must be > 0".into());
        }
        let c = &self.cluster;
        if c.nodes == 0 || c.min_nodes == 0 {
            return Err("cluster.nodes/min_nodes must be >= 1".into());
        }
        if c.min_nodes > c.nodes || c.nodes > c.max_nodes {
            return Err("cluster node counts must satisfy min <= nodes <= max".into());
        }
        if c.servers_per_node == 0 || c.workers_per_server == 0 {
            return Err("cluster.servers_per_node/workers_per_server must be >= 1".into());
        }
        if c.dram_per_node < m.page_bytes * c.servers_per_node as u64 {
            return Err("cluster.dram_per_node too small for its servers".into());
        }
        if c.cxl_pool == 0 {
            return Err("cluster.cxl_pool must be > 0".into());
        }
        if c.cxl_pool_bw_gbps <= 0.0 || c.cxl_link_bw_gbps <= 0.0 || c.bw_window_ns == 0 {
            return Err("cluster bandwidth model parameters must be positive".into());
        }
        if c.rate_per_s <= 0.0 || c.duration_s <= 0.0 {
            return Err("cluster.rate_per_s/duration_s must be > 0".into());
        }
        if c.functions == 0 {
            return Err("cluster.functions must be >= 1".into());
        }
        if c.zipf_theta < 0.0 {
            return Err("cluster.zipf_theta must be >= 0".into());
        }
        for (name, v) in [
            ("hint_affinity", c.hint_affinity),
            ("scale_up_backlog", c.scale_up_backlog),
            ("scale_up_violation", c.scale_up_violation),
            ("scale_down_idle", c.scale_down_idle),
        ] {
            if v < 0.0 {
                return Err(format!("cluster.{name} must be >= 0"));
            }
        }
        if c.autoscale_interval_ns == 0 {
            return Err("cluster.autoscale_interval_ns must be > 0".into());
        }
        let t = &self.telemetry;
        if t.enabled && t.buffer_bytes < KIB {
            return Err("telemetry.buffer must be at least 1KB".into());
        }
        if t.epoch_ns == 0 {
            return Err("telemetry.epoch_ns must be > 0".into());
        }
        let s = &self.sim;
        if s.shards == 0 {
            return Err("sim.shards must be >= 1".into());
        }
        if s.shards > 64 {
            return Err("sim.shards must be <= 64 (thread-per-shard)".into());
        }
        if s.batch_ns == 0 {
            return Err("sim.batch_ns must be > 0".into());
        }
        let f = &self.faults;
        if f.enabled {
            if !(f.derate > 0.0 && f.derate <= 1.0) {
                return Err(format!("faults.derate must be in (0, 1], got {}", f.derate));
            }
            // fail at config time, not mid-run: a scripted schedule must
            // parse (the cluster re-parses the validated spec when it
            // builds the schedule)
            crate::cluster::faults::FaultSchedule::parse(&f.spec)
                .map_err(|e| format!("faults.spec: {e}"))?;
        }
        let l = &self.lanes;
        if l.enabled {
            if l.max_lanes == 0 || l.max_lanes > 64 {
                return Err(format!("lanes.max_lanes must be in 1..=64, got {}", l.max_lanes));
            }
            if l.prefetch_degree == 0 || l.prefetch_degree > 64 {
                return Err(format!(
                    "lanes.prefetch_degree must be in 1..=64, got {}",
                    l.prefetch_degree
                ));
            }
            if l.prefetch_distance == 0 {
                return Err("lanes.prefetch_distance must be >= 1".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_and_match_table1() {
        let c = Config::default();
        c.validate().unwrap();
        assert_eq!(c.machine.total_cores(), 48);
        assert_eq!(c.machine.dram_bytes, 192 * GIB);
        assert!((c.machine.cxl_latency_ns - c.machine.dram_latency_ns - 70.0).abs() < 1e-9);
    }

    #[test]
    fn parses_overrides() {
        let text = r#"
[machine]
dram = "64GB"
cxl = "256GB"
cxl_latency_ns = 180.0

[porter]
servers = 4
migration_enabled = false
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert_eq!(c.machine.dram_bytes, 64 * GIB);
        assert_eq!(c.machine.cxl_bytes, 256 * GIB);
        assert_eq!(c.porter.servers, 4);
        assert!(!c.porter.migration_enabled);
        // untouched fields keep defaults
        assert_eq!(c.machine.sockets, 2);
    }

    #[test]
    fn rejects_unknown_key() {
        let e = Config::from_toml_str("[machine]\nnonsense = 3\n").unwrap_err();
        assert!(e.contains("unknown config key"), "{e}");
    }

    #[test]
    fn rejects_invalid_values() {
        assert!(Config::from_toml_str("[machine]\npage = \"3000\"\n").is_err()); // not pow2
        assert!(Config::from_toml_str("[porter]\ndram_budget_frac = 1.5\n").is_err());
        assert!(Config::from_toml_str("[machine]\ncxl_latency_ns = 10.0\n").is_err());
    }

    #[test]
    fn parses_migration_section() {
        let text = r#"
[migration]
policy = "hybrid"
epoch_ticks = 8
budget = "2MB"
watermark_low = 0.05
watermark_high = 0.2
target_occupancy = 0.8
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert_eq!(c.migration.policy, "hybrid");
        assert_eq!(c.migration.epoch_ticks, 8);
        assert_eq!(c.migration.budget_bytes, 2 * MIB);
        assert_eq!(c.migration.watermark_low, 0.05);
        assert_eq!(c.migration.target_occupancy, 0.8);
        // untouched fields keep defaults
        assert!(c.migration.enabled);
        assert_eq!(c.migration.promote_samples, 3);
    }

    #[test]
    fn migration_defaults_mirror_legacy_porter_knobs() {
        // keeps the with_porter_fallbacks sentinel a no-op on defaults
        let c = Config::default();
        assert_eq!(c.migration.promote_samples, c.porter.promote_threshold);
        assert_eq!(c.migration.promote_heat, c.porter.promote_threshold as f64);
        assert_eq!(c.migration.watermark_low, c.porter.demote_free_watermark);
        let bridged = c.migration.with_porter_fallbacks(&c.porter);
        assert_eq!(bridged, c.migration, "default config must not be rewritten by the bridge");
    }

    #[test]
    fn porter_fallbacks_feed_default_migration_keys() {
        // legacy porter knobs steer the engine when [migration] keys are
        // left at their defaults...
        let text = "[porter]\npromote_threshold = 8\ndemote_free_watermark = 0.2\n";
        let c = Config::from_toml_str(text).unwrap();
        let m = c.migration.with_porter_fallbacks(&c.porter);
        assert_eq!(m.promote_samples, 8);
        assert_eq!(m.promote_heat, 8.0);
        assert_eq!(m.watermark_low, 0.2);
        assert!(m.watermark_high >= m.watermark_low);
        // ...but explicit [migration] keys win
        let text = concat!(
            "[porter]\npromote_threshold = 8\n\n",
            "[migration]\npromote_samples = 5\npromote_heat = 6.0\n",
        );
        let c = Config::from_toml_str(text).unwrap();
        let m = c.migration.with_porter_fallbacks(&c.porter);
        assert_eq!(m.promote_samples, 5);
        assert_eq!(m.promote_heat, 6.0);
    }

    #[test]
    fn rejects_invalid_migration_values() {
        assert!(Config::from_toml_str("[migration]\npolicy = \"lru\"\n").is_err());
        assert!(Config::from_toml_str("[migration]\nepoch_ticks = 0\n").is_err());
        assert!(Config::from_toml_str("[migration]\nbudget = \"1KB\"\n").is_err()); // < one page
        assert!(Config::from_toml_str(
            "[migration]\nwatermark_low = 0.5\nwatermark_high = 0.1\n"
        )
        .is_err());
    }

    #[test]
    fn parses_trace_section() {
        let text = "[trace]\nlive_execution = true\nmax_cached = 16\n";
        let c = Config::from_toml_str(text).unwrap();
        assert!(c.trace.enabled, "untouched fields keep defaults");
        assert!(c.trace.live_execution);
        assert_eq!(c.trace.max_cached, 16);
    }

    #[test]
    fn trace_replay_is_the_default() {
        let c = Config::default();
        assert!(c.trace.enabled);
        assert!(!c.trace.live_execution, "replay is default-on; live_execution is the escape");
        c.validate().unwrap();
    }

    #[test]
    fn rejects_invalid_trace_values() {
        assert!(Config::from_toml_str("[trace]\nmax_cached = 0\n").is_err());
        assert!(Config::from_toml_str("[trace]\nnonsense = 1\n").is_err());
    }

    #[test]
    fn parses_provision_section() {
        let text = r#"
[provision]
enabled = true
ladder = "0, 0.25, 0.5, 1"
epoch_profiles = 2
min_gain_frac = 0.05
slo_floors = false
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert!(c.provision.enabled);
        assert_eq!(c.provision.ladder, vec![0.0, 0.25, 0.5, 1.0]);
        assert_eq!(c.provision.epoch_profiles, 2);
        assert_eq!(c.provision.min_gain_frac, 0.05);
        assert!(!c.provision.slo_floors);
    }

    #[test]
    fn provision_disabled_by_default() {
        let c = Config::default();
        assert!(!c.provision.enabled, "global-budget behaviour must stay the default");
        c.validate().unwrap();
    }

    #[test]
    fn rejects_invalid_provision_values() {
        assert!(Config::from_toml_str("[provision]\nladder = \"0\"\n").is_err());
        assert!(Config::from_toml_str("[provision]\nladder = \"0.1,0.5,1\"\n").is_err());
        assert!(Config::from_toml_str("[provision]\nladder = \"0,0.5,0.9\"\n").is_err());
        assert!(Config::from_toml_str("[provision]\nladder = \"0,0.5,0.5,1\"\n").is_err());
        assert!(Config::from_toml_str("[provision]\nladder = \"0,zap,1\"\n").is_err());
        assert!(Config::from_toml_str("[provision]\nepoch_profiles = 0\n").is_err());
        assert!(Config::from_toml_str("[provision]\nmin_gain_frac = 1.0\n").is_err());
        // the optimizer needs the Trace-IR replay path to build curves
        assert!(Config::from_toml_str(
            "[provision]\nenabled = true\n\n[trace]\nlive_execution = true\n"
        )
        .is_err());
        assert!(Config::from_toml_str("[provision]\nenabled = true\n\n[trace]\nenabled = false\n")
            .is_err());
        assert!(Config::from_toml_str("[provision]\nenabled = true\n").is_ok());
    }

    #[test]
    fn parses_lifecycle_section() {
        let text = r#"
[lifecycle]
enabled = true
warm_pool = "256MB"
policy = "histogram"
snapshot = true
snapshot_capacity_frac = 0.5
restore_overhead_ns = 10000
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert!(c.lifecycle.enabled);
        assert_eq!(c.lifecycle.warm_pool_bytes, 256 * MIB);
        assert_eq!(c.lifecycle.policy, "histogram");
        assert!(c.lifecycle.snapshot);
        assert_eq!(c.lifecycle.snapshot_capacity_frac, 0.5);
        assert_eq!(c.lifecycle.restore_overhead_ns, 10_000);
        // untouched fields keep defaults
        assert_eq!(c.lifecycle.snapshot_min_uses, 1);
        assert_eq!(c.lifecycle.ttl_ns, 10_000_000_000);
    }

    #[test]
    fn lifecycle_disabled_by_default() {
        let c = Config::default();
        assert!(!c.lifecycle.enabled, "legacy fleet behaviour must be the default");
        assert!(!c.lifecycle.snapshot);
        c.validate().unwrap();
    }

    #[test]
    fn rejects_invalid_lifecycle_values() {
        assert!(Config::from_toml_str("[lifecycle]\npolicy = \"fifo\"\n").is_err());
        assert!(Config::from_toml_str("[lifecycle]\nsnapshot_capacity_frac = 1.5\n").is_err());
        assert!(Config::from_toml_str("[lifecycle]\nhistogram_percentile = 0.0\n").is_err());
        assert!(Config::from_toml_str("[lifecycle]\nttl_ns = 0\n").is_err());
        assert!(Config::from_toml_str(
            "[lifecycle]\nhistogram_min_ns = 10\nhistogram_max_ns = 5\n"
        )
        .is_err());
    }

    #[test]
    fn parses_telemetry_section() {
        let text = r#"
[telemetry]
enabled = true
buffer = "2MB"
epoch_ns = 5000000
spans = false
out = "trace.json"
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert!(c.telemetry.enabled);
        assert_eq!(c.telemetry.buffer_bytes, 2 * MIB);
        assert_eq!(c.telemetry.epoch_ns, 5_000_000);
        assert!(!c.telemetry.spans);
        assert_eq!(c.telemetry.out, "trace.json");
    }

    #[test]
    fn telemetry_disabled_by_default() {
        let c = Config::default();
        assert!(!c.telemetry.enabled, "observability must be opt-in");
        assert!(c.telemetry.spans);
        assert!(c.telemetry.out.is_empty());
        c.validate().unwrap();
    }

    #[test]
    fn rejects_invalid_telemetry_values() {
        assert!(Config::from_toml_str("[telemetry]\nenabled = true\nbuffer = \"100\"\n").is_err());
        assert!(Config::from_toml_str("[telemetry]\nepoch_ns = 0\n").is_err());
        assert!(Config::from_toml_str("[telemetry]\nnonsense = 1\n").is_err());
        // a small buffer is fine while disabled (validated only when on)
        assert!(Config::from_toml_str("[telemetry]\nbuffer = \"100\"\n").is_ok());
    }

    #[test]
    fn parses_faults_section() {
        let text = r#"
[faults]
enabled = true
spec = "down@0.02:1,up@0.04:1"
seed = 99
downs = 2
degrades = 3
derate = 0.25
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.spec, "down@0.02:1,up@0.04:1");
        assert_eq!(c.faults.seed, 99);
        assert_eq!(c.faults.downs, 2);
        assert_eq!(c.faults.degrades, 3);
        assert!((c.faults.derate - 0.25).abs() < 1e-12);
    }

    #[test]
    fn faults_disabled_by_default() {
        let c = Config::default();
        assert!(!c.faults.enabled, "fault injection must be opt-in");
        assert!(c.faults.spec.is_empty());
        assert_eq!(c.faults.downs, 1);
        assert_eq!(c.faults.degrades, 1);
        c.validate().unwrap();
    }

    #[test]
    fn rejects_invalid_faults_values() {
        assert!(Config::from_toml_str("[faults]\nenabled = true\nderate = 0.0\n").is_err());
        assert!(Config::from_toml_str("[faults]\nenabled = true\nderate = 1.5\n").is_err());
        let bad_spec = "[faults]\nenabled = true\nspec = \"explode@0.1:0\"\n";
        assert!(Config::from_toml_str(bad_spec).is_err());
        assert!(Config::from_toml_str("[faults]\nnonsense = 1\n").is_err());
        // a bad spec is fine while disabled (validated only when on)
        assert!(Config::from_toml_str("[faults]\nspec = \"explode@0.1:0\"\n").is_ok());
    }

    #[test]
    fn parses_lanes_section() {
        let text = r#"
[lanes]
enabled = true
max_lanes = 8
prefetch = true
prefetch_degree = 2
prefetch_distance = 3
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert!(c.lanes.enabled);
        assert_eq!(c.lanes.max_lanes, 8);
        assert!(c.lanes.prefetch);
        assert_eq!(c.lanes.prefetch_degree, 2);
        assert_eq!(c.lanes.prefetch_distance, 3);
    }

    #[test]
    fn lanes_disabled_by_default() {
        let c = Config::default();
        assert!(!c.lanes.enabled, "lane scheduling must be opt-in");
        assert!(!c.lanes.prefetch);
        assert_eq!(c.lanes.max_lanes, 4);
        assert_eq!(c.lanes.prefetch_degree, 4);
        assert_eq!(c.lanes.prefetch_distance, 2);
        c.validate().unwrap();
    }

    #[test]
    fn rejects_invalid_lanes_values() {
        assert!(Config::from_toml_str("[lanes]\nenabled = true\nmax_lanes = 0\n").is_err());
        assert!(Config::from_toml_str("[lanes]\nenabled = true\nmax_lanes = 65\n").is_err());
        assert!(
            Config::from_toml_str("[lanes]\nenabled = true\nprefetch_degree = 0\n").is_err()
        );
        assert!(
            Config::from_toml_str("[lanes]\nenabled = true\nprefetch_distance = 0\n").is_err()
        );
        assert!(Config::from_toml_str("[lanes]\nnonsense = 1\n").is_err());
        // invalid knobs are fine while disabled (validated only when on)
        assert!(Config::from_toml_str("[lanes]\nmax_lanes = 0\n").is_ok());
    }

    #[test]
    fn parses_sim_section() {
        let text = "[sim]\nshards = 4\nbatch_ns = 250000\n";
        let c = Config::from_toml_str(text).unwrap();
        assert_eq!(c.sim.shards, 4);
        assert_eq!(c.sim.batch_ns, 250_000);
        // host-side defaults: in-line execution, 1 ms batches
        let d = Config::default();
        assert_eq!(d.sim.shards, 1);
        assert_eq!(d.sim.batch_ns, 1_000_000);
    }

    #[test]
    fn rejects_invalid_sim_values() {
        assert!(Config::from_toml_str("[sim]\nshards = 0\n").is_err());
        assert!(Config::from_toml_str("[sim]\nshards = 65\n").is_err());
        assert!(Config::from_toml_str("[sim]\nbatch_ns = 0\n").is_err());
        assert!(Config::from_toml_str("[sim]\nnonsense = 1\n").is_err());
    }

    #[test]
    fn parses_cluster_section() {
        let text = r#"
[cluster]
nodes = 4
max_nodes = 8
dram_per_node = "16GB"
cxl_pool = "1024GB"
arrivals = "bursty"
rate_per_s = 900.0
autoscale = false
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert_eq!(c.cluster.nodes, 4);
        assert_eq!(c.cluster.max_nodes, 8);
        assert_eq!(c.cluster.dram_per_node, 16 * GIB);
        assert_eq!(c.cluster.cxl_pool, 1024 * GIB);
        assert_eq!(c.cluster.arrivals, "bursty");
        assert!(!c.cluster.autoscale);
        // untouched fields keep defaults
        assert_eq!(c.cluster.min_nodes, 1);
    }

    #[test]
    fn rejects_invalid_cluster_values() {
        assert!(Config::from_toml_str("[cluster]\nnodes = 0\n").is_err());
        assert!(Config::from_toml_str("[cluster]\nnodes = 4\nmax_nodes = 2\n").is_err());
        assert!(Config::from_toml_str("[cluster]\nrate_per_s = 0.0\n").is_err());
        assert!(Config::from_toml_str("[cluster]\nzipf_theta = -1.0\n").is_err());
    }

    #[test]
    fn table_renders() {
        let s = MachineConfig::default().render_table();
        assert!(s.contains("Xeon Gold 6126"));
        assert!(s.contains("19.25MiB"));
    }
}
