//! Configuration system: a TOML-subset parser plus the typed simulation
//! and Porter configs (defaults mirror the paper's Table 1 testbed).

pub mod toml;

use crate::util::bytes::{parse_bytes, GIB, KIB, MIB};
use crate::util::table::Table;
use toml::TomlDoc;

/// Hardware/machine model parameters — defaults are the paper's Table 1
/// testbed plus the CXL latency from Pond [9] / TPP [7].
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    /// CPU model string (documentation only).
    pub cpu_model: String,
    /// Sockets × cores (paper: 2 × 24).
    pub sockets: u32,
    pub cores_per_socket: u32,
    /// Nominal core frequency (paper: 2.60 GHz) — converts cycles↔time.
    pub freq_ghz: f64,
    /// L3 capacity (paper: 19.25 MB), associativity, line size.
    pub l3_bytes: u64,
    pub l3_ways: u32,
    pub cache_line: u64,
    /// Local-DRAM capacity and tier model (paper: 192 GB DDR4-2133).
    pub dram_bytes: u64,
    pub dram_latency_ns: f64,
    pub dram_bw_gbps: f64,
    /// CXL tier: capacity, added port/controller latency (~70 ns above
    /// DRAM per the paper's §2.2 citing Pond), bandwidth.
    pub cxl_bytes: u64,
    pub cxl_latency_ns: f64,
    pub cxl_bw_gbps: f64,
    /// OS page size used for placement/migration granularity.
    pub page_bytes: u64,
    /// Average memory-level parallelism: how many outstanding LLC misses
    /// overlap. Divides raw miss latency into effective stall time.
    pub mlp: f64,
    /// Cost charged per LLC-hit line (folds L1/L2/L3 hit latencies).
    pub l3_hit_ns: f64,
    /// Fraction of page-migration cost that stalls the application (the
    /// rest is hidden behind Porter's background migration thread).
    pub migration_stall_frac: f64,
}

impl Default for MachineConfig {
    fn default() -> Self {
        MachineConfig {
            cpu_model: "Intel(R) Xeon Gold 6126 CPU @ 2.60GHz".to_string(),
            sockets: 2,
            cores_per_socket: 24,
            freq_ghz: 2.60,
            l3_bytes: (19.25 * MIB as f64) as u64,
            l3_ways: 11,
            cache_line: 64,
            dram_bytes: 192 * GIB,
            // DDR4-2133 loaded latency on SKX-era parts.
            dram_latency_ns: 90.0,
            dram_bw_gbps: 60.0,
            // "CXL-memory acts as a CPU-less NUMA node … latency of
            // around 70ns introduced by the CXL port and controller".
            cxl_bytes: 512 * GIB,
            cxl_latency_ns: 90.0 + 70.0,
            cxl_bw_gbps: 30.0,
            page_bytes: 4 * KIB,
            mlp: 4.0,
            l3_hit_ns: 1.2,
            migration_stall_frac: 0.2,
        }
    }
}

impl MachineConfig {
    pub fn total_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }

    /// Cycles per nanosecond.
    pub fn cycles_per_ns(&self) -> f64 {
        self.freq_ghz
    }

    /// Render the Table 1 equivalent for `porter-cli config --show`.
    pub fn render_table(&self) -> String {
        let mut t = Table::new(&["Hardware", "Specification"]).aligns(&[
            crate::util::table::Align::Left,
            crate::util::table::Align::Left,
        ]);
        t.row_strs(&["CPU", &self.cpu_model]);
        t.row(vec!["Cores".into(), format!("{} * {} cores", self.sockets, self.cores_per_socket)]);
        t.row(vec!["L3 cache".into(), crate::util::bytes::fmt_bytes(self.l3_bytes)]);
        t.row(vec![
            "Memory (DRAM tier)".into(),
            format!("{} @ {}ns / {}GB/s", crate::util::bytes::fmt_bytes(self.dram_bytes), self.dram_latency_ns, self.dram_bw_gbps),
        ]);
        t.row(vec![
            "Memory (CXL tier)".into(),
            format!("{} @ {}ns / {}GB/s", crate::util::bytes::fmt_bytes(self.cxl_bytes), self.cxl_latency_ns, self.cxl_bw_gbps),
        ]);
        t.row(vec!["Page size".into(), crate::util::bytes::fmt_bytes(self.page_bytes)]);
        t.render()
    }
}

/// DAMON monitor knobs (mirrors the kernel interface).
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorConfig {
    /// Sampling interval in trace-time nanoseconds.
    pub sample_interval_ns: u64,
    /// Aggregation interval: after this many samples-worth of time,
    /// access counts are aggregated into a snapshot and regions adjusted.
    pub aggregation_interval_ns: u64,
    pub min_regions: usize,
    pub max_regions: usize,
    /// Heatmap resolution (address bins × time bins).
    pub heatmap_bins: usize,
    pub heatmap_time_bins: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            sample_interval_ns: 5_000,
            aggregation_interval_ns: 100_000,
            min_regions: 10,
            max_regions: 1000,
            heatmap_bins: 64,
            heatmap_time_bins: 48,
        }
    }
}

/// Porter middleware knobs (§4).
#[derive(Debug, Clone, PartialEq)]
pub struct PorterConfig {
    /// Number of simulated servers behind the balancer.
    pub servers: usize,
    /// Engine worker threads per server.
    pub workers_per_server: usize,
    /// Per-function DRAM budget fraction used by the hint generator:
    /// hottest objects up to this fraction of the function's footprint
    /// go to DRAM.
    pub dram_budget_frac: f64,
    /// Fraction of accesses an object must absorb (relative to the
    /// hottest object) to be classified hot.
    pub hot_threshold: f64,
    /// First-invocation placement when no hint exists (paper: DRAM for
    /// best SLO, load permitting).
    pub first_touch_dram: bool,
    /// DRAM occupancy above which first-touch falls back to CXL.
    pub dram_pressure_high: f64,
    /// Enable the runtime promotion/demotion thread.
    pub migration_enabled: bool,
    /// Accesses within an aggregation window to promote a CXL page.
    pub promote_threshold: u32,
    /// Watermark of free DRAM the demotion loop maintains (TPP-style).
    pub demote_free_watermark: f64,
    /// Default SLO multiplier over all-DRAM latency (e.g. 1.10 → 10%
    /// over ideal is acceptable).
    pub slo_factor: f64,
}

impl Default for PorterConfig {
    fn default() -> Self {
        PorterConfig {
            servers: 2,
            workers_per_server: 4,
            dram_budget_frac: 0.35,
            hot_threshold: 0.02,
            first_touch_dram: true,
            dram_pressure_high: 0.90,
            migration_enabled: true,
            promote_threshold: 3,
            demote_free_watermark: 0.10,
            slo_factor: 1.10,
        }
    }
}

/// Top-level config bundle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub machine: MachineConfig,
    pub monitor: MonitorConfig,
    pub porter: PorterConfig,
}

impl Config {
    /// Load from a TOML-subset file; unknown keys are rejected so typos
    /// fail loudly.
    pub fn from_toml_str(text: &str) -> Result<Config, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Config::default();
        for (section, key, value) in doc.entries() {
            let path = format!("{section}.{key}");
            match path.as_str() {
                "machine.cpu_model" => cfg.machine.cpu_model = value.as_str()?.to_string(),
                "machine.sockets" => cfg.machine.sockets = value.as_u64()? as u32,
                "machine.cores_per_socket" => cfg.machine.cores_per_socket = value.as_u64()? as u32,
                "machine.freq_ghz" => cfg.machine.freq_ghz = value.as_f64()?,
                "machine.l3" => cfg.machine.l3_bytes = parse_bytes(value.as_str()?)?,
                "machine.l3_ways" => cfg.machine.l3_ways = value.as_u64()? as u32,
                "machine.cache_line" => cfg.machine.cache_line = value.as_u64()?,
                "machine.dram" => cfg.machine.dram_bytes = parse_bytes(value.as_str()?)?,
                "machine.dram_latency_ns" => cfg.machine.dram_latency_ns = value.as_f64()?,
                "machine.dram_bw_gbps" => cfg.machine.dram_bw_gbps = value.as_f64()?,
                "machine.cxl" => cfg.machine.cxl_bytes = parse_bytes(value.as_str()?)?,
                "machine.cxl_latency_ns" => cfg.machine.cxl_latency_ns = value.as_f64()?,
                "machine.cxl_bw_gbps" => cfg.machine.cxl_bw_gbps = value.as_f64()?,
                "machine.page" => cfg.machine.page_bytes = parse_bytes(value.as_str()?)?,
                "machine.mlp" => cfg.machine.mlp = value.as_f64()?,
                "machine.l3_hit_ns" => cfg.machine.l3_hit_ns = value.as_f64()?,
                "machine.migration_stall_frac" => cfg.machine.migration_stall_frac = value.as_f64()?,
                "monitor.sample_interval_ns" => cfg.monitor.sample_interval_ns = value.as_u64()?,
                "monitor.aggregation_interval_ns" => cfg.monitor.aggregation_interval_ns = value.as_u64()?,
                "monitor.min_regions" => cfg.monitor.min_regions = value.as_u64()? as usize,
                "monitor.max_regions" => cfg.monitor.max_regions = value.as_u64()? as usize,
                "monitor.heatmap_bins" => cfg.monitor.heatmap_bins = value.as_u64()? as usize,
                "monitor.heatmap_time_bins" => cfg.monitor.heatmap_time_bins = value.as_u64()? as usize,
                "porter.servers" => cfg.porter.servers = value.as_u64()? as usize,
                "porter.workers_per_server" => cfg.porter.workers_per_server = value.as_u64()? as usize,
                "porter.dram_budget_frac" => cfg.porter.dram_budget_frac = value.as_f64()?,
                "porter.hot_threshold" => cfg.porter.hot_threshold = value.as_f64()?,
                "porter.first_touch_dram" => cfg.porter.first_touch_dram = value.as_bool()?,
                "porter.dram_pressure_high" => cfg.porter.dram_pressure_high = value.as_f64()?,
                "porter.migration_enabled" => cfg.porter.migration_enabled = value.as_bool()?,
                "porter.promote_threshold" => cfg.porter.promote_threshold = value.as_u64()? as u32,
                "porter.demote_free_watermark" => cfg.porter.demote_free_watermark = value.as_f64()?,
                "porter.slo_factor" => cfg.porter.slo_factor = value.as_f64()?,
                _ => return Err(format!("unknown config key: {path}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Config::from_toml_str(&text)
    }

    pub fn validate(&self) -> Result<(), String> {
        let m = &self.machine;
        if m.page_bytes == 0 || !m.page_bytes.is_power_of_two() {
            return Err("machine.page must be a power of two".into());
        }
        if m.cache_line == 0 || !m.cache_line.is_power_of_two() {
            return Err("machine.cache_line must be a power of two".into());
        }
        if m.cxl_latency_ns < m.dram_latency_ns {
            return Err("cxl latency must be >= dram latency".into());
        }
        if m.l3_bytes < m.cache_line * m.l3_ways as u64 {
            return Err("l3 too small for associativity".into());
        }
        if m.mlp < 1.0 {
            return Err("machine.mlp must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&m.migration_stall_frac) {
            return Err("machine.migration_stall_frac must be in [0,1]".into());
        }
        let p = &self.porter;
        for (name, v) in [
            ("dram_budget_frac", p.dram_budget_frac),
            ("hot_threshold", p.hot_threshold),
            ("dram_pressure_high", p.dram_pressure_high),
            ("demote_free_watermark", p.demote_free_watermark),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("porter.{name} must be in [0,1]"));
            }
        }
        if p.servers == 0 || p.workers_per_server == 0 {
            return Err("porter.servers/workers must be >= 1".into());
        }
        if self.monitor.min_regions == 0 || self.monitor.max_regions < self.monitor.min_regions {
            return Err("monitor regions range invalid".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_and_match_table1() {
        let c = Config::default();
        c.validate().unwrap();
        assert_eq!(c.machine.total_cores(), 48);
        assert_eq!(c.machine.dram_bytes, 192 * GIB);
        assert!((c.machine.cxl_latency_ns - c.machine.dram_latency_ns - 70.0).abs() < 1e-9);
    }

    #[test]
    fn parses_overrides() {
        let text = r#"
[machine]
dram = "64GB"
cxl = "256GB"
cxl_latency_ns = 180.0

[porter]
servers = 4
migration_enabled = false
"#;
        let c = Config::from_toml_str(text).unwrap();
        assert_eq!(c.machine.dram_bytes, 64 * GIB);
        assert_eq!(c.machine.cxl_bytes, 256 * GIB);
        assert_eq!(c.porter.servers, 4);
        assert!(!c.porter.migration_enabled);
        // untouched fields keep defaults
        assert_eq!(c.machine.sockets, 2);
    }

    #[test]
    fn rejects_unknown_key() {
        let e = Config::from_toml_str("[machine]\nnonsense = 3\n").unwrap_err();
        assert!(e.contains("unknown config key"), "{e}");
    }

    #[test]
    fn rejects_invalid_values() {
        assert!(Config::from_toml_str("[machine]\npage = \"3000\"\n").is_err()); // not pow2
        assert!(Config::from_toml_str("[porter]\ndram_budget_frac = 1.5\n").is_err());
        assert!(Config::from_toml_str("[machine]\ncxl_latency_ns = 10.0\n").is_err());
    }

    #[test]
    fn table_renders() {
        let s = MachineConfig::default().render_table();
        assert!(s.contains("Xeon Gold 6126"));
        assert!(s.contains("19.25MiB"));
    }
}
