//! TOML-subset parser: `[section]` headers and `key = value` pairs where
//! value ∈ {string, integer, float, bool}. Comments (`#`) and blank lines
//! allowed. This covers the whole config surface; arrays/tables-of-tables
//! are intentionally unsupported (fail loudly rather than misparse).

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(format!("expected string, got {other:?}")),
        }
    }

    pub fn as_u64(&self) -> Result<u64, String> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            other => Err(format!("expected non-negative integer, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(format!("expected number, got {other:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {other:?}")),
        }
    }
}

/// A parsed document: ordered `(section, key, value)` triples.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String, TomlValue)>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') || name.contains('.') {
                    return Err(format!("line {}: unsupported section {name:?}", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            let val_text = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            if section.is_empty() {
                return Err(format!("line {}: key outside of a [section]", lineno + 1));
            }
            let value = parse_value(val_text).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.entries.push((section.clone(), key.to_string(), value));
        }
        Ok(doc)
    }

    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &TomlValue)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries
            .iter()
            .rev() // last wins, like TOML re-assignment would error but we allow override
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

fn strip_comment(line: &str) -> &str {
    // only strip # outside of quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quotes unsupported".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if clean.contains('.') || clean.contains('e') || clean.contains('E') {
        clean.parse::<f64>().map(TomlValue::Float).map_err(|_| format!("bad value {text:?}"))
    } else {
        clean.parse::<i64>().map(TomlValue::Int).map_err(|_| format!("bad value {text:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# comment
[alpha]
s = "hello"   # trailing comment
i = 42
f = 3.5
neg = -7
b = true
big = 1_000_000

[beta]
x = false
"#,
        )
        .unwrap();
        assert_eq!(doc.get("alpha", "s").unwrap().as_str().unwrap(), "hello");
        assert_eq!(doc.get("alpha", "i").unwrap().as_u64().unwrap(), 42);
        assert_eq!(doc.get("alpha", "f").unwrap().as_f64().unwrap(), 3.5);
        assert_eq!(doc.get("alpha", "big").unwrap().as_u64().unwrap(), 1_000_000);
        assert!(doc.get("beta", "x").unwrap().as_bool().unwrap() == false);
        assert!(doc.get("alpha", "neg").unwrap().as_u64().is_err());
        assert_eq!(doc.get("alpha", "neg").unwrap().as_f64().unwrap(), -7.0);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("[s]\nv = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("s", "v").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors_are_located() {
        let e = TomlDoc::parse("[s]\nbad\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        assert!(TomlDoc::parse("k = 1\n").is_err()); // outside section
        assert!(TomlDoc::parse("[a.b]\n").is_err()); // dotted section
        assert!(TomlDoc::parse("[s]\nv = \"open\n").is_err());
    }
}
