//! Cluster-wide shared CXL memory pool.
//!
//! The paper evaluates one server with its own CXL expander; pooled
//! deployments (Pond, TrEnv) instead attach many hosts to one capacity
//! pool. This module models that pool for the fleet simulation:
//!
//! * **capacity arbitration** — every in-flight invocation leases its
//!   CXL spill from the shared pool for its lifetime; when the pool is
//!   exhausted, the lease (and thus the invocation's start) is delayed
//!   until earlier leases release — capacity pressure becomes latency,
//!   exactly how an allocator stall manifests;
//! * **bandwidth contention** — per-node CXL links and the shared
//!   backplane are [`mem::bwmodel`](crate::mem::bwmodel) instances fed
//!   with each invocation's CXL byte traffic; the resulting M/M/1
//!   factor inflates the CXL-stall portion of co-running invocations.
//!
//! The pool is single-threaded by design: the cluster simulation
//! processes arrivals in virtual-time order, so plain `&mut` state keeps
//! the whole fleet run deterministic.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::mem::bwmodel::BandwidthModel;
use crate::mem::tier::{TierKind, TierParams};

/// The shared pool: capacity ledger + bandwidth models.
#[derive(Debug)]
pub struct CxlPool {
    capacity: u64,
    used: u64,
    /// Pending releases: (virtual release time, bytes).
    releases: BinaryHeap<Reverse<(u64, u64)>>,
    backplane: BandwidthModel,
    links: Vec<BandwidthModel>,
    /// Per-node link derate from fault injection: the fraction of
    /// nominal bandwidth still delivered (1.0 = healthy).
    derates: Vec<f64>,
    link_params: TierParams,
    window_ns: f64,
    /// Times the pool could not grant a full lease even after draining
    /// every pending release.
    pub shortages: u64,
    pub peak_used: u64,
    occ_sum: f64,
    occ_samples: u64,
}

impl CxlPool {
    pub fn new(
        capacity: u64,
        backplane_bw_gbps: f64,
        link_bw_gbps: f64,
        nodes: usize,
        window_ns: u64,
    ) -> CxlPool {
        let mk = |bw: f64| TierParams {
            kind: TierKind::Cxl,
            latency_ns: 0.0,
            bw_gbps: bw,
            capacity,
        };
        let link_params = mk(link_bw_gbps);
        let window_ns = window_ns as f64;
        let mut pool = CxlPool {
            capacity,
            used: 0,
            releases: BinaryHeap::new(),
            backplane: BandwidthModel::with_window(&mk(backplane_bw_gbps), window_ns),
            links: Vec::new(),
            derates: Vec::new(),
            link_params,
            window_ns,
            shortages: 0,
            peak_used: 0,
            occ_sum: 0.0,
            occ_samples: 0,
        };
        pool.ensure_nodes(nodes);
        pool
    }

    /// Grow the per-node link set (autoscaler added nodes).
    pub fn ensure_nodes(&mut self, n: usize) {
        while self.links.len() < n {
            self.links.push(BandwidthModel::with_window(&self.link_params, self.window_ns));
            self.derates.push(1.0);
        }
    }

    /// Fault injection: `node`'s link delivers only `derate` of its
    /// nominal bandwidth until restored with `derate = 1.0`. Clamped to
    /// (0, 1]; the config layer rejects out-of-range values up front.
    pub fn set_link_derate(&mut self, node: usize, derate: f64) {
        self.ensure_nodes(node + 1);
        self.derates[node] = derate.clamp(1e-6, 1.0);
    }

    /// Apply every pending release scheduled at or before `t_ns`.
    pub fn advance(&mut self, t_ns: u64) {
        while let Some(&Reverse((te, b))) = self.releases.peek() {
            if te > t_ns {
                break;
            }
            self.releases.pop();
            self.used -= b;
        }
    }

    /// Lease `want` bytes at virtual time `t_ns`. Returns the grant time
    /// (≥ `t_ns`; later when the lease had to wait for capacity) and the
    /// granted byte count (< `want` only when the pool cannot ever fit
    /// it — counted as a shortage).
    ///
    /// A delayed grant does not free the blocking leases early: their
    /// releases stay queued (and their bytes stay in `used`) until
    /// their release times, so an acquire landing in between still
    /// sees them held. The new lease is charged from acquire time even
    /// when its grant is in the future — conservative by at most the
    /// waiting lease's own size.
    pub fn acquire(&mut self, t_ns: u64, want: u64) -> (u64, u64) {
        let want = want.min(self.capacity);
        self.advance(t_ns);
        let mut t_grant = t_ns;
        // signed: `used` already includes leases granted in the future,
        // so the live deficit must not be lost to saturation — that is
        // what keeps several waiters from double-spending one release
        let mut free = self.capacity as i128 - self.used as i128;
        if free < want as i128 {
            // peek-scan forward for the time enough capacity frees,
            // leaving the release queue itself untouched
            let mut scanned = Vec::new();
            while free < want as i128 {
                match self.releases.pop() {
                    Some(entry) => {
                        let Reverse((te, b)) = entry;
                        free += b as i128;
                        t_grant = t_grant.max(te);
                        scanned.push(entry);
                    }
                    None => break,
                }
            }
            for entry in scanned {
                self.releases.push(entry);
            }
        }
        let granted = (want as i128).min(free.max(0)) as u64;
        if granted < want {
            self.shortages += 1;
        }
        self.used += granted;
        self.peak_used = self.peak_used.max(self.used);
        (t_grant, granted)
    }

    /// Schedule a lease release at virtual time `t_ns`.
    pub fn release_at(&mut self, t_ns: u64, bytes: u64) {
        if bytes > 0 {
            self.releases.push(Reverse((t_ns, bytes)));
        }
    }

    /// Charge a long-lived lease immediately, **without advancing
    /// virtual time**. Snapshot admissions happen at invocation
    /// *finish* times — calling [`CxlPool::acquire`] there would drain
    /// releases scheduled before that future instant and free
    /// in-flight capacity early for arrivals still being processed at
    /// earlier virtual times. Conservative by design: pending releases
    /// do not count as free capacity, and an unfittable lease is simply
    /// refused (no delayed grant).
    pub fn try_lease(&mut self, bytes: u64) -> bool {
        if self.used.saturating_add(bytes) > self.capacity {
            return false;
        }
        self.used += bytes;
        self.peak_used = self.peak_used.max(self.used);
        true
    }

    /// Record an invocation's CXL byte traffic on its node's link and
    /// the shared backplane.
    pub fn record_traffic(&mut self, node: usize, t_ns: u64, bytes: u64) {
        self.ensure_nodes(node + 1);
        if bytes > 0 {
            self.links[node].record(t_ns as f64, bytes);
            self.backplane.record(t_ns as f64, bytes);
        }
    }

    /// Latency-inflation factor a node currently sees: the worse of its
    /// own link and the shared backplane, divided by the link's fault
    /// derate (half the bandwidth doubles the inflation) — so migration
    /// throttling and provisioning re-allocation react to a degraded
    /// link through the same signal as organic contention.
    pub fn factor(&self, node: usize) -> f64 {
        let link = self.links.get(node).map(|l| l.factor()).unwrap_or(1.0);
        let derate = self.derates.get(node).copied().unwrap_or(1.0);
        link.max(self.backplane.factor()) / derate
    }

    /// Current occupancy, clamped to [0, 1] — `used` can transiently
    /// exceed capacity while a delayed lease waits for its grant time.
    pub fn occupancy(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            (self.used as f64 / self.capacity as f64).min(1.0)
        }
    }

    pub fn peak_occupancy(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            (self.peak_used as f64 / self.capacity as f64).min(1.0)
        }
    }

    /// Sample the current occupancy into the running mean.
    pub fn sample(&mut self) {
        self.occ_sum += self.occupancy();
        self.occ_samples += 1;
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.occ_samples == 0 {
            0.0
        } else {
            self.occ_sum / self.occ_samples as f64
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(cap: u64) -> CxlPool {
        CxlPool::new(cap, 64.0, 30.0, 2, 1_000_000)
    }

    #[test]
    fn lease_and_release_cycle() {
        let mut p = pool(1000);
        let (t, g) = p.acquire(10, 600);
        assert_eq!((t, g), (10, 600));
        assert!((p.occupancy() - 0.6).abs() < 1e-9);
        p.release_at(100, 600);
        p.advance(99);
        assert_eq!(p.occupancy(), 0.6);
        p.advance(100);
        assert_eq!(p.occupancy(), 0.0);
        assert_eq!(p.shortages, 0);
        assert_eq!(p.peak_used, 600);
    }

    #[test]
    fn exhausted_pool_delays_grant() {
        let mut p = pool(1000);
        let (_, g1) = p.acquire(0, 900);
        assert_eq!(g1, 900);
        p.release_at(500, 900);
        // wants 400 at t=10: must wait for the t=500 release
        let (t, g) = p.acquire(10, 400);
        assert_eq!(g, 400);
        assert_eq!(t, 500);
        assert_eq!(p.shortages, 0);
    }

    #[test]
    fn delayed_grant_does_not_free_blockers_early() {
        // A holds 900 until t=500; B's 400 must wait for it. A third
        // lease arriving in between must still see A's bytes held —
        // the pool must not over-commit the interval [t, 500).
        let mut p = pool(1000);
        p.acquire(0, 900);
        p.release_at(500, 900);
        let (tb, gb) = p.acquire(10, 400);
        assert_eq!((tb, gb), (500, 400));
        let (tc, gc) = p.acquire(20, 500);
        assert_eq!(gc, 500);
        assert!(tc >= 500, "C granted at {tc}, while A still holds 900 until t=500");
        assert!(p.occupancy() <= 1.0);
        // a fourth waiter cannot double-spend A's release: B (400) and
        // C (500) already claimed it, so only 100 bytes remain
        let (_, gd) = p.acquire(30, 400);
        assert_eq!(gd, 100);
        assert_eq!(p.shortages, 1);
    }

    #[test]
    fn oversized_lease_is_clamped_and_counted() {
        let mut p = pool(1000);
        let (t, g) = p.acquire(0, 5000);
        assert_eq!((t, g), (0, 1000));
        // want > capacity is clamped up front, not a shortage
        assert_eq!(p.shortages, 0);
        let (_, g2) = p.acquire(1, 500);
        assert_eq!(g2, 0);
        assert_eq!(p.shortages, 1);
    }

    #[test]
    fn try_lease_never_advances_time() {
        let mut p = pool(1000);
        p.acquire(0, 600);
        p.release_at(500, 600);
        // a future-timestamped admission must NOT drain the t=500
        // release: only 400 bytes are genuinely free right now
        assert!(!p.try_lease(500));
        assert!(p.try_lease(400));
        assert!((p.occupancy() - 1.0).abs() < 1e-9);
        // the queued release still fires on advance
        p.advance(500);
        assert!((p.occupancy() - 0.4).abs() < 1e-9);
        p.release_at(600, 400);
        p.advance(600);
        assert_eq!(p.occupancy(), 0.0);
    }

    #[test]
    fn traffic_inflates_factor() {
        let mut p = pool(1 << 30);
        assert!((p.factor(0) - 1.0).abs() < 1e-9);
        // hammer node 0's 30 GB/s link: 60 GB/s offered
        let mut t = 0u64;
        for _ in 0..200 {
            t += 500_000; // 0.5 ms steps
            p.record_traffic(0, t, 30_000_000); // 30 MB per 0.5 ms = 60 B/ns
        }
        assert!(p.factor(0) > 1.5, "factor={}", p.factor(0));
        // node 1's link is idle, but the shared backplane is not
        assert!(p.factor(1) >= 1.0);
    }

    #[test]
    fn link_derate_inflates_factor_and_restores() {
        let mut p = pool(1 << 30);
        assert!((p.factor(0) - 1.0).abs() < 1e-9);
        p.set_link_derate(0, 0.5);
        assert!((p.factor(0) - 2.0).abs() < 1e-9, "half bandwidth doubles inflation");
        assert!((p.factor(1) - 1.0).abs() < 1e-9, "other links unaffected");
        p.set_link_derate(0, 1.0);
        assert!((p.factor(0) - 1.0).abs() < 1e-9, "restore returns to nominal");
        // derate applies to a node the pool has not seen yet (autoscale)
        p.set_link_derate(5, 0.25);
        assert!((p.factor(5) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy_sampling() {
        let mut p = pool(100);
        p.acquire(0, 50);
        p.sample();
        p.release_at(1, 50);
        p.advance(1);
        p.sample();
        assert!((p.mean_occupancy() - 0.25).abs() < 1e-9);
    }
}
