//! Deterministic fault injection for the fleet DES.
//!
//! Real CXL pools fail in two ways the failure-free simulation never
//! exercised: a node drops out (taking its in-flight invocations and
//! its donated snapshots with it) and a link's effective bandwidth
//! degrades under fabric contention or partial failure. This module
//! models both as a **virtual-time-ordered schedule** of
//! [`FaultEvent`]s, applied from the epoch loop's *sequential*
//! admission phase — exactly like the autoscaler interleave — so any
//! `--shards K` run replays the same outage at the same instant and
//! stays bit-identical (the PR 7 invariant).
//!
//! Two ways to build a schedule:
//!
//! * [`FaultSchedule::parse`] — a scripted comma-separated DSL
//!   (`down@0.02:1,up@0.04:1,degrade@0.01:0:0.5,restore@0.03:0`),
//!   what `porter-cli cluster --faults <spec|file>` accepts;
//! * [`FaultSchedule::seeded`] — a PRNG-seeded generator over the run
//!   horizon (`[faults]` knobs: `seed`, `downs`, `degrades`,
//!   `derate`), for benches and property tests that want *some*
//!   deterministic outage without hand-writing one.
//!
//! The schedule itself is pure data; the cluster applies each event
//! (routing exclusion, in-flight failure accounting, orphaned-snapshot
//! eviction, pool link derate) and mixes it into the determinism
//! token. With `[faults]` disabled nothing here runs and a cluster run
//! is bit-identical to one built before this module existed.

use crate::util::prng::Rng;

/// What happens to a node at a scheduled virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// The node crashes: the balancer stops routing to it, its
    /// in-flight invocations are accounted as failed (and retried on a
    /// live node), and snapshots it donated are evicted from the store.
    NodeDown,
    /// The node rejoins with empty queues — the autoscaler sees the
    /// returned capacity immediately.
    NodeUp,
    /// The node's CXL link delivers only `derate` of its nominal
    /// bandwidth (0 < derate ≤ 1) until a [`FaultAction::LinkRestore`].
    LinkDegrade {
        /// Fraction of nominal link bandwidth still available.
        derate: f64,
    },
    /// The link returns to full nominal bandwidth.
    LinkRestore,
}

impl FaultAction {
    /// Stable small code: the schedule sort tiebreak, the determinism
    /// token contribution, and the telemetry `action` arg.
    pub fn code(&self) -> u64 {
        match self {
            FaultAction::NodeDown => 0,
            FaultAction::NodeUp => 1,
            FaultAction::LinkDegrade { .. } => 2,
            FaultAction::LinkRestore => 3,
        }
    }

    /// Stable name, used as the telemetry event label and in greps.
    pub fn name(&self) -> &'static str {
        match self {
            FaultAction::NodeDown => "node_down",
            FaultAction::NodeUp => "node_up",
            FaultAction::LinkDegrade { .. } => "link_degrade",
            FaultAction::LinkRestore => "link_restore",
        }
    }
}

/// One scheduled fault: `action` strikes `node` at virtual time `t_ns`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub t_ns: u64,
    /// Index into the cluster's node vector (node id == index).
    pub node: usize,
    pub action: FaultAction,
}

/// A virtual-time-ordered fault schedule with a drain cursor.
///
/// Construction sorts events by `(t_ns, node, action code)` so the
/// application order is a pure function of the schedule contents —
/// never of spec-string order or generator call order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
    cursor: usize,
}

impl FaultSchedule {
    /// Build a schedule from arbitrary-order events (sorted here).
    pub fn new(mut events: Vec<FaultEvent>) -> FaultSchedule {
        events.sort_by_key(|e| (e.t_ns, e.node, e.action.code()));
        FaultSchedule { events, cursor: 0 }
    }

    /// Parse the scripted DSL: comma-separated entries of
    ///
    /// ```text
    /// down@<t_s>:<node>
    /// up@<t_s>:<node>
    /// degrade@<t_s>:<node>:<derate>
    /// restore@<t_s>:<node>
    /// ```
    ///
    /// with `<t_s>` in virtual seconds (fractions allowed) and
    /// `<derate>` in (0, 1]. Empty entries are skipped, so a trailing
    /// comma is harmless.
    pub fn parse(spec: &str) -> Result<FaultSchedule, String> {
        let mut events = Vec::new();
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind, rest) = entry
                .split_once('@')
                .ok_or_else(|| format!("fault entry {entry:?}: missing '@'"))?;
            let mut parts = rest.split(':');
            let t_s: f64 = parts
                .next()
                .filter(|s| !s.is_empty())
                .ok_or_else(|| format!("fault entry {entry:?}: missing time"))?
                .parse()
                .map_err(|_| format!("fault entry {entry:?}: bad time"))?;
            if !t_s.is_finite() || t_s < 0.0 {
                return Err(format!("fault entry {entry:?}: time must be >= 0 seconds"));
            }
            let node: usize = parts
                .next()
                .filter(|s| !s.is_empty())
                .ok_or_else(|| format!("fault entry {entry:?}: missing node"))?
                .parse()
                .map_err(|_| format!("fault entry {entry:?}: bad node index"))?;
            let action = match kind {
                "down" => FaultAction::NodeDown,
                "up" => FaultAction::NodeUp,
                "restore" => FaultAction::LinkRestore,
                "degrade" => {
                    let derate: f64 = parts
                        .next()
                        .ok_or_else(|| format!("fault entry {entry:?}: missing derate"))?
                        .parse()
                        .map_err(|_| format!("fault entry {entry:?}: bad derate"))?;
                    if !(derate > 0.0 && derate <= 1.0) {
                        return Err(format!(
                            "fault entry {entry:?}: derate must be in (0, 1], got {derate}"
                        ));
                    }
                    FaultAction::LinkDegrade { derate }
                }
                _ => {
                    return Err(format!(
                        "fault entry {entry:?}: unknown kind {kind:?} (down|up|degrade|restore)"
                    ))
                }
            };
            if parts.next().is_some() {
                return Err(format!("fault entry {entry:?}: trailing fields"));
            }
            events.push(FaultEvent { t_ns: (t_s * 1e9).round() as u64, node, action });
        }
        Ok(FaultSchedule::new(events))
    }

    /// Generate a seeded schedule over `[0, horizon_ns)`: `downs`
    /// down/up pairs (down lands in the 20–50% window of the horizon,
    /// the rejoin in 55–90%) and `degrades` degrade/restore pairs at
    /// `derate` (degrade in 10–40%, restore in 55–95%). Node 0 is never
    /// taken down so routing always has a live fallback, which also
    /// means a 1-node fleet gets link faults only.
    pub fn seeded(
        seed: u64,
        nodes: usize,
        horizon_ns: u64,
        downs: u32,
        degrades: u32,
        derate: f64,
    ) -> FaultSchedule {
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let horizon = horizon_ns as f64;
        let mut events = Vec::new();
        if nodes > 1 {
            for _ in 0..downs {
                let node = 1 + rng.gen_range((nodes - 1) as u64) as usize;
                let down = (horizon * rng.f64_in(0.20, 0.50)) as u64;
                let up = (horizon * rng.f64_in(0.55, 0.90)) as u64;
                events.push(FaultEvent { t_ns: down, node, action: FaultAction::NodeDown });
                events.push(FaultEvent { t_ns: up, node, action: FaultAction::NodeUp });
            }
        }
        for _ in 0..degrades {
            let node = rng.gen_range(nodes.max(1) as u64) as usize;
            let start = (horizon * rng.f64_in(0.10, 0.40)) as u64;
            let end = (horizon * rng.f64_in(0.55, 0.95)) as u64;
            events.push(FaultEvent {
                t_ns: start,
                node,
                action: FaultAction::LinkDegrade { derate: derate.clamp(1e-6, 1.0) },
            });
            events.push(FaultEvent { t_ns: end, node, action: FaultAction::LinkRestore });
        }
        FaultSchedule::new(events)
    }

    /// Pop the next event due at or before `t_ns`, advancing the
    /// cursor. The cluster loops this at each sequential interleave
    /// point, so every due fault applies exactly once, in order.
    pub fn pop_due(&mut self, t_ns: u64) -> Option<FaultEvent> {
        let ev = *self.events.get(self.cursor)?;
        if ev.t_ns > t_ns {
            return None;
        }
        self.cursor += 1;
        Some(ev)
    }

    /// All scheduled events, in application order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events not yet drained by [`FaultSchedule::pop_due`]. Faults
    /// scheduled after the last arrival never apply (the DES has no
    /// later interleave point), which this exposes for diagnostics.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.cursor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sorts_and_round_trips_times() {
        let s = FaultSchedule::parse("up@0.04:1, down@0.02:1,degrade@0.01:0:0.5,restore@0.03:0,")
            .unwrap();
        assert_eq!(s.len(), 4);
        let order: Vec<(u64, usize, u64)> =
            s.events().iter().map(|e| (e.t_ns, e.node, e.action.code())).collect();
        assert_eq!(
            order,
            vec![(10_000_000, 0, 2), (20_000_000, 1, 0), (30_000_000, 0, 3), (40_000_000, 1, 1)]
        );
        assert_eq!(s.events()[0].action, FaultAction::LinkDegrade { derate: 0.5 });
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "down0.02:1",          // missing '@'
            "down@:1",             // missing time
            "down@0.02",           // missing node
            "down@-1.0:0",         // negative time
            "down@x:0",            // bad time
            "down@0.02:x",         // bad node
            "degrade@0.01:0",      // missing derate
            "degrade@0.01:0:0",    // derate out of range
            "degrade@0.01:0:1.5",  // derate out of range
            "down@0.02:1:extra",   // trailing field
            "explode@0.02:1",      // unknown kind
        ] {
            assert!(FaultSchedule::parse(bad).is_err(), "{bad:?} should be rejected");
        }
        assert!(FaultSchedule::parse("").unwrap().is_empty());
    }

    #[test]
    fn seeded_is_deterministic_sorted_and_spares_node_zero() {
        let a = FaultSchedule::seeded(42, 4, 1_000_000_000, 2, 2, 0.5);
        let b = FaultSchedule::seeded(42, 4, 1_000_000_000, 2, 2, 0.5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.events().windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        for e in a.events() {
            assert!(e.node < 4);
            assert!(e.t_ns < 1_000_000_000);
            if matches!(e.action, FaultAction::NodeDown | FaultAction::NodeUp) {
                assert_ne!(e.node, 0, "node 0 must stay up");
            }
        }
        let c = FaultSchedule::seeded(43, 4, 1_000_000_000, 2, 2, 0.5);
        assert_ne!(a, c, "different seeds must differ");
        // a 1-node fleet never loses its only node
        let solo = FaultSchedule::seeded(42, 1, 1_000_000_000, 3, 1, 0.5);
        for e in solo.events() {
            assert!(
                matches!(e.action, FaultAction::LinkDegrade { .. } | FaultAction::LinkRestore),
                "1-node fleet must only get link faults"
            );
        }
    }

    #[test]
    fn pop_due_drains_in_virtual_time_order() {
        let mut s = FaultSchedule::parse("down@0.002:1,up@0.004:1").unwrap();
        assert_eq!(s.remaining(), 2);
        assert!(s.pop_due(1_999_999).is_none());
        let first = s.pop_due(2_000_000).unwrap();
        assert_eq!((first.t_ns, first.node), (2_000_000, 1));
        assert!(s.pop_due(2_000_000).is_none(), "second event is not due yet");
        let second = s.pop_due(u64::MAX).unwrap();
        assert_eq!(second.action, FaultAction::NodeUp);
        assert_eq!(s.remaining(), 0);
        assert!(s.pop_due(u64::MAX).is_none());
    }
}
