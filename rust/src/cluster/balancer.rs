//! Two-level fleet routing: node pick (this module), then server pick
//! inside the node (`porter::balancer::LeastLoaded` over its virtual
//! servers).
//!
//! Node choice extends least-loaded with *hint locality*: a node whose
//! `HintCache` is cold for the invoked function would pay the profile
//! run + cold start, so it is charged a phantom backlog (a configurable
//! multiple of the fleet's mean service time) at pick time. Warm nodes
//! therefore attract repeat invocations of "their" functions, while a
//! sufficiently overloaded warm node still sheds traffic to cold ones —
//! locality is a bonus, not an affinity pin. Ties rotate round-robin
//! with the same advance-past-the-pick cursor as `LeastLoaded`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// What the balancer sees of one node at pick time.
#[derive(Debug, Clone, Copy)]
pub struct NodeView {
    /// Queued-but-unfinished virtual work at the arrival time.
    pub backlog_ns: u64,
    /// Node holds a warm hint for the invoked function.
    pub warm: bool,
    /// Draining or retired nodes receive no new work.
    pub draining: bool,
}

/// The node-level balancer.
#[derive(Debug, Default)]
pub struct ClusterBalancer {
    rr: AtomicUsize,
}

impl ClusterBalancer {
    /// Pick a node for one arrival; `cold_penalty_ns` is the phantom
    /// backlog charged to nodes without a warm hint. `None` only when
    /// every node is draining.
    pub fn pick(&self, views: &[NodeView], cold_penalty_ns: u64) -> Option<usize> {
        if views.is_empty() {
            return None;
        }
        let n = views.len();
        let start = self.rr.load(Ordering::Relaxed) % n;
        let mut best: Option<(usize, u64)> = None;
        for off in 0..n {
            let i = (start + off) % n;
            let v = &views[i];
            if v.draining {
                continue;
            }
            let score = v.backlog_ns.saturating_add(if v.warm { 0 } else { cold_penalty_ns });
            match best {
                Some((_, s)) if s <= score => {}
                _ => best = Some((i, score)),
            }
        }
        if let Some((i, _)) = best {
            self.rr.store(i + 1, Ordering::Relaxed);
        }
        best.map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(backlog_ns: u64, warm: bool) -> NodeView {
        NodeView { backlog_ns, warm, draining: false }
    }

    #[test]
    fn warm_node_attracts_under_equal_load() {
        let b = ClusterBalancer::default();
        let views = [view(1000, false), view(1000, true), view(1000, false)];
        for _ in 0..5 {
            assert_eq!(b.pick(&views, 500), Some(1));
        }
    }

    #[test]
    fn overloaded_warm_node_sheds_to_cold() {
        let b = ClusterBalancer::default();
        let views = [view(10_000, true), view(100, false)];
        assert_eq!(b.pick(&views, 500), Some(1));
    }

    #[test]
    fn ties_rotate_round_robin() {
        let b = ClusterBalancer::default();
        let views = [view(0, true), view(0, true), view(0, true)];
        let mut counts = [0usize; 3];
        for _ in 0..9 {
            counts[b.pick(&views, 500).unwrap()] += 1;
        }
        assert_eq!(counts, [3, 3, 3]);
    }

    #[test]
    fn draining_nodes_skipped_and_all_draining_is_none() {
        let b = ClusterBalancer::default();
        let mut views = [view(0, true), view(99, true)];
        views[0].draining = true;
        assert_eq!(b.pick(&views, 0), Some(1));
        views[1].draining = true;
        assert_eq!(b.pick(&views, 0), None);
        assert_eq!(b.pick(&[], 0), None);
    }
}
