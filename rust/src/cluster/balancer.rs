//! Two-level fleet routing: node pick (this module), then server pick
//! inside the node (`porter::balancer::LeastLoaded` over its virtual
//! servers).
//!
//! Node choice extends least-loaded with two locality signals:
//!
//! * **hint locality** — a node whose `HintCache` is cold for the
//!   invoked function would pay the profile run, so it is charged a
//!   phantom backlog (a configurable multiple of the fleet's mean
//!   service time) at pick time;
//! * **sandbox locality** — a node without a live warm sandbox pays the
//!   startup the lifecycle layer predicts for it: the full cold start,
//!   or only the snapshot-restore cost when a CXL-resident snapshot of
//!   the function exists (snapshots are pool-resident, so every node
//!   restores at the same predicted price — the signal shrinks the
//!   warm node's advantage exactly when a cheap restore is available).
//!
//! Warm nodes therefore attract repeat invocations of "their"
//! functions, while a sufficiently overloaded warm node still sheds
//! traffic — locality is a bonus, not an affinity pin. Ties rotate
//! round-robin with the same advance-past-the-pick cursor as
//! `LeastLoaded`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// What the balancer sees of one node at pick time.
#[derive(Debug, Clone, Copy)]
pub struct NodeView {
    /// Queued-but-unfinished virtual work at the arrival time.
    pub backlog_ns: u64,
    /// Node holds a warm hint for the invoked function.
    pub warm: bool,
    /// Node holds a live warm sandbox for the invoked function.
    pub sandbox_warm: bool,
    /// Draining or retired nodes receive no new work.
    pub draining: bool,
    /// Fault injection: a down node receives no new work until its
    /// `NodeUp` event rejoins it.
    pub down: bool,
}

/// The node-level balancer.
#[derive(Debug, Default)]
pub struct ClusterBalancer {
    rr: AtomicUsize,
}

impl ClusterBalancer {
    /// Pick a node for one arrival. `hint_penalty_ns` is the phantom
    /// backlog charged to nodes without a warm hint; `startup_penalty_ns`
    /// the predicted sandbox startup (cold start, or restore when a
    /// snapshot exists) charged to nodes without a live sandbox.
    /// `None` only when every node is draining or down.
    pub fn pick(
        &self,
        views: &[NodeView],
        hint_penalty_ns: u64,
        startup_penalty_ns: u64,
    ) -> Option<usize> {
        if views.is_empty() {
            return None;
        }
        let n = views.len();
        let start = self.rr.load(Ordering::Relaxed) % n;
        let mut best: Option<(usize, u64)> = None;
        for off in 0..n {
            let i = (start + off) % n;
            let v = &views[i];
            if v.draining || v.down {
                continue;
            }
            let score = v
                .backlog_ns
                .saturating_add(if v.warm { 0 } else { hint_penalty_ns })
                .saturating_add(if v.sandbox_warm { 0 } else { startup_penalty_ns });
            match best {
                Some((_, s)) if s <= score => {}
                _ => best = Some((i, score)),
            }
        }
        if let Some((i, _)) = best {
            // wrap at store time: a raw `i + 1` is harmless while the
            // fleet size is stable (loads take `% n`), but if the fleet
            // shrinks between picks a stale out-of-range cursor lands on
            // an arbitrary start node and silently skews tie rotation
            self.rr.store((i + 1) % n, Ordering::Relaxed);
        }
        best.map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(backlog_ns: u64, warm: bool) -> NodeView {
        NodeView { backlog_ns, warm, sandbox_warm: warm, draining: false, down: false }
    }

    #[test]
    fn warm_node_attracts_under_equal_load() {
        let b = ClusterBalancer::default();
        let views = [view(1000, false), view(1000, true), view(1000, false)];
        for _ in 0..5 {
            assert_eq!(b.pick(&views, 500, 0), Some(1));
        }
    }

    #[test]
    fn overloaded_warm_node_sheds_to_cold() {
        let b = ClusterBalancer::default();
        let views = [view(10_000, true), view(100, false)];
        assert_eq!(b.pick(&views, 500, 0), Some(1));
    }

    #[test]
    fn ties_rotate_round_robin() {
        let b = ClusterBalancer::default();
        let views = [view(0, true), view(0, true), view(0, true)];
        let mut counts = [0usize; 3];
        for _ in 0..9 {
            counts[b.pick(&views, 500, 0).unwrap()] += 1;
        }
        assert_eq!(counts, [3, 3, 3]);
    }

    #[test]
    fn ties_keep_rotating_after_fleet_shrinks() {
        let b = ClusterBalancer::default();
        // 3-node fleet: picking the last node must store a wrapped
        // cursor (0), not the raw 3
        let views3 = [view(0, true), view(0, true), view(0, true)];
        assert_eq!(b.pick(&views3, 0, 0), Some(0));
        assert_eq!(b.pick(&views3, 0, 0), Some(1));
        assert_eq!(b.pick(&views3, 0, 0), Some(2));
        // fleet shrinks to 2: rotation resumes from the wrapped cursor
        // (node 0 — just past the last pick), not from the stale raw
        // index (3 % 2 = 1), and stays a fair alternation
        let views2 = [view(0, true), view(0, true)];
        let picks: Vec<usize> = (0..4).map(|_| b.pick(&views2, 0, 0).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn sandbox_warm_node_attracts_under_cold_start_penalty() {
        let b = ClusterBalancer::default();
        let mut views = [view(1000, true), view(1000, true)];
        views[1].sandbox_warm = false;
        // same hint state, but node 1 would pay a 250µs cold start
        assert_eq!(b.pick(&views, 0, 250_000), Some(0));
        // a small restore penalty (snapshot exists) lets backlog win again
        views[0].backlog_ns = 100_000;
        assert_eq!(b.pick(&views, 0, 5_000), Some(1));
    }

    #[test]
    fn draining_nodes_skipped_and_all_draining_is_none() {
        let b = ClusterBalancer::default();
        let mut views = [view(0, true), view(99, true)];
        views[0].draining = true;
        assert_eq!(b.pick(&views, 0, 0), Some(1));
        views[1].draining = true;
        assert_eq!(b.pick(&views, 0, 0), None);
        assert_eq!(b.pick(&[], 0, 0), None);
    }

    #[test]
    fn down_nodes_skipped_like_draining_but_rejoin() {
        let b = ClusterBalancer::default();
        // node 0 is idle but down — the loaded healthy node wins
        let mut views = [view(0, true), view(99_999, true)];
        views[0].down = true;
        assert_eq!(b.pick(&views, 0, 0), Some(1));
        views[1].down = true;
        assert_eq!(b.pick(&views, 0, 0), None, "all down routes nowhere");
        // rejoin: clearing the flag makes the idle node attractive again
        views[0].down = false;
        assert_eq!(b.pick(&views, 0, 0), Some(0));
    }
}
