//! One fleet node: today's single-machine Porter stack (servers +
//! engines + its own offline tuner/hint cache) wrapped behind a
//! virtual-time dispatch interface.
//!
//! A node owns real [`porter::server::Server`](crate::porter::server)
//! worker threads and a private [`OfflineTuner`] — hint caches are
//! per-node, which is what makes *hint locality* a routing signal: a
//! node that has profiled a function serves it warm, any other node
//! pays the profile run + cold start again.
//!
//! Execution is hybrid: the first cold (profiled) and first warm
//! (hinted) invocation of each function *actually run* through the
//! engine on the node's servers, producing a measured [`ServiceShape`];
//! repeat invocations replay that shape in virtual time, with the
//! CXL-stall portion inflated by the current pool contention factor.
//! The engine runs themselves consult the process-wide
//! [`crate::trace::TraceStore`]: only the fleet-wide first execution of
//! a `(workload, size)` pair runs the algorithm live (recording its
//! Trace-IR); every other engine run — including another node's profile
//! run of the same function — replays the stored stream, with
//! replay-identity guaranteeing bit-equal reports (counted in
//! `trace_records` / `trace_replays` / `trace_bytes`).
//! This keeps a 16-node × thousands-of-arrivals fleet run fast and —
//! because shapes, hints, and queues evolve only with the deterministic
//! arrival order — exactly reproducible under a fixed seed.
//!
//! With the lifecycle layer enabled (`[lifecycle] enabled = true`),
//! each node additionally owns a [`WarmPool`] of finished sandboxes:
//! the cluster classifies every arrival as warm / restored / cold and
//! passes the resulting startup cost into [`Node::dispatch`]; the node
//! keeps the finished sandbox afterwards (under the pool's byte
//! budget) and hands evictions back for snapshot demotion.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::Config;
use crate::lifecycle::{policy_from_config, Sandbox, StartKind, WarmPool, WarmPoolMetrics};
use crate::porter::balancer::{LeastLoaded, Loaded};
use crate::porter::engine::InvocationOutcome;
use crate::porter::gateway::FunctionSpec;
use crate::porter::server::Server;
use crate::porter::tuner::OfflineTuner;
use crate::shim::SandboxImage;

/// Deterministic service-time shape measured from a real engine run.
#[derive(Debug, Clone)]
pub struct ServiceShape {
    pub wall_ns: f64,
    /// Stall time attributable to CXL-tier misses (scales with pool
    /// contention).
    pub cxl_stall_ns: f64,
    /// Line traffic to the CXL tier (fed to the pool bandwidth models).
    pub cxl_bytes: u64,
    /// Page-migration traffic: every promotion/demotion copies one page
    /// across the node's CXL link, so this debits the link alongside
    /// `cxl_bytes`.
    pub migration_bytes: u64,
    pub promotions: u64,
    pub demotions: u64,
    pub ping_pongs: u64,
    /// Peak DRAM residency (what a kept sandbox pins node-locally).
    pub peak_dram_bytes: u64,
    /// Peak CXL residency (leased from the shared pool while running).
    pub peak_cxl_bytes: u64,
    /// Lane-scheduler overlap: serial stall time hidden under other
    /// lanes' compute (0 when `[lanes]` is off).
    pub overlapped_ns: f64,
    pub lane_switches: u64,
    pub prefetch_issued: u64,
    pub prefetch_useful: u64,
    /// Shim-captured sandbox image (object list + per-tier residency) —
    /// what the warm pool keeps and the snapshot store persists.
    /// `Arc`-shared: shapes are cloned on every replayed dispatch, and
    /// the image must not deep-copy with them.
    pub image: Arc<SandboxImage>,
    pub checksum: u64,
}

impl ServiceShape {
    fn from_outcome(out: &InvocationOutcome, cache_line: u64) -> ServiceShape {
        let misses = out.report.dram_misses + out.report.cxl_misses;
        let cxl_frac = if misses == 0 {
            0.0
        } else {
            out.report.cxl_misses as f64 / misses as f64
        };
        ServiceShape {
            wall_ns: out.report.wall_ns,
            cxl_stall_ns: out.report.stall_ns * cxl_frac,
            cxl_bytes: out.report.cxl_misses * cache_line,
            migration_bytes: out.report.migration_bytes,
            promotions: out.report.promotions,
            demotions: out.report.demotions,
            ping_pongs: out.report.ping_pongs,
            peak_dram_bytes: out.report.peak_dram_bytes,
            peak_cxl_bytes: out.report.peak_cxl_bytes,
            overlapped_ns: out.report.overlapped_ns,
            lane_switches: out.report.lane_switches,
            prefetch_issued: out.report.prefetch_issued,
            prefetch_useful: out.report.prefetch_useful,
            image: Arc::new(out.sandbox.clone()),
            checksum: out.checksum,
        }
    }
}

/// One Porter server plus its virtual engine workers' busy-until times.
struct VServer {
    server: Server,
    free_ns: Vec<u64>,
    cached_backlog: usize,
}

impl Loaded for VServer {
    fn load(&self) -> usize {
        self.cached_backlog
    }
}

/// Output of [`Node::prepare`]: the shared-state-dependent inputs of a
/// dispatch (replay shape, hint warmth, SLO target), resolved
/// sequentially so [`Node::dispatch_prepared`] is safe to run from a
/// shard worker thread.
#[derive(Debug, Clone)]
pub struct PreparedShape {
    shape: ServiceShape,
    warm: bool,
    slo_target_ns: Option<f64>,
}

/// The result of routing one arrival to this node.
#[derive(Debug, Clone)]
pub struct Dispatch {
    pub start_ns: u64,
    pub finish_ns: u64,
    pub wait_ns: u64,
    pub service_ns: u64,
    /// No hint was cached on this node — the profiled path ran.
    pub cold: bool,
    /// How the sandbox was obtained (always `Warm`/`Cold` by hint state
    /// when the lifecycle layer is disabled).
    pub kind: StartKind,
    /// Startup latency charged on top of the replayed shape (cold start
    /// or snapshot restore).
    pub startup_ns: u64,
    /// Which of the node's servers executed it.
    pub server: usize,
    pub slo_target_ns: Option<f64>,
    pub cxl_bytes: u64,
    /// Migration traffic of the replayed shape (debits the CXL link).
    pub migration_bytes: u64,
    pub promotions: u64,
    pub demotions: u64,
    pub ping_pongs: u64,
    /// Lane-scheduler counters of the replayed shape (see
    /// [`ServiceShape::overlapped_ns`]).
    pub overlapped_ns: f64,
    pub lane_switches: u64,
    pub prefetch_issued: u64,
    pub prefetch_useful: u64,
    pub checksum: u64,
}

/// A fleet node.
pub struct Node {
    pub id: usize,
    cfg: Config,
    tuner: Arc<OfflineTuner>,
    vservers: Vec<VServer>,
    picker: LeastLoaded,
    cold_shapes: HashMap<String, ServiceShape>,
    warm_shapes: HashMap<String, ServiceShape>,
    /// Keep-alive pool (lifecycle layer enabled).
    warm_pool: Option<WarmPool>,
    /// Drain mode: the balancer stops routing here; the node retires
    /// once its backlog empties.
    pub draining: bool,
    /// Fault injection (`cluster::faults`): the node is down — the
    /// balancer excludes it and its in-flight work is failed. Unlike
    /// [`Node::retire`] this is reversible: a `NodeUp` event clears it
    /// and the node rejoins with its servers intact.
    pub down: bool,
    pub joined_ns: u64,
    pub retired_ns: Option<u64>,
    pub invocations: u64,
    pub cold_runs: u64,
    /// Sandbox-start outcome counters (see [`StartKind`]).
    pub warm_starts: u64,
    pub restores: u64,
    pub cold_starts: u64,
    pub peak_dram_bytes: u64,
    /// Trace-IR counters over this node's real engine runs: canonical
    /// recordings captured here, replays served from the process-wide
    /// store (including traces recorded by *other* nodes — the
    /// cross-node profile-run amortization), and recorded bytes.
    pub trace_records: u64,
    pub trace_replays: u64,
    pub trace_bytes: u64,
    next_exec_id: u64,
}

impl Node {
    /// Spawn a node: `servers_per_node` real Porter servers sharing one
    /// per-node tuner, each granted an equal slice of the node's DRAM;
    /// the CXL tier is the (nominal) shared pool.
    pub fn spawn(id: usize, base: &Config, joined_ns: u64) -> Node {
        let cl = &base.cluster;
        let mut cfg = base.clone();
        cfg.machine.dram_bytes =
            (cl.dram_per_node / cl.servers_per_node as u64).max(cfg.machine.page_bytes);
        cfg.machine.cxl_bytes = cl.cxl_pool;
        cfg.porter.servers = cl.servers_per_node;
        // one real worker thread per server: the fleet simulation
        // measures sequentially and replays in virtual time
        cfg.porter.workers_per_server = 1;
        let tuner = Arc::new(OfflineTuner::new(&cfg));
        let vservers = (0..cl.servers_per_node)
            .map(|s| VServer {
                server: Server::spawn(id * 1000 + s, &cfg, Arc::clone(&tuner)),
                free_ns: vec![joined_ns; cl.workers_per_server],
                cached_backlog: 0,
            })
            .collect();
        let warm_pool = if cfg.lifecycle.enabled {
            Some(WarmPool::new(cfg.lifecycle.warm_pool_bytes, policy_from_config(&cfg.lifecycle)))
        } else {
            None
        };
        Node {
            id,
            cfg,
            tuner,
            vservers,
            picker: LeastLoaded::default(),
            cold_shapes: HashMap::new(),
            warm_shapes: HashMap::new(),
            warm_pool,
            draining: false,
            down: false,
            joined_ns,
            retired_ns: None,
            invocations: 0,
            cold_runs: 0,
            warm_starts: 0,
            restores: 0,
            cold_starts: 0,
            peak_dram_bytes: 0,
            trace_records: 0,
            trace_replays: 0,
            trace_bytes: 0,
            next_exec_id: 0,
        }
    }

    /// Does this node hold a warm hint for `function`?
    pub fn warm_for(&self, function: &str) -> bool {
        self.tuner.hints().get(function).is_some()
    }

    /// Can this node serve `function` without a profile run? True when
    /// a hint is cached *or* a restore seeded the service shape — the
    /// routing layer's "hint locality" signal.
    pub fn knows(&self, function: &str) -> bool {
        self.warm_for(function)
            || self.cold_shapes.contains_key(function)
            || self.warm_shapes.contains_key(function)
    }

    /// Queued-but-unfinished virtual work at time `t_ns`, summed over
    /// every engine worker.
    pub fn backlog_ns(&self, t_ns: u64) -> u64 {
        self.vservers
            .iter()
            .flat_map(|v| v.free_ns.iter())
            .map(|&f| f.saturating_sub(t_ns))
            .sum()
    }

    pub fn workers(&self) -> usize {
        self.vservers.iter().map(|v| v.free_ns.len()).sum()
    }

    pub fn retired(&self) -> bool {
        self.retired_ns.is_some()
    }

    /// Expected CXL lease for an invocation of `spec` (measured shape if
    /// known, otherwise half the declared footprint).
    pub fn spill_estimate(&self, spec: &FunctionSpec) -> u64 {
        if let Some(s) = self.warm_shapes.get(&spec.name) {
            s.peak_cxl_bytes
        } else if let Some(s) = self.cold_shapes.get(&spec.name) {
            s.peak_cxl_bytes
        } else {
            spec.body.footprint_hint() / 2
        }
    }

    /// Run one invocation for real on a node server (sequentially — the
    /// fleet stays deterministic), draining the tuner after a profiled
    /// run so the hint is visible to the next arrival. With
    /// `[provision]` enabled that drain also covers the demand-curve
    /// ladder replays for a fleet-wide-first function — a one-off
    /// host-time cost; later nodes hit the process-wide curve memo.
    fn measure(&mut self, spec: &FunctionSpec) -> InvocationOutcome {
        let id = ((self.id as u64) << 32) | self.next_exec_id;
        self.next_exec_id += 1;
        let s = (self.next_exec_id as usize) % self.vservers.len();
        let rx = self.vservers[s].server.enqueue(id, spec.clone());
        let out = rx.recv().expect("node server worker died");
        if out.profiled {
            self.cold_runs += 1;
            self.tuner.drain();
        }
        if out.trace_replayed {
            self.trace_replays += 1;
        } else if out.trace_recorded_bytes > 0 {
            self.trace_records += 1;
            self.trace_bytes += out.trace_recorded_bytes;
        }
        self.peak_dram_bytes = self.peak_dram_bytes.max(out.report.peak_dram_bytes);
        out
    }

    fn shape_for(&mut self, spec: &FunctionSpec, warm: bool) -> ServiceShape {
        let map = if warm { &self.warm_shapes } else { &self.cold_shapes };
        if let Some(s) = map.get(&spec.name) {
            return s.clone();
        }
        let out = self.measure(spec);
        let shape = ServiceShape::from_outcome(&out, self.cfg.machine.cache_line);
        let map = if warm { &mut self.warm_shapes } else { &mut self.cold_shapes };
        map.insert(spec.name.clone(), shape.clone());
        shape
    }

    /// The sequential half of a dispatch: resolve the SLO target from
    /// the tuner's hints and the replay shape from the caches — which may
    /// run the function live (profile run through the process-wide
    /// TraceStore / tuner). This must happen in arrival order on the
    /// coordinator thread; the returned [`PreparedShape`] is pure data a
    /// shard worker can consume without shared state.
    pub fn prepare(&mut self, spec: &FunctionSpec) -> PreparedShape {
        let slo_target_ns =
            self.tuner.hints().best_wall(&spec.name).map(|w| w * spec.slo_factor);
        let warm = self.warm_for(&spec.name);
        let shape = self.shape_for(spec, warm);
        PreparedShape { shape, warm, slo_target_ns }
    }

    /// The node-local half of a dispatch: pick a server (least-loaded,
    /// round-robin ties), queue it on that server's earliest-free engine
    /// worker, and return the virtual timeline. Touches nothing outside
    /// this node, so shard workers run it in parallel. `earliest_ns` ≥
    /// the arrival time — it carries any pool-capacity delay.
    /// `startup_ns` is the sandbox startup the cluster's lifecycle
    /// classification charges (0 for a warm hit, the restore latency, or
    /// the full cold start), `kind` the matching outcome for the
    /// per-kind counters.
    pub fn dispatch_prepared(
        &mut self,
        arrival_ns: u64,
        earliest_ns: u64,
        prep: &PreparedShape,
        pool_factor: f64,
        startup_ns: u64,
        kind: StartKind,
    ) -> Dispatch {
        debug_assert!(earliest_ns >= arrival_ns);
        debug_assert!(!self.retired(), "dispatch to retired node {}", self.id);
        let shape = &prep.shape;
        let service = shape.wall_ns
            + shape.cxl_stall_ns * (pool_factor - 1.0).max(0.0)
            + startup_ns as f64;
        let service_ns = (service.round() as u64).max(1);
        match kind {
            StartKind::Warm => self.warm_starts += 1,
            StartKind::Restored => self.restores += 1,
            StartKind::Cold => self.cold_starts += 1,
        }

        for v in &mut self.vservers {
            v.cached_backlog = v.free_ns.iter().filter(|&&f| f > earliest_ns).count();
        }
        let s = self.picker.pick(&self.vservers);
        let v = &mut self.vservers[s];
        let mut wi = 0;
        for (i, f) in v.free_ns.iter().enumerate() {
            if *f < v.free_ns[wi] {
                wi = i;
            }
        }
        let start_ns = earliest_ns.max(v.free_ns[wi]);
        let finish_ns = start_ns + service_ns;
        v.free_ns[wi] = finish_ns;
        self.invocations += 1;
        Dispatch {
            start_ns,
            finish_ns,
            wait_ns: start_ns - arrival_ns,
            service_ns,
            cold: !prep.warm,
            kind,
            startup_ns,
            server: s,
            slo_target_ns: prep.slo_target_ns,
            cxl_bytes: shape.cxl_bytes,
            migration_bytes: shape.migration_bytes,
            promotions: shape.promotions,
            demotions: shape.demotions,
            ping_pongs: shape.ping_pongs,
            overlapped_ns: shape.overlapped_ns,
            lane_switches: shape.lane_switches,
            prefetch_issued: shape.prefetch_issued,
            prefetch_useful: shape.prefetch_useful,
            checksum: shape.checksum,
        }
    }

    /// Dispatch one arrival end to end (prepare + node-local timeline) —
    /// the single-threaded entry point tests and simple callers use.
    pub fn dispatch(
        &mut self,
        arrival_ns: u64,
        earliest_ns: u64,
        spec: &FunctionSpec,
        pool_factor: f64,
        startup_ns: u64,
        kind: StartKind,
    ) -> Dispatch {
        let prep = self.prepare(spec);
        self.dispatch_prepared(arrival_ns, earliest_ns, &prep, pool_factor, startup_ns, kind)
    }

    // ---- lifecycle layer ------------------------------------------------

    pub fn lifecycle_enabled(&self) -> bool {
        self.warm_pool.is_some()
    }

    /// Non-mutating: would an arrival of `function` at `t_ns` find a
    /// live sandbox? (The balancer's sandbox-locality signal.)
    pub fn sandbox_warm_for(&self, function: &str, t_ns: u64) -> bool {
        self.warm_pool.as_ref().is_some_and(|p| p.contains(function, t_ns))
    }

    /// Claim a warm sandbox for an arrival (feeds the keep-alive
    /// policy's learning hook either way). True = warm hit.
    pub fn lifecycle_lookup(&mut self, function: &str, t_ns: u64) -> bool {
        match &mut self.warm_pool {
            Some(p) => {
                p.note_invocation(function, t_ns);
                p.lookup(function, t_ns)
            }
            None => false,
        }
    }

    /// Reclaim keep-alive-expired sandboxes as of `t_ns` (snapshot
    /// candidates for the cluster layer).
    pub fn lifecycle_advance(&mut self, t_ns: u64) -> Vec<Sandbox> {
        self.warm_pool.as_mut().map(|p| p.advance(t_ns)).unwrap_or_default()
    }

    /// Keep the sandbox of a just-finished cold/restored invocation,
    /// returning whatever the byte budget evicted to make room.
    pub fn lifecycle_keep(&mut self, function: &str, finish_ns: u64) -> Vec<Sandbox> {
        let image = self
            .warm_shapes
            .get(function)
            .or_else(|| self.cold_shapes.get(function))
            .map(|s| s.image.clone())
            .unwrap_or_default();
        match &mut self.warm_pool {
            Some(p) => p.insert(Sandbox::new(function, image, finish_ns)),
            None => Vec::new(),
        }
    }

    /// Refresh the live sandbox after a warm invocation finished.
    pub fn lifecycle_touch(&mut self, function: &str, finish_ns: u64) {
        if let Some(p) = &mut self.warm_pool {
            p.touch(function, finish_ns);
        }
    }

    /// Seed the replay shape a restore carries (the donor node's
    /// measured shape), so serving the restored function never needs a
    /// profile run here.
    pub fn seed_shape(&mut self, function: &str, shape: &ServiceShape) {
        self.cold_shapes.entry(function.to_string()).or_insert_with(|| shape.clone());
    }

    /// The node's best measured shape for `function` (what a snapshot
    /// of it should carry).
    pub fn shape_of(&self, function: &str) -> Option<&ServiceShape> {
        self.warm_shapes.get(function).or_else(|| self.cold_shapes.get(function))
    }

    /// Completed uses of the live sandbox for `function` (1 when none
    /// is kept — a just-finished sandbox has served one invocation).
    pub fn sandbox_uses(&self, function: &str) -> u64 {
        self.warm_pool
            .as_ref()
            .and_then(|p| p.sandboxes().iter().find(|s| s.function == function))
            .map(|s| s.uses)
            .unwrap_or(1)
    }

    /// Provisioning-loop rollup from the node's tuner:
    /// `(curves, reallocs, dram_saved_bytes)` — all zero when the
    /// `[provision]` section is off.
    pub fn provision_counts(&self) -> (u64, u64, u64) {
        self.tuner.provision_metrics().counts()
    }

    pub fn warm_pool_metrics(&self) -> Option<WarmPoolMetrics> {
        self.warm_pool.as_ref().map(|p| p.metrics)
    }

    pub fn warm_pool_used_bytes(&self) -> u64 {
        self.warm_pool.as_ref().map(|p| p.used_bytes()).unwrap_or(0)
    }

    /// Shut the node's real servers down (drained or end of run).
    pub fn retire(&mut self, t_ns: u64) {
        if self.retired() {
            return;
        }
        self.retired_ns = Some(t_ns.max(self.joined_ns));
        for v in self.vservers.drain(..) {
            v.server.shutdown();
        }
    }

    /// Seconds of fleet time this node was provisioned for.
    pub fn active_seconds(&self, end_ns: u64) -> f64 {
        let until = self.retired_ns.unwrap_or(end_ns).max(self.joined_ns);
        (until - self.joined_ns) as f64 / 1e9
    }

    pub fn dram_bytes_total(&self) -> u64 {
        self.cfg.machine.dram_bytes * self.cfg.cluster.servers_per_node as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::registry::{build, Scale};

    fn spec(name: &str) -> FunctionSpec {
        FunctionSpec::new(name, Arc::from(build(name, Scale::Small).unwrap()))
    }

    fn node() -> Node {
        let mut cfg = Config::default();
        cfg.cluster.workers_per_server = 2;
        Node::spawn(0, &cfg, 0)
    }

    fn lifecycle_node(budget: u64) -> Node {
        let mut cfg = Config::default();
        cfg.cluster.workers_per_server = 2;
        cfg.lifecycle.enabled = true;
        cfg.lifecycle.warm_pool_bytes = budget;
        Node::spawn(0, &cfg, 0)
    }

    #[test]
    fn cold_then_warm_then_replay() {
        let mut n = node();
        let f = spec("json");
        assert!(!n.warm_for("json"));
        assert!(!n.knows("json"));
        let d1 = n.dispatch(0, 0, &f, 1.0, 1000, StartKind::Cold);
        assert!(d1.cold);
        assert_eq!(d1.kind, StartKind::Cold);
        assert_eq!(d1.startup_ns, 1000);
        assert!(d1.slo_target_ns.is_none());
        // the profiled run published a hint on this node
        assert!(n.warm_for("json"));
        assert!(n.knows("json"));
        let d2 = n.dispatch(d1.finish_ns, d1.finish_ns, &f, 1.0, 0, StartKind::Warm);
        assert!(!d2.cold);
        assert!(d2.slo_target_ns.is_some());
        assert_eq!(d1.checksum, d2.checksum, "placement must not change results");
        // third invocation replays the warm shape exactly
        let d3 = n.dispatch(d2.finish_ns, d2.finish_ns, &f, 1.0, 0, StartKind::Warm);
        assert_eq!(d3.service_ns, d2.service_ns);
        assert_eq!(n.cold_runs, 1);
        assert_eq!(n.invocations, 3);
        assert_eq!(n.cold_starts, 1);
        assert_eq!(n.warm_starts, 2);
        n.retire(d3.finish_ns);
    }

    #[test]
    fn pool_contention_inflates_service() {
        let mut n = node();
        let f = spec("kvstore");
        let d1 = n.dispatch(0, 0, &f, 1.0, 0, StartKind::Cold);
        let warm = n.dispatch(d1.finish_ns, d1.finish_ns, &f, 1.0, 0, StartKind::Warm);
        let contended =
            n.dispatch(warm.finish_ns, warm.finish_ns, &f, 3.0, 0, StartKind::Warm);
        assert!(
            contended.service_ns >= warm.service_ns,
            "contended {} < uncontended {}",
            contended.service_ns,
            warm.service_ns
        );
        n.retire(contended.finish_ns);
    }

    #[test]
    fn queueing_when_workers_busy() {
        let mut n = node(); // 1 server × 2 workers
        let f = spec("json");
        // warm the shape caches first
        let w = n.dispatch(0, 0, &f, 1.0, 0, StartKind::Cold);
        let w2 = n.dispatch(w.finish_ns, w.finish_ns, &f, 1.0, 0, StartKind::Warm);
        let t0 = w2.finish_ns;
        // three simultaneous arrivals on two workers: the third waits
        let a = n.dispatch(t0, t0, &f, 1.0, 0, StartKind::Warm);
        let b = n.dispatch(t0, t0, &f, 1.0, 0, StartKind::Warm);
        let c = n.dispatch(t0, t0, &f, 1.0, 0, StartKind::Warm);
        assert_eq!(a.wait_ns, 0);
        assert_eq!(b.wait_ns, 0);
        assert!(c.wait_ns > 0);
        assert_eq!(c.start_ns, a.finish_ns.min(b.finish_ns));
        assert_eq!(n.backlog_ns(c.finish_ns), 0);
        n.retire(c.finish_ns);
    }

    #[test]
    fn retire_empties_servers() {
        let mut n = node();
        n.retire(5);
        assert!(n.retired());
        assert_eq!(n.workers(), 0);
        assert_eq!(n.backlog_ns(0), 0);
        assert!((n.active_seconds(1_000_000_000) - 5e-9).abs() < 1e-12);
    }

    #[test]
    fn lifecycle_keep_then_warm_hit() {
        let mut n = lifecycle_node(512 * 1024 * 1024);
        let f = spec("json");
        assert!(!n.sandbox_warm_for("json", 0));
        assert!(!n.lifecycle_lookup("json", 0));
        let d = n.dispatch(0, 0, &f, 1.0, 1000, StartKind::Cold);
        let evicted = n.lifecycle_keep("json", d.finish_ns);
        assert!(evicted.is_empty());
        // before the sandbox finished there is no warm hit…
        assert!(!n.sandbox_warm_for("json", d.finish_ns - 1));
        // …after it there is
        assert!(n.sandbox_warm_for("json", d.finish_ns + 1));
        assert!(n.lifecycle_lookup("json", d.finish_ns + 1));
        n.retire(d.finish_ns);
    }

    #[test]
    fn lifecycle_zero_budget_never_warms() {
        let mut n = lifecycle_node(0);
        let f = spec("json");
        let d = n.dispatch(0, 0, &f, 1.0, 1000, StartKind::Cold);
        let evicted = n.lifecycle_keep("json", d.finish_ns);
        assert_eq!(evicted.len(), 1, "zero budget returns the sandbox as evicted");
        assert!(!evicted[0].image.objects.is_empty(), "shim image travels with the sandbox");
        assert!(!n.sandbox_warm_for("json", d.finish_ns + 1));
        n.retire(d.finish_ns);
    }

    #[test]
    fn seeded_shape_avoids_profile_run() {
        let mut donor = node();
        let f = spec("json");
        let d = donor.dispatch(0, 0, &f, 1.0, 0, StartKind::Cold);
        let shape = donor.shape_of("json").unwrap().clone();
        donor.retire(d.finish_ns);

        let mut n = lifecycle_node(512 * 1024 * 1024);
        n.seed_shape("json", &shape);
        assert!(n.knows("json"), "seeded node is warm for routing");
        assert!(!n.warm_for("json"), "…but has no hint");
        let d2 = n.dispatch(0, 0, &f, 1.0, 500, StartKind::Restored);
        assert_eq!(n.cold_runs, 0, "restore must not trigger a profile run");
        assert_eq!(n.restores, 1);
        assert_eq!(d2.checksum, d.checksum);
        n.retire(d2.finish_ns);
    }
}
