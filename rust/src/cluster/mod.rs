//! Fleet simulation: Porter scaled from one machine to a multi-node
//! cluster.
//!
//! The single-machine stack (gateway → balancer → server → engine →
//! tuner) reproduces the paper's testbed; this layer answers the
//! question the paper motivates but cannot measure on one box — what
//! fine-grained DRAM/CXL provisioning buys *fleet-wide*:
//!
//! * [`node`] — a fleet node: real Porter servers + a private per-node
//!   tuner/hint cache, dispatched in virtual time;
//! * [`pool`] — the cluster-wide shared CXL pool (capacity leases +
//!   link/backplane bandwidth contention via `mem::bwmodel`);
//! * [`arrivals`] — open-loop load generation (Poisson, bursty,
//!   diurnal, Azure-style trace replay), PRNG-seeded and deterministic;
//! * [`balancer`] — two-level routing with hint- and sandbox-locality
//!   awareness;
//! * [`autoscaler`] — node add/drain on queue-depth and SLO signals;
//! * [`faults`] — deterministic fault injection (node loss/rejoin, CXL
//!   link derating) applied on the sequential epoch phases, with the
//!   availability rollup in the report.
//!
//! With `[lifecycle] enabled = true` the warm path is modeled
//! explicitly (see [`crate::lifecycle`]): every arrival is classified
//! warm / restored / cold against the picked node's
//! [`crate::lifecycle::WarmPool`] and the cluster
//! [`SnapshotStore`]; snapshots lease capacity from the
//! shared CXL pool and their transfer bytes debit link bandwidth like
//! migration traffic, so the report's pool occupancy and per-kind
//! latency breakout show exactly what keep-alive buys.
//!
//! The simulation is a discrete-event loop over the arrival schedule in
//! virtual time. Real engine runs (on real server threads) measure each
//! function's service shape per node and placement mode; everything
//! else — queueing, contention, scaling — is replayed deterministically,
//! so an entire 16-node run is exactly reproducible from one seed
//! (checked by [`ClusterReport::determinism_token`]).

pub mod arrivals;
pub mod autoscaler;
pub mod balancer;
pub mod faults;
pub mod node;
pub mod pool;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};

use crate::config::Config;
use crate::lifecycle::{AdmitOutcome, Sandbox, SnapshotStore, StartKind};
use crate::metrics::Histogram;
use crate::porter::gateway::FunctionSpec;
use crate::porter::slo::SloTracker;
use crate::telemetry::{
    EventKind, FleetSample, FleetSampler, TelemetryEvent, TelemetryReport, TelemetrySink,
};
use crate::util::bytes::{fmt_bytes, GIB};
use crate::workloads::mix;
use crate::workloads::registry::{build, Scale};

use arrivals::{ArrivalSpec, AzureTrace, Shape};
use autoscaler::{Autoscaler, FleetSignal, ScaleDirection, ScaleEvent};
use balancer::{ClusterBalancer, NodeView};
use faults::{FaultAction, FaultEvent, FaultSchedule};
use node::{Dispatch, Node, PreparedShape, ServiceShape};
use pool::CxlPool;

/// Cost proxy, in relative $/GiB-second: local DRAM versus pooled CXL
/// capacity. The 1 : 0.33 ratio reflects the pooled-memory TCO premise
/// (Pond: cheaper media, amortized across hosts); only the ratio
/// matters to the trends the benches track.
pub const DRAM_COST_PER_GIB_S: f64 = 1.0;
pub const CXL_COST_PER_GIB_S: f64 = 0.33;

/// Serving-oriented default population, lightest functions first (rank 0
/// is the Zipf-hottest).
const POPULATION_ORDER: [&str; 14] = [
    "json", "kvstore", "chameleon", "image", "compression", "sort", "matmul", "bfs", "cc",
    "pagerank", "linpack", "dl_serve", "dl_train", "txn_bench",
];

/// The first `n` registry functions of the serving population.
pub fn default_population(n: usize) -> Vec<String> {
    POPULATION_ORDER
        .iter()
        .take(n.clamp(1, POPULATION_ORDER.len()))
        .map(|s| s.to_string())
        .collect()
}

/// Build the open-loop schedule a config describes.
pub fn arrivals_from_config(cfg: &Config) -> Result<ArrivalSpec, String> {
    let cl = &cfg.cluster;
    if cl.functions > POPULATION_ORDER.len() {
        return Err(format!(
            "cluster.functions = {} exceeds the {}-function registry population",
            cl.functions,
            POPULATION_ORDER.len()
        ));
    }
    if cl.arrivals == "replay" {
        let trace = if cl.trace_path.is_empty() {
            // demo trace: synthesized, deterministic from the seed
            let bins = ((cl.duration_s * 10.0).ceil() as usize).max(1);
            let per_bin = cl.rate_per_s * 0.1 / default_population(cl.functions).len() as f64 * 2.0;
            AzureTrace::synthesize(&default_population(cl.functions), bins, 100, per_bin, cl.seed)
        } else {
            let text = std::fs::read_to_string(&cl.trace_path)
                .map_err(|e| format!("read trace {}: {e}", cl.trace_path))?;
            AzureTrace::parse(&text)?
        };
        return Ok(trace.expand(cl.seed));
    }
    let shape = Shape::parse(&cl.arrivals).ok_or_else(|| {
        format!("unknown arrival shape {:?} (poisson|bursty|diurnal|replay)", cl.arrivals)
    })?;
    Ok(arrivals::synthetic(
        shape,
        &default_population(cl.functions),
        cl.rate_per_s,
        cl.duration_s,
        cl.zipf_theta,
        cl.seed,
    ))
}

/// Host-side execution counters for the sharded event loop: how the
/// simulator *ran*, not what it simulated.
///
/// Excluded from report equality on purpose — worker count and
/// wall-clock event rate describe the host machine and legitimately
/// vary across `--shards` settings, while every simulated field must
/// stay bit-identical. The hand-written [`PartialEq`] below is what
/// lets `ClusterReport: PartialEq` mean "same simulation".
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Peak worker threads used by any epoch's dispatch phase.
    pub workers: usize,
    /// Epoch barriers crossed (one deterministic merge each).
    pub merges: u64,
    /// Arrival events processed through the batched loop.
    pub events: u64,
    /// Events per wall-clock second over the whole run — the
    /// simulator-speed trajectory the hotpath bench tracks.
    pub events_per_sec: f64,
}

impl PartialEq for ShardStats {
    /// Always equal: host-time throughput is not simulation state.
    fn eq(&self, _: &ShardStats) -> bool {
        true
    }
}

/// Per-node slice of the final report.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSummary {
    pub id: usize,
    pub invocations: u64,
    pub cold_runs: u64,
    pub warm_starts: u64,
    pub restores: u64,
    pub cold_starts: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub active_s: f64,
    pub peak_dram_bytes: u64,
    pub retired: bool,
}

/// Fleet-level results of one simulation run.
///
/// `PartialEq` compares every simulated field; the acceptance bar for
/// the sharded loop is field-for-field equality across shard counts
/// (host-side [`ShardStats`] compare equal by construction).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterReport {
    pub completed: u64,
    pub virtual_duration_s: f64,
    pub throughput_per_s: f64,
    pub fleet_p50_ns: u64,
    pub fleet_p99_ns: u64,
    pub fleet_mean_ns: f64,
    pub mean_wait_ns: f64,
    pub mean_service_ns: f64,
    pub judged: u64,
    pub violation_rate: f64,
    pub cold_runs: u64,
    pub pool_mean_occupancy: f64,
    pub pool_peak_occupancy: f64,
    pub pool_shortages: u64,
    /// Fleet-wide page-migration rollup (replayed shapes included): the
    /// engine's promotions/demotions/ping-pongs, and the migration
    /// traffic debited against the nodes' CXL links.
    pub promotions: u64,
    pub demotions: u64,
    pub ping_pongs: u64,
    pub migration_bytes: u64,
    /// Lane-scheduler rollup (`[lanes]` enabled): CXL stall time hidden
    /// under other lanes' compute, summed over every settled dispatch
    /// (replayed shapes included), plus scheduler/prefetcher counters.
    /// All zero with the section off.
    pub lanes_enabled: bool,
    pub overlapped_ns: f64,
    pub lane_switches: u64,
    pub prefetch_issued: u64,
    pub prefetch_useful: u64,
    /// Trace-IR rollup over the fleet's real engine runs: canonical
    /// recordings, replays served from the process-wide store (a node
    /// replaying a peer's profile run counts here), and recorded bytes.
    pub trace_records: u64,
    pub trace_replays: u64,
    pub trace_bytes: u64,
    /// Per-function DRAM provisioning rollup (`[provision]` enabled):
    /// demand curves held across the node tuners, allocator runs, and
    /// the latest DRAM-saved-vs-uniform snapshots summed over nodes.
    /// The SLO-violation delta against a uniform run comes from
    /// comparing two reports' `violation_rate` (see
    /// `benches/e2e_provision.rs`) — a single run has no counterfactual.
    pub provision_enabled: bool,
    pub provision_curves: u64,
    pub provision_reallocs: u64,
    pub provision_dram_saved_bytes: u64,
    /// Sandbox-lifecycle rollup. With the lifecycle layer disabled the
    /// start counters fall back to the legacy hint-based cold/warm
    /// split and the snapshot fields stay zero.
    pub lifecycle_enabled: bool,
    pub cold_starts: u64,
    pub warm_starts: u64,
    pub restores: u64,
    pub cold_p50_ns: u64,
    pub warm_p50_ns: u64,
    pub restore_p50_ns: u64,
    pub warm_hits: u64,
    pub warm_evictions: u64,
    pub warm_rejected: u64,
    pub warm_pool_peak_bytes: u64,
    pub snapshots_taken: u64,
    /// Bytes written over CXL links creating snapshots.
    pub snapshot_bytes: u64,
    /// Bytes read over CXL links restoring snapshots.
    pub restore_bytes: u64,
    /// Pool capacity currently leased by (and peak-leased to) snapshots.
    pub snapshot_leased_bytes: u64,
    pub snapshot_peak_leased_bytes: u64,
    pub snapshot_lease_denied: u64,
    pub snapshot_evicted: u64,
    /// Fault-injection availability rollup (`[faults]` enabled). A
    /// fault-free run reports zero counters and availability 1.0.
    pub faults_enabled: bool,
    pub fault_downs: u64,
    pub fault_rejoins: u64,
    pub fault_degrades: u64,
    /// In-flight invocations voided by a node loss. Each one already
    /// counted toward `completed` when it settled, so availability is
    /// `1 − failed / completed`.
    pub fault_failed: u64,
    /// Failed invocations re-admitted on a surviving node.
    pub fault_retried: u64,
    /// Epoch barriers crossed while any node was down or any link
    /// degraded.
    pub degraded_epochs: u64,
    pub availability: f64,
    /// p99 end-to-end latency over completions settled while a fault
    /// was active (0 when no completion overlapped a fault).
    pub degraded_p99_ns: u64,
    pub node_seconds: f64,
    /// DRAM + pooled-CXL provisioning cost (relative units; see
    /// [`DRAM_COST_PER_GIB_S`]).
    pub cost_units: f64,
    pub nodes: Vec<NodeSummary>,
    pub events: Vec<ScaleEvent>,
    /// How the sharded loop executed (host-side; never part of
    /// equality).
    pub shards: ShardStats,
    /// Order-sensitive hash over every routing decision and virtual
    /// timeline — two runs of the same config+seed must match exactly.
    pub determinism_token: u64,
}

impl ClusterReport {
    /// ASCII report: fleet rollup, per-node table, autoscaler events.
    pub fn render(&self) -> String {
        use crate::bench::fmt_ns;
        use crate::util::table::Table;
        let mut out = String::new();
        let mut t = Table::new(&["fleet metric", "value"]).left_first();
        t.row(vec!["invocations".into(), self.completed.to_string()]);
        t.row(vec!["virtual duration".into(), format!("{:.3}s", self.virtual_duration_s)]);
        t.row(vec!["throughput".into(), format!("{:.1} inv/s", self.throughput_per_s)]);
        t.row(vec![
            "e2e latency".into(),
            format!(
                "mean {} p50≤{} p99≤{}",
                fmt_ns(self.fleet_mean_ns),
                fmt_ns(self.fleet_p50_ns as f64),
                fmt_ns(self.fleet_p99_ns as f64)
            ),
        ]);
        t.row(vec!["mean queue wait".into(), fmt_ns(self.mean_wait_ns)]);
        t.row(vec!["mean service".into(), fmt_ns(self.mean_service_ns)]);
        t.row(vec![
            "SLO violations".into(),
            format!("{:.1}% of {} judged", self.violation_rate * 100.0, self.judged),
        ]);
        t.row(vec!["cold (profile) runs".into(), self.cold_runs.to_string()]);
        t.row(vec![
            "sandbox starts".into(),
            format!(
                "{} cold / {} warm / {} restored",
                self.cold_starts, self.warm_starts, self.restores
            ),
        ]);
        if self.lifecycle_enabled {
            t.row(vec![
                "startup p50".into(),
                format!(
                    "cold {} / warm {} / restored {}",
                    fmt_ns(self.cold_p50_ns as f64),
                    fmt_ns(self.warm_p50_ns as f64),
                    fmt_ns(self.restore_p50_ns as f64)
                ),
            ]);
            t.row(vec![
                "warm pools".into(),
                format!(
                    "{} hits, {} evictions (+{} oversized), peak {}",
                    self.warm_hits,
                    self.warm_evictions,
                    self.warm_rejected,
                    fmt_bytes(self.warm_pool_peak_bytes)
                ),
            ]);
            t.row(vec![
                "snapshot store".into(),
                format!(
                    "{} taken ({} evicted, {} denied), wrote {} read {}, leased {} peak {}",
                    self.snapshots_taken,
                    self.snapshot_evicted,
                    self.snapshot_lease_denied,
                    fmt_bytes(self.snapshot_bytes),
                    fmt_bytes(self.restore_bytes),
                    fmt_bytes(self.snapshot_leased_bytes),
                    fmt_bytes(self.snapshot_peak_leased_bytes)
                ),
            ]);
        }
        t.row(vec![
            "CXL pool occupancy".into(),
            format!(
                "mean {:.1}% peak {:.1}% ({} shortages)",
                self.pool_mean_occupancy * 100.0,
                self.pool_peak_occupancy * 100.0,
                self.pool_shortages
            ),
        ]);
        t.row(vec![
            "page migration".into(),
            format!(
                "{}↑ {}↓ ({} ping-pongs, {} over CXL links)",
                self.promotions,
                self.demotions,
                self.ping_pongs,
                fmt_bytes(self.migration_bytes)
            ),
        ]);
        if self.lanes_enabled {
            t.row(vec![
                "lane overlap".into(),
                format!(
                    "{} hidden ({} switches, prefetch {}/{} useful)",
                    fmt_ns(self.overlapped_ns),
                    self.lane_switches,
                    self.prefetch_useful,
                    self.prefetch_issued
                ),
            ]);
        }
        t.row(vec![
            "trace IR".into(),
            format!(
                "{} recorded ({}), {} replays",
                self.trace_records,
                fmt_bytes(self.trace_bytes),
                self.trace_replays
            ),
        ]);
        if self.provision_enabled {
            t.row(vec![
                "provisioning".into(),
                format!(
                    "{} curves, {} reallocs, {} saved vs uniform",
                    self.provision_curves,
                    self.provision_reallocs,
                    fmt_bytes(self.provision_dram_saved_bytes)
                ),
            ]);
        }
        if self.faults_enabled {
            t.row(vec![
                "faults".into(),
                format!(
                    "{} downs / {} rejoins / {} degrades, {} failed ({} retried)",
                    self.fault_downs,
                    self.fault_rejoins,
                    self.fault_degrades,
                    self.fault_failed,
                    self.fault_retried
                ),
            ]);
            t.row(vec![
                "availability".into(),
                format!(
                    "{:.4} ({} degraded epochs, degraded p99 {})",
                    self.availability,
                    self.degraded_epochs,
                    fmt_ns(self.degraded_p99_ns as f64)
                ),
            ]);
        }
        t.row(vec!["node-seconds".into(), format!("{:.3}", self.node_seconds)]);
        t.row(vec!["cost proxy".into(), format!("{:.1} units", self.cost_units)]);
        t.row(vec![
            "determinism token".into(),
            format!("{:#018x}", self.determinism_token),
        ]);
        out.push_str(&t.render());

        let headers =
            ["node", "invocations", "cold", "w/r/c", "p50", "p99", "active", "peak DRAM"];
        let mut nt = Table::new(&headers).left_first();
        for n in &self.nodes {
            nt.row(vec![
                format!("n{}{}", n.id, if n.retired { " (drained)" } else { "" }),
                n.invocations.to_string(),
                n.cold_runs.to_string(),
                format!("{}/{}/{}", n.warm_starts, n.restores, n.cold_starts),
                fmt_ns(n.p50_ns as f64),
                fmt_ns(n.p99_ns as f64),
                format!("{:.3}s", n.active_s),
                fmt_bytes(n.peak_dram_bytes),
            ]);
        }
        out.push('\n');
        out.push_str(&nt.render());

        if !self.events.is_empty() {
            out.push_str("\nautoscaler events:\n");
            for e in &self.events {
                out.push_str(&format!(
                    "  t={:8.3}s {:10} → {} nodes  ({})\n",
                    e.t_ns as f64 / 1e9,
                    e.direction.name(),
                    e.nodes_after,
                    e.reason
                ));
            }
        }
        out
    }
}

/// The fleet.
pub struct Cluster {
    cfg: Config,
    specs: Vec<FunctionSpec>,
    nodes: Vec<Node>,
    pool: CxlPool,
    balancer: ClusterBalancer,
    autoscaler: Option<Autoscaler>,
    /// Cluster-wide snapshot store (lifecycle layer with snapshots on).
    snapshots: Option<SnapshotStore>,
    /// Replay shapes travelling with snapshots: what a restoring node
    /// seeds so it never pays a profile run. Shapes are node-independent
    /// (identical node configs), so one entry per function suffices.
    snapshot_shapes: HashMap<String, ServiceShape>,
    /// Functions whose image can never fit the snapshot store — stop
    /// retrying admission for them on every arrival.
    snapshot_skip: HashSet<String>,
    /// Fault schedule (`None` when `[faults]` is disabled — the entire
    /// subsystem then adds one branch per interleave point and the run
    /// stays bit-identical to a build without it). Events apply on the
    /// sequential phase-A path, so shard count never changes them.
    faults: Option<FaultSchedule>,
    /// Per-node in-flight completions `(finish_ns, function)` —
    /// maintained only while fault injection is on, so a `NodeDown` can
    /// fail and retry exactly the work that was running there.
    inflight: Vec<BinaryHeap<Reverse<(u64, usize)>>>,
    /// Links currently derated (guards double-counting on repeated
    /// degrade events for one node).
    degraded_links: HashSet<usize>,
    /// Nodes currently down (O(1) fault-active check in `settle`).
    down_now: usize,
    fault_downs: u64,
    fault_rejoins: u64,
    fault_degrades: u64,
    fault_failed: u64,
    fault_retried: u64,
    degraded_epochs: u64,
    degraded_hist: Histogram,
    slo: SloTracker,
    fleet_hist: Histogram,
    cold_hist: Histogram,
    warm_hist: Histogram,
    restore_hist: Histogram,
    node_hists: Vec<Histogram>,
    events: Vec<ScaleEvent>,
    window_judged: u64,
    window_violations: u64,
    wait_sum_ns: f64,
    service_sum_ns: f64,
    completed: u64,
    promotions: u64,
    demotions: u64,
    ping_pongs: u64,
    migration_bytes: u64,
    overlapped_ns: f64,
    lane_switches: u64,
    prefetch_issued: u64,
    prefetch_useful: u64,
    end_ns: u64,
    token: u64,
    next_node_id: usize,
    /// Event sink + per-epoch fleet sampler (`[telemetry]` section;
    /// disabled, each hook is one branch). Telemetry only *reads*
    /// already-computed values after the determinism token was mixed,
    /// so enabling it never changes a report.
    telemetry: TelemetrySink,
    sampler: FleetSampler,
    /// Fleet-wide provision realloc count at the last telemetry check
    /// (delta detection for `Provision` events).
    last_reallocs: u64,
    /// Host-side counters for [`ShardStats`].
    merges: u64,
    sim_events: u64,
    shard_workers: usize,
}

/// One arrival after phase A (admission): routed, classified, pool
/// lease acquired, service shape prepared — everything the node-local
/// dispatch (phase B) needs without touching shared state.
struct PreparedArrival {
    t_ns: u64,
    /// Index into the population (`Cluster::specs`); mixed into the
    /// determinism token exactly as the per-event loop did.
    function: usize,
    spec: FunctionSpec,
    /// Index into `Cluster::nodes` (phase-B routing target).
    ni: usize,
    node_id: usize,
    kind: StartKind,
    startup_ns: u64,
    spill: u64,
    grant_ns: u64,
    granted: u64,
    factor: f64,
    prep: PreparedShape,
}

/// The telemetry a shard worker emits for one dispatch. Workers buffer
/// these per node and the barrier splices the buffers in node-index
/// order, so the sink's event order is a pure function of the virtual
/// timeline — never of thread scheduling or shard count.
struct WorkerTelemetry {
    enabled: bool,
    spans: bool,
    policy: String,
}

impl WorkerTelemetry {
    fn record(&self, buf: &mut Vec<TelemetryEvent>, p: &PreparedArrival, d: &Dispatch) {
        let nid = p.node_id as u64;
        let e2e_ns = d.finish_ns - p.t_ns;
        if self.spans {
            buf.push(
                TelemetryEvent::new(EventKind::Invocation, p.t_ns)
                    .span(e2e_ns)
                    .on_node(nid)
                    .func(&p.spec.name)
                    .tag(p.kind.name())
                    .arg("wait_ns", d.wait_ns)
                    .arg("service_ns", d.service_ns)
                    .arg("startup_ns", d.startup_ns)
                    .arg("cxl_bytes", d.cxl_bytes)
                    .arg("migration_bytes", d.migration_bytes),
            );
        }
        if d.startup_ns > 0 {
            buf.push(
                TelemetryEvent::new(EventKind::Startup, d.start_ns)
                    .on_node(nid)
                    .func(&p.spec.name)
                    .tag(p.kind.name())
                    .arg("startup_ns", d.startup_ns),
            );
        }
        if d.promotions + d.demotions > 0 {
            buf.push(
                TelemetryEvent::new(EventKind::Migration, d.start_ns)
                    .on_node(nid)
                    .func(&p.spec.name)
                    .tag(&self.policy)
                    .arg("promotions", d.promotions)
                    .arg("demotions", d.demotions)
                    .arg("ping_pongs", d.ping_pongs)
                    .arg("bytes", d.migration_bytes),
            );
        }
    }
}

impl Cluster {
    /// Build a fleet for the given function population (registry names).
    pub fn new(cfg: &Config, names: &[String]) -> Result<Cluster, String> {
        cfg.validate()?;
        let cl = &cfg.cluster;
        let mut specs = Vec::with_capacity(names.len());
        for name in names {
            let body = build(name, Scale::Small)
                .ok_or_else(|| format!("unknown registry workload {name:?}"))?;
            let mut spec = FunctionSpec::new(name, std::sync::Arc::from(body));
            spec.slo_factor = cfg.porter.slo_factor;
            specs.push(spec);
        }
        let nodes: Vec<Node> = (0..cl.nodes).map(|i| Node::spawn(i, cfg, 0)).collect();
        let node_hists = (0..cl.nodes).map(|_| Histogram::default()).collect();
        let pool = CxlPool::new(
            cl.cxl_pool,
            cl.cxl_pool_bw_gbps,
            cl.cxl_link_bw_gbps,
            cl.nodes,
            cl.bw_window_ns,
        );
        let lc = &cfg.lifecycle;
        let snapshots = if lc.enabled && lc.snapshot {
            let capacity = (cl.cxl_pool as f64 * lc.snapshot_capacity_frac) as u64;
            Some(SnapshotStore::new(capacity, lc.snapshot_min_uses, lc.restore_overhead_ns))
        } else {
            None
        };
        let fl = &cfg.faults;
        let fault_schedule = if fl.enabled {
            Some(if fl.spec.is_empty() {
                FaultSchedule::seeded(
                    fl.seed,
                    cl.nodes,
                    (cl.duration_s * 1e9) as u64,
                    fl.downs,
                    fl.degrades,
                    fl.derate,
                )
            } else {
                // validate() already parsed the spec; re-parse for the
                // owned schedule
                FaultSchedule::parse(&fl.spec)?
            })
        } else {
            None
        };
        let tl = &cfg.telemetry;
        Ok(Cluster {
            telemetry: if tl.enabled {
                TelemetrySink::new(tl.buffer_bytes)
            } else {
                TelemetrySink::disabled()
            },
            sampler: if tl.enabled {
                FleetSampler::new(tl.epoch_ns)
            } else {
                FleetSampler::disabled()
            },
            last_reallocs: 0,
            cfg: cfg.clone(),
            specs,
            next_node_id: nodes.len(),
            nodes,
            pool,
            balancer: ClusterBalancer::default(),
            autoscaler: if cl.autoscale { Some(Autoscaler::new(cl)) } else { None },
            snapshots,
            snapshot_shapes: HashMap::new(),
            snapshot_skip: HashSet::new(),
            faults: fault_schedule,
            inflight: Vec::new(),
            degraded_links: HashSet::new(),
            down_now: 0,
            fault_downs: 0,
            fault_rejoins: 0,
            fault_degrades: 0,
            fault_failed: 0,
            fault_retried: 0,
            degraded_epochs: 0,
            degraded_hist: Histogram::default(),
            slo: SloTracker::default(),
            fleet_hist: Histogram::default(),
            cold_hist: Histogram::default(),
            warm_hist: Histogram::default(),
            restore_hist: Histogram::default(),
            node_hists,
            events: Vec::new(),
            window_judged: 0,
            window_violations: 0,
            wait_sum_ns: 0.0,
            service_sum_ns: 0.0,
            completed: 0,
            promotions: 0,
            demotions: 0,
            ping_pongs: 0,
            migration_bytes: 0,
            overlapped_ns: 0.0,
            lane_switches: 0,
            prefetch_issued: 0,
            prefetch_useful: 0,
            end_ns: 0,
            token: 0x0C1A57E5,
            merges: 0,
            sim_events: 0,
            shard_workers: 0,
        })
    }

    fn mean_service_ns(&self) -> f64 {
        if self.completed == 0 {
            // before any completion, use the cold-start penalty as the
            // locality bonus scale
            self.cfg.cluster.cold_start_ns as f64
        } else {
            self.service_sum_ns / self.completed as f64
        }
    }

    /// Offer evicted sandboxes to the snapshot store (lease pool
    /// capacity, debit the write over the evicting node's link).
    fn demote(&mut self, ni: usize, evicted: Vec<Sandbox>, t_ns: u64) {
        let node_id = self.nodes[ni].id;
        if self.telemetry.is_enabled() {
            for sb in &evicted {
                let ev = TelemetryEvent::new(EventKind::WarmEvict, t_ns)
                    .on_node(node_id as u64)
                    .func(&sb.function)
                    .arg("bytes", sb.bytes())
                    .arg("uses", sb.uses);
                self.telemetry.push(ev);
            }
        }
        if self.snapshots.is_none() {
            return;
        }
        for sb in evicted {
            if self.snapshot_skip.contains(&sb.function) {
                continue;
            }
            let shape = self.nodes[ni].shape_of(&sb.function).cloned();
            let Some(shape) = shape else { continue };
            let st = self.snapshots.as_mut().expect("checked above");
            match st.admit(&sb, t_ns, node_id, &mut self.pool) {
                AdmitOutcome::Admitted => {
                    self.note_snapshot_write(node_id, &sb.function, sb.bytes(), t_ns);
                    self.snapshot_shapes.entry(sb.function.clone()).or_insert(shape);
                }
                AdmitOutcome::TooBig => {
                    self.snapshot_skip.insert(sb.function.clone());
                }
                _ => {}
            }
        }
    }

    fn note_snapshot_write(&mut self, node_id: usize, function: &str, bytes: u64, t_ns: u64) {
        if self.telemetry.is_enabled() {
            let ev = TelemetryEvent::new(EventKind::SnapshotWrite, t_ns)
                .on_node(node_id as u64)
                .func(function)
                .arg("bytes", bytes);
            self.telemetry.push(ev);
        }
    }

    /// Classify one arrival's sandbox outcome on the picked node and
    /// return the startup latency to charge.
    fn classify(&mut self, ni: usize, function: &str, t_ns: u64) -> (StartKind, u64) {
        if !self.cfg.lifecycle.enabled {
            // legacy model: a node that has run the function keeps its
            // sandbox forever; the hint state is the cold/warm split
            return if self.nodes[ni].warm_for(function) {
                (StartKind::Warm, 0)
            } else {
                (StartKind::Cold, self.cfg.cluster.cold_start_ns)
            };
        }
        // reclaim expired sandboxes first so they can snapshot out
        let expired = self.nodes[ni].lifecycle_advance(t_ns);
        self.demote(ni, expired, t_ns);
        if self.nodes[ni].lifecycle_lookup(function, t_ns) {
            return (StartKind::Warm, 0);
        }
        let node_id = self.nodes[ni].id;
        let contention = self.pool.factor(node_id);
        let restorable = self
            .snapshots
            .as_ref()
            .is_some_and(|st| st.has(function) && self.snapshot_shapes.contains_key(function));
        if restorable {
            let st = self.snapshots.as_mut().expect("checked above");
            if let Some((latency_ns, bytes)) = st.restore(
                function,
                t_ns,
                node_id,
                &mut self.pool,
                self.cfg.cluster.cxl_link_bw_gbps,
                contention,
            ) {
                let shape = self.snapshot_shapes.get(function).expect("checked above").clone();
                self.nodes[ni].seed_shape(function, &shape);
                if self.telemetry.is_enabled() {
                    let ev = TelemetryEvent::new(EventKind::SnapshotRestore, t_ns)
                        .on_node(node_id as u64)
                        .func(function)
                        .arg("latency_ns", latency_ns)
                        .arg("bytes", bytes);
                    self.telemetry.push(ev);
                }
                return (StartKind::Restored, latency_ns);
            }
        }
        (StartKind::Cold, self.cfg.cluster.cold_start_ns)
    }

    /// Phase A — admit one arrival: route it, classify its sandbox
    /// outcome, lease pool capacity, and prepare its service shape (the
    /// only dispatch step that may run a real engine measurement, so it
    /// stays on this sequential path). Returns `None` only when no live
    /// node exists.
    fn admit(&mut self, a: arrivals::Arrival) -> Option<PreparedArrival> {
        let t = a.t_ns;
        let spec = self.specs[a.function].clone();
        self.pool.advance(t);
        self.pool.sample();
        let lifecycle = self.cfg.lifecycle.enabled;
        let bonus =
            (self.cfg.cluster.hint_affinity * self.mean_service_ns()).round().max(0.0) as u64;
        // sandbox-locality penalty: a node without a live sandbox pays a
        // full cold start — unless a snapshot makes a cheap restore
        // available to everyone (the snapshot-locality signal).
        let startup_penalty = if lifecycle {
            self.snapshots
                .as_ref()
                .and_then(|st| {
                    st.restore_estimate_ns(&spec.name, self.cfg.cluster.cxl_link_bw_gbps)
                })
                .unwrap_or(self.cfg.cluster.cold_start_ns)
        } else {
            0
        };
        let views: Vec<NodeView> = self
            .nodes
            .iter()
            .map(|n| NodeView {
                backlog_ns: n.backlog_ns(t),
                warm: n.knows(&spec.name),
                sandbox_warm: lifecycle && n.sandbox_warm_for(&spec.name, t),
                draining: n.draining || n.retired(),
                down: n.down,
            })
            .collect();
        let ni = match self.balancer.pick(&views, bonus, startup_penalty) {
            Some(i) => i,
            // defensive: everything draining (should not happen — the
            // autoscaler keeps min_nodes active); use any live node.
            // `None` here with every node down means the arrival is
            // dropped — the fleet is fully dark
            None => self.nodes.iter().position(|n| !n.retired() && !n.down)?,
        };
        let node_id = self.nodes[ni].id;
        let (kind, startup_ns) = self.classify(ni, &spec.name, t);
        let spill = self.nodes[ni].spill_estimate(&spec);
        let (grant_ns, granted) = self.pool.acquire(t, spill);
        let factor = self.pool.factor(node_id);
        let prep = self.nodes[ni].prepare(&spec);
        Some(PreparedArrival {
            t_ns: t,
            function: a.function,
            spec,
            ni,
            node_id,
            kind,
            startup_ns,
            spill,
            grant_ns,
            granted,
            factor,
            prep,
        })
    }

    /// Phase B — dispatch every prepared arrival on its node, the nodes
    /// sharded across up to `[sim] shards` worker threads in contiguous
    /// index chunks. `Node::dispatch_prepared` touches only node-local
    /// state and each node's arrivals run in batch order on exactly one
    /// worker, so the result is independent of the shard count; worker
    /// telemetry is buffered per node and spliced in node order at the
    /// barrier. Returns dispatches aligned with `batch` order.
    fn dispatch_batch(&mut self, batch: &[PreparedArrival]) -> Vec<Dispatch> {
        let n = self.nodes.len();
        let workers = self.cfg.sim.shards.max(1).min(n.max(1));
        self.shard_workers = self.shard_workers.max(workers);
        let tele = WorkerTelemetry {
            enabled: self.telemetry.is_enabled(),
            spans: self.cfg.telemetry.spans,
            policy: self.cfg.migration.policy.clone(),
        };
        // contiguous node chunks: chunk w covers [starts[w], starts[w+1])
        let mut starts = Vec::with_capacity(workers + 1);
        starts.push(0usize);
        for w in 0..workers {
            starts.push(starts[w] + n / workers + usize::from(w < n % workers));
        }
        let mut owner = vec![0usize; n];
        for w in 0..workers {
            for o in &mut owner[starts[w]..starts[w + 1]] {
                *o = w;
            }
        }
        // per-worker item lists, preserving batch order within a worker
        let mut items: Vec<Vec<usize>> = vec![Vec::new(); workers];
        for (bi, p) in batch.iter().enumerate() {
            items[owner[p.ni]].push(bi);
        }
        // a worker dispatches its items in batch order against its node
        // chunk (`lo` = first node index in the chunk)
        let worker = |nodes: &mut [Node], lo: usize, idxs: &[usize]| {
            let mut out = Vec::with_capacity(idxs.len());
            let mut bufs: Vec<Vec<TelemetryEvent>> = vec![Vec::new(); nodes.len()];
            for &bi in idxs {
                let p = &batch[bi];
                let d = nodes[p.ni - lo].dispatch_prepared(
                    p.t_ns,
                    p.grant_ns.max(p.t_ns),
                    &p.prep,
                    p.factor,
                    p.startup_ns,
                    p.kind,
                );
                if tele.enabled {
                    tele.record(&mut bufs[p.ni - lo], p, &d);
                }
                out.push((bi, d));
            }
            (out, bufs)
        };
        let mut results = Vec::with_capacity(workers);
        if workers <= 1 {
            // single shard: same closure, run in-line — K = 1 is the
            // identical code path, not a special case
            results.push(worker(&mut self.nodes, 0, &items[0]));
        } else {
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(workers);
                let mut rest: &mut [Node] = &mut self.nodes;
                let (worker, items) = (&worker, &items);
                for w in 0..workers {
                    let (chunk, tail) = rest.split_at_mut(starts[w + 1] - starts[w]);
                    rest = tail;
                    let lo = starts[w];
                    handles.push(s.spawn(move || worker(chunk, lo, &items[w])));
                }
                for h in handles {
                    results.push(h.join().expect("shard worker panicked"));
                }
            });
        }
        // barrier merge: dispatches back into batch order; telemetry
        // buffers spliced in node-index order (workers hold contiguous
        // ascending chunks, so worker order × chunk order = node order)
        let mut out: Vec<Option<Dispatch>> = Vec::new();
        out.resize_with(batch.len(), || None);
        for (dispatches, bufs) in results {
            for (bi, d) in dispatches {
                out[bi] = Some(d);
            }
            for buf in bufs {
                self.telemetry.append(buf);
            }
        }
        out.into_iter().map(|d| d.expect("every prepared arrival dispatches")).collect()
    }

    /// Phase C — merge one dispatched arrival back into shared state, in
    /// batch order: pool releases and link traffic, fleet counters and
    /// histograms, the determinism token, cluster-side telemetry, and
    /// the lifecycle keep/demote tail.
    fn settle(&mut self, p: &PreparedArrival, d: &Dispatch) {
        let t = p.t_ns;
        let spec = &p.spec;
        let (ni, node_id, kind) = (p.ni, p.node_id, p.kind);
        let lifecycle = self.cfg.lifecycle.enabled;
        self.pool.release_at(d.finish_ns, p.granted);
        // demand traffic AND migration copies share the node's CXL link:
        // an aggressive policy's page churn inflates neighbours' stalls
        // (snapshot/restore transfers were debited by the store already)
        self.pool.record_traffic(node_id, d.start_ns, d.cxl_bytes + d.migration_bytes);
        self.promotions += d.promotions;
        self.demotions += d.demotions;
        self.ping_pongs += d.ping_pongs;
        self.migration_bytes += d.migration_bytes;
        // f64 sum in settle (arrival) order — identical for every shard
        // count, so the report stays bit-equal across --shards
        self.overlapped_ns += d.overlapped_ns;
        self.lane_switches += d.lane_switches;
        self.prefetch_issued += d.prefetch_issued;
        self.prefetch_useful += d.prefetch_useful;

        let e2e_ns = d.finish_ns - t;
        self.fleet_hist.record(e2e_ns);
        self.node_hists[ni].record(e2e_ns);
        if self.faults.is_some() {
            if self.down_now > 0 || !self.degraded_links.is_empty() {
                self.degraded_hist.record(e2e_ns);
            }
            // remember the completion so a later NodeDown can void it
            while self.inflight.len() <= ni {
                self.inflight.push(BinaryHeap::new());
            }
            self.inflight[ni].push(Reverse((d.finish_ns, p.function)));
        }
        match kind {
            StartKind::Warm => self.warm_hist.record(e2e_ns),
            StartKind::Restored => self.restore_hist.record(e2e_ns),
            StartKind::Cold => self.cold_hist.record(e2e_ns),
        }
        self.slo.record_latency(&spec.name, e2e_ns as f64, d.slo_target_ns);
        if let Some(target) = d.slo_target_ns {
            self.window_judged += 1;
            if e2e_ns as f64 > target {
                self.window_violations += 1;
            }
        }
        self.wait_sum_ns += d.wait_ns as f64;
        self.service_sum_ns += d.service_ns as f64;
        self.completed += 1;
        self.end_ns = self.end_ns.max(d.finish_ns);
        self.token = mix(self.token, p.function as u64);
        self.token = mix(self.token, node_id as u64);
        self.token = mix(self.token, d.start_ns);
        self.token = mix(self.token, d.finish_ns);

        // telemetry reads only the values computed above — after the
        // token was mixed — so recording cannot perturb the run. The
        // dispatch-side events (invocation span, startup, migration)
        // were buffered by the phase-B worker and spliced at the epoch
        // barrier; only the cluster-side events are recorded here.
        if self.telemetry.is_enabled() {
            let nid = node_id as u64;
            self.telemetry.push(
                TelemetryEvent::new(EventKind::Queued, t)
                    .on_node(nid)
                    .func(&spec.name)
                    .arg("wait_ns", d.wait_ns),
            );
            if p.grant_ns > t || p.granted < p.spill {
                self.telemetry.push(
                    TelemetryEvent::new(EventKind::PoolContention, t)
                        .on_node(nid)
                        .func(&spec.name)
                        .arg("wait_ns", p.grant_ns - t)
                        .arg("short_bytes", p.spill.saturating_sub(p.granted)),
                );
            }
            let reallocs: u64 = self.nodes.iter().map(|n| n.provision_counts().1).sum();
            if reallocs > self.last_reallocs {
                let saved: u64 = self.nodes.iter().map(|n| n.provision_counts().2).sum();
                self.telemetry.push(
                    TelemetryEvent::new(EventKind::Provision, d.finish_ns)
                        .arg("reallocs", reallocs - self.last_reallocs)
                        .arg("dram_saved_bytes", saved),
                );
                self.last_reallocs = reallocs;
            }
            self.sampler.record_latency(&spec.name, e2e_ns);
            let s = self.fleet_sample(t);
            self.sampler.observe(t, &s);
        }

        if lifecycle {
            match kind {
                StartKind::Warm => self.nodes[ni].lifecycle_touch(&spec.name, d.finish_ns),
                _ => {
                    let evicted = self.nodes[ni].lifecycle_keep(&spec.name, d.finish_ns);
                    self.demote(ni, evicted, d.finish_ns);
                }
            }
            // eager checkpoint: the first kept sandbox of a function is
            // snapshotted fleet-wide (TrEnv-style capture-once), so peer
            // nodes restore instead of cold-starting from scratch
            if !self.snapshot_skip.contains(&spec.name)
                && self.snapshots.as_ref().is_some_and(|st| !st.has(&spec.name))
            {
                let candidate = self.nodes[ni].shape_of(&spec.name).map(|shape| {
                    let mut sb = Sandbox::new(&spec.name, shape.image.clone(), d.finish_ns);
                    sb.uses = self.nodes[ni].sandbox_uses(&spec.name);
                    (sb, shape.clone())
                });
                if let Some((sb, shape)) = candidate {
                    let st = self.snapshots.as_mut().expect("checked above");
                    match st.admit(&sb, d.finish_ns, node_id, &mut self.pool) {
                        AdmitOutcome::Admitted => {
                            self.note_snapshot_write(node_id, &spec.name, sb.bytes(), d.finish_ns);
                            self.snapshot_shapes.entry(spec.name.clone()).or_insert(shape);
                        }
                        AdmitOutcome::TooBig => {
                            self.snapshot_skip.insert(spec.name.clone());
                        }
                        _ => {}
                    }
                }
            }
        }
    }

    /// One autoscaler evaluation at virtual time `t`.
    fn autoscale_tick(&mut self, t: u64) {
        // retire drained nodes whose queues have emptied
        for n in &mut self.nodes {
            if n.draining && !n.retired() && n.backlog_ns(t) == 0 {
                n.retire(t);
            }
        }
        let active: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| {
                let n = &self.nodes[i];
                !n.draining && !n.retired() && !n.down
            })
            .collect();
        let sig = FleetSignal {
            t_ns: t,
            active_nodes: active.len(),
            total_workers: active.iter().map(|&i| self.nodes[i].workers()).sum(),
            backlog_ns: active.iter().map(|&i| self.nodes[i].backlog_ns(t)).sum(),
            interval_ns: self.cfg.cluster.autoscale_interval_ns,
            window_judged: self.window_judged,
            window_violations: self.window_violations,
            down_nodes: self.down_now,
        };
        self.window_judged = 0;
        self.window_violations = 0;
        let decision = match &mut self.autoscaler {
            Some(a) => a.decide(&sig),
            None => None,
        };
        if let Some((direction, reason)) = decision {
            let nodes_after = match direction {
                ScaleDirection::Up => {
                    let id = self.next_node_id;
                    self.next_node_id += 1;
                    self.pool.ensure_nodes(id + 1);
                    self.nodes.push(Node::spawn(id, &self.cfg, t));
                    self.node_hists.push(Histogram::default());
                    sig.active_nodes + 1
                }
                ScaleDirection::Down => {
                    // drain the youngest active node
                    if let Some(&i) = active.last() {
                        self.nodes[i].draining = true;
                    }
                    sig.active_nodes - 1
                }
            };
            if self.telemetry.is_enabled() {
                let ev = TelemetryEvent::new(EventKind::Autoscale, t)
                    .tag(direction.name())
                    .arg("nodes_after", nodes_after as u64);
                self.telemetry.push(ev);
            }
            self.events.push(ScaleEvent { t_ns: t, direction, nodes_after, reason });
        }
    }

    /// Apply every fault due at or before `t` — called on the
    /// sequential phase-A path (and drained once more after the last
    /// arrival), so transitions, token mixes, and retries happen at the
    /// same virtual instant for every `--shards` setting. Retried
    /// arrivals are admitted immediately and join the current epoch's
    /// batch.
    fn apply_due_faults(&mut self, t: u64, batch: &mut Vec<PreparedArrival>) {
        while let Some(ev) = self.faults.as_mut().and_then(|s| s.pop_due(t)) {
            self.apply_fault(ev, batch);
        }
    }

    /// One fault transition. No-op transitions (downing a node that is
    /// already down or retired, rejoining a healthy node, restoring an
    /// underated link, out-of-range node ids) return before any state
    /// or token change, so sloppy specs stay deterministic instead of
    /// corrupting the counters.
    fn apply_fault(&mut self, ev: FaultEvent, batch: &mut Vec<PreparedArrival>) {
        let mut orphaned = 0u64;
        let mut failed = 0u64;
        match ev.action {
            FaultAction::NodeDown => {
                let Some(n) = self.nodes.get_mut(ev.node) else { return };
                if n.down || n.retired() {
                    return;
                }
                n.down = true;
                self.down_now += 1;
                self.fault_downs += 1;
                // orphan the dead node's snapshot donations: their pool
                // leases are released and later arrivals fall back to a
                // cold start instead of restoring from lost memory
                if let Some(st) = self.snapshots.as_mut() {
                    orphaned = st.evict_donor(ev.node, ev.t_ns, &mut self.pool);
                }
                // void the work that was still running there (ascending
                // finish order out of the heap keeps retries ordered)
                let mut lost: Vec<usize> = Vec::new();
                if let Some(heap) = self.inflight.get_mut(ev.node) {
                    while let Some(Reverse((finish_ns, function))) = heap.pop() {
                        if finish_ns > ev.t_ns {
                            lost.push(function);
                        }
                    }
                }
                failed = lost.len() as u64;
                self.fault_failed += failed;
                // retry on the survivors, if any node is still up
                if self.nodes.iter().any(|n| !n.retired() && !n.down) {
                    for function in lost {
                        let retry = arrivals::Arrival { t_ns: ev.t_ns, function };
                        if let Some(p) = self.admit(retry) {
                            batch.push(p);
                            self.fault_retried += 1;
                        }
                    }
                }
            }
            FaultAction::NodeUp => {
                let Some(n) = self.nodes.get_mut(ev.node) else { return };
                if !n.down {
                    return;
                }
                n.down = false;
                self.down_now -= 1;
                self.fault_rejoins += 1;
            }
            FaultAction::LinkDegrade { derate } => {
                if ev.node >= self.nodes.len() {
                    return;
                }
                self.pool.set_link_derate(ev.node, derate);
                if self.degraded_links.insert(ev.node) {
                    self.fault_degrades += 1;
                }
            }
            FaultAction::LinkRestore => {
                if !self.degraded_links.remove(&ev.node) {
                    return;
                }
                self.pool.set_link_derate(ev.node, 1.0);
            }
        }
        self.token = mix(self.token, ev.t_ns);
        self.token = mix(self.token, ev.node as u64);
        self.token = mix(self.token, ev.action.code());
        if self.telemetry.is_enabled() {
            let mut tev = TelemetryEvent::new(EventKind::Fault, ev.t_ns)
                .on_node(ev.node as u64)
                .tag(ev.action.name());
            if let FaultAction::LinkDegrade { derate } = ev.action {
                tev = tev.arg("derate_pct", (derate * 100.0).round() as u64);
            }
            if failed > 0 || orphaned > 0 {
                tev = tev.arg("failed", failed).arg("orphaned", orphaned);
            }
            self.telemetry.push(tev);
        }
    }

    /// Snapshot of fleet-wide state for the per-epoch sampler. Pure
    /// read: sums node counters and pool gauges at virtual time `t_ns`.
    fn fleet_sample(&self, t_ns: u64) -> FleetSample {
        let mut worst = 1.0f64;
        for n in &self.nodes {
            worst = worst.max(self.pool.factor(n.id));
        }
        FleetSample {
            dram_used_bytes: self.nodes.iter().map(|n| n.peak_dram_bytes).sum(),
            dram_capacity_bytes: self.nodes.iter().map(|n| n.dram_bytes_total()).sum(),
            pool_occupancy: self.pool.occupancy(),
            // M/M/1 inflation factor f ≥ 1 mapped to utilization 1 − 1/f
            link_utilization: 1.0 - 1.0 / worst,
            queue_depth_ns: self
                .nodes
                .iter()
                .filter(|n| !n.retired())
                .map(|n| n.backlog_ns(t_ns))
                .sum(),
            warm_pool_bytes: self.nodes.iter().map(|n| n.warm_pool_used_bytes()).sum(),
            active_nodes: self
                .nodes
                .iter()
                .filter(|n| !n.draining && !n.retired() && !n.down)
                .count() as u64,
            completed: self.completed,
            promotions: self.promotions,
            demotions: self.demotions,
            ping_pongs: self.ping_pongs,
            migration_bytes: self.migration_bytes,
            cold_starts: self.nodes.iter().map(|n| n.cold_starts).sum(),
            restores: self.nodes.iter().map(|n| n.restores).sum(),
        }
    }

    /// Hand the collected telemetry out (sink + series), leaving the
    /// cluster with disabled no-op instances.
    pub fn take_telemetry(&mut self) -> TelemetryReport {
        let sink = std::mem::replace(&mut self.telemetry, TelemetrySink::disabled());
        let sampler = std::mem::replace(&mut self.sampler, FleetSampler::disabled());
        TelemetryReport { sink, series: sampler.into_series() }
    }

    /// Run the whole schedule and produce the fleet report.
    ///
    /// The loop is epoch-batched: arrivals are grouped into windows of
    /// `[sim] batch_ns` virtual time (the schedule is time-sorted, and
    /// index order is the stable tiebreak within a window), admitted
    /// sequentially (phase A, with the autoscaler interleave intact),
    /// dispatched node-locally by up to `[sim] shards` workers (phase
    /// B), and merged back in arrival order (phase C). Every cross-node
    /// effect lives in a sequential phase that is identical for every
    /// shard count, so any `--shards K` produces a bit-identical report
    /// and determinism token (see `sharded_runs_are_bit_identical`).
    pub fn run(&mut self, spec: &ArrivalSpec) -> ClusterReport {
        // Host stopwatch, NOT simulation time: feeds only the
        // `events_per_sec` throughput metric, which ShardStats'
        // always-true PartialEq excludes from report equality.
        let started = crate::util::hosttime::HostTimer::start();
        let interval = self.cfg.cluster.autoscale_interval_ns;
        let batch_ns = self.cfg.sim.batch_ns.max(1);
        let mut next_check = interval;
        let arrivals = &spec.arrivals;
        let mut batch: Vec<PreparedArrival> = Vec::new();
        let mut i = 0;
        while i < arrivals.len() {
            let epoch = arrivals[i].t_ns / batch_ns;
            let mut end = i + 1;
            while end < arrivals.len() && arrivals[end].t_ns / batch_ns == epoch {
                end += 1;
            }
            // phase A — sequential admission
            batch.clear();
            for a in &arrivals[i..end] {
                if self.autoscaler.is_some() {
                    while next_check <= a.t_ns {
                        self.autoscale_tick(next_check);
                        next_check += interval;
                    }
                }
                if self.faults.is_some() {
                    self.apply_due_faults(a.t_ns, &mut batch);
                }
                assert!(
                    a.function < self.specs.len(),
                    "arrival references function {} outside the population",
                    a.function
                );
                if let Some(p) = self.admit(*a) {
                    batch.push(p);
                }
            }
            // phase B — sharded node-local dispatch
            let dispatched = self.dispatch_batch(&batch);
            // phase C — deterministic merge in arrival order
            for (p, d) in batch.iter().zip(&dispatched) {
                self.settle(p, d);
            }
            self.merges += 1;
            self.sim_events += batch.len() as u64;
            if self.faults.is_some() && (self.down_now > 0 || !self.degraded_links.is_empty()) {
                self.degraded_epochs += 1;
            }
            i = end;
        }
        // drain faults scheduled after the last arrival so the report's
        // counters cover the whole schedule (downs pair with rejoins);
        // retries from a tail NodeDown run through one final epoch
        if self.faults.is_some() {
            batch.clear();
            self.apply_due_faults(u64::MAX, &mut batch);
            if !batch.is_empty() {
                let dispatched = self.dispatch_batch(&batch);
                for (p, d) in batch.iter().zip(&dispatched) {
                    self.settle(p, d);
                }
                self.merges += 1;
                self.sim_events += batch.len() as u64;
            }
        }
        self.finish(started.elapsed_secs())
    }

    fn finish(&mut self, elapsed_s: f64) -> ClusterReport {
        let end = self.end_ns.max(1);
        // final forced sample before the nodes retire, so short runs
        // still get at least one point per series
        if self.sampler.is_enabled() {
            let s = self.fleet_sample(end);
            self.sampler.flush(end, &s);
        }
        for n in &mut self.nodes {
            n.retire(end);
        }
        let node_seconds: f64 = self.nodes.iter().map(|n| n.active_seconds(end)).sum();
        let dram_gib = self.cfg.cluster.dram_per_node as f64 / GIB as f64;
        let pool_gib = self.pool.capacity() as f64 / GIB as f64;
        let duration_s = end as f64 / 1e9;
        let cost_units = node_seconds * dram_gib * DRAM_COST_PER_GIB_S
            + duration_s * pool_gib * CXL_COST_PER_GIB_S;
        let nodes: Vec<NodeSummary> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| NodeSummary {
                id: n.id,
                invocations: n.invocations,
                cold_runs: n.cold_runs,
                warm_starts: n.warm_starts,
                restores: n.restores,
                cold_starts: n.cold_starts,
                p50_ns: self.node_hists[i].percentile(50.0),
                p99_ns: self.node_hists[i].percentile(99.0),
                active_s: n.active_seconds(end),
                peak_dram_bytes: n.peak_dram_bytes,
                retired: n.draining,
            })
            .collect();
        let judged: u64 = self.slo.functions().map(|(_, f)| f.judged).sum();
        let completed_f = self.completed as f64;
        let throughput_per_s = if duration_s > 0.0 { completed_f / duration_s } else { 0.0 };
        let mean_wait_ns = if self.completed == 0 { 0.0 } else { self.wait_sum_ns / completed_f };
        let mean_service_ns =
            if self.completed == 0 { 0.0 } else { self.service_sum_ns / completed_f };
        let mut warm_hits = 0u64;
        let mut warm_evictions = 0u64;
        let mut warm_rejected = 0u64;
        let mut warm_pool_peak_bytes = 0u64;
        for n in &self.nodes {
            if let Some(m) = n.warm_pool_metrics() {
                warm_hits += m.hits;
                warm_evictions += m.evictions_expired + m.evictions_pressure;
                warm_rejected += m.rejected_oversized;
                warm_pool_peak_bytes = warm_pool_peak_bytes.max(m.peak_used_bytes);
            }
        }
        let snap = self.snapshots.as_ref();
        ClusterReport {
            completed: self.completed,
            virtual_duration_s: duration_s,
            throughput_per_s,
            fleet_p50_ns: self.fleet_hist.percentile(50.0),
            fleet_p99_ns: self.fleet_hist.percentile(99.0),
            fleet_mean_ns: self.fleet_hist.mean(),
            mean_wait_ns,
            mean_service_ns,
            judged,
            violation_rate: self.slo.overall_violation_rate(),
            cold_runs: self.nodes.iter().map(|n| n.cold_runs).sum(),
            pool_mean_occupancy: self.pool.mean_occupancy(),
            pool_peak_occupancy: self.pool.peak_occupancy(),
            pool_shortages: self.pool.shortages,
            promotions: self.promotions,
            demotions: self.demotions,
            ping_pongs: self.ping_pongs,
            migration_bytes: self.migration_bytes,
            lanes_enabled: self.cfg.lanes.enabled,
            overlapped_ns: self.overlapped_ns,
            lane_switches: self.lane_switches,
            prefetch_issued: self.prefetch_issued,
            prefetch_useful: self.prefetch_useful,
            trace_records: self.nodes.iter().map(|n| n.trace_records).sum(),
            trace_replays: self.nodes.iter().map(|n| n.trace_replays).sum(),
            trace_bytes: self.nodes.iter().map(|n| n.trace_bytes).sum(),
            provision_enabled: self.cfg.provision.enabled,
            provision_curves: self.nodes.iter().map(|n| n.provision_counts().0).sum(),
            provision_reallocs: self.nodes.iter().map(|n| n.provision_counts().1).sum(),
            provision_dram_saved_bytes: self.nodes.iter().map(|n| n.provision_counts().2).sum(),
            lifecycle_enabled: self.cfg.lifecycle.enabled,
            cold_starts: self.nodes.iter().map(|n| n.cold_starts).sum(),
            warm_starts: self.nodes.iter().map(|n| n.warm_starts).sum(),
            restores: self.nodes.iter().map(|n| n.restores).sum(),
            cold_p50_ns: self.cold_hist.percentile(50.0),
            warm_p50_ns: self.warm_hist.percentile(50.0),
            restore_p50_ns: self.restore_hist.percentile(50.0),
            warm_hits,
            warm_evictions,
            warm_rejected,
            warm_pool_peak_bytes,
            snapshots_taken: snap.map(|s| s.metrics.snapshots_taken).unwrap_or(0),
            snapshot_bytes: snap.map(|s| s.metrics.snapshot_bytes).unwrap_or(0),
            restore_bytes: snap.map(|s| s.metrics.restore_bytes).unwrap_or(0),
            snapshot_leased_bytes: snap.map(|s| s.leased_bytes()).unwrap_or(0),
            snapshot_peak_leased_bytes: snap.map(|s| s.metrics.peak_leased_bytes).unwrap_or(0),
            snapshot_lease_denied: snap.map(|s| s.metrics.lease_denied).unwrap_or(0),
            snapshot_evicted: snap.map(|s| s.metrics.evicted).unwrap_or(0),
            faults_enabled: self.cfg.faults.enabled,
            fault_downs: self.fault_downs,
            fault_rejoins: self.fault_rejoins,
            fault_degrades: self.fault_degrades,
            fault_failed: self.fault_failed,
            fault_retried: self.fault_retried,
            degraded_epochs: self.degraded_epochs,
            availability: if self.fault_failed == 0 {
                1.0
            } else {
                1.0 - self.fault_failed as f64 / self.completed.max(1) as f64
            },
            degraded_p99_ns: self.degraded_hist.percentile(99.0),
            node_seconds,
            cost_units,
            nodes,
            events: std::mem::take(&mut self.events),
            shards: ShardStats {
                workers: self.shard_workers.max(1),
                merges: self.merges,
                events: self.sim_events,
                events_per_sec: if elapsed_s > 0.0 {
                    self.sim_events as f64 / elapsed_s
                } else {
                    0.0
                },
            },
            determinism_token: self.token,
        }
    }
}

/// Convenience entry point: schedule from the config, then simulate.
pub fn simulate(cfg: &Config) -> Result<ClusterReport, String> {
    simulate_full(cfg).map(|(report, _)| report)
}

/// Like [`simulate`], but also hands back the run's telemetry (an
/// empty/disabled report unless `[telemetry] enabled = true`).
pub fn simulate_full(cfg: &Config) -> Result<(ClusterReport, TelemetryReport), String> {
    let spec = arrivals_from_config(cfg)?;
    let mut cluster = Cluster::new(cfg, &spec.names)?;
    let report = cluster.run(&spec);
    let telemetry = cluster.take_telemetry();
    Ok((report, telemetry))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 2;
        cfg.cluster.max_nodes = 4;
        cfg.cluster.functions = 2;
        cfg.cluster.rate_per_s = 300.0;
        cfg.cluster.duration_s = 0.05;
        cfg.cluster.autoscale = false;
        cfg.cluster.seed = 7;
        cfg
    }

    fn lifecycle_cfg(warm_pool_bytes: u64, snapshot: bool) -> Config {
        let mut cfg = small_cfg();
        cfg.lifecycle.enabled = true;
        cfg.lifecycle.warm_pool_bytes = warm_pool_bytes;
        cfg.lifecycle.snapshot = snapshot;
        cfg
    }

    #[test]
    fn population_defaults_are_registry_names() {
        for name in default_population(14) {
            assert!(build(&name, Scale::Small).is_some(), "{name} missing from registry");
        }
        assert_eq!(default_population(0).len(), 1);
        assert_eq!(default_population(99).len(), 14);
    }

    #[test]
    fn simulate_completes_all_arrivals() {
        let cfg = small_cfg();
        let spec = arrivals_from_config(&cfg).unwrap();
        let r = simulate(&cfg).unwrap();
        assert_eq!(r.completed, spec.arrivals.len() as u64);
        assert!(r.fleet_p99_ns >= r.fleet_p50_ns);
        assert!(r.violation_rate >= 0.0 && r.violation_rate <= 1.0);
        assert!(r.cost_units > 0.0);
        assert!(r.node_seconds > 0.0);
        // every node profiled each function at most once
        for n in &r.nodes {
            assert!(n.cold_runs <= cfg.cluster.functions as u64);
        }
        // legacy model: the start split mirrors the hint split and no
        // snapshot machinery runs
        assert!(!r.lifecycle_enabled);
        assert_eq!(r.cold_starts + r.warm_starts, r.completed);
        assert_eq!(r.restores, 0);
        assert_eq!(r.snapshot_bytes, 0);
        assert!(!r.render().is_empty());
    }

    #[test]
    fn unknown_shape_and_function_rejected() {
        let mut cfg = small_cfg();
        cfg.cluster.arrivals = "sawtooth".into();
        assert!(arrivals_from_config(&cfg).is_err());
        let err = Cluster::new(&small_cfg(), &["not-a-workload".to_string()]).unwrap_err();
        assert!(err.contains("unknown registry workload"), "{err}");
    }

    #[test]
    fn oversized_population_rejected_not_clamped() {
        let mut cfg = small_cfg();
        cfg.cluster.functions = POPULATION_ORDER.len() + 1;
        let err = arrivals_from_config(&cfg).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn trace_ir_amortizes_engine_runs() {
        let r = simulate(&small_cfg()).unwrap();
        // every (function, placement-mode) shape needs a real engine
        // run, but only the fleet-wide first one per function executes
        // the workload — the rest replay the stored trace (cross-node
        // included), so replays must dominate records
        assert!(r.trace_replays > 0, "warm/cross-node engine runs must replay");
        assert!(
            r.trace_records <= small_cfg().cluster.functions as u64,
            "at most one canonical recording per function fleet-wide, got {}",
            r.trace_records
        );
        assert!(r.render().contains("trace IR"));
    }

    #[test]
    fn warm_pool_cuts_cold_starts_and_latency() {
        // the acceptance scenario: warm pool + snapshots versus the same
        // run with keep-alive disabled (zero budget)
        let disabled = simulate(&lifecycle_cfg(0, false)).unwrap();
        let enabled = simulate(&lifecycle_cfg(512 * 1024 * 1024, true)).unwrap();
        assert_eq!(disabled.completed, enabled.completed);
        assert_eq!(
            disabled.cold_starts, disabled.completed,
            "zero budget: every invocation cold-starts"
        );
        assert!(
            enabled.cold_starts < disabled.cold_starts,
            "warm pool must cut cold starts: {} vs {}",
            enabled.cold_starts,
            disabled.cold_starts
        );
        assert!(enabled.warm_starts > 0);
        assert!(
            enabled.fleet_p50_ns < disabled.fleet_p50_ns,
            "warm pool must cut p50: {} vs {}",
            enabled.fleet_p50_ns,
            disabled.fleet_p50_ns
        );
        // snapshots were taken and their leases are visible in the pool
        assert!(enabled.snapshots_taken > 0);
        assert!(enabled.snapshot_bytes > 0);
        assert!(enabled.snapshot_leased_bytes > 0);
        assert!(enabled.pool_peak_occupancy > 0.0);
        // start-kind accounting is exhaustive
        assert_eq!(
            enabled.cold_starts + enabled.warm_starts + enabled.restores,
            enabled.completed
        );
    }

    #[test]
    fn snapshots_enable_cross_node_restores() {
        // 2 nodes, zero keep-alive budget, snapshots on: after the first
        // node checkpoints a function, later arrivals restore instead of
        // cold-starting — even on the peer node.
        let r = simulate(&lifecycle_cfg(0, true)).unwrap();
        assert!(r.restores > 0, "snapshot-only mode must restore");
        assert!(r.restore_bytes > 0);
        assert!(
            r.restore_p50_ns < r.cold_p50_ns,
            "restore p50 {} must beat cold p50 {}",
            r.restore_p50_ns,
            r.cold_p50_ns
        );
        // profile runs stay bounded by node × function even though
        // sandbox cold starts are per-invocation
        for n in &r.nodes {
            assert!(n.cold_runs <= 2);
        }
    }

    #[test]
    fn provisioning_rollup_and_determinism() {
        let mut cfg = small_cfg();
        cfg.provision.enabled = true;
        let a = simulate(&cfg).unwrap();
        assert!(a.provision_enabled);
        assert!(a.provision_curves > 0, "tuners must build demand curves");
        assert!(a.provision_reallocs > 0, "allocator must run on the epoch cadence");
        assert!(a.render().contains("provisioning"));
        // provisioning decisions are part of the deterministic replay
        let b = simulate(&cfg).unwrap();
        assert_eq!(a.determinism_token, b.determinism_token);
        assert_eq!(a.provision_reallocs, b.provision_reallocs);
        assert_eq!(a.provision_dram_saved_bytes, b.provision_dram_saved_bytes);
    }

    #[test]
    fn provisioning_disabled_stays_bit_identical() {
        // the [provision] section is default-off; flipping unrelated
        // knobs in it must not change a run at all
        let base = simulate(&small_cfg()).unwrap();
        let mut cfg = small_cfg();
        cfg.provision.epoch_profiles = 1;
        cfg.provision.min_gain_frac = 0.5;
        let tweaked = simulate(&cfg).unwrap();
        assert_eq!(base.determinism_token, tweaked.determinism_token);
        assert_eq!(base.fleet_p50_ns, tweaked.fleet_p50_ns);
        assert_eq!(base.provision_curves, 0);
        assert_eq!(base.provision_reallocs, 0);
        assert!(!base.render().contains("provisioning"));
    }

    #[test]
    fn telemetry_disabled_stays_bit_identical() {
        // the [telemetry] section is default-off; flipping unrelated
        // knobs in it must not change a run at all
        let base = simulate(&small_cfg()).unwrap();
        let mut cfg = small_cfg();
        cfg.telemetry.buffer_bytes = 1 << 20;
        cfg.telemetry.epoch_ns = 1_000_000;
        cfg.telemetry.spans = false;
        let tweaked = simulate(&cfg).unwrap();
        assert_eq!(base.determinism_token, tweaked.determinism_token);
        assert_eq!(base.fleet_p50_ns, tweaked.fleet_p50_ns);
        assert_eq!(base.fleet_p99_ns, tweaked.fleet_p99_ns);
        assert_eq!(base.completed, tweaked.completed);
        // ...and *enabling* it must not change the run either: events
        // are recorded from already-computed values only
        let mut on = small_cfg();
        on.telemetry.enabled = true;
        let (instrumented, tele) = simulate_full(&on).unwrap();
        assert_eq!(base.determinism_token, instrumented.determinism_token);
        assert_eq!(base.fleet_p50_ns, instrumented.fleet_p50_ns);
        assert!(base.fleet_mean_ns == instrumented.fleet_mean_ns);
        assert_eq!(base.cold_starts, instrumented.cold_starts);
        assert!(tele.is_enabled());
        assert!(tele.sink.total_events() > 0);
        // the disabled run collected nothing
        let (_, off) = simulate_full(&small_cfg()).unwrap();
        assert!(!off.is_enabled());
        assert_eq!(off.sink.total_events(), 0);
        assert!(off.series.is_empty());
    }

    #[test]
    fn telemetry_collects_events_and_series() {
        let mut cfg = lifecycle_cfg(512 * 1024 * 1024, true);
        cfg.telemetry.enabled = true;
        cfg.telemetry.epoch_ns = 5_000_000;
        let (report, tele) = simulate_full(&cfg).unwrap();
        assert!(report.completed > 0);
        let kinds = tele.sink.kind_counts();
        assert!(kinds.len() >= 4, "expected >= 4 event kinds, got {kinds:?}");
        assert!(kinds.contains_key("queued"));
        assert!(kinds.contains_key("invocation"));
        assert!(kinds.contains_key("startup"));
        assert!(kinds.contains_key("snapshot_write"));
        assert!(tele.series.len() >= 5, "expected >= 5 series, got {}", tele.series.len());
        for name in ["pool_occupancy", "queue_depth_ns", "completions_per_epoch"] {
            let s = tele.series.get(name).unwrap_or_else(|| panic!("missing series {name}"));
            assert!(!s.t_ns.is_empty());
        }
        // completions-per-epoch deltas sum back to the cumulative total
        let comp = tele.series.get("completions_per_epoch").unwrap();
        let total: f64 = comp.values.iter().sum();
        assert_eq!(total as u64, report.completed);
        assert!(tele.counter_line().starts_with("TELEMETRY events="));
        // the combined export round-trips through the JSON parser
        let doc = tele.to_chrome_json(vec![]);
        let parsed = crate::util::json::Json::parse(&doc.to_string_compact()).unwrap();
        assert!(!parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn lifecycle_runs_are_deterministic() {
        let cfg = lifecycle_cfg(64 * 1024 * 1024, true);
        let a = simulate(&cfg).unwrap();
        let b = simulate(&cfg).unwrap();
        assert_eq!(a.determinism_token, b.determinism_token);
        assert_eq!(a.cold_starts, b.cold_starts);
        assert_eq!(a.restores, b.restores);
        assert_eq!(a.snapshot_bytes, b.snapshot_bytes);
    }

    #[test]
    fn sharded_runs_are_bit_identical() {
        // the tentpole invariant: any --shards K produces the same
        // report, field for field, and the same determinism token
        let base = simulate(&small_cfg()).unwrap();
        for k in [2, 3, 7] {
            let mut cfg = small_cfg();
            cfg.sim.shards = k;
            let r = simulate(&cfg).unwrap();
            assert_eq!(r.determinism_token, base.determinism_token, "shards={k} token");
            assert_eq!(r, base, "shards={k} report diverged");
        }
        // ... with the lifecycle + snapshot machinery on as well
        let lc_base = simulate(&lifecycle_cfg(64 * 1024 * 1024, true)).unwrap();
        for k in [2, 3, 7] {
            let mut cfg = lifecycle_cfg(64 * 1024 * 1024, true);
            cfg.sim.shards = k;
            let r = simulate(&cfg).unwrap();
            assert_eq!(r, lc_base, "lifecycle shards={k} report diverged");
        }
    }

    #[test]
    fn wide_batches_stay_shard_invariant() {
        // one epoch spanning the whole schedule is the worst case for
        // the phase split (maximum deferred merging) — reports must
        // still agree across shard counts and complete every arrival
        let spec = arrivals_from_config(&small_cfg()).unwrap();
        let mut one = small_cfg();
        one.sim.batch_ns = 1_000_000_000;
        let a = simulate(&one).unwrap();
        assert_eq!(a.completed, spec.arrivals.len() as u64);
        let mut five = one.clone();
        five.sim.shards = 5;
        let b = simulate(&five).unwrap();
        assert_eq!(a, b, "wide-batch run diverged across shard counts");
    }

    #[test]
    fn shard_stats_count_the_run_but_never_compare() {
        let r = simulate(&small_cfg()).unwrap();
        assert_eq!(r.shards.events, r.completed);
        assert!(r.shards.merges > 0);
        assert!(r.shards.merges <= r.shards.events);
        assert_eq!(r.shards.workers, 1, "default config runs in-line");
        // host-side stats are excluded from report equality on purpose
        let mut tweaked = r.clone();
        tweaked.shards.workers = 99;
        tweaked.shards.events_per_sec = -1.0;
        assert_eq!(r, tweaked);
    }

    #[test]
    fn telemetry_event_order_is_shard_invariant() {
        // per-node worker buffers spliced at the epoch barrier: the
        // sink's event order (and thus the Chrome-trace export) must be
        // a pure function of the run, not of the shard count
        let mut cfg = lifecycle_cfg(512 * 1024 * 1024, true);
        cfg.telemetry.enabled = true;
        cfg.telemetry.epoch_ns = 5_000_000;
        let (r1, t1) = simulate_full(&cfg).unwrap();
        let mut sharded = cfg.clone();
        sharded.sim.shards = 4;
        let (r4, t4) = simulate_full(&sharded).unwrap();
        assert_eq!(r1, r4);
        let order1: Vec<(u64, &str)> = t1.sink.events().map(|e| (e.t_ns, e.kind.name())).collect();
        let order4: Vec<(u64, &str)> = t4.sink.events().map(|e| (e.t_ns, e.kind.name())).collect();
        assert_eq!(order1, order4, "event order depends on shard count");
        assert_eq!(
            t1.to_chrome_json(vec![]).to_string_compact(),
            t4.to_chrome_json(vec![]).to_string_compact(),
            "Chrome-trace export depends on shard count"
        );
    }

    #[test]
    fn lanes_disabled_stays_bit_identical() {
        // the [lanes] section is default-off; flipping its knobs while
        // disabled must not change a run at all — report AND token
        let base = simulate(&small_cfg()).unwrap();
        let mut cfg = small_cfg();
        cfg.lanes.max_lanes = 8;
        cfg.lanes.prefetch_degree = 16;
        cfg.lanes.prefetch_distance = 7;
        let tweaked = simulate(&cfg).unwrap();
        assert_eq!(base.determinism_token, tweaked.determinism_token);
        assert_eq!(base, tweaked);
        assert!(!base.lanes_enabled);
        assert_eq!(base.overlapped_ns, 0.0);
        assert_eq!(base.lane_switches, 0);
        assert_eq!(base.prefetch_issued, 0);
        assert!(!base.render().contains("lane overlap"));
    }

    #[test]
    fn lanes_overlap_stalls_fleet_wide() {
        // kvstore + txn_bench both annotate lanes; with the scheduler on
        // the fleet must hide stall time, deterministically
        let mut cfg = small_cfg();
        cfg.cluster.functions = 2; // json + kvstore
        cfg.lanes.enabled = true;
        cfg.lanes.prefetch = true;
        let a = simulate(&cfg).unwrap();
        assert!(a.lanes_enabled);
        assert!(a.overlapped_ns > 0.0, "kvstore lanes must overlap stalls");
        assert!(a.lane_switches > 0);
        assert!(a.render().contains("lane overlap"));
        let b = simulate(&cfg).unwrap();
        assert_eq!(a.determinism_token, b.determinism_token);
        assert_eq!(a, b);
    }

    #[test]
    fn laned_runs_are_shard_invariant() {
        // acceptance bar: lanes + prefetch on, --shards 4 produces the
        // identical report and token as --shards 1
        let mut cfg = small_cfg();
        cfg.lanes.enabled = true;
        cfg.lanes.prefetch = true;
        let base = simulate(&cfg).unwrap();
        let mut sharded = cfg.clone();
        sharded.sim.shards = 4;
        let r = simulate(&sharded).unwrap();
        assert_eq!(r.determinism_token, base.determinism_token, "laned token diverged");
        assert_eq!(r, base, "laned report diverged across shard counts");
    }

    #[test]
    fn faults_disabled_stays_bit_identical() {
        // the [faults] section is default-off; tweaking its knobs (and
        // even setting a parseable spec) must not change a run at all
        let base = simulate(&small_cfg()).unwrap();
        let mut cfg = small_cfg();
        cfg.faults.seed = 99;
        cfg.faults.downs = 3;
        cfg.faults.degrades = 2;
        cfg.faults.derate = 0.25;
        cfg.faults.spec = "down@0.01:1,up@0.03:1".into();
        let tweaked = simulate(&cfg).unwrap();
        assert_eq!(base.determinism_token, tweaked.determinism_token);
        assert_eq!(base, tweaked);
        assert!(!base.faults_enabled);
        assert_eq!(base.fault_downs, 0);
        assert_eq!(base.fault_failed, 0);
        assert!(base.availability == 1.0);
        assert!(!base.render().contains("availability"));
    }

    #[test]
    fn node_loss_fails_inflight_and_retries_on_survivors() {
        // 50 ms cold starts guarantee work admitted before the outage
        // is still in flight when node 0 dies at 20 ms
        let mut cfg = small_cfg();
        cfg.cluster.rate_per_s = 2000.0;
        cfg.cluster.cold_start_ns = 50_000_000;
        cfg.faults.enabled = true;
        cfg.faults.spec = "down@0.02:0".into();
        let r = simulate(&cfg).unwrap();
        assert!(r.faults_enabled);
        assert_eq!(r.fault_downs, 1);
        assert_eq!(r.fault_rejoins, 0);
        assert!(r.fault_failed >= 1, "in-flight work on node 0 must fail");
        assert_eq!(r.fault_retried, r.fault_failed, "node 1 survives: every failure retries");
        assert!(r.availability < 1.0, "failed work must dent availability");
        let expect = 1.0 - r.fault_failed as f64 / r.completed as f64;
        assert!((r.availability - expect).abs() < 1e-12);
        assert!(r.degraded_epochs > 0, "epochs after the down must count as degraded");
        assert!(r.degraded_p99_ns > 0, "completions during the outage feed the hist");
        let rendered = r.render();
        assert!(rendered.contains("availability"));
        assert!(rendered.contains("faults"));
    }

    #[test]
    fn node_loss_orphans_snapshots_without_leaks_or_panics() {
        // lifecycle + snapshots on, then node 1 (first donor of the
        // second function's snapshot) dies mid-run: its donations are
        // orphaned — leases released, restores fall back to cold starts
        let mut cfg = lifecycle_cfg(0, true);
        cfg.cluster.rate_per_s = 1000.0;
        cfg.faults.enabled = true;
        cfg.faults.spec = "down@0.02:1".into();
        let r = simulate(&cfg).unwrap();
        assert_eq!(r.fault_downs, 1);
        assert!(r.snapshot_evicted >= 1, "dead donor's snapshots must evict");
        // start-kind accounting stays exhaustive across the fallback
        assert_eq!(r.cold_starts + r.warm_starts + r.restores, r.completed);
        assert!(r.availability > 0.0 && r.availability <= 1.0);
        // deterministic under faults: replaying reproduces the report
        let again = simulate(&cfg).unwrap();
        assert_eq!(r.determinism_token, again.determinism_token);
        assert_eq!(r, again);
    }

    #[test]
    fn fault_injection_is_shard_invariant() {
        // scripted node loss + link degrade, lifecycle on: every
        // --shards K must produce the identical report and token
        let mut cfg = lifecycle_cfg(64 * 1024 * 1024, true);
        cfg.cluster.rate_per_s = 1000.0;
        cfg.cluster.cold_start_ns = 10_000_000;
        cfg.faults.enabled = true;
        cfg.faults.spec = "degrade@0.012:0:0.5,down@0.02:1,up@0.035:1,restore@0.04:0".into();
        let base = simulate(&cfg).unwrap();
        assert_eq!(base.fault_downs, 1);
        assert_eq!(base.fault_rejoins, 1);
        assert_eq!(base.fault_degrades, 1);
        for k in [2, 4] {
            let mut sharded = cfg.clone();
            sharded.sim.shards = k;
            let r = simulate(&sharded).unwrap();
            assert_eq!(r.determinism_token, base.determinism_token, "shards={k} token");
            assert_eq!(r, base, "shards={k} faulted report diverged");
        }
        // the seeded generator rides the same sequential path, so it is
        // shard-invariant too
        let mut seeded = small_cfg();
        seeded.faults.enabled = true;
        let s1 = simulate(&seeded).unwrap();
        let mut wide = seeded.clone();
        wide.sim.shards = 4;
        assert_eq!(s1, simulate(&wide).unwrap(), "seeded faults diverged across shards");
    }
}
