//! Open-loop invocation generation.
//!
//! The single-machine examples drive Porter closed-loop (invoke → wait →
//! invoke), which can never overload anything. Fleet behaviour — queue
//! growth, SLO violations, autoscaling — only appears under *open-loop*
//! arrivals: invocations fire on a schedule regardless of completions.
//!
//! Three synthetic shapes (all PRNG-seeded and fully deterministic):
//!
//! * **Poisson** — homogeneous rate λ, exponential gaps;
//! * **Bursty** — ON/OFF modulated Poisson (mean rate preserved);
//! * **Diurnal** — sinusoidal rate over the horizon, sampled by
//!   thinning (one simulated "day" compressed into the run).
//!
//! Plus **replay** of a compact Azure-Functions-style trace: per
//! function, invocation counts per fixed time bin — the format the
//! public Azure traces use, scaled down so traces stay reviewable text.

use crate::util::prng::Rng;

/// One invocation request: fires at `t_ns` (virtual) for population
/// function `function` (index into [`ArrivalSpec::names`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    pub t_ns: u64,
    pub function: usize,
}

/// A full open-loop schedule: the function population plus the
/// time-sorted arrivals over it.
#[derive(Debug, Clone)]
pub struct ArrivalSpec {
    pub names: Vec<String>,
    pub arrivals: Vec<Arrival>,
}

/// Synthetic arrival shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    Poisson,
    Bursty,
    Diurnal,
}

impl Shape {
    pub fn parse(s: &str) -> Option<Shape> {
        match s {
            "poisson" => Some(Shape::Poisson),
            "bursty" => Some(Shape::Bursty),
            "diurnal" => Some(Shape::Diurnal),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Shape::Poisson => "poisson",
            Shape::Bursty => "bursty",
            Shape::Diurnal => "diurnal",
        }
    }
}

/// Generate a synthetic open-loop schedule. Functions are drawn
/// Zipf(θ)-skewed over `names` (rank 0 hottest), matching the skewed
/// function popularity of production serverless fleets.
pub fn synthetic(
    shape: Shape,
    names: &[String],
    rate_per_s: f64,
    duration_s: f64,
    zipf_theta: f64,
    seed: u64,
) -> ArrivalSpec {
    assert!(!names.is_empty());
    assert!(rate_per_s > 0.0 && duration_s > 0.0);
    let mut rng = Rng::new(seed ^ 0xA221_7A15);
    let horizon_ns = duration_s * 1e9;
    let rate_per_ns = rate_per_s / 1e9;
    let mut arrivals = Vec::new();
    match shape {
        Shape::Poisson => {
            let mut t = 0.0f64;
            loop {
                t += rng.exp(rate_per_ns);
                if t >= horizon_ns {
                    break;
                }
                arrivals.push(at(t, names.len(), zipf_theta, &mut rng));
            }
        }
        Shape::Bursty => {
            // ON/OFF modulation: equal mean dwell in a hot (1.8×) and a
            // quiet (0.2×) phase keeps the mean rate at λ.
            let dwell_mean_ns = (horizon_ns / 10.0).max(1.0);
            let mut t = 0.0f64;
            let mut hot = true;
            let mut phase_end = rng.exp(1.0 / dwell_mean_ns);
            loop {
                let factor = if hot { 1.8 } else { 0.2 };
                t += rng.exp(rate_per_ns * factor);
                if t >= horizon_ns {
                    break;
                }
                while t > phase_end {
                    hot = !hot;
                    phase_end += rng.exp(1.0 / dwell_mean_ns);
                }
                arrivals.push(at(t, names.len(), zipf_theta, &mut rng));
            }
        }
        Shape::Diurnal => {
            // rate(t) = λ·(1 + 0.8·sin(2πt/T)): one compressed "day";
            // sampled by thinning against the peak rate.
            let peak = rate_per_ns * 1.8;
            let mut t = 0.0f64;
            loop {
                t += rng.exp(peak);
                if t >= horizon_ns {
                    break;
                }
                let rate_t =
                    rate_per_ns * (1.0 + 0.8 * (std::f64::consts::TAU * t / horizon_ns).sin());
                if rng.f64() < rate_t / peak {
                    arrivals.push(at(t, names.len(), zipf_theta, &mut rng));
                }
            }
        }
    }
    ArrivalSpec { names: names.to_vec(), arrivals }
}

fn at(t_ns: f64, n_functions: usize, zipf_theta: f64, rng: &mut Rng) -> Arrival {
    Arrival {
        t_ns: t_ns as u64,
        function: rng.zipf(n_functions as u64, zipf_theta) as usize,
    }
}

/// A compact Azure-Functions-style trace: per-function invocation counts
/// over fixed time bins.
///
/// Text format (one header, then one line per function):
///
/// ```text
/// # porter-trace v1
/// bin_ms=100
/// json,12,0,7,3
/// kvstore,2,2,2,2
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AzureTrace {
    pub bin_ms: u64,
    /// (function name, invocations per bin); every row has equal length.
    pub rows: Vec<(String, Vec<u32>)>,
}

impl AzureTrace {
    pub fn parse(text: &str) -> Result<AzureTrace, String> {
        let mut bin_ms = None;
        let mut rows: Vec<(String, Vec<u32>)> = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(v) = line.strip_prefix("bin_ms=") {
                bin_ms =
                    Some(v.parse::<u64>().map_err(|_| format!("line {}: bad bin_ms", ln + 1))?);
                continue;
            }
            let mut parts = line.split(',');
            let name = parts.next().unwrap_or("").trim().to_string();
            if name.is_empty() {
                return Err(format!("line {}: missing function name", ln + 1));
            }
            let counts = parts
                .map(|c| c.trim().parse::<u32>().map_err(|_| format!("line {}: bad count", ln + 1)))
                .collect::<Result<Vec<_>, _>>()?;
            if counts.is_empty() {
                return Err(format!("line {}: no bins for {name}", ln + 1));
            }
            rows.push((name, counts));
        }
        let bin_ms = bin_ms.ok_or("trace missing bin_ms header")?;
        if bin_ms == 0 {
            return Err("bin_ms must be > 0".into());
        }
        if rows.is_empty() {
            return Err("trace has no function rows".into());
        }
        let bins = rows[0].1.len();
        if rows.iter().any(|(_, c)| c.len() != bins) {
            return Err("trace rows have unequal bin counts".into());
        }
        Ok(AzureTrace { bin_ms, rows })
    }

    pub fn render(&self) -> String {
        let mut out = String::from("# porter-trace v1\n");
        out.push_str(&format!("bin_ms={}\n", self.bin_ms));
        for (name, counts) in &self.rows {
            let cs: Vec<String> = counts.iter().map(|c| c.to_string()).collect();
            out.push_str(&format!("{name},{}\n", cs.join(",")));
        }
        out
    }

    /// Synthesize a trace with Zipf-popular functions and per-bin jitter
    /// (demo input for `porter cluster --arrivals replay`).
    pub fn synthesize(
        names: &[String],
        bins: usize,
        bin_ms: u64,
        mean_per_bin: f64,
        seed: u64,
    ) -> AzureTrace {
        let mut rng = Rng::new(seed ^ 0x7AACE);
        let rows = names
            .iter()
            .enumerate()
            .map(|(rank, name)| {
                // harmonic popularity falloff by rank
                let scale = mean_per_bin / (1.0 + rank as f64);
                let counts = (0..bins)
                    .map(|_| (scale * rng.f64_in(0.25, 1.75)).round() as u32)
                    .collect();
                (name.clone(), counts)
            })
            .collect();
        AzureTrace { bin_ms, rows }
    }

    /// Expand to a time-sorted open-loop schedule: each bin's count is
    /// spread uniformly (PRNG-seeded) within the bin.
    pub fn expand(&self, seed: u64) -> ArrivalSpec {
        let mut rng = Rng::new(seed ^ 0xE9A4D);
        let bin_ns = self.bin_ms * 1_000_000;
        let mut arrivals = Vec::new();
        for (fi, (_, counts)) in self.rows.iter().enumerate() {
            for (bi, &c) in counts.iter().enumerate() {
                for _ in 0..c {
                    let t = bi as u64 * bin_ns + rng.gen_range(bin_ns.max(1));
                    arrivals.push(Arrival { t_ns: t, function: fi });
                }
            }
        }
        arrivals.sort_by_key(|a| (a.t_ns, a.function));
        ArrivalSpec { names: self.rows.iter().map(|(n, _)| n.clone()).collect(), arrivals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("f{i}")).collect()
    }

    #[test]
    fn poisson_deterministic_and_sorted() {
        let a = synthetic(Shape::Poisson, &names(4), 1000.0, 0.5, 0.9, 7);
        let b = synthetic(Shape::Poisson, &names(4), 1000.0, 0.5, 0.9, 7);
        assert_eq!(a.arrivals, b.arrivals);
        assert!(a.arrivals.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        // ~λ·T arrivals
        let n = a.arrivals.len() as f64;
        assert!((n - 500.0).abs() < 120.0, "n={n}");
        assert!(a.arrivals.iter().all(|x| x.function < 4));
    }

    #[test]
    fn different_seeds_differ() {
        let a = synthetic(Shape::Poisson, &names(2), 500.0, 0.2, 0.0, 1);
        let b = synthetic(Shape::Poisson, &names(2), 500.0, 0.2, 0.0, 2);
        assert_ne!(a.arrivals, b.arrivals);
    }

    #[test]
    fn popularity_is_skewed() {
        let a = synthetic(Shape::Poisson, &names(8), 5000.0, 1.0, 0.99, 3);
        let mut counts = [0usize; 8];
        for x in &a.arrivals {
            counts[x.function] += 1;
        }
        assert!(counts[0] > counts[7] * 3, "{counts:?}");
    }

    #[test]
    fn bursty_and_diurnal_preserve_mean_rate_roughly() {
        for shape in [Shape::Bursty, Shape::Diurnal] {
            let a = synthetic(shape, &names(2), 2000.0, 0.5, 0.5, 11);
            let n = a.arrivals.len() as f64;
            // bursty's realized rate wanders with the ON/OFF phase draw;
            // only the order of magnitude is pinned here
            assert!((n - 1000.0).abs() < 600.0, "{}: n={n}", shape.name());
            assert!(a.arrivals.windows(2).all(|w| w[0].t_ns <= w[1].t_ns), "{}", shape.name());
        }
    }

    #[test]
    fn shape_parse_roundtrip() {
        for s in [Shape::Poisson, Shape::Bursty, Shape::Diurnal] {
            assert_eq!(Shape::parse(s.name()), Some(s));
        }
        assert_eq!(Shape::parse("nope"), None);
    }

    #[test]
    fn trace_parse_render_roundtrip() {
        let text = "# porter-trace v1\nbin_ms=100\njson,12,0,7,3\nkvstore,2,2,2,2\n";
        let t = AzureTrace::parse(text).unwrap();
        assert_eq!(t.bin_ms, 100);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(AzureTrace::parse(&t.render()).unwrap(), t);
    }

    #[test]
    fn trace_rejects_malformed() {
        assert!(AzureTrace::parse("json,1,2\n").is_err()); // no bin_ms
        assert!(AzureTrace::parse("bin_ms=100\n").is_err()); // no rows
        assert!(AzureTrace::parse("bin_ms=100\njson,1\nkv,1,2\n").is_err()); // ragged
        assert!(AzureTrace::parse("bin_ms=100\njson,x\n").is_err()); // bad count
    }

    #[test]
    fn trace_expand_matches_counts() {
        let t = AzureTrace::parse("bin_ms=10\na,3,0,2\nb,1,1,1\n").unwrap();
        let spec = t.expand(5);
        assert_eq!(spec.names, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(spec.arrivals.len(), 8);
        assert!(spec.arrivals.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        // every arrival lands inside its bin
        let n_a = spec.arrivals.iter().filter(|x| x.function == 0).count();
        assert_eq!(n_a, 5);
        assert!(spec.arrivals.iter().all(|x| x.t_ns < 30_000_000));
        // deterministic
        assert_eq!(spec.arrivals, t.expand(5).arrivals);
    }

    #[test]
    fn synthesize_expands() {
        let t = AzureTrace::synthesize(&names(3), 5, 50, 4.0, 9);
        assert_eq!(t.rows.len(), 3);
        let spec = t.expand(9);
        assert!(!spec.arrivals.is_empty());
        assert_eq!(AzureTrace::parse(&t.render()).unwrap(), t);
    }
}
