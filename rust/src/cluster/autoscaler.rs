//! Fleet autoscaling on queue-depth and SLO-violation signals.
//!
//! Evaluated at a fixed virtual cadence. Scale **up** when either the
//! queued work per engine worker exceeds a threshold (expressed in
//! evaluation intervals of backlog) or the windowed SLO violation rate
//! does; scale **down** when the fleet is near-idle and meeting SLOs.
//! A cooldown suppresses flapping; node counts stay within
//! `[min_nodes, max_nodes]`.

use crate::config::ClusterConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

impl ScaleDirection {
    pub fn name(self) -> &'static str {
        match self {
            ScaleDirection::Up => "scale-up",
            ScaleDirection::Down => "scale-down",
        }
    }
}

/// One applied scaling decision (for the report/event log).
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleEvent {
    pub t_ns: u64,
    pub direction: ScaleDirection,
    pub nodes_after: usize,
    pub reason: String,
}

/// Fleet snapshot handed to each evaluation.
#[derive(Debug, Clone, Copy)]
pub struct FleetSignal {
    pub t_ns: u64,
    /// Nodes accepting traffic (not draining, not retired).
    pub active_nodes: usize,
    /// Engine workers across active nodes.
    pub total_workers: usize,
    /// Σ queued-but-unfinished virtual work across active nodes.
    pub backlog_ns: u64,
    /// Evaluation interval (normalizes the backlog signal).
    pub interval_ns: u64,
    /// SLO outcomes since the previous evaluation.
    pub window_judged: u64,
    pub window_violations: u64,
    /// Nodes currently failed by fault injection (not draining — they
    /// are expected back). Scale-down is suppressed while nonzero: a
    /// rejoin restores this capacity for free, so draining a healthy
    /// node during an outage would double the loss.
    pub down_nodes: usize,
}

impl FleetSignal {
    /// Queued work per worker, in units of evaluation intervals.
    pub fn backlog_per_worker(&self) -> f64 {
        if self.total_workers == 0 {
            0.0
        } else {
            self.backlog_ns as f64
                / self.total_workers as f64
                / self.interval_ns.max(1) as f64
        }
    }

    pub fn violation_rate(&self) -> f64 {
        if self.window_judged == 0 {
            0.0
        } else {
            self.window_violations as f64 / self.window_judged as f64
        }
    }
}

/// The decision policy.
#[derive(Debug)]
pub struct Autoscaler {
    min_nodes: usize,
    max_nodes: usize,
    up_backlog: f64,
    up_violation: f64,
    down_idle: f64,
    cooldown_ns: u64,
    last_action_ns: Option<u64>,
}

impl Autoscaler {
    pub fn new(cfg: &ClusterConfig) -> Autoscaler {
        Autoscaler {
            min_nodes: cfg.min_nodes,
            max_nodes: cfg.max_nodes,
            up_backlog: cfg.scale_up_backlog,
            up_violation: cfg.scale_up_violation,
            down_idle: cfg.scale_down_idle,
            cooldown_ns: cfg.cooldown_ns,
            last_action_ns: None,
        }
    }

    /// Evaluate one window; `Some` means the cluster should add or
    /// drain one node.
    pub fn decide(&mut self, sig: &FleetSignal) -> Option<(ScaleDirection, String)> {
        if let Some(last) = self.last_action_ns {
            if sig.t_ns.saturating_sub(last) < self.cooldown_ns {
                return None;
            }
        }
        let bpw = sig.backlog_per_worker();
        let vr = sig.violation_rate();
        if sig.active_nodes < self.max_nodes && (bpw > self.up_backlog || vr > self.up_violation) {
            self.last_action_ns = Some(sig.t_ns);
            let reason = if bpw > self.up_backlog {
                format!("backlog {bpw:.2} intervals/worker > {:.2}", self.up_backlog)
            } else {
                format!("violation rate {:.0}% > {:.0}%", vr * 100.0, self.up_violation * 100.0)
            };
            return Some((ScaleDirection::Up, reason));
        }
        if sig.active_nodes > self.min_nodes
            && sig.down_nodes == 0
            && bpw < self.down_idle
            && vr <= self.up_violation / 2.0
        {
            self.last_action_ns = Some(sig.t_ns);
            return Some((
                ScaleDirection::Down,
                format!("idle: backlog {bpw:.3} intervals/worker < {:.3}", self.down_idle),
            ));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler() -> Autoscaler {
        let mut cfg = ClusterConfig::default();
        cfg.min_nodes = 1;
        cfg.max_nodes = 4;
        cfg.cooldown_ns = 100;
        Autoscaler::new(&cfg)
    }

    fn sig(t: u64, nodes: usize, backlog_ns: u64, judged: u64, viol: u64) -> FleetSignal {
        FleetSignal {
            t_ns: t,
            active_nodes: nodes,
            total_workers: nodes * 4,
            backlog_ns,
            interval_ns: 1000,
            window_judged: judged,
            window_violations: viol,
            down_nodes: 0,
        }
    }

    #[test]
    fn overload_scales_up_until_max() {
        let mut a = scaler();
        // backlog 10 intervals/worker on 1 node (4 workers × 1000 ns)
        let s = sig(0, 1, 40_000, 0, 0);
        assert_eq!(a.decide(&s).unwrap().0, ScaleDirection::Up);
        // cooldown suppresses the immediate next decision
        assert!(a.decide(&sig(50, 1, 40_000, 0, 0)).is_none());
        // at max_nodes no further scale-up
        assert!(a.decide(&sig(500, 4, 160_000, 0, 0)).is_none());
    }

    #[test]
    fn violations_scale_up_even_without_backlog() {
        let mut a = scaler();
        let (d, reason) = a.decide(&sig(0, 2, 0, 10, 6)).unwrap();
        assert_eq!(d, ScaleDirection::Up);
        assert!(reason.contains("violation"), "{reason}");
    }

    #[test]
    fn idle_scales_down_to_min() {
        let mut a = scaler();
        assert_eq!(a.decide(&sig(0, 3, 0, 10, 0)).unwrap().0, ScaleDirection::Down);
        assert!(a.decide(&sig(50, 2, 0, 10, 0)).is_none()); // cooldown
        assert_eq!(a.decide(&sig(200, 2, 0, 10, 0)).unwrap().0, ScaleDirection::Down);
        assert!(a.decide(&sig(400, 1, 0, 10, 0)).is_none()); // at min
    }

    #[test]
    fn steady_state_does_nothing() {
        let mut a = scaler();
        // modest backlog, no violations: between thresholds
        assert!(a.decide(&sig(0, 2, 4_000, 20, 1)).is_none());
    }

    #[test]
    fn outage_suppresses_scale_down_but_not_scale_up() {
        let mut a = scaler();
        // idle fleet, but one node is down: keep the survivors
        let mut s = sig(0, 3, 0, 10, 0);
        s.down_nodes = 1;
        assert!(a.decide(&s).is_none(), "must not drain during an outage");
        // overload during the same outage still scales up
        let mut hot = sig(200, 3, 480_000, 0, 0);
        hot.down_nodes = 1;
        assert_eq!(a.decide(&hot).unwrap().0, ScaleDirection::Up);
        // rejoin: with down_nodes back to 0, idle drains again
        assert_eq!(a.decide(&sig(400, 3, 0, 10, 0)).unwrap().0, ScaleDirection::Down);
    }
}
