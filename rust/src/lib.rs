//! # Porter — Serverless Workloads on CXL-Enabled Tiered Memory
//!
//! A full reproduction of *"Understanding and Optimizing Serverless
//! Workloads in CXL-Enabled Tiered Memory"* (Li & Yao, 2023) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the Porter middleware (gateway, balancer,
//!   per-server engines, offline tuner, runtime migration) on top of a
//!   complete tiered-memory simulation substrate (DRAM + CXL tiers, L3
//!   cache model, DAMON-style access monitor, allocation shim, serverless
//!   workload suite).
//! * **Layer 2 (python/compile/model.py)** — JAX models for the DL
//!   serverless functions, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas tiled-matmul kernel
//!   called by the L2 model, verified against a pure-jnp oracle.
//!
//! Python never runs on the request path: `runtime::` loads the HLO
//! artifacts via PJRT and executes them natively.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bench;
pub mod cli;
pub mod config;
pub mod mem;
pub mod metrics;
pub mod monitor;
pub mod placement;
pub mod porter;
pub mod runtime;
pub mod shim;
pub mod sim;
pub mod testing;
pub mod trace;
pub mod util;
pub mod workloads;
