//! # Porter — Serverless Workloads on CXL-Enabled Tiered Memory
//!
//! A full reproduction of *"Understanding and Optimizing Serverless
//! Workloads in CXL-Enabled Tiered Memory"* (Li & Yao, 2023) as a
//! three-layer Rust + JAX + Pallas system, grown toward fleet scale:
//!
//! * **Layer 3 (this crate)** — the Porter middleware (gateway, balancer,
//!   per-server engines, offline tuner, runtime migration) on top of a
//!   complete tiered-memory simulation substrate (DRAM + CXL tiers, L3
//!   cache model, DAMON-style access monitor, allocation shim, serverless
//!   workload suite).
//! * **Layer 2 (python/compile/model.py)** — JAX models for the DL
//!   serverless functions, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas tiled-matmul kernel
//!   called by the L2 model, verified against a pure-jnp oracle.
//!
//! Python never runs on the request path: `runtime::` executes the AOT
//! artifacts with a pure-Rust reference interpreter (the PJRT-backed
//! executor lives in git history; the offline image ships no crate
//! registry).
//!
//! ## The `cluster::` layer
//!
//! [`cluster`] scales the single-machine stack to a simulated fleet:
//! every node wraps real Porter servers plus its own tuner/hint cache
//! (hint locality), all nodes share one cluster-wide CXL pool (capacity
//! leases + bandwidth contention via [`mem::bwmodel`]), an open-loop
//! generator (Poisson / bursty / diurnal / Azure-style trace replay)
//! drives the fleet, a two-level balancer routes node-then-server, and
//! an autoscaler adds/drains nodes on queue-depth and SLO signals. The
//! whole run is a deterministic virtual-time simulation: try
//! `porter-cli cluster --nodes 8 --arrivals poisson`.
//!
//! ## The `lifecycle::` layer
//!
//! [`lifecycle`] makes sandbox lifetime explicit — per-node warm pools
//! with pluggable keep-alive policies (fixed TTL, LRU-under-pressure,
//! inter-arrival histogram) and a cluster-wide snapshot store that
//! demotes evicted sandboxes into the shared CXL pool, so any node can
//! restore a peer's snapshot instead of paying a full cold start +
//! profile run: try `porter-cli cluster --warm-pool-mb 512 --snapshot`.
//!
//! ## Determinism, machine-checked
//!
//! The headline claims are determinism claims — Trace-IR replay identity,
//! `--shards K` bit-identity, disabled-path bit-identity — so the repo
//! carries its own static-analysis pass: [`analysis`] (the `detlint`
//! binary, also `porter-cli detlint`) lints every decision path for
//! hash-map iteration, host-clock reads, cross-shard float accumulation,
//! unseeded randomness, and determinism-token hygiene. It runs as an
//! enforced CI gate; see `DESIGN.md` § "Static analysis".
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// The simulator is pure safe Rust (zero `unsafe` as of PR 10) — lock it
// in so the advisory miri CI job stays trivially green and any future
// unsafe block must argue its case by loosening this.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod lifecycle;
pub mod mem;
pub mod metrics;
pub mod monitor;
pub mod placement;
pub mod porter;
pub mod runtime;
pub mod shim;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod trace;
pub mod util;
pub mod workloads;
