//! porter-cli — the leader entrypoint.
//!
//! Subcommands:
//!   config  --show                       print the Table-1 machine spec
//!   run     <workload> [--tier dram|cxl] [--policy tpp|hybrid|naive|none]
//!           [--keep-warm] run one workload on one tier; with a migration
//!           policy (from the `[migration]` config section or --policy)
//!           the epoch engine promotes/demotes pages at runtime;
//!           [--lanes K] [--prefetch] enable the lane scheduler (+ stride
//!           prefetcher) so annotated workloads overlap CXL stalls with
//!           compute (greppable LANES counter line); with
//!           --keep-warm the shim's sandbox capture + warm-pool replay
//!           report what keep-alive amortizes; with the Trace-IR on
//!           (default) the run records its stream and verifies replay
//!           identity (TRACE counter line); [--telemetry-out F.json]
//!           exports machine-level phase/epoch events as a Chrome trace
//!   trace   record <workload> [--out F]  capture the canonical Trace-IR
//!           replay [<w>|--in F] [--tier]  drive a machine from the IR
//!           info   [<w>|--in F]           IR stats + per-phase summary
//!   profile <workload>                   DAMON heatmap + boundness
//!   place   <workload>                   §3 profile → static placement
//!   provision [--functions N] [--dram-mb M]  per-function DRAM
//!           provisioning what-if: build latency-vs-DRAM demand curves
//!           from Trace-IR replays, then partition a node's DRAM across
//!           the functions by greedy marginal-utility descent and
//!           compare against uniform provisioning at equal DRAM
//!           (greppable PROVISION counter line)
//!   serve   [--requests N]               Porter serving demo (DL path)
//!   cluster [--nodes N] [--arrivals S]   fleet simulation (open-loop)
//!           [--warm-pool-mb N] [--snapshot] [--keepalive ttl|lru|histogram]
//!           enable the lifecycle layer: per-node warm pools and
//!           CXL-resident snapshots in the shared pool;
//!           [--telemetry-out F.json] export a Chrome-trace/Perfetto
//!           event file (+ sibling F.csv time series);
//!           [--shards K] shard the nodes across K worker threads —
//!           bit-identical report/token for any K (greppable SHARDS
//!           counter line);
//!           [--faults seeded|<spec>|<file>] deterministic fault
//!           injection — node loss/rejoin and CXL-link derating with
//!           graceful degradation (greppable FAULTS counter line);
//!           [--lanes K] [--prefetch] lane-based latency hiding on every
//!           engine run (greppable LANES counter line)
//!   telemetry summarize <trace.json>     roll up an exported trace:
//!           per-kind event counts/durations, series stats
//!   detlint [--config detlint.toml]      run the determinism lints
//!           (D1-D5) over rust/src + rust/benches; greppable DETLINT
//!           counter line; exit 1 on violations (the CI gate)
//!   list                                 workload registry
//!
//! The figure benches live under `cargo bench` (see rust/benches/).

use porter::cli::Args;
use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::monitor::TopDown;
use porter::placement::static_place::profile_and_place;
use porter::util::table::Table;
use porter::workloads::registry::{build, Scale, NAMES};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("config") => cmd_config(&args),
        Some("list") => cmd_list(),
        Some("run") => cmd_run(&args),
        Some("trace") => cmd_trace(&args),
        Some("profile") => cmd_profile(&args),
        Some("place") => cmd_place(&args),
        Some("provision") => cmd_provision(&args),
        Some("serve") => cmd_serve(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("telemetry") => cmd_telemetry(&args),
        Some("detlint") => porter::analysis::cli_main(args.opt("config")),
        _ => {
            eprintln!(
                "usage: porter-cli \
                 <config|list|run|trace|profile|place|provision|serve|cluster|telemetry|detlint> \
                 [options]\n\
                 see `cargo bench` for the paper-figure harnesses"
            );
            2
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Config {
    match args.opt("config") {
        Some(path) => Config::from_toml_file(path).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }),
        None => Config::default(),
    }
}

fn scale_of(args: &Args) -> Scale {
    if args.flag("full") {
        Scale::Default
    } else {
        Scale::Small
    }
}

/// Resolve the telemetry output path: the `--telemetry-out` flag wins,
/// else a `[telemetry]` section with `out` set.
fn telemetry_out(args: &Args, cfg: &Config) -> Option<String> {
    if let Some(path) = args.opt("telemetry-out") {
        return Some(path.to_string());
    }
    if cfg.telemetry.enabled && !cfg.telemetry.out.is_empty() {
        return Some(cfg.telemetry.out.clone());
    }
    None
}

/// Write the combined Chrome-trace JSON plus the sibling `.csv` of the
/// time series next to it.
fn write_telemetry(
    tele: &porter::telemetry::TelemetryReport,
    path: &str,
    summary: Vec<(&str, porter::util::json::Json)>,
) -> Result<(), String> {
    let doc = tele.to_chrome_json(summary);
    std::fs::write(path, doc.to_string_compact()).map_err(|e| format!("write {path}: {e}"))?;
    let csv_path = format!("{}.csv", path.trim_end_matches(".json"));
    std::fs::write(&csv_path, tele.to_csv()).map_err(|e| format!("write {csv_path}: {e}"))?;
    println!("wrote {path} and {csv_path}");
    Ok(())
}

fn cmd_config(args: &Args) -> i32 {
    let cfg = load_config(args);
    println!("{}", cfg.machine.render_table());
    0
}

fn cmd_list() -> i32 {
    println!("registered workloads (SeBS/FunctionBench/vSwarm/GAPBS-derived):");
    for n in NAMES {
        println!("  {n}");
    }
    0
}

type WorkloadBox = Box<dyn porter::workloads::Workload + Send + Sync>;

fn workload_arg(args: &Args, scale: Scale) -> Option<WorkloadBox> {
    let name = args.positional.first()?;
    match build(name, scale) {
        Some(w) => Some(w),
        None => {
            eprintln!("unknown workload {name:?}; see `porter-cli list`");
            None
        }
    }
}

/// Build the `run`/`trace replay` machine: everything in `tier`, the
/// epoch migration engine attached when enabled. Deterministic — two
/// calls with the same config produce machines whose runs over the same
/// stream are bit-identical, which is what the replay verification in
/// [`cmd_run`] relies on.
fn build_run_machine(cfg: &Config, tier: TierKind) -> (porter::sim::Machine, Option<String>) {
    use porter::mem::migrate::MigrationEngine;
    use porter::sim::Machine;
    let mut machine = Machine::all_in(&cfg.machine, tier);
    let mig_cfg = cfg.migration.with_porter_fallbacks(&cfg.porter);
    let engine = MigrationEngine::from_config(&mig_cfg);
    let policy_name = engine.as_ref().map(|e| e.policy_name().to_string());
    if let Some(engine) = engine {
        machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
        machine.set_migrator(Box::new(engine));
    }
    (machine, policy_name)
}

fn tier_arg(args: &Args) -> Option<TierKind> {
    match args.opt_or("tier", "dram") {
        "dram" => Some(TierKind::Dram),
        "cxl" => Some(TierKind::Cxl),
        other => {
            eprintln!("unknown tier {other:?} (dram|cxl)");
            None
        }
    }
}

fn apply_policy_arg(cfg: &mut Config, args: &Args) -> Result<(), String> {
    if let Some(policy) = args.opt("policy") {
        cfg.migration.policy = policy.to_string();
        cfg.migration.enabled = policy != "none";
        cfg.validate()?;
    }
    Ok(())
}

/// `--lanes K` / `--prefetch`: turn the `[lanes]` section on from the
/// command line (either flag enables the scheduler).
fn apply_lanes_args(cfg: &mut Config, args: &Args) -> Result<(), String> {
    if let Some(n) = args.opt("lanes") {
        cfg.lanes.max_lanes =
            n.parse().map_err(|_| format!("--lanes expects an integer, got {n:?}"))?;
        cfg.lanes.enabled = true;
    }
    if args.flag("prefetch") {
        cfg.lanes.prefetch = true;
        cfg.lanes.enabled = true;
    }
    if cfg.lanes.enabled {
        cfg.validate()?;
    }
    Ok(())
}

/// Attach the lane scheduler (+ prefetcher) per `[lanes]`, capped by the
/// workload's annotated parallelism. Returns the effective lane count.
fn apply_lanes(cfg: &Config, machine: &mut porter::sim::Machine, hints: usize) -> usize {
    if !cfg.lanes.enabled {
        return 1;
    }
    let k = cfg.lanes.max_lanes.min(hints).max(1);
    machine.set_lanes(k);
    if cfg.lanes.prefetch {
        machine.set_prefetcher(cfg.lanes.prefetch_degree, cfg.lanes.prefetch_distance);
    }
    k
}

fn cmd_run(args: &Args) -> i32 {
    let mut cfg = load_config(args);
    let Some(w) = workload_arg(args, scale_of(args)) else { return 2 };
    let Some(tier) = tier_arg(args) else { return 2 };
    if let Err(e) = apply_policy_arg(&mut cfg, args).and_then(|()| apply_lanes_args(&mut cfg, args))
    {
        eprintln!("config error: {e}");
        return 2;
    }
    // the epoch engine only matters when it is enabled: pages start in
    // `tier` and migrate as heatmap samples accumulate. Legacy [porter]
    // knobs bridge in exactly as on the serving path, so `run` numbers
    // stay comparable to `serve`/`cluster` for the same config file.
    let (mut machine, policy_name) = build_run_machine(&cfg, tier);
    let eff_lanes = apply_lanes(&cfg, &mut machine, w.lane_hints());
    let tele_out = telemetry_out(args, &cfg);
    if tele_out.is_some() || cfg.telemetry.enabled {
        machine
            .set_telemetry(porter::telemetry::TelemetrySink::new(cfg.telemetry.buffer_bytes));
    }
    // with the Trace-IR on (the default), the measured run records the
    // canonical stream; a verification replay below proves replay
    // identity on this exact invocation
    let trace_on = cfg.trace.enabled && !cfg.trace.live_execution;
    let (checksum, objects, trace) = if trace_on {
        let mut env = porter::shim::Env::new_recording(cfg.machine.page_bytes, &mut machine);
        let checksum = w.run(&mut env);
        let objects: Vec<porter::shim::MemoryObject> =
            if args.flag("keep-warm") { env.objects().to_vec() } else { Vec::new() };
        let mut t = env.finish_recording().expect("recording env");
        t.workload = w.name().to_string();
        t.checksum = checksum;
        (checksum, objects, Some(t))
    } else {
        let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut machine);
        let checksum = w.run(&mut env);
        // the object log is only needed for the --keep-warm capture
        let objects: Vec<porter::shim::MemoryObject> =
            if args.flag("keep-warm") { env.objects().to_vec() } else { Vec::new() };
        drop(env);
        (checksum, objects, None)
    };
    let report = machine.report();
    let td = TopDown::from_report(&report);
    let mut t = Table::new(&["metric", "value"]).left_first();
    t.row(vec!["workload".into(), w.name().into()]);
    t.row(vec!["tier".into(), tier.name().into()]);
    t.row(vec![
        "migration policy".into(),
        policy_name.clone().unwrap_or_else(|| "off".to_string()),
    ]);
    t.row(vec!["virtual time".into(), porter::bench::fmt_ns(report.wall_ns)]);
    t.row(vec!["accesses".into(), report.accesses.to_string()]);
    t.row(vec!["l3 hit rate".into(), format!("{:.1}%", report.l3_hit_rate() * 100.0)]);
    t.row(vec!["memory-bound".into(), format!("{:.1}%", td.memory_bound_pct())]);
    t.row(vec![
        "page migration".into(),
        format!(
            "{}↑ {}↓ ({} ping-pongs, {})",
            report.promotions,
            report.demotions,
            report.ping_pongs,
            porter::util::bytes::fmt_bytes(report.migration_bytes)
        ),
    ]);
    t.row(vec!["checksum".into(), format!("{checksum:#018x}")]);
    println!("{}", t.render());
    // stable machine-readable counter line (CI smoke greps this — a
    // silently-zero metric must fail the build, not pass vacuously)
    println!(
        "COUNTERS policy={} promotions={} demotions={} ping_pongs={} migration_bytes={}",
        policy_name.as_deref().unwrap_or("off"),
        report.promotions,
        report.demotions,
        report.ping_pongs,
        report.migration_bytes
    );
    // stable machine-readable lane line (CI smoke greps this)
    println!(
        "LANES enabled={} lanes={} overlapped_ns={:.0} lane_switches={} prefetch_issued={} \
         prefetch_useful={}",
        cfg.lanes.enabled,
        eff_lanes,
        report.overlapped_ns,
        report.lane_switches,
        report.prefetch_issued,
        report.prefetch_useful
    );
    // replay verification: drive an identically configured machine from
    // the recording and require a field-for-field identical report —
    // the replay-identity invariant, checked on every `run` (CI greps
    // the TRACE counter line so a silently-dead replay path fails)
    if let Some(trace) = &trace {
        let (mut m2, _) = build_run_machine(&cfg, tier);
        apply_lanes(&cfg, &mut m2, w.lane_hints());
        m2.replay(trace);
        let replayed = m2.report();
        let identical = replayed == report && trace.checksum == checksum;
        println!(
            "TRACE records=1 replays=1 bytes={} events={} replay_identical={}",
            trace.encoded_bytes(),
            trace.len(),
            identical
        );
        if !identical {
            eprintln!("error: replayed run diverged from the live run (replay-identity broken)");
            return 1;
        }
    }
    if args.flag("keep-warm") {
        keep_warm_report(&cfg, w.name(), &objects, &report);
    }
    if let Some(sink) = machine.take_telemetry() {
        let tele = porter::telemetry::TelemetryReport { sink, series: Default::default() };
        println!("{}", tele.counter_line());
        if let Some(path) = &tele_out {
            let summary = vec![
                ("workload", porter::util::json::Json::str(w.name())),
                ("tier", porter::util::json::Json::str(tier.name())),
            ];
            if let Err(e) = write_telemetry(&tele, path, summary) {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    0
}

/// `run --keep-warm`: capture the sandbox image the shim saw, keep it in
/// a warm pool, and replay a second invocation against it — the
/// single-machine view of what the cluster's lifecycle layer amortizes.
fn keep_warm_report(
    cfg: &Config,
    name: &str,
    objects: &[porter::shim::MemoryObject],
    report: &porter::sim::machine::RunReport,
) {
    use porter::lifecycle::{policy_from_config, Sandbox, WarmPool};
    use porter::shim::SandboxImage;
    let image =
        SandboxImage::capture(objects, report.peak_dram_bytes, report.peak_cxl_bytes);
    println!(
        "keep-warm: sandbox captured objects={} heap={} mmap={} dram_resident={} \
         cxl_resident={}",
        image.objects.len(),
        porter::util::bytes::fmt_bytes(image.heap_bytes),
        porter::util::bytes::fmt_bytes(image.mmap_bytes),
        porter::util::bytes::fmt_bytes(image.dram_resident_bytes),
        porter::util::bytes::fmt_bytes(image.cxl_resident_bytes)
    );
    let mut lc = cfg.lifecycle.clone();
    lc.enabled = true;
    let mut pool = WarmPool::new(lc.warm_pool_bytes, policy_from_config(&lc));
    let finish_ns = report.wall_ns.round().max(0.0) as u64;
    let evicted = pool.insert(Sandbox::new(name, image, finish_ns));
    let warm_hit = evicted.is_empty() && pool.lookup(name, finish_ns + 1);
    let cold_wall_ns = report.wall_ns + cfg.cluster.cold_start_ns as f64;
    let warm_wall_ns = if warm_hit { report.wall_ns } else { cold_wall_ns };
    println!(
        "keep-warm: warm replay {} (cold {} vs warm {}, saved {})",
        if warm_hit { "hit" } else { "miss (pool budget too small)" },
        porter::bench::fmt_ns(cold_wall_ns),
        porter::bench::fmt_ns(warm_wall_ns),
        porter::bench::fmt_ns(cold_wall_ns - warm_wall_ns)
    );
    println!(
        "LIFECYCLE warm_hits={} cold_starts=1 pool_used={} pool_budget={} policy={}",
        pool.metrics.hits,
        pool.used_bytes(),
        pool.budget_bytes(),
        pool.policy_name()
    );
}

/// Load a trace from `--in FILE` (the serialized IR) or record one from
/// the named registry workload.
fn trace_source(args: &Args, cfg: &Config) -> Result<porter::trace::AccessTrace, String> {
    use porter::trace::AccessTrace;
    if let Some(path) = args.opt("in") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let j = porter::util::json::Json::parse(&text).map_err(|e| e.to_string())?;
        return AccessTrace::from_json(&j);
    }
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| "expected a workload name or --in FILE".to_string())?;
    let w = build(name, scale_of(args))
        .ok_or_else(|| format!("unknown workload {name:?}; see `porter-cli list`"))?;
    Ok(porter::trace::record_workload(w.as_ref(), cfg.machine.page_bytes))
}

fn print_trace_info(trace: &porter::trace::AccessTrace) {
    let mut t = Table::new(&["trace", "value"]).left_first();
    t.row(vec!["ir version".into(), trace.version.to_string()]);
    t.row(vec![
        "workload".into(),
        if trace.workload.is_empty() { "(anonymous)".into() } else { trace.workload.clone() },
    ]);
    t.row(vec!["events".into(), trace.len().to_string()]);
    t.row(vec!["accesses".into(), trace.n_accesses().to_string()]);
    t.row(vec![
        "bytes accessed".into(),
        porter::util::bytes::fmt_bytes(trace.bytes_accessed()),
    ]);
    t.row(vec!["compute cycles".into(), trace.compute_cycles().to_string()]);
    t.row(vec!["objects (interned)".into(), trace.objects.len().to_string()]);
    t.row(vec!["phases (interned)".into(), trace.phases.len().to_string()]);
    t.row(vec!["page size".into(), porter::util::bytes::fmt_bytes(trace.page_bytes)]);
    t.row(vec![
        "encoded size".into(),
        porter::util::bytes::fmt_bytes(trace.encoded_bytes()),
    ]);
    t.row(vec!["checksum".into(), format!("{:#018x}", trace.checksum)]);
    println!("{}", t.render());
    let summaries = trace.phase_summaries();
    if !summaries.is_empty() {
        let headers = ["phase", "accesses", "bytes", "compute cycles", "allocs", "frees"];
        let mut pt = Table::new(&headers).left_first();
        for s in &summaries {
            pt.row(vec![
                s.name.clone(),
                s.accesses.to_string(),
                porter::util::bytes::fmt_bytes(s.bytes),
                s.compute_cycles.to_string(),
                s.allocs.to_string(),
                s.frees.to_string(),
            ]);
        }
        println!("{}", pt.render());
    }
}

/// `porter-cli trace record|replay|info` — expose the Trace-IR for
/// inspection and cross-run reuse.
fn cmd_trace(args: &Args) -> i32 {
    let mut cfg = load_config(args);
    let action = args.positional.first().map(String::as_str);
    let trace = match action {
        Some("record" | "replay" | "info") => match trace_source(args, &cfg) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        },
        _ => {
            eprintln!(
                "usage: porter-cli trace record <workload> [--full] [--out FILE]\n\
                 \x20      porter-cli trace replay [<workload>] [--in FILE] [--tier dram|cxl] \
                 [--policy P]\n\
                 \x20      porter-cli trace info [<workload>] [--in FILE]"
            );
            return 2;
        }
    };
    match action {
        Some("record") => {
            print_trace_info(&trace);
            if let Some(path) = args.opt("out") {
                match std::fs::write(path, trace.to_json().to_string_pretty()) {
                    Ok(()) => println!("wrote {path}"),
                    Err(e) => {
                        eprintln!("error: write {path}: {e}");
                        return 1;
                    }
                }
            }
            0
        }
        Some("replay") => {
            let Some(tier) = tier_arg(args) else { return 2 };
            if let Err(e) =
                apply_policy_arg(&mut cfg, args).and_then(|()| apply_lanes_args(&mut cfg, args))
            {
                eprintln!("config error: {e}");
                return 2;
            }
            let (mut machine, policy_name) = build_run_machine(&cfg, tier);
            // no workload body here (the trace may come from a file), so
            // the lane cap is [lanes] max_lanes; LANE events in the
            // stream fold modulo that count either way
            apply_lanes(&cfg, &mut machine, usize::MAX);
            let t0 = std::time::Instant::now();
            machine.replay(&trace);
            let report = machine.report();
            let workload_label = if trace.workload.is_empty() {
                "(anonymous)".to_string()
            } else {
                trace.workload.clone()
            };
            let mut t = Table::new(&["metric", "value"]).left_first();
            t.row(vec!["workload".into(), workload_label]);
            t.row(vec!["tier".into(), tier.name().into()]);
            t.row(vec![
                "migration policy".into(),
                policy_name.unwrap_or_else(|| "off".to_string()),
            ]);
            t.row(vec!["virtual time".into(), porter::bench::fmt_ns(report.wall_ns)]);
            t.row(vec!["accesses".into(), report.accesses.to_string()]);
            t.row(vec!["host replay time".into(), format!("{:?}", t0.elapsed())]);
            t.row(vec!["checksum (recorded)".into(), format!("{:#018x}", trace.checksum)]);
            println!("{}", t.render());
            println!(
                "TRACE records={} replays=1 bytes={} events={}",
                if args.opt("in").is_some() { 0 } else { 1 },
                trace.encoded_bytes(),
                trace.len()
            );
            0
        }
        _ => {
            print_trace_info(&trace);
            0
        }
    }
}

fn cmd_profile(args: &Args) -> i32 {
    use porter::monitor::{Damon, Heatmap};
    use porter::sim::Machine;
    let cfg = load_config(args);
    let Some(w) = workload_arg(args, scale_of(args)) else { return 2 };
    let mut machine = Machine::all_in(&cfg.machine, TierKind::Cxl);
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine.attach_observer(Box::new(Damon::new(&cfg.monitor, cfg.machine.page_bytes, 0xDA11)));
    let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut machine);
    w.run(&mut env);
    let objects: Vec<_> = env.objects().to_vec();
    drop(env);
    let report = machine.report();
    let damon = machine
        .take_observers()
        .pop()
        .unwrap()
        .into_any()
        .downcast::<Damon>()
        .expect("damon observer");
    let lo = objects.iter().filter(|o| o.via_mmap).map(|o| o.start).min().unwrap_or(0);
    let hi = objects.iter().filter(|o| o.via_mmap).map(|o| o.end()).max().unwrap_or(lo + 1);
    let map = Heatmap::from_damon(
        &damon.snapshots,
        lo,
        hi,
        cfg.monitor.heatmap_bins,
        cfg.monitor.heatmap_time_bins,
    );
    println!("{}", map.render_ascii());
    println!(
        "locality score: {:.2}  memory-bound: {:.1}%  regions: {}",
        map.locality_score(),
        TopDown::from_report(&report).memory_bound_pct(),
        damon.n_regions()
    );
    0
}

fn cmd_place(args: &Args) -> i32 {
    let cfg = load_config(args);
    let Some(w) = workload_arg(args, scale_of(args)) else { return 2 };
    let r = profile_and_place(&cfg, w.as_ref());
    let mut t = Table::new(&["policy", "virtual time", "slowdown vs DRAM"]).left_first();
    t.row(vec!["all-dram".into(), porter::bench::fmt_ns(r.all_dram.wall_ns), "0%".into()]);
    t.row(vec![
        "static-hint".into(),
        porter::bench::fmt_ns(r.hinted.wall_ns),
        format!("{:.1}%", r.hinted_slowdown_pct()),
    ]);
    t.row(vec![
        "all-cxl".into(),
        porter::bench::fmt_ns(r.all_cxl.wall_ns),
        format!("{:.1}%", r.cxl_slowdown_pct()),
    ]);
    println!("{}", t.render());
    println!(
        "hint: {} objects, {} hot bytes; improvement over pure CXL: {:.1}%",
        r.hint.objects.len(),
        porter::util::bytes::fmt_bytes(r.hint.hot_bytes()),
        r.improvement_over_cxl_pct()
    );
    for o in &r.hint.objects {
        println!("  [{}] {} ({})", o.class.name(), o.site, porter::util::bytes::fmt_bytes(o.bytes));
    }
    0
}

/// Per-function DRAM provisioning what-if: demand curves from Trace-IR
/// ladder replays, greedy marginal-utility budgets vs uniform
/// provisioning at equal DRAM (see `placement::provision`).
fn cmd_provision(args: &Args) -> i32 {
    use porter::cluster::default_population;
    use porter::placement::provision::{obtain_curve, BudgetAllocator, FunctionDemand};
    use porter::porter::slo::SloTracker;
    use porter::trace::TraceStore;
    use porter::util::bytes::{fmt_bytes, MIB};

    let cfg = load_config(args);
    let parsed = (|| -> Result<(usize, Option<u64>), String> {
        let functions = args.opt_usize("functions", 6)?;
        let dram_mb = match args.opt("dram-mb") {
            None => None,
            Some(v) => Some(
                v.parse::<u64>()
                    .map_err(|_| format!("--dram-mb expects an integer, got {v:?}"))?,
            ),
        };
        Ok((functions, dram_mb))
    })();
    let (functions, dram_mb) = match parsed {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let scale = scale_of(args);
    let store = TraceStore::global();
    let ladder = &cfg.provision.ladder;

    // 1. demand curves (record each trace once, replay it per rung)
    let mut demands = Vec::new();
    let mut slo = SloTracker::default();
    for name in default_population(functions) {
        let Some(w) = build(&name, scale) else {
            eprintln!("unknown workload {name:?}");
            return 2;
        };
        let (curve, built) =
            obtain_curve(store, w.as_ref(), &cfg.machine, ladder, cfg.trace.max_cached);
        eprintln!(
            "  curve {name}: footprint {} across {} rungs{}",
            fmt_bytes(curve.footprint),
            curve.points.len(),
            if built { "" } else { " (memoized)" }
        );
        // seed the SLO reference the serving path would learn online:
        // the ladder-top wall is the function's best observed latency
        slo.record_latency(&name, curve.best_wall_ns(), None);
        demands.push(FunctionDemand::new(curve));
    }
    if cfg.provision.slo_floors {
        for d in &mut demands {
            let target = slo
                .get(&d.curve.function)
                .map(|f| f.mean_wall_ns() * cfg.porter.slo_factor);
            d.floor_bytes = target.and_then(|t| d.curve.bytes_for_target(t));
        }
    }

    // 2. allocate at each what-if capacity
    let total: u64 = demands.iter().map(|d| d.curve.footprint).sum();
    let capacities: Vec<u64> = match dram_mb {
        Some(mb) => vec![mb * MIB],
        None => [0.25, 0.5, 0.75].iter().map(|f| (total as f64 * f) as u64).collect(),
    };
    let allocator = BudgetAllocator::from_config(&cfg.provision);
    let mut reallocs = 0u64;
    let mut best_saved = 0u64;
    for &capacity in &capacities {
        let alloc = allocator.allocate(capacity, &demands);
        reallocs += 1;
        best_saved = best_saved.max(alloc.dram_saved_bytes());
        println!(
            "capacity {} (uniform ladder ratio {:.3}{}):",
            fmt_bytes(capacity),
            alloc.uniform_ratio,
            if alloc.fell_back_to_uniform { ", fell back to uniform" } else { "" }
        );
        let headers =
            ["function", "footprint", "uniform", "optimized", "frac", "wall uni", "wall opt"];
        let mut t = Table::new(&headers).left_first();
        for (d, b) in demands.iter().zip(&alloc.budgets) {
            let uni_bytes = (d.curve.footprint as f64 * alloc.uniform_ratio) as u64;
            t.row(vec![
                format!("{}{}", b.function, if b.floor_met { " (slo floor)" } else { "" }),
                fmt_bytes(d.curve.footprint),
                fmt_bytes(uni_bytes),
                fmt_bytes(b.dram_bytes),
                format!("{:.3}", b.frac),
                porter::bench::fmt_ns(d.curve.wall_at(uni_bytes)),
                porter::bench::fmt_ns(b.predicted_wall_ns),
            ]);
        }
        println!("{}", t.render());
        println!(
            "  totals: optimized {} / {} used, predicted wall {} vs uniform {} (saved {})",
            fmt_bytes(alloc.used_bytes),
            fmt_bytes(capacity),
            porter::bench::fmt_ns(alloc.predicted_wall_ns),
            porter::bench::fmt_ns(alloc.uniform_wall_ns),
            fmt_bytes(alloc.dram_saved_bytes())
        );
    }

    // stable machine-readable counter line (CI smoke greps this)
    let (curve_builds, curve_hits) = store.curve_counts();
    println!(
        "PROVISION curves={} reallocs={} dram_saved_mb={} curve_builds={} curve_hits={}",
        demands.len(),
        reallocs,
        best_saved / MIB,
        curve_builds,
        curve_hits
    );
    0
}

/// Fleet simulation: open-loop arrivals over a multi-node Porter
/// deployment with a shared CXL pool (see `cluster::`).
fn cmd_cluster(args: &Args) -> i32 {
    let mut cfg = load_config(args);
    let parse_result = (|| -> Result<(), String> {
        let c = &mut cfg.cluster;
        c.nodes = args.opt_usize("nodes", c.nodes)?;
        if c.max_nodes < c.nodes {
            c.max_nodes = c.nodes;
        }
        c.max_nodes = args.opt_usize("max-nodes", c.max_nodes)?;
        c.arrivals = args.opt_or("arrivals", &c.arrivals).to_string();
        c.trace_path = args.opt_or("trace", &c.trace_path).to_string();
        c.rate_per_s = args.opt_f64("rate", c.rate_per_s)?;
        c.duration_s = args.opt_f64("duration", c.duration_s)?;
        c.functions = args.opt_usize("functions", c.functions)?;
        c.seed = args.opt_usize("seed", c.seed as usize)? as u64;
        if args.flag("no-autoscale") {
            c.autoscale = false;
        }
        cfg.sim.shards = args.opt_usize("shards", cfg.sim.shards)?;
        // lifecycle layer: any of these flags turns explicit sandbox
        // lifetime modeling on
        let lc = &mut cfg.lifecycle;
        if let Some(mb) = args.opt("warm-pool-mb") {
            let mb: u64 = mb
                .parse()
                .map_err(|_| format!("--warm-pool-mb expects an integer, got {mb:?}"))?;
            lc.warm_pool_bytes = mb * (1 << 20);
            lc.enabled = true;
        }
        if args.flag("snapshot") {
            lc.snapshot = true;
            lc.enabled = true;
        }
        if let Some(p) = args.opt("keepalive") {
            lc.policy = p.to_string();
            lc.enabled = true;
        }
        if let Some(path) = args.opt("telemetry-out") {
            cfg.telemetry.enabled = true;
            cfg.telemetry.out = path.to_string();
        }
        // fault injection: "seeded" uses the generator from [faults]
        // knobs, a readable path loads a spec file, anything else is
        // the inline DSL (down@t:n, up@t:n, degrade@t:n:f, restore@t:n)
        if let Some(spec) = args.opt("faults") {
            cfg.faults.enabled = true;
            cfg.faults.spec = if spec == "seeded" {
                String::new()
            } else if std::path::Path::new(spec).is_file() {
                std::fs::read_to_string(spec)
                    .map_err(|e| format!("read faults spec {spec}: {e}"))?
                    .trim()
                    .to_string()
            } else {
                spec.to_string()
            };
        }
        apply_lanes_args(&mut cfg, args)?;
        Ok(())
    })();
    if let Err(e) = parse_result {
        eprintln!("error: {e}");
        return 2;
    }
    println!(
        "fleet: {} node(s) (max {}), {} functions, {} arrivals @ {:.0}/s for {:.2}s (seed {})",
        cfg.cluster.nodes,
        cfg.cluster.max_nodes,
        cfg.cluster.functions,
        cfg.cluster.arrivals,
        cfg.cluster.rate_per_s,
        cfg.cluster.duration_s,
        cfg.cluster.seed
    );
    if cfg.lifecycle.enabled {
        println!(
            "lifecycle: warm pool {} per node ({} policy), snapshots {}",
            porter::util::bytes::fmt_bytes(cfg.lifecycle.warm_pool_bytes),
            cfg.lifecycle.policy,
            if cfg.lifecycle.snapshot { "on (shared CXL pool)" } else { "off" }
        );
    }
    match porter::cluster::simulate_full(&cfg) {
        Ok((report, tele)) => {
            println!("{}", report.render());
            // stable machine-readable counter line (CI smoke greps this)
            println!(
                "LIFECYCLE enabled={} cold_starts={} warm_starts={} restores={} \
                 snapshot_bytes={} restore_bytes={} snapshot_leased={} p50_ns={}",
                report.lifecycle_enabled,
                report.cold_starts,
                report.warm_starts,
                report.restores,
                report.snapshot_bytes,
                report.restore_bytes,
                report.snapshot_leased_bytes,
                report.fleet_p50_ns
            );
            println!(
                "SHARDS workers={} merges={} events_per_sec={:.0} token={:#018x}",
                report.shards.workers,
                report.shards.merges,
                report.shards.events_per_sec,
                report.determinism_token
            );
            println!(
                "FAULTS downs={} rejoins={} degrades={} failed={} availability={:.4} \
                 retried={} degraded_epochs={}",
                report.fault_downs,
                report.fault_rejoins,
                report.fault_degrades,
                report.fault_failed,
                report.availability,
                report.fault_retried,
                report.degraded_epochs
            );
            println!(
                "LANES enabled={} overlapped_ns={:.0} lane_switches={} prefetch_issued={} \
                 prefetch_useful={}",
                report.lanes_enabled,
                report.overlapped_ns,
                report.lane_switches,
                report.prefetch_issued,
                report.prefetch_useful
            );
            if tele.is_enabled() {
                println!("{}", tele.counter_line());
                if !cfg.telemetry.out.is_empty() {
                    use porter::util::json::Json;
                    let summary = vec![
                        ("completed", Json::num(report.completed as f64)),
                        ("virtual_duration_s", Json::num(report.virtual_duration_s)),
                        (
                            "determinism_token",
                            Json::str(format!("{:#018x}", report.determinism_token)),
                        ),
                    ];
                    if let Err(e) = write_telemetry(&tele, &cfg.telemetry.out, summary) {
                        eprintln!("error: {e}");
                        return 1;
                    }
                }
            }
            0
        }
        Err(e) => {
            eprintln!("cluster error: {e}");
            2
        }
    }
}

/// `porter-cli telemetry summarize <trace.json>` — read an exported
/// Chrome-trace file back and print the per-kind/series rollup.
fn cmd_telemetry(args: &Args) -> i32 {
    let usage = "usage: porter-cli telemetry summarize <trace.json>";
    if args.positional.first().map(String::as_str) != Some("summarize") {
        eprintln!("{usage}");
        return 2;
    }
    let Some(path) = args.positional.get(1) else {
        eprintln!("{usage}");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: read {path}: {e}");
            return 1;
        }
    };
    let doc = match porter::util::json::Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: parse {path}: {e}");
            return 1;
        }
    };
    match porter::telemetry::export::summarize(&doc) {
        Ok(s) => {
            println!("{s}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    use porter::runtime::{MlpParams, ModelRuntime};
    let requests = args.opt_usize("requests", 32).unwrap_or(32);
    let rt = match ModelRuntime::load(porter::runtime::ArtifactManifest::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime error: {e:#}");
            return 1;
        }
    };
    println!("runtime platform: {}", rt.platform());
    let params = MlpParams::init(&rt.manifest.model_layers.clone(), 42);
    let sig = rt.manifest.get("mlp_infer").expect("mlp_infer artifact");
    let xin = sig.inputs.last().unwrap();
    let lat = porter::metrics::Histogram::default();
    let mut checksum = 0.0f64;
    for r in 0..requests {
        let x: Vec<f32> =
            (0..xin.elements()).map(|i| (((i + r * 31) % 23) as f32 - 11.0) * 0.09).collect();
        let t0 = std::time::Instant::now();
        let logits = rt.mlp_infer(&params, &x).expect("infer");
        lat.record(t0.elapsed().as_nanos() as u64);
        checksum += logits.iter().map(|v| *v as f64).sum::<f64>();
    }
    println!(
        "served {requests} batches: mean={} p99≤{} (checksum {checksum:.3})",
        porter::bench::fmt_ns(lat.mean()),
        porter::bench::fmt_ns(lat.percentile(99.0) as f64)
    );
    0
}
