//! Runtime metrics: counters and log-bucketed latency histograms used by
//! the serving path and benches.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-written f64 value (occupancy fractions, rates). Stored as bits
/// in an atomic so gauges share the lock-free registry.
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log₂-bucketed histogram (ns scale): cheap concurrent recording,
/// percentile estimates good to ~2× within a bucket, which is plenty for
/// latency reporting.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, value_ns: u64) {
        let b = 64 - value_ns.max(1).leading_zeros() as usize - 1;
        self.buckets[b.min(63)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value_ns, Ordering::Relaxed);
        self.max.fetch_max(value_ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Fold another histogram into this one (fleet rollups aggregate
    /// per-node histograms; log buckets merge exactly by addition).
    pub fn merge_from(&self, other: &Histogram) {
        for (b, ob) in self.buckets.iter().zip(&other.buckets) {
            b.fetch_add(ob.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Upper bound of the bucket containing the p-th percentile.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max()
    }
}

/// Named metric registry for a component.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Render all metrics as a report block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", crate::util::fmt_f64(g.get(), 4)));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={} p50≤{} p99≤{} max={}\n",
                h.count(),
                crate::bench::fmt_ns(h.mean()),
                crate::bench::fmt_ns(h.percentile(50.0) as f64),
                crate::bench::fmt_ns(h.percentile(99.0) as f64),
                crate::bench::fmt_ns(h.max() as f64),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_bracket() {
        let h = Histogram::default();
        for v in [100u64, 200, 400, 800, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        let p50 = h.percentile(50.0);
        assert!((256..=512).contains(&p50), "p50={p50}");
        assert!(h.percentile(100.0) >= 100_000);
        assert_eq!(h.max(), 100_000);
        assert!((h.mean() - 20300.0).abs() < 1.0);
    }

    #[test]
    fn empty_histogram_zeroes() {
        let h = Histogram::default();
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn gauge_roundtrips() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(0.75);
        assert_eq!(g.get(), 0.75);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
    }

    #[test]
    fn histogram_merge_adds() {
        let a = Histogram::default();
        let b = Histogram::default();
        a.record(100);
        a.record(1000);
        b.record(1000);
        b.record(1 << 20);
        a.merge_from(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max(), 1 << 20);
        assert!((a.mean() - (100.0 + 1000.0 + 1000.0 + (1u64 << 20) as f64) / 4.0).abs() < 1.0);
        // p100 bracketed by the top recorded bucket
        assert!(a.percentile(100.0) >= 1 << 20);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        r.histogram("lat").record(1000);
        assert!(r.render().contains("a: 2"));
        assert!(r.render().contains("lat:"));
    }
}
