//! Runtime metrics: counters and log-bucketed latency histograms used by
//! the serving path and benches.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-written f64 value (occupancy fractions, rates). Stored as bits
/// in an atomic so gauges share the lock-free registry.
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log₂-bucketed histogram (ns scale): cheap concurrent recording,
/// percentile estimates good to ~2× within a bucket, which is plenty for
/// latency reporting.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

/// Bucket index for a value: bucket `i` holds values in
/// `(2^(i-1), 2^i]`, so the reported upper bound `2^i` is *exact* at
/// power-of-two boundaries (recording 256 reports p100 ≤ 256, not 512).
#[inline]
fn bucket_of(value_ns: u64) -> usize {
    if value_ns <= 1 {
        0
    } else {
        (64 - (value_ns - 1).leading_zeros() as usize).min(63)
    }
}

impl Histogram {
    pub fn record(&self, value_ns: u64) {
        self.buckets[bucket_of(value_ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value_ns, Ordering::Relaxed);
        self.max.fetch_max(value_ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Fold another histogram into this one (fleet rollups aggregate
    /// per-node histograms; log buckets merge exactly by addition).
    pub fn merge_from(&self, other: &Histogram) {
        for (b, ob) in self.buckets.iter().zip(&other.buckets) {
            b.fetch_add(ob.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Upper bound of the bucket containing the p-th percentile.
    pub fn percentile(&self, p: f64) -> u64 {
        self.snapshot().percentile(p)
    }

    /// Non-destructive point-in-time copy (cumulative view).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    /// Snapshot-and-reset: everything recorded since the previous
    /// `interval()` call, zeroing the live histogram — the telemetry
    /// sampler's per-epoch (not cumulative) percentile view. Fields are
    /// swapped individually, so concurrent recorders may straddle the
    /// boundary by one event; exact in the single-threaded DES.
    pub fn interval(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.swap(0, Ordering::Relaxed)).collect(),
            count: self.count.swap(0, Ordering::Relaxed),
            sum: self.sum.swap(0, Ordering::Relaxed),
            max: self.max.swap(0, Ordering::Relaxed),
        }
    }
}

/// Plain (non-atomic) histogram state handed out by
/// [`Histogram::snapshot`]/[`Histogram::interval`], with the same
/// percentile/mean math as the live histogram.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    buckets: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the p-th percentile: bucket
    /// `i` covers `(2^(i-1), 2^i]`, so the bound is exact at powers of
    /// two and within 2× otherwise.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return 1u64 << i;
            }
        }
        self.max
    }
}

/// Named metric registry for a component.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// All counters by name (sorted) — exporter iteration surface.
    pub fn counter_values(&self) -> Vec<(String, u64)> {
        self.counters.lock().unwrap().iter().map(|(k, c)| (k.clone(), c.get())).collect()
    }

    /// All gauges by name (sorted).
    pub fn gauge_values(&self) -> Vec<(String, f64)> {
        self.gauges.lock().unwrap().iter().map(|(k, g)| (k.clone(), g.get())).collect()
    }

    /// Non-destructive snapshots of all histograms by name (sorted).
    pub fn histogram_values(&self) -> Vec<(String, HistogramSnapshot)> {
        self.histograms.lock().unwrap().iter().map(|(k, h)| (k.clone(), h.snapshot())).collect()
    }

    /// Render all metrics as a report block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", crate::util::fmt_f64(g.get(), 4)));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={} p50≤{} p99≤{} max={}\n",
                h.count(),
                crate::bench::fmt_ns(h.mean()),
                crate::bench::fmt_ns(h.percentile(50.0) as f64),
                crate::bench::fmt_ns(h.percentile(99.0) as f64),
                crate::bench::fmt_ns(h.max() as f64),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_bracket() {
        let h = Histogram::default();
        for v in [100u64, 200, 400, 800, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        let p50 = h.percentile(50.0);
        assert!((256..=512).contains(&p50), "p50={p50}");
        assert!(h.percentile(100.0) >= 100_000);
        assert_eq!(h.max(), 100_000);
        assert!((h.mean() - 20300.0).abs() < 1.0);
    }

    #[test]
    fn histogram_exact_at_power_of_two_boundaries() {
        // bucket i covers (2^(i-1), 2^i]: a power-of-two value reports
        // its own value as the bound, not the next bucket up
        for v in [1u64, 2, 4, 256, 1 << 20] {
            let h = Histogram::default();
            h.record(v);
            assert_eq!(h.percentile(100.0), v, "p100 of a single record of {v}");
        }
        let h = Histogram::default();
        h.record(3);
        assert_eq!(h.percentile(100.0), 4, "3 lands in the (2,4] bucket");
        h.record(257);
        assert_eq!(h.percentile(100.0), 512, "257 lands in the (256,512] bucket");
    }

    #[test]
    fn histogram_interval_resets_cumulative_snapshot_does_not() {
        let h = Histogram::default();
        h.record(100);
        h.record(200);
        let snap = h.snapshot();
        assert_eq!(snap.count(), 2);
        assert_eq!(h.count(), 2, "snapshot() is non-destructive");

        let iv = h.interval();
        assert_eq!(iv.count(), 2);
        assert_eq!(iv.max(), 200);
        assert!((iv.mean() - 150.0).abs() < 1e-9);
        assert_eq!(iv.percentile(50.0), 128);
        // live histogram is drained; the next interval sees only new data
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
        h.record(4000);
        let iv2 = h.interval();
        assert_eq!(iv2.count(), 1);
        assert_eq!(iv2.percentile(100.0), 4096);
        assert_eq!(h.interval().count(), 0);
    }

    #[test]
    fn registry_exposes_values_for_exporters() {
        let r = Registry::default();
        r.counter("requests").add(3);
        r.gauge("occupancy").set(0.5);
        r.histogram("lat").record(100);
        assert_eq!(r.counter_values(), vec![("requests".to_string(), 3)]);
        assert_eq!(r.gauge_values(), vec![("occupancy".to_string(), 0.5)]);
        let hists = r.histogram_values();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].0, "lat");
        assert_eq!(hists[0].1.count(), 1);
    }

    #[test]
    fn empty_histogram_zeroes() {
        let h = Histogram::default();
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn gauge_roundtrips() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(0.75);
        assert_eq!(g.get(), 0.75);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
    }

    #[test]
    fn histogram_merge_adds() {
        let a = Histogram::default();
        let b = Histogram::default();
        a.record(100);
        a.record(1000);
        b.record(1000);
        b.record(1 << 20);
        a.merge_from(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max(), 1 << 20);
        assert!((a.mean() - (100.0 + 1000.0 + 1000.0 + (1u64 << 20) as f64) / 4.0).abs() < 1.0);
        // p100 bracketed by the top recorded bucket
        assert!(a.percentile(100.0) >= 1 << 20);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        r.histogram("lat").record(1000);
        assert!(r.render().contains("a: 2"));
        assert!(r.render().contains("lat:"));
    }
}
