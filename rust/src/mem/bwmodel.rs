//! Bandwidth-contention model.
//!
//! Each tier tracks its recent demand as an exponentially-decayed
//! bytes-per-window counter. Utilization `U = demand / peak` inflates
//! effective access latency with an M/M/1-style queueing factor
//! `1 + U/(1-U)` (capped), which is how loaded-latency curves on real
//! DDR/CXL parts behave to first order. Colocated tenants share the
//! model, so bandwidth interference (Fig. 7) falls out naturally.

use crate::mem::tier::TierParams;

/// Sliding-window bandwidth tracker for one tier.
#[derive(Debug, Clone)]
pub struct BandwidthModel {
    /// Peak bytes per ns (GB/s == bytes/ns).
    peak_bytes_per_ns: f64,
    /// Averaging window in virtual ns.
    window_ns: f64,
    /// Bytes accumulated in the current window.
    window_bytes: f64,
    /// Decayed demand estimate in bytes/ns.
    demand: f64,
    /// Window anchor time.
    window_start_ns: f64,
    /// Cap on the queueing inflation factor.
    max_factor: f64,
    /// Factor memoized at the last window roll (it only changes when the
    /// demand estimate does, so recomputing per access is wasted work).
    cached_factor: f64,
}

impl BandwidthModel {
    pub fn new(params: &TierParams) -> BandwidthModel {
        BandwidthModel::with_window(params, 10_000.0)
    }

    /// A model with an explicit averaging window. The per-access default
    /// (10 µs) suits line-granular traffic inside one run; coarser
    /// consumers (the cluster-wide CXL pool records whole-invocation
    /// byte counts) pick a window matching their event granularity.
    pub fn with_window(params: &TierParams, window_ns: f64) -> BandwidthModel {
        assert!(window_ns > 0.0);
        BandwidthModel {
            peak_bytes_per_ns: params.bw_gbps,
            window_ns,
            window_bytes: 0.0,
            demand: 0.0,
            window_start_ns: 0.0,
            max_factor: 8.0,
            cached_factor: 1.0,
        }
    }

    /// Record `bytes` transferred at virtual time `now_ns`.
    #[inline]
    pub fn record(&mut self, now_ns: f64, bytes: u64) {
        self.roll(now_ns);
        self.window_bytes += bytes as f64;
    }

    #[inline]
    fn roll(&mut self, now_ns: f64) {
        let elapsed = now_ns - self.window_start_ns;
        if elapsed >= self.window_ns {
            // fold the finished window into the decayed demand estimate
            let inst = self.window_bytes / elapsed.max(1.0);
            self.demand = 0.5 * self.demand + 0.5 * inst;
            self.window_bytes = 0.0;
            self.window_start_ns = now_ns;
            let u = self.utilization();
            // M/M/1 waiting-time growth: u=0.5 → 1.5×, u≥0.9 → cap
            self.cached_factor = (1.0 + u / (1.0 - u)).min(self.max_factor);
        }
    }

    /// Current utilization estimate in [0, 1).
    #[inline]
    pub fn utilization(&self) -> f64 {
        (self.demand / self.peak_bytes_per_ns).min(0.99)
    }

    /// Latency inflation factor for the current load (memoized at window
    /// granularity).
    #[inline]
    pub fn factor(&self) -> f64 {
        self.cached_factor
    }

    /// Reset (between experiments).
    pub fn reset(&mut self) {
        self.window_bytes = 0.0;
        self.demand = 0.0;
        self.window_start_ns = 0.0;
        self.cached_factor = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::tier::TierKind;

    fn params(bw: f64) -> TierParams {
        TierParams { kind: TierKind::Dram, latency_ns: 90.0, bw_gbps: bw, capacity: 1 << 30 }
    }

    #[test]
    fn idle_factor_is_one() {
        let bw = BandwidthModel::new(&params(60.0));
        assert!((bw.factor() - 1.0).abs() < 1e-9);
        assert_eq!(bw.utilization(), 0.0);
    }

    #[test]
    fn saturating_demand_inflates() {
        let mut bw = BandwidthModel::new(&params(10.0)); // 10 B/ns peak
        let mut t = 0.0;
        // push 20 B/ns for a while — demand should exceed peak and clamp
        for _ in 0..100 {
            t += 1000.0;
            bw.record(t, 20_000);
        }
        assert!(bw.utilization() > 0.9, "u={}", bw.utilization());
        assert!(bw.factor() > 4.0);
    }

    #[test]
    fn light_demand_small_factor() {
        let mut bw = BandwidthModel::new(&params(60.0));
        let mut t = 0.0;
        for _ in 0..100 {
            t += 10_000.0;
            bw.record(t, 60_000); // 6 B/ns = 10% util
        }
        assert!(bw.factor() < 1.3, "factor={}", bw.factor());
    }

    #[test]
    fn reset_clears() {
        let mut bw = BandwidthModel::new(&params(10.0));
        for i in 0..50 {
            bw.record(i as f64 * 1000.0, 50_000);
        }
        bw.reset();
        assert_eq!(bw.utilization(), 0.0);
    }
}
