//! Tiered-memory substrate: the DRAM + CXL memory system under the
//! serverless runtime.
//!
//! The paper emulates CXL as a CPU-less NUMA node whose access latency
//! sits ~70 ns above local DRAM (§2.2/§2.3). We model each tier with a
//! (latency, bandwidth, capacity) triple, keep a page table mapping every
//! touched page to its tier, and expose placement + migration as the two
//! operations Porter drives.

pub mod bwmodel;
pub mod migrate;
pub mod page;
pub mod soa;
pub mod tier;
pub mod tiered;

pub use bwmodel::BandwidthModel;
pub use migrate::{MigrationEngine, MigrationMetrics, MigrationPolicy};
pub use page::{PageMap, PageMeta};
pub use soa::PageCol;
pub use tier::{TierKind, TierParams};
pub use tiered::{Migration, PagePlacer, TieredMemory};
