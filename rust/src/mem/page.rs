//! Page table over the simulated address space.
//!
//! The shim's address layout has exactly two linear segments (brk heap at
//! `HEAP_BASE`, mmap segment at `MMAP_BASE`), so the page table is two
//! flat arrays indexed by `(addr - base) >> page_shift` — O(1) lookup
//! with no hashing on the access hot path.

use crate::mem::tier::TierKind;
use crate::shim::intercept::{HEAP_BASE, MMAP_BASE};

/// Per-page state, packed to 8 bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageMeta {
    /// 0 = unmapped, 1 = DRAM, 2 = CXL.
    tier: u8,
    /// Accesses since the last aggregation tick (saturating).
    pub window_accesses: u16,
    /// Ticks since last access (saturating) — demotion candidate signal.
    pub idle_ticks: u8,
    /// Lifetime access count (saturating) — reporting only.
    pub total_accesses: u32,
}

pub const UNMAPPED: PageMeta =
    PageMeta { tier: 0, window_accesses: 0, idle_ticks: 0, total_accesses: 0 };

impl PageMeta {
    pub fn tier(&self) -> Option<TierKind> {
        match self.tier {
            1 => Some(TierKind::Dram),
            2 => Some(TierKind::Cxl),
            _ => None,
        }
    }

    pub fn set_tier(&mut self, t: TierKind) {
        self.tier = match t {
            TierKind::Dram => 1,
            TierKind::Cxl => 2,
        };
    }

    pub fn unmap(&mut self) {
        *self = UNMAPPED;
    }

    pub fn is_mapped(&self) -> bool {
        self.tier != 0
    }

    pub fn touch(&mut self) {
        self.window_accesses = self.window_accesses.saturating_add(1);
        self.total_accesses = self.total_accesses.saturating_add(1);
        self.idle_ticks = 0;
    }
}

/// Global page number — encodes which segment and the index within it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageNo {
    pub segment: Segment,
    pub index: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Segment {
    Heap,
    Mmap,
}

/// Two-segment flat page table.
#[derive(Debug)]
pub struct PageMap {
    page_shift: u32,
    heap: Vec<PageMeta>,
    mmap: Vec<PageMeta>,
}

impl PageMap {
    pub fn new(page_bytes: u64) -> PageMap {
        assert!(page_bytes.is_power_of_two());
        PageMap { page_shift: page_bytes.trailing_zeros(), heap: Vec::new(), mmap: Vec::new() }
    }

    pub fn page_bytes(&self) -> u64 {
        1 << self.page_shift
    }

    /// Translate an address to its page number. Addresses outside both
    /// segments are a workload bug — panic in debug, clamp in release.
    #[inline]
    pub fn page_of(&self, addr: u64) -> PageNo {
        if addr >= MMAP_BASE {
            PageNo { segment: Segment::Mmap, index: ((addr - MMAP_BASE) >> self.page_shift) as u32 }
        } else {
            debug_assert!(addr >= HEAP_BASE, "address {addr:#x} below heap base");
            PageNo {
                segment: Segment::Heap,
                index: ((addr.saturating_sub(HEAP_BASE)) >> self.page_shift) as u32,
            }
        }
    }

    /// First byte address of a page.
    pub fn addr_of(&self, p: PageNo) -> u64 {
        let base = match p.segment {
            Segment::Heap => HEAP_BASE,
            Segment::Mmap => MMAP_BASE,
        };
        base + ((p.index as u64) << self.page_shift)
    }

    #[inline]
    fn seg_mut(&mut self, segment: Segment) -> &mut Vec<PageMeta> {
        match segment {
            Segment::Heap => &mut self.heap,
            Segment::Mmap => &mut self.mmap,
        }
    }

    /// Get page state, growing the table as needed.
    #[inline]
    pub fn entry(&mut self, p: PageNo) -> &mut PageMeta {
        let seg = self.seg_mut(p.segment);
        let idx = p.index as usize;
        if idx >= seg.len() {
            seg.resize(idx + 1, UNMAPPED);
        }
        &mut seg[idx]
    }

    /// Read-only view (unmapped default for untouched pages).
    pub fn get(&self, p: PageNo) -> PageMeta {
        let seg = match p.segment {
            Segment::Heap => &self.heap,
            Segment::Mmap => &self.mmap,
        };
        seg.get(p.index as usize).copied().unwrap_or(UNMAPPED)
    }

    /// Iterate over all mapped pages.
    pub fn iter_mapped(&self) -> impl Iterator<Item = (PageNo, &PageMeta)> {
        let heap = self
            .heap
            .iter()
            .enumerate()
            .map(|(i, m)| (PageNo { segment: Segment::Heap, index: i as u32 }, m));
        let mmap = self
            .mmap
            .iter()
            .enumerate()
            .map(|(i, m)| (PageNo { segment: Segment::Mmap, index: i as u32 }, m));
        heap.chain(mmap).filter(|(_, m)| m.is_mapped())
    }

    /// Mutable iteration over mapped pages (migration tick).
    pub fn iter_mapped_mut(&mut self) -> impl Iterator<Item = (PageNo, &mut PageMeta)> {
        let heap = self
            .heap
            .iter_mut()
            .enumerate()
            .map(|(i, m)| (PageNo { segment: Segment::Heap, index: i as u32 }, m));
        let mmap = self
            .mmap
            .iter_mut()
            .enumerate()
            .map(|(i, m)| (PageNo { segment: Segment::Mmap, index: i as u32 }, m));
        heap.chain(mmap).filter(|(_, m)| m.is_mapped())
    }

    pub fn mapped_count(&self) -> usize {
        self.iter_mapped().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_translation_roundtrip() {
        let pm = PageMap::new(4096);
        for addr in [HEAP_BASE, HEAP_BASE + 4095, HEAP_BASE + 4096, MMAP_BASE, MMAP_BASE + 123456] {
            let p = pm.page_of(addr);
            let start = pm.addr_of(p);
            assert!(start <= addr && addr < start + 4096);
        }
    }

    #[test]
    fn segments_separate() {
        let pm = PageMap::new(4096);
        assert_eq!(pm.page_of(HEAP_BASE).segment, Segment::Heap);
        assert_eq!(pm.page_of(MMAP_BASE).segment, Segment::Mmap);
        assert_eq!(pm.page_of(HEAP_BASE).index, 0);
        assert_eq!(pm.page_of(MMAP_BASE + 8192).index, 2);
    }

    #[test]
    fn entry_grows_and_tracks() {
        let mut pm = PageMap::new(4096);
        let p = pm.page_of(MMAP_BASE + 10 * 4096);
        assert!(!pm.get(p).is_mapped());
        pm.entry(p).set_tier(TierKind::Cxl);
        pm.entry(p).touch();
        let m = pm.get(p);
        assert_eq!(m.tier(), Some(TierKind::Cxl));
        assert_eq!(m.window_accesses, 1);
        assert_eq!(m.total_accesses, 1);
        assert_eq!(pm.mapped_count(), 1);
    }

    #[test]
    fn touch_saturates() {
        let mut m = UNMAPPED;
        m.set_tier(TierKind::Dram);
        for _ in 0..100_000 {
            m.touch();
        }
        assert_eq!(m.window_accesses, u16::MAX);
        assert_eq!(m.total_accesses, 100_000);
    }

    #[test]
    fn unmap_resets() {
        let mut m = UNMAPPED;
        m.set_tier(TierKind::Dram);
        m.touch();
        m.unmap();
        assert!(!m.is_mapped());
        assert_eq!(m.total_accesses, 0);
    }
}
