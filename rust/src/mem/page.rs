//! Page table over the simulated address space.
//!
//! The shim's address layout has exactly two linear segments (brk heap at
//! `HEAP_BASE`, mmap segment at `MMAP_BASE`), so the page table is flat
//! arrays indexed by `(addr - base) >> page_shift` — O(1) lookup with no
//! hashing on the access hot path.
//!
//! Page state is stored struct-of-arrays: one column per field (tier
//! code, window accesses, idle ticks, lifetime total) per segment, so the
//! per-window maintenance sweep (`end_window`) and the migration
//! policies' epoch scans walk contiguous `u8`/`u16` arrays instead of
//! pointer-chasing through per-page structs. `PageMeta` survives as the
//! by-value view assembled from the columns on read.

use crate::mem::tier::TierKind;
use crate::shim::intercept::{HEAP_BASE, MMAP_BASE};

/// By-value view of one page's state, assembled from the columns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageMeta {
    /// 0 = unmapped, 1 = DRAM, 2 = CXL.
    tier: u8,
    /// Accesses since the last aggregation tick (saturating).
    pub window_accesses: u16,
    /// Ticks since last access (saturating) — demotion candidate signal.
    pub idle_ticks: u8,
    /// Lifetime access count (saturating) — reporting only.
    pub total_accesses: u32,
}

pub const UNMAPPED: PageMeta =
    PageMeta { tier: 0, window_accesses: 0, idle_ticks: 0, total_accesses: 0 };

impl PageMeta {
    pub fn tier(&self) -> Option<TierKind> {
        tier_from_code(self.tier)
    }

    pub fn set_tier(&mut self, t: TierKind) {
        self.tier = tier_code(t);
    }

    pub fn unmap(&mut self) {
        *self = UNMAPPED;
    }

    pub fn is_mapped(&self) -> bool {
        self.tier != 0
    }

    pub fn touch(&mut self) {
        self.window_accesses = self.window_accesses.saturating_add(1);
        self.total_accesses = self.total_accesses.saturating_add(1);
        self.idle_ticks = 0;
    }
}

#[inline]
fn tier_code(t: TierKind) -> u8 {
    match t {
        TierKind::Dram => 1,
        TierKind::Cxl => 2,
    }
}

#[inline]
fn tier_from_code(c: u8) -> Option<TierKind> {
    match c {
        1 => Some(TierKind::Dram),
        2 => Some(TierKind::Cxl),
        _ => None,
    }
}

/// Global page number — encodes which segment and the index within it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageNo {
    pub segment: Segment,
    pub index: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Segment {
    Heap,
    Mmap,
}

/// One segment's page-state columns (parallel, always equal length).
#[derive(Debug, Default)]
struct SegCols {
    tier: Vec<u8>,
    window: Vec<u16>,
    idle: Vec<u8>,
    total: Vec<u32>,
}

impl SegCols {
    #[inline]
    fn grow_to(&mut self, idx: usize) {
        if idx >= self.tier.len() {
            self.tier.resize(idx + 1, 0);
            self.window.resize(idx + 1, 0);
            self.idle.resize(idx + 1, 0);
            self.total.resize(idx + 1, 0);
        }
    }

    #[inline]
    fn view(&self, idx: usize) -> PageMeta {
        PageMeta {
            tier: self.tier[idx],
            window_accesses: self.window[idx],
            idle_ticks: self.idle[idx],
            total_accesses: self.total[idx],
        }
    }

    #[inline]
    fn touch(&mut self, idx: usize) {
        self.window[idx] = self.window[idx].saturating_add(1);
        self.total[idx] = self.total[idx].saturating_add(1);
        self.idle[idx] = 0;
    }

    fn end_window(&mut self) {
        for i in 0..self.tier.len() {
            if self.tier[i] != 0 {
                self.window[i] = 0;
                self.idle[i] = self.idle[i].saturating_add(1);
            }
        }
    }
}

/// Two-segment flat struct-of-arrays page table.
#[derive(Debug)]
pub struct PageMap {
    page_shift: u32,
    heap: SegCols,
    mmap: SegCols,
}

impl PageMap {
    pub fn new(page_bytes: u64) -> PageMap {
        assert!(page_bytes.is_power_of_two());
        PageMap {
            page_shift: page_bytes.trailing_zeros(),
            heap: SegCols::default(),
            mmap: SegCols::default(),
        }
    }

    pub fn page_bytes(&self) -> u64 {
        1 << self.page_shift
    }

    /// Translate an address to its page number. Addresses outside both
    /// segments are a workload bug — panic in debug, clamp in release.
    #[inline]
    pub fn page_of(&self, addr: u64) -> PageNo {
        if addr >= MMAP_BASE {
            PageNo { segment: Segment::Mmap, index: ((addr - MMAP_BASE) >> self.page_shift) as u32 }
        } else {
            debug_assert!(addr >= HEAP_BASE, "address {addr:#x} below heap base");
            PageNo {
                segment: Segment::Heap,
                index: ((addr.saturating_sub(HEAP_BASE)) >> self.page_shift) as u32,
            }
        }
    }

    /// First byte address of a page.
    pub fn addr_of(&self, p: PageNo) -> u64 {
        let base = match p.segment {
            Segment::Heap => HEAP_BASE,
            Segment::Mmap => MMAP_BASE,
        };
        base + ((p.index as u64) << self.page_shift)
    }

    #[inline]
    fn seg(&self, segment: Segment) -> &SegCols {
        match segment {
            Segment::Heap => &self.heap,
            Segment::Mmap => &self.mmap,
        }
    }

    #[inline]
    fn seg_mut(&mut self, segment: Segment) -> &mut SegCols {
        match segment {
            Segment::Heap => &mut self.heap,
            Segment::Mmap => &mut self.mmap,
        }
    }

    /// Read-only view (unmapped default for untouched pages).
    pub fn get(&self, p: PageNo) -> PageMeta {
        let seg = self.seg(p.segment);
        let idx = p.index as usize;
        if idx < seg.tier.len() {
            seg.view(idx)
        } else {
            UNMAPPED
        }
    }

    /// Read-only tier lookup; never grows the table.
    #[inline]
    pub fn tier_of(&self, p: PageNo) -> Option<TierKind> {
        let seg = self.seg(p.segment);
        seg.tier.get(p.index as usize).copied().and_then(tier_from_code)
    }

    /// Map (or re-tier) a page, growing the table as needed.
    #[inline]
    pub fn set_tier(&mut self, p: PageNo, t: TierKind) {
        let idx = p.index as usize;
        let seg = self.seg_mut(p.segment);
        seg.grow_to(idx);
        seg.tier[idx] = tier_code(t);
    }

    /// Record one access to a page, growing the table as needed.
    #[inline]
    pub fn touch(&mut self, p: PageNo) {
        let idx = p.index as usize;
        let seg = self.seg_mut(p.segment);
        seg.grow_to(idx);
        seg.touch(idx);
    }

    /// Hot-path combined op: map on first touch (kernel first-touch
    /// default: DRAM) and record the access. Returns the page's tier and
    /// whether this access mapped it (caller charges tier capacity).
    #[inline]
    pub fn touch_and_map(&mut self, p: PageNo) -> (TierKind, bool) {
        let idx = p.index as usize;
        let seg = self.seg_mut(p.segment);
        seg.grow_to(idx);
        let (kind, was_unmapped) = match tier_from_code(seg.tier[idx]) {
            Some(k) => (k, false),
            None => {
                seg.tier[idx] = tier_code(TierKind::Dram);
                (TierKind::Dram, true)
            }
        };
        seg.touch(idx);
        (kind, was_unmapped)
    }

    /// Unmap a page, resetting all its counters.
    pub fn unmap(&mut self, p: PageNo) {
        let seg = self.seg_mut(p.segment);
        let idx = p.index as usize;
        if idx < seg.tier.len() {
            seg.tier[idx] = 0;
            seg.window[idx] = 0;
            seg.idle[idx] = 0;
            seg.total[idx] = 0;
        }
    }

    /// Close an aggregation window: clear window counters and age idle
    /// ticks for every mapped page — one linear sweep per segment.
    pub fn end_window(&mut self) {
        self.heap.end_window();
        self.mmap.end_window();
    }

    /// Iterate over all mapped pages (by-value views, page order).
    pub fn iter_mapped(&self) -> impl Iterator<Item = (PageNo, PageMeta)> + '_ {
        let heap = (0..self.heap.tier.len())
            .map(|i| (PageNo { segment: Segment::Heap, index: i as u32 }, self.heap.view(i)));
        let mmap = (0..self.mmap.tier.len())
            .map(|i| (PageNo { segment: Segment::Mmap, index: i as u32 }, self.mmap.view(i)));
        heap.chain(mmap).filter(|(_, m)| m.is_mapped())
    }

    pub fn mapped_count(&self) -> usize {
        let count = |seg: &SegCols| seg.tier.iter().filter(|&&t| t != 0).count();
        count(&self.heap) + count(&self.mmap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_translation_roundtrip() {
        let pm = PageMap::new(4096);
        for addr in [HEAP_BASE, HEAP_BASE + 4095, HEAP_BASE + 4096, MMAP_BASE, MMAP_BASE + 123456] {
            let p = pm.page_of(addr);
            let start = pm.addr_of(p);
            assert!(start <= addr && addr < start + 4096);
        }
    }

    #[test]
    fn segments_separate() {
        let pm = PageMap::new(4096);
        assert_eq!(pm.page_of(HEAP_BASE).segment, Segment::Heap);
        assert_eq!(pm.page_of(MMAP_BASE).segment, Segment::Mmap);
        assert_eq!(pm.page_of(HEAP_BASE).index, 0);
        assert_eq!(pm.page_of(MMAP_BASE + 8192).index, 2);
    }

    #[test]
    fn mutators_grow_and_track() {
        let mut pm = PageMap::new(4096);
        let p = pm.page_of(MMAP_BASE + 10 * 4096);
        assert!(!pm.get(p).is_mapped());
        pm.set_tier(p, TierKind::Cxl);
        pm.touch(p);
        let m = pm.get(p);
        assert_eq!(m.tier(), Some(TierKind::Cxl));
        assert_eq!(m.window_accesses, 1);
        assert_eq!(m.total_accesses, 1);
        assert_eq!(pm.mapped_count(), 1);
    }

    #[test]
    fn touch_and_map_defaults_to_dram_once() {
        let mut pm = PageMap::new(4096);
        let p = pm.page_of(MMAP_BASE);
        assert_eq!(pm.touch_and_map(p), (TierKind::Dram, true));
        assert_eq!(pm.touch_and_map(p), (TierKind::Dram, false));
        let m = pm.get(p);
        assert_eq!(m.window_accesses, 2);
        assert_eq!(m.total_accesses, 2);
        // An already-mapped CXL page keeps its tier.
        let q = pm.page_of(MMAP_BASE + 4096);
        pm.set_tier(q, TierKind::Cxl);
        assert_eq!(pm.touch_and_map(q), (TierKind::Cxl, false));
    }

    #[test]
    fn reads_never_grow_the_table() {
        let pm = PageMap::new(4096);
        let far = PageNo { segment: Segment::Mmap, index: 1_000_000 };
        assert_eq!(pm.tier_of(far), None);
        assert!(!pm.get(far).is_mapped());
        assert_eq!(pm.mapped_count(), 0);
    }

    #[test]
    fn unmap_clears_columns() {
        let mut pm = PageMap::new(4096);
        let p = pm.page_of(HEAP_BASE);
        pm.set_tier(p, TierKind::Dram);
        pm.touch(p);
        pm.unmap(p);
        assert!(!pm.get(p).is_mapped());
        assert_eq!(pm.get(p).total_accesses, 0);
        assert_eq!(pm.mapped_count(), 0);
    }

    #[test]
    fn end_window_sweeps_mapped_pages_only() {
        let mut pm = PageMap::new(4096);
        let p = pm.page_of(MMAP_BASE);
        pm.set_tier(p, TierKind::Dram);
        pm.touch(p);
        // Grow past p with unmapped slots; they must stay untouched.
        let far = pm.page_of(MMAP_BASE + 8 * 4096);
        assert_eq!(pm.tier_of(far), None);
        pm.end_window();
        let m = pm.get(p);
        assert_eq!(m.window_accesses, 0);
        assert_eq!(m.idle_ticks, 1);
        assert_eq!(m.total_accesses, 1);
    }

    #[test]
    fn touch_saturates() {
        let mut m = UNMAPPED;
        m.set_tier(TierKind::Dram);
        for _ in 0..100_000 {
            m.touch();
        }
        assert_eq!(m.window_accesses, u16::MAX);
        assert_eq!(m.total_accesses, 100_000);
    }

    #[test]
    fn unmap_resets() {
        let mut m = UNMAPPED;
        m.set_tier(TierKind::Dram);
        m.touch();
        m.unmap();
        assert!(!m.is_mapped());
        assert_eq!(m.total_accesses, 0);
    }
}
