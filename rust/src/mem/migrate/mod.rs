//! Epoch-driven page-migration engine with pluggable promotion policies.
//!
//! The paper's §4 promotion/demotion thread, generalized: the machine
//! ticks the engine at every aggregation interval; the engine folds that
//! tick's per-page access samples into a decayed [`PageHeat`] signal,
//! and every `epoch_ticks` ticks it closes an *epoch* — it hands the
//! heat map plus tier occupancy to a [`MigrationPolicy`], throttles the
//! returned plan to the per-epoch bandwidth budget, and issues the
//! survivors through `TieredMemory::migrate` (via the machine's
//! [`Migrator`] hook, which also charges copy stalls and tier
//! bandwidth).
//!
//! Three policies ship, spanning the design space the paper positions
//! against:
//! * [`naive::NaiveThreshold`] — flat hot-threshold promotion + idle
//!   demotion under a free-DRAM watermark (the repo's original
//!   `TppMigrator` behaviour, refactored behind the trait);
//! * [`tpp::TppLists`] — TPP-style (arXiv 2206.02878) active/inactive
//!   lists: promotion on the second sample within an epoch, demotion of
//!   inactive pages between low/high free watermarks;
//! * [`hybrid::HybridTier`] — HybridTier-style (arXiv 2312.04789) log₂
//!   frequency buckets with a promotion threshold that adapts to DRAM
//!   occupancy.

pub mod hybrid;
pub mod naive;
pub mod tpp;

use crate::config::MigrationConfig;
use crate::mem::page::PageNo;
use crate::mem::soa::PageCol;
use crate::mem::tier::TierKind;
use crate::mem::tiered::{Migration, TieredMemory};
use crate::monitor::heatmap::PageHeat;
use crate::sim::machine::Migrator;

pub use hybrid::HybridTier;
pub use naive::NaiveThreshold;
pub use tpp::TppLists;

/// What a policy sees at an epoch boundary.
pub struct EpochView<'a> {
    /// Epochs completed before this one.
    pub epoch: u64,
    pub mem: &'a TieredMemory,
    /// Decayed per-page hotness accumulated from access samples.
    pub heat: &'a PageHeat,
    /// Pages the engine will move at most this epoch; policies should
    /// order plans most-valuable-first since the excess is deferred.
    pub budget_pages: usize,
}

impl EpochView<'_> {
    /// Free-DRAM fraction, the demotion-watermark signal.
    pub fn dram_free_frac(&self) -> f64 {
        let t = self.mem.tier(TierKind::Dram);
        t.free_bytes() as f64 / t.params.capacity.max(1) as f64
    }
}

/// A promotion/demotion planner evaluated once per epoch.
pub trait MigrationPolicy {
    fn name(&self) -> &'static str;

    /// Plan this epoch's migrations, most-valuable first.
    fn plan(&mut self, view: &EpochView) -> Vec<Migration>;

    /// Invocation boundary: drop any cross-epoch state (active lists,
    /// bucket history). Policies without state keep the default no-op.
    fn reset(&mut self) {}
}

/// Lifetime counters of one engine (one invocation). Apart from
/// `epochs`/`deferred` (plan-time), every counter is fed by
/// [`Migrator::note_applied`] — i.e. from the moves the machine actually
/// applied, never from plans.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationMetrics {
    /// Epochs closed.
    pub epochs: u64,
    /// Applied CXL→DRAM moves.
    pub promotions: u64,
    /// Applied DRAM→CXL moves.
    pub demotions: u64,
    /// Pages re-migrated within the ping-pong window.
    pub ping_pongs: u64,
    /// Plan entries dropped by the bandwidth budget.
    pub deferred: u64,
    /// Bytes actually copied between tiers.
    pub migrated_bytes: u64,
}

/// The engine: heat ingestion + epoch cadence + budget throttle around a
/// boxed policy. Plugs into [`crate::sim::Machine::set_migrator`].
pub struct MigrationEngine {
    policy: Box<dyn MigrationPolicy>,
    heat: PageHeat,
    epoch_ticks: u32,
    ticks_into_epoch: u32,
    budget_bytes: u64,
    ping_pong_epochs: u64,
    /// Epoch of each page's most recent applied move (dense column;
    /// `u64::MAX` = never moved).
    last_move: PageCol<u64>,
    metrics: MigrationMetrics,
    /// Epoch/page size of the most recent plan, for `note_applied`.
    last_plan_epoch: u64,
    last_page_bytes: u64,
}

impl MigrationEngine {
    pub fn new(policy: Box<dyn MigrationPolicy>, epoch_ticks: u32, budget_bytes: u64) -> Self {
        assert!(epoch_ticks >= 1);
        MigrationEngine {
            policy,
            heat: PageHeat::new(),
            epoch_ticks,
            ticks_into_epoch: 0,
            budget_bytes,
            ping_pong_epochs: 2,
            last_move: PageCol::new(u64::MAX),
            metrics: MigrationMetrics::default(),
            last_plan_epoch: 0,
            last_page_bytes: 0,
        }
    }

    /// Build the configured engine, or `None` when the config disables
    /// migration (`enabled = false` or `policy = "none"`).
    pub fn from_config(cfg: &MigrationConfig) -> Option<MigrationEngine> {
        if !cfg.enabled {
            return None;
        }
        let policy: Box<dyn MigrationPolicy> = match cfg.policy.as_str() {
            "naive" => Box::new(NaiveThreshold::from_config(cfg)),
            "tpp" => Box::new(TppLists::from_config(cfg)),
            "hybrid" => Box::new(HybridTier::from_config(cfg)),
            _ => return None, // "none" (validation rejects other strings)
        };
        let mut engine = MigrationEngine::new(policy, cfg.epoch_ticks, cfg.budget_bytes);
        engine.ping_pong_epochs = cfg.ping_pong_epochs;
        Some(engine)
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Invocation boundary: clear hotness, move history, and counters
    /// so nothing leaks into the next run on the same server.
    pub fn reset(&mut self) {
        self.heat.reset();
        self.last_move.clear();
        self.policy.reset();
        self.ticks_into_epoch = 0;
        self.metrics = MigrationMetrics::default();
        self.last_plan_epoch = 0;
    }
}

impl Migrator for MigrationEngine {
    fn plan(&mut self, mem: &TieredMemory) -> Vec<Migration> {
        // ingest this tick's per-page access samples (the machine resets
        // window counters right after the migration pass)
        for (p, m) in mem.pages.iter_mapped() {
            if m.window_accesses > 0 {
                self.heat.record(p, m.window_accesses as u32);
            }
        }
        self.ticks_into_epoch += 1;
        if self.ticks_into_epoch < self.epoch_ticks {
            return Vec::new();
        }
        self.ticks_into_epoch = 0;

        let page_bytes = mem.page_bytes().max(1);
        let budget_pages = ((self.budget_bytes / page_bytes) as usize).max(1);
        let epoch = self.heat.epoch();
        let mut plan = {
            let view = EpochView { epoch, mem, heat: &self.heat, budget_pages };
            self.policy.plan(&view)
        };
        if plan.len() > budget_pages {
            self.metrics.deferred += (plan.len() - budget_pages) as u64;
            plan.truncate(budget_pages);
        }
        // drop entries `TieredMemory::migrate` would reject, simulating
        // the machine's in-order application (a demotion frees room for
        // a later promotion in the same plan) — hygiene only, so the
        // bandwidth budget and copy stalls are not wasted on no-ops;
        // the *counters* come from note_applied, never from the plan
        let mut free = [
            mem.tier(TierKind::Dram).free_bytes(),
            mem.tier(TierKind::Cxl).free_bytes(),
        ];
        let mut seen: std::collections::HashSet<PageNo> = std::collections::HashSet::new();
        plan.retain(|m| {
            let valid = m.from != m.to
                && seen.insert(m.page)
                && mem.pages.tier_of(m.page) == Some(m.from)
                && free[m.to.index()] >= page_bytes;
            if valid {
                free[m.to.index()] -= page_bytes;
                free[m.from.index()] += page_bytes;
            }
            valid
        });
        self.last_plan_epoch = epoch;
        self.last_page_bytes = page_bytes;
        self.metrics.epochs += 1;
        self.heat.roll_epoch();
        plan
    }

    /// Count exactly what the machine applied (ground truth — plans can
    /// still be rejected by rules this engine does not model).
    fn note_applied(&mut self, applied: &[Migration]) {
        let epoch = self.last_plan_epoch;
        for m in applied {
            match (m.from, m.to) {
                (TierKind::Cxl, TierKind::Dram) => self.metrics.promotions += 1,
                (TierKind::Dram, TierKind::Cxl) => self.metrics.demotions += 1,
                _ => {}
            }
            let prev = self.last_move.get(m.page);
            if prev != u64::MAX && epoch.saturating_sub(prev) <= self.ping_pong_epochs {
                self.metrics.ping_pongs += 1;
            }
            self.last_move.set(m.page, epoch);
            self.metrics.migrated_bytes += self.last_page_bytes;
        }
    }

    fn name(&self) -> &str {
        self.policy.name()
    }

    fn metrics(&self) -> Option<MigrationMetrics> {
        Some(self.metrics)
    }
}

/// Shared helper: demotion candidates, coldest first. Returns DRAM pages
/// whose current-epoch samples are zero, sorted by ascending decayed
/// heat (ties: higher page-table idle_ticks first).
pub(crate) fn cold_dram_pages(view: &EpochView) -> Vec<(PageNo, f64)> {
    let mut cold: Vec<(PageNo, f64, u8)> = view
        .mem
        .pages
        .iter_mapped()
        .filter(|(p, m)| {
            m.tier() == Some(TierKind::Dram) && view.heat.epoch_samples(*p) == 0
        })
        .map(|(p, m)| (p, view.heat.heat(p), m.idle_ticks))
        .collect();
    cold.sort_by(|a, b| {
        a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal).then(b.2.cmp(&a.2))
    });
    cold.into_iter().map(|(p, h, _)| (p, h)).collect()
}

/// Shared helper: watermark-reserving promotion plan. Walks `candidates`
/// (already sorted most-valuable first) and promotes to DRAM while its
/// free space stays above the `watermark_low` reserve.
pub(crate) fn promote_above_watermark(
    view: &EpochView,
    candidates: impl IntoIterator<Item = PageNo>,
    watermark_low: f64,
) -> Vec<Migration> {
    let page_bytes = view.mem.page_bytes().max(1);
    let dram = view.mem.tier(TierKind::Dram);
    let reserve = (dram.params.capacity as f64 * watermark_low) as u64;
    let mut dram_free = dram.free_bytes();
    let mut moves = Vec::new();
    for page in candidates {
        if dram_free < page_bytes + reserve {
            break;
        }
        moves.push(Migration { page, from: TierKind::Cxl, to: TierKind::Dram });
        dram_free -= page_bytes;
    }
    moves
}

/// Shared helper: how many pages must leave DRAM to lift the free
/// fraction to `target_free`, given the current view.
pub(crate) fn pages_to_free(view: &EpochView, target_free: f64) -> usize {
    let t = view.mem.tier(TierKind::Dram);
    let want_free = (t.params.capacity as f64 * target_free) as u64;
    let have_free = t.free_bytes();
    if have_free >= want_free {
        0
    } else {
        ((want_free - have_free) / view.mem.page_bytes().max(1)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::mem::tiered::FixedPlacer;
    use crate::shim::object::{MemoryObject, ObjectId};

    fn cfg(dram_pages: u64) -> MachineConfig {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = dram_pages * cfg.page_bytes;
        cfg.cxl_bytes = 1 << 30;
        cfg
    }

    fn obj(start: u64, pages: u64, page_bytes: u64) -> MemoryObject {
        MemoryObject {
            id: ObjectId(0),
            start,
            bytes: pages * page_bytes,
            site: "t".into(),
            seq: 0,
            via_mmap: true,
        }
    }

    fn touch(mem: &mut TieredMemory, page: PageNo, times: u32) {
        for _ in 0..times {
            mem.pages.touch(page);
        }
    }

    /// Trivial policy for engine-mechanics tests: promote every CXL page
    /// that has any heat.
    struct PromoteHot;

    impl MigrationPolicy for PromoteHot {
        fn name(&self) -> &'static str {
            "promote-hot"
        }

        fn plan(&mut self, view: &EpochView) -> Vec<Migration> {
            let mut hot: Vec<(PageNo, f64)> = view
                .mem
                .pages
                .iter_mapped()
                .filter(|(p, m)| m.tier() == Some(TierKind::Cxl) && view.heat.heat(*p) > 0.0)
                .map(|(p, _)| (p, view.heat.heat(p)))
                .collect();
            hot.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            hot.into_iter()
                .map(|(page, _)| Migration { page, from: TierKind::Cxl, to: TierKind::Dram })
                .collect()
        }
    }

    #[test]
    fn engine_waits_for_epoch_boundary() {
        let c = cfg(64);
        let mut mem = TieredMemory::new(&c);
        let o = obj(crate::shim::intercept::MMAP_BASE, 4, c.page_bytes);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        let p0 = mem.pages.page_of(o.start);
        let mut eng = MigrationEngine::new(Box::new(PromoteHot), 3, 1 << 30);
        touch(&mut mem, p0, 5);
        assert!(eng.plan(&mem).is_empty(), "tick 1 of 3: no epoch yet");
        assert!(eng.plan(&mem).is_empty(), "tick 2 of 3: no epoch yet");
        let plan = eng.plan(&mem);
        assert_eq!(plan.len(), 1, "epoch boundary must produce the promotion");
        assert_eq!(plan[0].page, p0);
        eng.note_applied(&plan);
        let m = Migrator::metrics(&eng).unwrap();
        assert_eq!(m.epochs, 1);
        assert_eq!(m.promotions, 1);
        assert_eq!(m.demotions, 0);
    }

    #[test]
    fn engine_throttles_to_budget_and_counts_deferred() {
        let c = cfg(1024);
        let mut mem = TieredMemory::new(&c);
        let o = obj(crate::shim::intercept::MMAP_BASE, 16, c.page_bytes);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        let first = mem.pages.page_of(o.start);
        for i in 0..16u32 {
            touch(&mut mem, PageNo { index: first.index + i, ..first }, 3);
        }
        // budget: 4 pages per epoch
        let mut eng = MigrationEngine::new(Box::new(PromoteHot), 1, 4 * c.page_bytes);
        let plan = eng.plan(&mem);
        assert_eq!(plan.len(), 4, "plan must be truncated to the budget");
        eng.note_applied(&plan);
        let m = Migrator::metrics(&eng).unwrap();
        assert_eq!(m.deferred, 12);
        assert_eq!(m.promotions, 4);
        assert_eq!(m.migrated_bytes, 4 * c.page_bytes);
    }

    #[test]
    fn engine_counts_ping_pongs() {
        let c = cfg(64);
        let mut mem = TieredMemory::new(&c);
        let o = obj(crate::shim::intercept::MMAP_BASE, 1, c.page_bytes);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        let p0 = mem.pages.page_of(o.start);
        let mut eng = MigrationEngine::new(Box::new(PromoteHot), 1, 1 << 30);
        touch(&mut mem, p0, 3);
        let plan = eng.plan(&mem);
        assert_eq!(plan.len(), 1);
        // apply, then push it back to CXL as if demoted elsewhere, and
        // heat it again: the second applied move is a ping-pong
        assert!(mem.migrate(plan[0]));
        eng.note_applied(&plan);
        assert!(mem.migrate(Migration { page: p0, from: TierKind::Dram, to: TierKind::Cxl }));
        mem.end_window();
        touch(&mut mem, p0, 3);
        let plan = eng.plan(&mem);
        assert_eq!(plan.len(), 1);
        assert!(mem.migrate(plan[0]));
        eng.note_applied(&plan);
        let m = Migrator::metrics(&eng).unwrap();
        assert_eq!(m.ping_pongs, 1, "re-migration within the window is a ping-pong");
    }

    /// Plans demotion of every DRAM page, valid or not.
    struct DemoteAll;

    impl MigrationPolicy for DemoteAll {
        fn name(&self) -> &'static str {
            "demote-all"
        }

        fn plan(&mut self, view: &EpochView) -> Vec<Migration> {
            view.mem
                .pages
                .iter_mapped()
                .filter(|(_, m)| m.tier() == Some(TierKind::Dram))
                .map(|(page, _)| Migration { page, from: TierKind::Dram, to: TierKind::Cxl })
                .collect()
        }
    }

    #[test]
    fn engine_drops_moves_the_memory_would_reject() {
        // CXL has zero capacity: every planned demotion is unappliable
        // and must not reach the plan or the counters
        let mut c = cfg(8);
        c.cxl_bytes = 0;
        let mut mem = TieredMemory::new(&c);
        let o = obj(crate::shim::intercept::MMAP_BASE, 4, c.page_bytes);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        let mut eng = MigrationEngine::new(Box::new(DemoteAll), 1, 1 << 30);
        assert!(eng.plan(&mem).is_empty(), "unappliable moves must be filtered out");
        let m = Migrator::metrics(&eng).unwrap();
        assert_eq!(m.demotions, 0, "rejected moves must not count");
        assert_eq!(m.ping_pongs, 0);
        assert_eq!(m.migrated_bytes, 0);
        assert_eq!(m.epochs, 1);
    }

    #[test]
    fn engine_reset_drops_history() {
        let c = cfg(64);
        let mut mem = TieredMemory::new(&c);
        let o = obj(crate::shim::intercept::MMAP_BASE, 2, c.page_bytes);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        let p0 = mem.pages.page_of(o.start);
        let mut eng = MigrationEngine::new(Box::new(PromoteHot), 1, 1 << 30);
        touch(&mut mem, p0, 9);
        assert_eq!(eng.plan(&mem).len(), 1);
        eng.reset();
        mem.end_window();
        // after reset no residual heat: an idle tick plans nothing
        assert!(eng.plan(&mem).is_empty(), "stale heat must not survive reset");
    }

    #[test]
    fn from_config_respects_policy_and_switch() {
        let mut mc = crate::config::MigrationConfig::default();
        assert_eq!(MigrationEngine::from_config(&mc).unwrap().policy_name(), "tpp");
        mc.policy = "hybrid".into();
        assert_eq!(MigrationEngine::from_config(&mc).unwrap().policy_name(), "hybrid");
        mc.policy = "naive".into();
        assert_eq!(MigrationEngine::from_config(&mc).unwrap().policy_name(), "naive");
        mc.policy = "none".into();
        assert!(MigrationEngine::from_config(&mc).is_none());
        mc.policy = "tpp".into();
        mc.enabled = false;
        assert!(MigrationEngine::from_config(&mc).is_none());
    }
}
