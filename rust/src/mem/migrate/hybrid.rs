//! HybridTier-style policy (arXiv 2312.04789): frequency buckets with a
//! promotion threshold that adapts to DRAM occupancy.
//!
//! Every mapped page is dropped into a log₂ bucket of its decayed heat
//! (`bucket = floor(log2(1 + heat))`, clamped to `buckets`). The policy
//! then picks the *promotion threshold bucket* from current DRAM
//! occupancy relative to `target_occupancy`:
//!
//! * plenty of headroom (occupancy < ½·target) → threshold at the base
//!   bucket: even mildly warm pages promote;
//! * nearing the target → threshold climbs one bucket, so only clearly
//!   hot pages move;
//! * past the target → threshold climbs two buckets *and* the coldest
//!   DRAM buckets demote until occupancy is back at the target.
//!
//! The result is frequency-aware bidirectional flow: hot CXL pages
//! displace cold DRAM pages instead of promotions simply stopping when
//! DRAM fills (the failure mode of the naive threshold).

use crate::config::MigrationConfig;
use crate::mem::migrate::{EpochView, MigrationPolicy};
use crate::mem::page::PageNo;
use crate::mem::tier::TierKind;
use crate::mem::tiered::Migration;

pub struct HybridTier {
    /// Number of log₂ heat buckets.
    pub buckets: usize,
    /// DRAM occupancy the policy steers toward.
    pub target_occupancy: f64,
    /// Minimum heat (bucket floor) for any promotion.
    pub base_heat: f64,
}

impl HybridTier {
    pub fn new(buckets: usize, target_occupancy: f64, base_heat: f64) -> HybridTier {
        HybridTier { buckets: buckets.max(2), target_occupancy, base_heat }
    }

    pub fn from_config(cfg: &MigrationConfig) -> HybridTier {
        HybridTier::new(cfg.buckets, cfg.target_occupancy, cfg.promote_heat)
    }

    fn bucket_of(&self, heat: f64) -> usize {
        ((1.0 + heat.max(0.0)).log2() as usize).min(self.buckets - 1)
    }

    /// The promotion threshold bucket for the current occupancy.
    fn threshold_bucket(&self, occupancy: f64) -> usize {
        let base = self.bucket_of(self.base_heat);
        let extra = if occupancy >= self.target_occupancy {
            2
        } else if occupancy >= 0.5 * self.target_occupancy {
            1
        } else {
            0
        };
        (base + extra).min(self.buckets - 1)
    }
}

impl MigrationPolicy for HybridTier {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn plan(&mut self, view: &EpochView) -> Vec<Migration> {
        let mem = view.mem;
        let page_bytes = mem.page_bytes().max(1);
        let dram = mem.tier(TierKind::Dram);
        let capacity = dram.params.capacity.max(1);
        let occupancy = dram.occupancy();
        let thr = self.threshold_bucket(occupancy);

        // bucketize both tiers
        let mut cxl_hot: Vec<(PageNo, usize, f64)> = Vec::new();
        let mut dram_by_bucket: Vec<Vec<PageNo>> = vec![Vec::new(); self.buckets];
        for (p, m) in mem.pages.iter_mapped() {
            let heat = view.heat.heat(p);
            let b = self.bucket_of(heat);
            match m.tier() {
                Some(TierKind::Cxl) => {
                    if b >= thr && heat >= self.base_heat {
                        cxl_hot.push((p, b, heat));
                    }
                }
                Some(TierKind::Dram) => {
                    if view.heat.epoch_samples(p) == 0 {
                        dram_by_bucket[b].push(p);
                    }
                }
                None => {}
            }
        }

        // promotions: hottest buckets first; demotions are sized so
        // that used + promotions - demotions lands on the target
        // occupancy — hot CXL pages *displace* cold DRAM pages instead
        // of promotions stalling once DRAM fills
        cxl_hot.sort_by(|a, b| {
            b.1.cmp(&a.1).then(b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal))
        });
        let target_bytes = (capacity as f64 * self.target_occupancy) as u64;
        let used = dram.used_bytes;
        let promo_wanted = cxl_hot.len().min(view.budget_pages);

        // drain the coldest buckets until the target holds even after
        // the promotions land
        let mut demotions: Vec<Migration> = Vec::new();
        let projected = used + promo_wanted as u64 * page_bytes;
        if projected > target_bytes {
            let mut need = ((projected - target_bytes) / page_bytes) as usize;
            'drain: for bucket in dram_by_bucket.iter() {
                for &p in bucket {
                    if need == 0 {
                        break 'drain;
                    }
                    demotions.push(Migration { page: p, from: TierKind::Dram, to: TierKind::Cxl });
                    need -= 1;
                }
            }
        }
        let freed = demotions.len() as u64 * page_bytes;

        let headroom = target_bytes.saturating_sub(used.saturating_sub(freed));
        // hard floor: never plan promotions past physical free space
        // plus what this epoch's demotions release
        let physically_free = ((dram.free_bytes() + freed) / page_bytes) as usize;
        let promo_budget =
            ((headroom / page_bytes) as usize).min(physically_free).min(promo_wanted);
        let promotions = cxl_hot
            .into_iter()
            .take(promo_budget)
            .map(|(page, _, _)| Migration { page, from: TierKind::Cxl, to: TierKind::Dram });

        // interleave demote/promote pairs so the engine's head-first
        // budget truncation keeps the displacement balanced: any prefix
        // of the plan carries (roughly) one freed slot per promotion,
        // instead of a tiny budget draining DRAM without promoting
        let mut moves = Vec::with_capacity(demotions.len() + promo_budget);
        let mut demotions = demotions.into_iter();
        let mut promotions = promotions;
        loop {
            match (demotions.next(), promotions.next()) {
                (None, None) => break,
                (d, p) => {
                    moves.extend(d);
                    moves.extend(p);
                }
            }
        }
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::mem::tiered::{FixedPlacer, TieredMemory};
    use crate::monitor::heatmap::PageHeat;
    use crate::shim::object::{MemoryObject, ObjectId};

    fn mem_with(dram_pages: u64, cxl_pages: u64, dram_obj_pages: u64) -> TieredMemory {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = dram_pages * cfg.page_bytes;
        cfg.cxl_bytes = 1 << 30;
        let mut mem = TieredMemory::new(&cfg);
        if cxl_pages > 0 {
            let o = MemoryObject {
                id: ObjectId(0),
                start: crate::shim::intercept::MMAP_BASE,
                bytes: cxl_pages * cfg.page_bytes,
                site: "c".into(),
                seq: 0,
                via_mmap: true,
            };
            mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        }
        if dram_obj_pages > 0 {
            let o = MemoryObject {
                id: ObjectId(1),
                start: crate::shim::intercept::MMAP_BASE + (1 << 24),
                bytes: dram_obj_pages * cfg.page_bytes,
                site: "d".into(),
                seq: 1,
                via_mmap: true,
            };
            mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        }
        mem
    }

    #[test]
    fn threshold_adapts_to_occupancy() {
        let pol = HybridTier::new(8, 0.8, 3.0);
        let base = pol.bucket_of(3.0);
        assert_eq!(pol.threshold_bucket(0.1), base, "empty DRAM: base threshold");
        assert_eq!(pol.threshold_bucket(0.5), base + 1, "half-way to target: one up");
        assert_eq!(pol.threshold_bucket(0.9), base + 2, "past target: two up");
    }

    #[test]
    fn empty_dram_promotes_warm_pages() {
        let mem = mem_with(100, 4, 0);
        let first = mem.pages.page_of(crate::shim::intercept::MMAP_BASE);
        let mut heat = PageHeat::new();
        heat.record(first, 8);
        heat.record(PageNo { index: first.index + 1, ..first }, 4);
        let mut pol = HybridTier::new(8, 0.9, 3.0);
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        let plan = pol.plan(&view);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].page, first, "hottest page promotes first");
        assert!(plan.iter().all(|m| m.to == TierKind::Dram));
    }

    #[test]
    fn past_target_demotes_cold_and_still_promotes_hot() {
        // DRAM 10 pages, 10 resident cold pages (past the 0.8 target);
        // one very hot CXL page should displace a cold page
        let mem = mem_with(10, 1, 10);
        let cxl_page = mem.pages.page_of(crate::shim::intercept::MMAP_BASE);
        let mut heat = PageHeat::new();
        heat.record(cxl_page, 200); // bucket ~7, above any threshold
        let mut pol = HybridTier::new(8, 0.8, 3.0);
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        let plan = pol.plan(&view);
        let demotions: Vec<_> = plan.iter().filter(|m| m.to == TierKind::Cxl).collect();
        let promotions: Vec<_> = plan.iter().filter(|m| m.to == TierKind::Dram).collect();
        // drain to the 80% target with room for the incoming promotion:
        // 10 used + 1 promoted - 3 demoted = 8 = target
        assert_eq!(demotions.len(), 3);
        assert_eq!(promotions.len(), 1);
        assert_eq!(promotions[0].page, cxl_page);
    }

    #[test]
    fn lukewarm_pages_blocked_when_dram_tight() {
        // occupancy at 100%: threshold climbs two buckets above base, so
        // a heat-4 page (bucket 2) no longer qualifies
        let mem = mem_with(4, 1, 4);
        let cxl_page = mem.pages.page_of(crate::shim::intercept::MMAP_BASE);
        let mut heat = PageHeat::new();
        heat.record(cxl_page, 4);
        // DRAM pages are all active (sampled) → no demotion candidates
        let dram_first = mem.pages.page_of(crate::shim::intercept::MMAP_BASE + (1 << 24));
        for i in 0..4u32 {
            heat.record(PageNo { index: dram_first.index + i, ..dram_first }, 2);
        }
        let mut pol = HybridTier::new(8, 0.8, 3.0);
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        assert!(
            pol.plan(&view).is_empty(),
            "tight DRAM must raise the bar past lukewarm pages"
        );
    }
}
