//! The flat hot-threshold policy — the repo's original reactive
//! migrator (`placement::policies::TppMigrator`) refactored behind
//! [`MigrationPolicy`]: promote any CXL page whose decayed heat clears a
//! fixed threshold, demote idle DRAM pages when free DRAM falls below
//! the watermark. No adaptivity — the baseline the smarter policies are
//! swept against.

use crate::config::MigrationConfig;
use crate::mem::migrate::{
    cold_dram_pages, pages_to_free, promote_above_watermark, EpochView, MigrationPolicy,
};
use crate::mem::page::PageNo;
use crate::mem::tier::TierKind;
use crate::mem::tiered::Migration;

pub struct NaiveThreshold {
    /// Decayed heat a CXL page needs to be promoted.
    pub promote_heat: f64,
    /// Free-DRAM fraction below which idle pages are demoted...
    pub watermark_low: f64,
    /// ...until this free fraction is restored.
    pub watermark_high: f64,
}

impl NaiveThreshold {
    pub fn from_config(cfg: &MigrationConfig) -> NaiveThreshold {
        NaiveThreshold {
            promote_heat: cfg.promote_heat,
            watermark_low: cfg.watermark_low,
            watermark_high: cfg.watermark_high,
        }
    }
}

impl MigrationPolicy for NaiveThreshold {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn plan(&mut self, view: &EpochView) -> Vec<Migration> {
        // promotion scan: hot CXL pages, hottest first, while DRAM has
        // room above the low watermark
        let mut hot: Vec<(PageNo, f64)> = view
            .mem
            .pages
            .iter_mapped()
            .filter(|(p, m)| {
                m.tier() == Some(TierKind::Cxl) && view.heat.heat(*p) >= self.promote_heat
            })
            .map(|(p, _)| (p, view.heat.heat(p)))
            .collect();
        hot.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        let mut moves =
            promote_above_watermark(view, hot.into_iter().map(|(p, _)| p), self.watermark_low);

        // demotion scan: restore the high watermark with the coldest
        // idle pages
        if view.dram_free_frac() < self.watermark_low {
            let need = pages_to_free(view, self.watermark_high);
            for (page, _) in cold_dram_pages(view).into_iter().take(need) {
                moves.push(Migration { page, from: TierKind::Dram, to: TierKind::Cxl });
            }
        }
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::mem::tiered::{FixedPlacer, TieredMemory};
    use crate::monitor::heatmap::PageHeat;
    use crate::shim::object::{MemoryObject, ObjectId};

    fn setup(dram_pages: u64, cxl_obj_pages: u64, dram_obj_pages: u64) -> (TieredMemory, u64) {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = dram_pages * cfg.page_bytes;
        cfg.cxl_bytes = 1 << 30;
        let mut mem = TieredMemory::new(&cfg);
        if cxl_obj_pages > 0 {
            let o = MemoryObject {
                id: ObjectId(0),
                start: crate::shim::intercept::MMAP_BASE,
                bytes: cxl_obj_pages * cfg.page_bytes,
                site: "cxl".into(),
                seq: 0,
                via_mmap: true,
            };
            mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        }
        if dram_obj_pages > 0 {
            let o = MemoryObject {
                id: ObjectId(1),
                start: crate::shim::intercept::MMAP_BASE + (1 << 24),
                bytes: dram_obj_pages * cfg.page_bytes,
                site: "dram".into(),
                seq: 1,
                via_mmap: true,
            };
            mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        }
        (mem, cfg.page_bytes)
    }

    #[test]
    fn promotes_only_above_threshold() {
        let (mem, _) = setup(100, 4, 0);
        let first = mem.pages.page_of(crate::shim::intercept::MMAP_BASE);
        let mut heat = PageHeat::new();
        heat.record(first, 10); // hot
        heat.record(PageNo { index: first.index + 1, ..first }, 1); // lukewarm
        let mut pol = NaiveThreshold { promote_heat: 4.0, watermark_low: 0.1, watermark_high: 0.2 };
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        let plan = pol.plan(&view);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].page, first);
        assert_eq!(plan[0].to, TierKind::Dram);
    }

    #[test]
    fn demotes_idle_pages_below_watermark() {
        // DRAM completely full of idle pages → demote toward the high
        // watermark
        let (mem, _) = setup(10, 0, 10);
        let heat = PageHeat::new();
        let mut pol = NaiveThreshold { promote_heat: 4.0, watermark_low: 0.2, watermark_high: 0.4 };
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        let plan = pol.plan(&view);
        assert_eq!(plan.len(), 4, "restore 40% free of a 10-page DRAM");
        assert!(plan.iter().all(|m| m.to == TierKind::Cxl));
    }

    #[test]
    fn hot_dram_pages_are_never_demoted() {
        let (mem, _) = setup(4, 0, 4);
        let first = mem.pages.page_of(crate::shim::intercept::MMAP_BASE + (1 << 24));
        let mut heat = PageHeat::new();
        // every DRAM page sampled this epoch → no demotion candidates
        for i in 0..4u32 {
            heat.record(PageNo { index: first.index + i, ..first }, 5);
        }
        let mut pol = NaiveThreshold { promote_heat: 4.0, watermark_low: 0.5, watermark_high: 0.9 };
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        assert!(pol.plan(&view).is_empty());
    }
}
