//! TPP-style policy (Transparent Page Placement, arXiv 2206.02878):
//! active/inactive page lists with demotion watermarks.
//!
//! * A page that takes samples in an epoch enters (or refreshes) the
//!   **active list**; a page unsampled for `active_epochs` epochs falls
//!   to **inactive**.
//! * **Promotion** mirrors TPP's NUMA-hint-fault filter: a CXL page is
//!   promoted only once it takes `promote_samples`+ samples within a
//!   single epoch (one-off touches stay in CXL), hottest first, while
//!   DRAM stays above the low watermark.
//! * **Demotion** runs only when free DRAM drops below `watermark_low`,
//!   and pushes *inactive* pages out (oldest activity first) until
//!   `watermark_high` free is restored — TPP's kswapd-style watermark
//!   reclaim, never touching the active list.

use crate::config::MigrationConfig;
use crate::mem::migrate::{pages_to_free, promote_above_watermark, EpochView, MigrationPolicy};
use crate::mem::page::PageNo;
use crate::mem::soa::PageCol;
use crate::mem::tier::TierKind;
use crate::mem::tiered::Migration;

/// Sentinel for "never sampled" in the dense active-list column.
const NEVER: u64 = u64::MAX;

pub struct TppLists {
    /// Samples within one epoch that qualify a CXL page for promotion.
    pub promote_samples: u32,
    /// Epochs without a sample before an active page turns inactive.
    pub active_epochs: u64,
    pub watermark_low: f64,
    pub watermark_high: f64,
    /// Epoch of each page's last observed sample (the active list; pages
    /// older than `active_epochs` are the inactive list). Dense column,
    /// [`NEVER`] = never sampled.
    last_active: PageCol<u64>,
}

impl TppLists {
    pub fn new(promote_samples: u32, active_epochs: u64, low: f64, high: f64) -> TppLists {
        TppLists {
            promote_samples: promote_samples.max(1),
            active_epochs: active_epochs.max(1),
            watermark_low: low,
            watermark_high: high,
            last_active: PageCol::new(NEVER),
        }
    }

    pub fn from_config(cfg: &MigrationConfig) -> TppLists {
        TppLists::new(
            cfg.promote_samples,
            cfg.active_epochs as u64,
            cfg.watermark_low,
            cfg.watermark_high,
        )
    }

    /// Pages on the active list as of `epoch` (test/introspection hook).
    pub fn active_len(&self, epoch: u64) -> usize {
        self.last_active
            .iter()
            .filter(|&(_, e)| e != NEVER && epoch.saturating_sub(e) < self.active_epochs)
            .count()
    }
}

impl MigrationPolicy for TppLists {
    fn name(&self) -> &'static str {
        "tpp"
    }

    fn plan(&mut self, view: &EpochView) -> Vec<Migration> {
        let epoch = view.epoch;
        // 1. refresh the active list from this epoch's samples
        for (p, m) in view.mem.pages.iter_mapped() {
            if m.is_mapped() && view.heat.epoch_samples(p) > 0 {
                self.last_active.set(p, epoch);
            }
        }
        // expire entries long past inactive — one linear column sweep
        let horizon = self.active_epochs * 4 + 1;
        for e in self.last_active.values_mut() {
            if *e != NEVER && epoch.saturating_sub(*e) >= horizon {
                *e = NEVER;
            }
        }

        // 2. promotion: CXL pages with >= promote_samples this epoch,
        // hottest first, respecting the low watermark
        let mut hot: Vec<(PageNo, u32)> = view
            .mem
            .pages
            .iter_mapped()
            .filter(|(p, m)| {
                m.tier() == Some(TierKind::Cxl)
                    && view.heat.epoch_samples(*p) >= self.promote_samples
            })
            .map(|(p, _)| (p, view.heat.epoch_samples(p)))
            .collect();
        hot.sort_by_key(|&(_, s)| std::cmp::Reverse(s));
        let mut moves =
            promote_above_watermark(view, hot.into_iter().map(|(p, _)| p), self.watermark_low);

        // 3. demotion: below the low watermark, evict inactive DRAM
        // pages (oldest activity first) until the high watermark holds
        if view.dram_free_frac() < self.watermark_low {
            let need = pages_to_free(view, self.watermark_high);
            let mut inactive: Vec<(PageNo, u64)> = view
                .mem
                .pages
                .iter_mapped()
                .filter(|(p, m)| {
                    m.tier() == Some(TierKind::Dram) && view.heat.epoch_samples(*p) == 0
                })
                .filter(|(p, _)| {
                    match self.last_active.get(*p) {
                        NEVER => true, // never sampled: inactive by definition
                        e => epoch.saturating_sub(e) >= self.active_epochs,
                    }
                })
                .map(|(p, _)| {
                    // never-sampled sorts oldest (same as epoch 0)
                    let e = self.last_active.get(p);
                    (p, if e == NEVER { 0 } else { e })
                })
                .collect();
            inactive.sort_by_key(|&(_, e)| e);
            for (page, _) in inactive.into_iter().take(need) {
                moves.push(Migration { page, from: TierKind::Dram, to: TierKind::Cxl });
            }
        }
        moves
    }

    /// Drop the active list: a fresh invocation starts with no history.
    fn reset(&mut self) {
        self.last_active.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::mem::tiered::{FixedPlacer, TieredMemory};
    use crate::monitor::heatmap::PageHeat;
    use crate::shim::object::{MemoryObject, ObjectId};

    fn mem_with(dram_pages: u64, cxl_pages: u64, dram_obj_pages: u64) -> (TieredMemory, u64) {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = dram_pages * cfg.page_bytes;
        cfg.cxl_bytes = 1 << 30;
        let mut mem = TieredMemory::new(&cfg);
        if cxl_pages > 0 {
            let o = MemoryObject {
                id: ObjectId(0),
                start: crate::shim::intercept::MMAP_BASE,
                bytes: cxl_pages * cfg.page_bytes,
                site: "c".into(),
                seq: 0,
                via_mmap: true,
            };
            mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        }
        if dram_obj_pages > 0 {
            let o = MemoryObject {
                id: ObjectId(1),
                start: crate::shim::intercept::MMAP_BASE + (1 << 24),
                bytes: dram_obj_pages * cfg.page_bytes,
                site: "d".into(),
                seq: 1,
                via_mmap: true,
            };
            mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        }
        (mem, cfg.page_bytes)
    }

    #[test]
    fn single_touch_stays_in_cxl_second_touch_promotes() {
        let (mem, _) = mem_with(100, 2, 0);
        let p0 = mem.pages.page_of(crate::shim::intercept::MMAP_BASE);
        let mut pol = TppLists::new(2, 2, 0.05, 0.1);
        let mut heat = PageHeat::new();
        heat.record(p0, 1); // one sample: below the fault filter
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        assert!(pol.plan(&view).is_empty(), "one touch must not promote");
        heat.record(p0, 1); // second sample in the same epoch
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        let plan = pol.plan(&view);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].page, p0);
        assert_eq!(plan[0].to, TierKind::Dram);
    }

    #[test]
    fn demotes_only_inactive_pages() {
        // DRAM full: 4 pages, 2 active (sampled this epoch), 2 never
        // sampled → only the inactive ones may be demoted
        let (mem, _) = mem_with(4, 0, 4);
        let first = mem.pages.page_of(crate::shim::intercept::MMAP_BASE + (1 << 24));
        let mut heat = PageHeat::new();
        heat.record(first, 3);
        heat.record(PageNo { index: first.index + 1, ..first }, 3);
        let mut pol = TppLists::new(2, 2, 0.3, 0.6);
        let view = EpochView { epoch: 5, mem: &mem, heat: &heat, budget_pages: 64 };
        let plan = pol.plan(&view);
        assert!(!plan.is_empty(), "full DRAM must trigger demotion");
        for m in &plan {
            assert_eq!(m.to, TierKind::Cxl);
            assert!(
                m.page.index >= first.index + 2,
                "active page {:?} must not be demoted",
                m.page
            );
        }
    }

    #[test]
    fn active_list_expires_after_active_epochs() {
        let (mem, _) = mem_with(4, 0, 4);
        let first = mem.pages.page_of(crate::shim::intercept::MMAP_BASE + (1 << 24));
        let mut pol = TppLists::new(2, 2, 0.3, 0.6);
        // epoch 0: all four pages active
        let mut heat = PageHeat::new();
        for i in 0..4u32 {
            heat.record(PageNo { index: first.index + i, ..first }, 2);
        }
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        assert!(pol.plan(&view).is_empty(), "everything active: nothing to demote");
        assert_eq!(pol.active_len(0), 4);
        // two epochs later with no samples: the list has gone inactive
        heat.roll_epoch();
        heat.roll_epoch();
        let view = EpochView { epoch: 2, mem: &mem, heat: &heat, budget_pages: 64 };
        let plan = pol.plan(&view);
        assert!(!plan.is_empty(), "expired pages are demotable");
        assert!(plan.iter().all(|m| m.to == TierKind::Cxl));
    }

    #[test]
    fn reset_clears_the_active_list() {
        let (mem, _) = mem_with(4, 0, 4);
        let first = mem.pages.page_of(crate::shim::intercept::MMAP_BASE + (1 << 24));
        let mut pol = TppLists::new(2, 2, 0.3, 0.6);
        let mut heat = PageHeat::new();
        for i in 0..4u32 {
            heat.record(PageNo { index: first.index + i, ..first }, 2);
        }
        let view = EpochView { epoch: 0, mem: &mem, heat: &heat, budget_pages: 64 };
        pol.plan(&view);
        assert_eq!(pol.active_len(0), 4);
        pol.reset();
        assert_eq!(pol.active_len(0), 0, "reset must drop all activity history");
        // Without reset, entries recorded at a *later* epoch than the
        // engine's restarted epoch counter would look permanently active
        // (epoch.saturating_sub(e) == 0) — the latent bug the policy
        // reset hook fixes.
    }
}
