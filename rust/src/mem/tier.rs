//! Memory tiers: local DRAM and CXL-attached memory.

use crate::config::MachineConfig;

/// Which tier a page lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TierKind {
    /// Local DDR behind the socket's memory controller.
    Dram,
    /// CXL.mem expander: a CPU-less NUMA node reachable by load/store,
    /// with port/controller latency added on every access.
    Cxl,
}

impl TierKind {
    pub const ALL: [TierKind; 2] = [TierKind::Dram, TierKind::Cxl];

    pub fn index(self) -> usize {
        match self {
            TierKind::Dram => 0,
            TierKind::Cxl => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TierKind::Dram => "dram",
            TierKind::Cxl => "cxl",
        }
    }

    pub fn other(self) -> TierKind {
        match self {
            TierKind::Dram => TierKind::Cxl,
            TierKind::Cxl => TierKind::Dram,
        }
    }
}

/// Performance/capacity parameters of one tier.
#[derive(Debug, Clone, PartialEq)]
pub struct TierParams {
    pub kind: TierKind,
    /// Idle (uncontended) access latency for a cache-line fill.
    pub latency_ns: f64,
    /// Peak sustainable bandwidth.
    pub bw_gbps: f64,
    /// Capacity in bytes.
    pub capacity: u64,
}

impl TierParams {
    /// Build both tiers from the machine config.
    pub fn from_config(cfg: &MachineConfig) -> [TierParams; 2] {
        [
            TierParams {
                kind: TierKind::Dram,
                latency_ns: cfg.dram_latency_ns,
                bw_gbps: cfg.dram_bw_gbps,
                capacity: cfg.dram_bytes,
            },
            TierParams {
                kind: TierKind::Cxl,
                latency_ns: cfg.cxl_latency_ns,
                bw_gbps: cfg.cxl_bw_gbps,
                capacity: cfg.cxl_bytes,
            },
        ]
    }

    /// Time to transfer `bytes` at peak bandwidth, in ns.
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bw_gbps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_and_names() {
        assert_eq!(TierKind::Dram.index(), 0);
        assert_eq!(TierKind::Cxl.index(), 1);
        assert_eq!(TierKind::Dram.other(), TierKind::Cxl);
        assert_eq!(TierKind::Cxl.name(), "cxl");
    }

    #[test]
    fn from_config_matches() {
        let cfg = MachineConfig::default();
        let [dram, cxl] = TierParams::from_config(&cfg);
        assert_eq!(dram.kind, TierKind::Dram);
        assert!((cxl.latency_ns - dram.latency_ns - 70.0).abs() < 1e-9);
        assert!(cxl.capacity > dram.capacity);
    }

    #[test]
    fn transfer_time() {
        let t =
            TierParams { kind: TierKind::Dram, latency_ns: 90.0, bw_gbps: 64.0, capacity: 1 << 30 };
        // 64 bytes at 64 GB/s = 1 ns
        assert!((t.transfer_ns(64) - 1.0).abs() < 1e-9);
    }
}
