//! The tiered memory system: page tables + tier occupancy + placement +
//! migration. This is the "CXL-enabled tiered memory" the paper's
//! middleware manages.

use crate::config::MachineConfig;
use crate::mem::bwmodel::BandwidthModel;
use crate::mem::page::{PageMap, PageNo};
use crate::mem::tier::{TierKind, TierParams};
use crate::shim::object::MemoryObject;

/// Decides the tier for each page of a new allocation. Implementations
/// live in `placement::policies` (AllDram, AllCxl, static hints, Porter).
pub trait PagePlacer {
    /// `page_idx` is the page's 0-based index within the object.
    fn place(&mut self, obj: &MemoryObject, page_idx: u64, mem: &TieredMemory) -> TierKind;

    /// Human-readable policy name for reports.
    fn name(&self) -> &str;
}

/// A page movement between tiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Migration {
    pub page: PageNo,
    pub from: TierKind,
    pub to: TierKind,
}

/// Occupancy state of one tier.
#[derive(Debug, Clone)]
pub struct TierState {
    pub params: TierParams,
    pub used_bytes: u64,
    pub bw: BandwidthModel,
}

impl TierState {
    pub fn free_bytes(&self) -> u64 {
        self.params.capacity.saturating_sub(self.used_bytes)
    }

    pub fn occupancy(&self) -> f64 {
        self.used_bytes as f64 / self.params.capacity as f64
    }
}

/// Page table + two tiers.
#[derive(Debug)]
pub struct TieredMemory {
    pub pages: PageMap,
    tiers: [TierState; 2],
    page_bytes: u64,
    /// Lifetime migration counters (promotions = CXL→DRAM).
    pub promotions: u64,
    pub demotions: u64,
}

impl TieredMemory {
    pub fn new(cfg: &MachineConfig) -> TieredMemory {
        let params = TierParams::from_config(cfg);
        TieredMemory {
            pages: PageMap::new(cfg.page_bytes),
            tiers: params
                .map(|p| TierState { bw: BandwidthModel::new(&p), params: p, used_bytes: 0 }),
            page_bytes: cfg.page_bytes,
            promotions: 0,
            demotions: 0,
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn tier(&self, kind: TierKind) -> &TierState {
        &self.tiers[kind.index()]
    }

    pub fn tier_mut(&mut self, kind: TierKind) -> &mut TierState {
        &mut self.tiers[kind.index()]
    }

    /// Map every page of `obj`, asking the placer tier by tier. If the
    /// chosen tier is full the page falls back to the other tier (DRAM
    /// overflow goes to CXL — the whole point of the capacity tier; CXL
    /// "overflow" cannot happen at simulated capacities but is handled).
    pub fn map_object(&mut self, obj: &MemoryObject, placer: &mut dyn PagePlacer) {
        let first = self.pages.page_of(obj.start);
        let last = self.pages.page_of(obj.end().saturating_sub(1));
        let n_pages = (last.index - first.index + 1) as u64;
        debug_assert_eq!(first.segment, last.segment);
        for i in 0..n_pages {
            let p = PageNo { segment: first.segment, index: first.index + i as u32 };
            // shared pages (brk heap packs small objects) keep their tier
            if self.pages.get(p).is_mapped() {
                continue;
            }
            let mut kind = placer.place(obj, i, self);
            if self.tier(kind).free_bytes() < self.page_bytes {
                kind = kind.other();
            }
            self.map_page(p, kind);
        }
    }

    fn map_page(&mut self, p: PageNo, kind: TierKind) {
        debug_assert!(self.pages.tier_of(p).is_none());
        self.pages.set_tier(p, kind);
        self.tiers[kind.index()].used_bytes += self.page_bytes;
    }

    /// Unmap the pages of a freed object (pages shared with live objects
    /// are kept: the heap segment packs small allocations).
    pub fn unmap_object(&mut self, obj: &MemoryObject, page_is_shared: impl Fn(PageNo) -> bool) {
        let first = self.pages.page_of(obj.start);
        let last = self.pages.page_of(obj.end().saturating_sub(1));
        for idx in first.index..=last.index {
            let p = PageNo { segment: first.segment, index: idx };
            if page_is_shared(p) {
                continue;
            }
            if let Some(kind) = self.pages.tier_of(p) {
                self.pages.unmap(p);
                self.tiers[kind.index()].used_bytes -= self.page_bytes;
            }
        }
    }

    /// Move one page between tiers. Returns false — leaving occupancy,
    /// free bytes, and the promotion/demotion counters strictly
    /// untouched — when the move is degenerate (`from == to`), the page
    /// is not currently mapped in `from`, or the target tier is full.
    /// Every accepted move bumps exactly one counter: promotions for
    /// CXL→DRAM, demotions for DRAM→CXL (symmetric accounting).
    pub fn migrate(&mut self, m: Migration) -> bool {
        if m.from == m.to {
            return false;
        }
        // validate via the read-only lookup: a rejected migration must
        // not even grow the page table
        if self.pages.tier_of(m.page) != Some(m.from) {
            return false;
        }
        if self.tier(m.to).free_bytes() < self.page_bytes {
            return false;
        }
        self.pages.set_tier(m.page, m.to);
        self.tiers[m.from.index()].used_bytes -= self.page_bytes;
        self.tiers[m.to.index()].used_bytes += self.page_bytes;
        match (m.from, m.to) {
            (TierKind::Cxl, TierKind::Dram) => self.promotions += 1,
            (TierKind::Dram, TierKind::Cxl) => self.demotions += 1,
            _ => unreachable!("from == to rejected above"),
        }
        true
    }

    /// Bytes resident per tier, for reports.
    pub fn used(&self, kind: TierKind) -> u64 {
        self.tier(kind).used_bytes
    }

    /// Reset per-window page counters (called at aggregation ticks).
    /// Delegates to the page table's linear column sweep.
    pub fn end_window(&mut self) {
        self.pages.end_window();
    }
}

/// Trivial placers used across tests and as Fig. 2 endpoints.
pub struct FixedPlacer {
    pub kind: TierKind,
}

impl PagePlacer for FixedPlacer {
    fn place(&mut self, _obj: &MemoryObject, _page_idx: u64, _mem: &TieredMemory) -> TierKind {
        self.kind
    }

    fn name(&self) -> &str {
        match self.kind {
            TierKind::Dram => "all-dram",
            TierKind::Cxl => "all-cxl",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::object::{MemoryObject, ObjectId};

    fn small_cfg() -> MachineConfig {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = 16 * 4096; // 16 pages of DRAM
        cfg.cxl_bytes = 1024 * 4096;
        cfg
    }

    fn obj(id: u32, start: u64, bytes: u64) -> MemoryObject {
        MemoryObject {
            id: ObjectId(id),
            start,
            bytes,
            site: "t".into(),
            seq: id as u64,
            via_mmap: true,
        }
    }

    #[test]
    fn map_object_places_all_pages() {
        let mut mem = TieredMemory::new(&small_cfg());
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 10 * 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        assert_eq!(mem.used(TierKind::Dram), 10 * 4096);
        assert_eq!(mem.pages.mapped_count(), 10);
    }

    #[test]
    fn dram_overflow_falls_to_cxl() {
        let mut mem = TieredMemory::new(&small_cfg());
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 32 * 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        assert_eq!(mem.used(TierKind::Dram), 16 * 4096); // capacity
        assert_eq!(mem.used(TierKind::Cxl), 16 * 4096); // overflow
    }

    #[test]
    fn migrate_moves_accounting() {
        let mut mem = TieredMemory::new(&small_cfg());
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Cxl });
        let p = mem.pages.page_of(o.start);
        assert!(mem.migrate(Migration { page: p, from: TierKind::Cxl, to: TierKind::Dram }));
        assert_eq!(mem.used(TierKind::Dram), 4096);
        assert_eq!(mem.used(TierKind::Cxl), 0);
        assert_eq!(mem.promotions, 1);
        // wrong 'from' tier is rejected
        assert!(!mem.migrate(Migration { page: p, from: TierKind::Cxl, to: TierKind::Dram }));
    }

    #[test]
    fn migrate_rejected_when_full() {
        let mut cfg = small_cfg();
        cfg.dram_bytes = 4096; // one page
        let mut mem = TieredMemory::new(&cfg);
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 2 * 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        // page 0 in DRAM (full), page 1 overflowed to CXL
        let p0 = mem.pages.page_of(o.start);
        let p1 = PageNo { index: p0.index + 1, ..p0 };
        assert!(!mem.migrate(Migration { page: p1, from: TierKind::Cxl, to: TierKind::Dram }));
    }

    #[test]
    fn rejected_migrations_touch_nothing() {
        let mut mem = TieredMemory::new(&small_cfg());
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 2 * 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        let p = mem.pages.page_of(o.start);
        let snapshot = |m: &TieredMemory| {
            (
                m.used(TierKind::Dram),
                m.used(TierKind::Cxl),
                m.promotions,
                m.demotions,
                m.pages.mapped_count(),
            )
        };
        let before = snapshot(&mem);
        // same-tier "move"
        assert!(!mem.migrate(Migration { page: p, from: TierKind::Dram, to: TierKind::Dram }));
        // wrong source tier
        assert!(!mem.migrate(Migration { page: p, from: TierKind::Cxl, to: TierKind::Dram }));
        // unmapped page far past the object (must not grow the table)
        let far = PageNo { index: p.index + 10_000, ..p };
        assert!(!mem.migrate(Migration { page: far, from: TierKind::Dram, to: TierKind::Cxl }));
        assert_eq!(snapshot(&mem), before, "rejected migrations must leave all accounting intact");
    }

    #[test]
    fn demotion_counted_symmetrically() {
        let mut mem = TieredMemory::new(&small_cfg());
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        let p = mem.pages.page_of(o.start);
        assert!(mem.migrate(Migration { page: p, from: TierKind::Dram, to: TierKind::Cxl }));
        assert_eq!((mem.promotions, mem.demotions), (0, 1));
        assert!(mem.migrate(Migration { page: p, from: TierKind::Cxl, to: TierKind::Dram }));
        assert_eq!((mem.promotions, mem.demotions), (1, 1));
    }

    #[test]
    fn unmap_returns_capacity() {
        let mut mem = TieredMemory::new(&small_cfg());
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 8 * 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        mem.unmap_object(&o, |_| false);
        assert_eq!(mem.used(TierKind::Dram), 0);
        assert_eq!(mem.pages.mapped_count(), 0);
    }

    #[test]
    fn shared_heap_page_not_double_mapped() {
        let mut mem = TieredMemory::new(&small_cfg());
        // two small objects in the same heap page
        let a = obj(1, crate::shim::intercept::HEAP_BASE, 64);
        let b = obj(2, crate::shim::intercept::HEAP_BASE + 64, 64);
        mem.map_object(&a, &mut FixedPlacer { kind: TierKind::Dram });
        mem.map_object(&b, &mut FixedPlacer { kind: TierKind::Cxl });
        // page stays in DRAM (first mapping wins), accounted once
        assert_eq!(mem.used(TierKind::Dram), 4096);
        assert_eq!(mem.used(TierKind::Cxl), 0);
    }

    #[test]
    fn end_window_resets_counters() {
        let mut mem = TieredMemory::new(&small_cfg());
        let o = obj(1, crate::shim::intercept::MMAP_BASE, 4096);
        mem.map_object(&o, &mut FixedPlacer { kind: TierKind::Dram });
        let p = mem.pages.page_of(o.start);
        mem.pages.touch(p);
        assert_eq!(mem.pages.get(p).window_accesses, 1);
        mem.end_window();
        assert_eq!(mem.pages.get(p).window_accesses, 0);
        assert_eq!(mem.pages.get(p).total_accesses, 1);
        assert_eq!(mem.pages.get(p).idle_ticks, 1);
    }
}
