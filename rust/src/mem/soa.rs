//! Generic dense per-page side tables for the struct-of-arrays hot path.
//!
//! `PageCol<T>` replaces `HashMap<PageNo, T>` in the migration layer: two
//! flat `Vec<T>` columns (one per address segment, mirroring `PageMap`'s
//! layout) indexed by dense page id, with a default fill value standing in
//! for "absent". Lookups are O(1) with no hashing, per-epoch maintenance
//! becomes a linear sweep over contiguous memory, and iteration order is
//! page order — deterministic by construction, which the sharded cluster
//! merge depends on.

use crate::mem::page::{PageNo, Segment};

#[derive(Debug, Clone)]
pub struct PageCol<T: Copy> {
    default: T,
    heap: Vec<T>,
    mmap: Vec<T>,
}

impl<T: Copy> PageCol<T> {
    pub fn new(default: T) -> PageCol<T> {
        PageCol { default, heap: Vec::new(), mmap: Vec::new() }
    }

    #[inline]
    fn seg(&self, s: Segment) -> &[T] {
        match s {
            Segment::Heap => &self.heap,
            Segment::Mmap => &self.mmap,
        }
    }

    #[inline]
    fn seg_mut(&mut self, s: Segment) -> &mut Vec<T> {
        match s {
            Segment::Heap => &mut self.heap,
            Segment::Mmap => &mut self.mmap,
        }
    }

    /// Read a slot; unmaterialized slots read as the default.
    #[inline]
    pub fn get(&self, p: PageNo) -> T {
        self.seg(p.segment).get(p.index as usize).copied().unwrap_or(self.default)
    }

    /// Write a slot, growing the column (default-filled) as needed.
    #[inline]
    pub fn set(&mut self, p: PageNo, v: T) {
        let default = self.default;
        let seg = self.seg_mut(p.segment);
        let idx = p.index as usize;
        if idx >= seg.len() {
            seg.resize(idx + 1, default);
        }
        seg[idx] = v;
    }

    /// Drop all materialized slots (every page reads as default again).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.mmap.clear();
    }

    /// Linear pass over every materialized slot, page order (heap then
    /// mmap, ascending index).
    pub fn values_mut(&mut self) -> impl Iterator<Item = &mut T> {
        self.heap.iter_mut().chain(self.mmap.iter_mut())
    }

    /// Materialized slots with their page numbers, page order.
    pub fn iter(&self) -> impl Iterator<Item = (PageNo, T)> + '_ {
        let heap = self
            .heap
            .iter()
            .enumerate()
            .map(|(i, v)| (PageNo { segment: Segment::Heap, index: i as u32 }, *v));
        let mmap = self
            .mmap
            .iter()
            .enumerate()
            .map(|(i, v)| (PageNo { segment: Segment::Mmap, index: i as u32 }, *v));
        heap.chain(mmap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page(i: u32) -> PageNo {
        PageNo { segment: Segment::Mmap, index: i }
    }

    #[test]
    fn absent_slots_read_default() {
        let col: PageCol<u64> = PageCol::new(u64::MAX);
        assert_eq!(col.get(page(0)), u64::MAX);
        assert_eq!(col.get(page(1_000_000)), u64::MAX);
    }

    #[test]
    fn set_grows_and_backfills_default() {
        let mut col: PageCol<u64> = PageCol::new(u64::MAX);
        col.set(page(4), 7);
        assert_eq!(col.get(page(4)), 7);
        // Slots materialized by the grow still read as default.
        assert_eq!(col.get(page(2)), u64::MAX);
        // Heap segment untouched by an mmap write.
        assert_eq!(col.get(PageNo { segment: Segment::Heap, index: 4 }), u64::MAX);
    }

    #[test]
    fn clear_resets_everything() {
        let mut col: PageCol<u32> = PageCol::new(0);
        col.set(page(3), 9);
        col.clear();
        assert_eq!(col.get(page(3)), 0);
        assert_eq!(col.iter().count(), 0);
    }

    #[test]
    fn iter_is_page_ordered() {
        let mut col: PageCol<u32> = PageCol::new(0);
        col.set(page(5), 50);
        col.set(PageNo { segment: Segment::Heap, index: 2 }, 20);
        let pages: Vec<PageNo> = col.iter().map(|(p, _)| p).collect();
        let mut sorted = pages.clone();
        sorted.sort();
        assert_eq!(pages, sorted, "iteration must follow page order");
    }
}
